from repro.optim.optimizers import (Optimizer, adamw, apply_updates,  # noqa: F401
                                    cosine_schedule, sgd)
