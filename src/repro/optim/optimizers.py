"""Minimal optimizer library (optax is not available offline).

The paper's algorithm is plain SGD (Eq. 3/6); AdamW exists for the
"pretraining" phase that stands in for the foundation-model checkpoint we
cannot download, and as a beyond-paper server-optimizer option.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class Optimizer(NamedTuple):
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree], tuple[PyTree, PyTree]]
    # update(grads, state, params) -> (updates, new_state); apply as p + u


def sgd(lr: float, momentum: float = 0.0) -> Optimizer:
    def init(params):
        if momentum:
            return {"mu": jax.tree.map(jnp.zeros_like, params)}
        return {}

    def update(grads, state, params=None):
        if momentum:
            mu = jax.tree.map(lambda m, g: momentum * m + g.astype(m.dtype),
                              state["mu"], grads)
            return jax.tree.map(lambda m: -lr * m, mu), {"mu": mu}
        return jax.tree.map(lambda g: -lr * g, grads), state

    return Optimizer(init, update)


def adamw(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return {"m": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
                "v": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
                "t": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        t = state["t"] + 1
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2)
                         * jnp.square(g.astype(jnp.float32)), state["v"], grads)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)

        def u(m_, v_, p):
            step = m_ / bc1 / (jnp.sqrt(v_ / bc2) + eps)
            if weight_decay:
                step = step + weight_decay * p.astype(jnp.float32)
            return (-lr * step).astype(p.dtype)

        return (jax.tree.map(u, m, v, params),
                {"m": m, "v": v, "t": t})

    return Optimizer(init, update)


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)


def cosine_schedule(base_lr: float, total_steps: int, warmup: int = 0):
    def lr_at(step):
        step = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        prog = jnp.clip((step - warmup) / jnp.maximum(total_steps - warmup, 1), 0, 1)
        return base_lr * warm * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return lr_at
