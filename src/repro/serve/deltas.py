"""Per-user sparse selected-layer deltas: records, export, and the store.

The paper's per-client artifact is exactly ``{(layer_idx, Δ_layer)}`` — a
client fine-tunes the layers its mask selects and everything else stays at
the base parameters (§B.2 freezes embed/head/norms).  A
:class:`DeltaRecord` holds those rows, keyed by the global mask index order
of :func:`repro.models.model.layer_layout`; a :class:`DeltaStore` maps
user ids to records and can materialise a user's private full-parameter
copy (the dense serving baseline and the serving parity oracle) via
:func:`repro.core.aggregation.apply_delta_rows`.

Export paths:

* :func:`delta_from_params` — diff a tuned tree against base on selected
  (or auto-detected) layers;
* :func:`repro.ckpt.checkpoint.extract_delta` — the same diff against a
  saved FL round checkpoint.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterable, Optional

import jax
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.aggregation import apply_delta_rows
from repro.models.model import layer_layout


def mask_index_map(cfg: ArchConfig) -> list[tuple[str, int]]:
    """Global mask index → (segment path, local row), in mask-index order."""
    out = []
    for seg in layer_layout(cfg):
        out.extend((seg.path, r) for r in range(seg.count))
    return out


@dataclass
class DeltaRecord:
    """One user's sparse selected-layer delta.

    ``layers``: (k,) sorted global mask indices; ``segments``: per segment
    path, the (k_path,) local row indices plus ``{leaf_name: (k_path, …)}``
    delta rows (host numpy, f32).
    """
    layers: np.ndarray
    segments: dict[str, tuple[np.ndarray, dict[str, np.ndarray]]] = \
        field(default_factory=dict)

    @property
    def n_layers(self) -> int:
        return int(self.layers.size)

    @property
    def nbytes(self) -> int:
        return sum(leaf.nbytes for _, leaves in self.segments.values()
                   for leaf in leaves.values())

    def rows(self) -> dict[str, np.ndarray]:
        return {path: rows for path, (rows, _) in self.segments.items()}

    def leaves(self) -> dict[str, dict[str, np.ndarray]]:
        return {path: leaves for path, (_, leaves) in self.segments.items()}


def delta_from_params(base, tuned, cfg: ArchConfig,
                      layers: Optional[Iterable[int]] = None,
                      atol: float = 0.0) -> DeltaRecord:
    """Diff ``tuned`` against ``base`` into a sparse :class:`DeltaRecord`.

    ``layers``: global mask indices to export; ``None`` auto-detects the
    rows where any leaf moved by more than ``atol`` (an FL client's selected
    layers are exactly the rows its masked update touched).
    """
    idx_map = mask_index_map(cfg)
    if layers is None:
        layers = []
        for gi, (path, row) in enumerate(idx_map):
            moved = any(
                np.max(np.abs(np.asarray(t[row], np.float32)
                              - np.asarray(b[row], np.float32)), initial=0.0)
                > atol
                for b, t in zip(jax.tree.leaves(base[path]),
                                jax.tree.leaves(tuned[path])))
            if moved:
                layers.append(gi)
    layers = np.asarray(sorted(int(l) for l in layers), np.int32)

    segments: dict[str, tuple[np.ndarray, dict[str, np.ndarray]]] = {}
    for gi in layers:
        path, row = idx_map[gi]
        rows, leaves = segments.setdefault(path, ([], {}))
        rows.append(row)
    out = {}
    for path, (rows, _) in segments.items():
        idx = np.asarray(rows, np.int32)
        leaves = {
            name: np.asarray(tuned[path][name], np.float32)[idx]
            - np.asarray(base[path][name], np.float32)[idx]
            for name in base[path]}
        out[path] = (idx, leaves)
    return DeltaRecord(layers=layers, segments=out)


class DeltaStore:
    """user id → :class:`DeltaRecord`; the FL-output side of serving."""

    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self._records: dict[int, DeltaRecord] = {}

    def put(self, user_id: int, record: DeltaRecord) -> None:
        self._records[int(user_id)] = record

    def put_from_params(self, user_id: int, base, tuned,
                        layers: Optional[Iterable[int]] = None,
                        atol: float = 0.0) -> DeltaRecord:
        rec = delta_from_params(base, tuned, self.cfg, layers=layers,
                                atol=atol)
        self.put(user_id, rec)
        return rec

    def get(self, user_id: int) -> Optional[DeltaRecord]:
        return self._records.get(int(user_id))  # repro: allow[host-sync] -- host int user id, no device value

    def users(self) -> list[int]:
        return sorted(self._records)

    def __contains__(self, user_id: int) -> bool:
        return int(user_id) in self._records

    def __len__(self) -> int:
        return len(self._records)

    @property
    def nbytes(self) -> int:
        return sum(r.nbytes for r in self._records.values())

    def materialize(self, params, user_id: int):
        """The user's private full-parameter copy (base + their delta rows).

        This is what dense per-user serving has to build per request — and
        the oracle the batched delta path is tested against.
        """
        rec = self._records.get(int(user_id))  # repro: allow[host-sync] -- host int user id, no device value
        if rec is None:
            return params
        return apply_delta_rows(params, rec.rows(), rec.leaves())
