"""Device-side machinery for personalized-delta serving (DESIGN.md §9).

Two pieces:

* :class:`DeltaOverlay` — the capacity-C per-layer delta entry table the
  fused decode consumes.  Device state is ``{"slots": (L, C) int32 owner
  slot ids (-1 = free), "leaves": {name: (L, C, *shape)}}``; a host-side
  ``slot_ids`` mirror makes admit/release pure bookkeeping.  Admitting a
  user uploads only *their* delta rows (donated in-place entry writes);
  releasing a slot just marks entries free — stale leaf rows are masked
  by the -1 owner id inside the kernel, so eviction is O(1) host work.

* :func:`serve_suite` — the jitted decode programs, registered in the
  same cache as the training suites (``core.client._JIT_CACHE``) so
  ``jit_cache_stats()["programs"]`` pins their counts: ONE program serves
  every mix of per-slot deltas (the overlay is data, not structure).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.client import _JIT_CACHE, _JIT_STATS
from repro.models.model import Model, _block_shapes, supports_delta_decode
from repro.serve.deltas import DeltaRecord


def _write_entry(leaves: dict, l, c, rows: dict) -> dict:
    """Set entry (l, c) of every overlay leaf to the user's delta row.

    Jitted with the leaf dict donated: one compiled program per overlay
    shape, and each admit transfers only the (k,)-layer delta rows —
    never the (L, C, …) table.
    """
    return {name: leaf.at[l, c].set(rows[name].astype(leaf.dtype))
            for name, leaf in leaves.items()}


class DeltaOverlay:
    """Capacity-C per-layer delta entries over the scanned ``blocks`` stack.

    ``injector`` (a ``repro.faults.FaultInjector``, optional) makes entry
    uploads failable: each write retries up to ``max_upload_retries``
    times on :class:`TransientFault`; a permanently failed upload rolls
    the already-written entries back (owner ids cleared — the kernel masks
    the stale rows) and the admit reports False with
    ``stats["failed_admits"]`` bumped, so a fault never leaves a
    half-admitted user visible to the decode."""

    def __init__(self, model: Model, capacity: int, *,
                 injector=None, max_upload_retries: int = 3):
        if not supports_delta_decode(model.cfg):
            raise ValueError(
                f"family {model.cfg.family!r} has no delta-decode path")
        shapes = _block_shapes(model.cfg, "dense")   # per-layer leaf shapes
        L = model.cfg.n_layers
        self.capacity = int(capacity)
        self.leaves = {
            name: jnp.zeros((L, self.capacity) + tuple(shp), jnp.float32)
            for name, shp in shapes.items()}
        self.slot_ids = np.full((L, self.capacity), -1, np.int32)
        self.entries: dict[int, list[tuple[int, int]]] = {}
        self._slots_dev = jnp.asarray(self.slot_ids)
        self._dirty = False
        self._write = jax.jit(_write_entry, donate_argnums=0)
        self.injector = injector
        self.max_upload_retries = int(max_upload_retries)
        self.stats = {"upload_retries": 0, "failed_admits": 0}
        self._upload_seq = 0     # monotone entry-write counter (fault lane)

    @property
    def n_entries(self) -> int:
        return int((self.slot_ids >= 0).sum())

    def try_admit(self, slot: int, record: Optional[DeltaRecord]) -> bool:
        """Claim one entry per selected layer for ``slot`` and upload the
        delta rows.  Returns False (writing nothing) if any layer's
        capacity is exhausted — the caller keeps the request queued."""
        self.release(slot)
        if record is None or record.n_layers == 0:
            self.entries[slot] = []
            return True
        extra = set(record.segments) - {"blocks"}
        if extra:
            raise ValueError(
                f"delta overlay only serves the scanned 'blocks' stack, "
                f"record touches {sorted(extra)}")
        rows_idx, leaves = record.segments["blocks"]
        plan = []
        taken: dict[int, int] = {}
        # repro: allow[host-sync] -- admission control runs at delta-publish time on the host row index, not per decode step
        for l in np.asarray(rows_idx, np.int32):
            li = int(l)  # repro: allow[host-sync] -- host np row index (admission time)
            free = np.nonzero(self.slot_ids[li] < 0)[0]
            free = free[taken.get(li, 0):]
            if free.size == 0:
                return False
            taken[li] = taken.get(li, 0) + 1
            plan.append((li, int(free[0])))  # repro: allow[host-sync] -- host np slot bookkeeping (admission time)
        ent = []
        for j, (li, c) in enumerate(plan):
            if not self._upload_entry(j, li, c, leaves):
                # permanent upload failure: roll back this admit's already-
                # written entries (owner -1 masks the stale leaf rows —
                # same O(1) trick as release) so no partial user is visible
                for rli, rc in ent:
                    self.slot_ids[rli, rc] = -1
                self.entries[slot] = []
                self._dirty = True
                self.stats["failed_admits"] += 1
                return False
            self.slot_ids[li, c] = slot
            ent.append((li, c))
        self.entries[slot] = ent
        self._dirty = True
        return True

    def _upload_entry(self, j: int, li: int, c: int, leaves: dict) -> bool:
        """One entry write with bounded fault retry.  The injected failure
        fires BEFORE the donating write, so a failed attempt consumes no
        buffer and the retry re-reads intact overlay leaves."""
        from repro.faults.injector import TransientFault
        attempt = 0
        while True:
            seq = self._upload_seq
            self._upload_seq += 1
            try:
                if self.injector is not None and self.injector.enabled:
                    self.injector.maybe_fail_upload(seq)
            except TransientFault:
                attempt += 1
                if attempt > self.max_upload_retries:
                    return False
                self.stats["upload_retries"] += 1
                continue
            rows = {name: jnp.asarray(leaves[name][j])
                    for name in self.leaves}
            self.leaves = self._write(self.leaves, jnp.int32(li),
                                      jnp.int32(c), rows)
            return True

    def release(self, slot: int) -> None:
        for li, c in self.entries.pop(slot, []):
            self.slot_ids[li, c] = -1
            self._dirty = True

    def device(self) -> dict:
        """The ``delta`` argument for :meth:`Model.decode_step`."""
        if self._dirty:
            self._slots_dev = jnp.asarray(self.slot_ids)
            self._dirty = False
        return {"slots": self._slots_dev, "leaves": self.leaves}


def serve_suite(model: Model) -> dict:
    """Jitted serving programs, cached like the Client suites so
    ``jit_cache_stats()`` counts their traces.

    One trace per entry regardless of which users' deltas are resident:
    ``serve_decode`` (shared base), ``serve_decode_delta`` (base + overlay),
    ``serve_decode_dense`` (vmapped per-slot private params — the dense
    baseline), ``serve_reset_slot``, ``serve_write_params`` (dense refill).
    """
    key = (None if getattr(model, "custom_shard", False)
           else (model.cfg, model.runtime, "serve"))
    suite = _JIT_CACHE.get(key) if key is not None else None
    if suite is not None:
        _JIT_STATS["hits"] += 1
        return suite

    def _decode(params, tokens, pos, cache, window):
        return model.decode_step(params, tokens, pos, cache, window=window)

    def _decode_delta(params, tokens, pos, cache, delta, window):
        return model.decode_step(params, tokens, pos, cache, window=window,
                                 delta=delta)

    def _decode_dense(stacked, tokens, pos, cache, window):
        def one(p, tok, ps, kv):
            logits, nkv = model.decode_step(p, tok[None], ps[None], kv,
                                            window=window)
            return logits[0], nkv
        return jax.vmap(one)(stacked, tokens, pos, cache)

    def _write_params(stacked, p, b):
        return jax.tree.map(lambda s, x: s.at[b].set(x.astype(s.dtype)),
                            stacked, p)

    # decode entries never donate: the base params (and the dense bank)
    # are shared state serving EVERY slot across steps, and the parity
    # oracles replay one cache snapshot through shared/delta/dense
    # programs — the donated paths are the write programs below, whose
    # aliasing the program auditor verifies (donation-honored contract)
    suite = {
        "serve_decode": jax.jit(_decode, static_argnums=(4,)),  # repro: allow[donation-miss] -- shared base params + replayed cache snapshots outlive the call
        "serve_decode_delta": jax.jit(_decode_delta, static_argnums=(5,)),  # repro: allow[donation-miss] -- shared base params + replayed cache snapshots outlive the call
        "serve_decode_dense": jax.jit(_decode_dense, static_argnums=(4,)),  # repro: allow[donation-miss] -- the stacked bank is reused across decode steps; only refills rewrite it
        "serve_reset_slot": jax.jit(model.reset_slot,
                                    static_argnames=("stacked",)),
        "serve_write_params": jax.jit(_write_params, donate_argnums=0),
    }
    if key is None:
        _JIT_STATS["uncached"] += 1
    else:
        _JIT_CACHE[key] = suite
        _JIT_STATS["misses"] += 1
    return suite


# -- program-auditor enumeration hook ---------------------------------------

def serve_program_specs(model: Model, *, slots: int = 3, capacity: int = 2,
                        capacities: tuple = (1, 2, 3), max_seq: int = 16,
                        window: int = 0) -> list[dict]:
    """Shape-only audit specs for every serving program family.

    Covers shared decode, the delta decode at batch ``slots`` AND
    ``2·slots`` for each overlay capacity in ``capacities`` (the auditor's
    B-independence / C-linearity contract reads these), the dense vmapped
    baseline at both batches (its weight traffic MUST scale with B — the
    contrast that makes the delta contract meaningful), and the two donated
    writes (overlay entry write, dense bank refill).  Plain dicts; nothing
    allocates.
    """
    from repro.models.model import init_params
    suite = serve_suite(model)
    cfg = model.cfg
    SDS = jax.ShapeDtypeStruct
    params = jax.eval_shape(lambda k: init_params(cfg, k),
                            SDS((2,), jnp.uint32))
    L = cfg.n_layers

    def cache_for(b):
        return jax.eval_shape(lambda: model.init_cache(
            b, max_seq, window=window, per_slot=True))

    def toks_pos(b):
        return SDS((b,), jnp.int32), SDS((b,), jnp.int32)

    base = dict(static_argnums=(), donate_argnums=(), weight_argnums=(0,))
    common = {"single_host": True, "dtype": cfg.dtype}
    specs = []
    for b in (slots, 2 * slots):
        tok, pos = toks_pos(b)
        specs.append(dict(
            base, name=f"serve_decode/B{b}", fn=suite["serve_decode"],
            args=(params, tok, pos, cache_for(b), window),
            static_argnums=(4,),
            meta=dict(common, kind="serve_decode", batch=b)))
    if supports_delta_decode(cfg):
        shapes = _block_shapes(cfg, "dense")
        for b in (slots, 2 * slots):
            tok, pos = toks_pos(b)
            for C in capacities:
                delta = {
                    "slots": SDS((L, C), jnp.int32),
                    "leaves": {name: SDS((L, C) + tuple(shp), jnp.float32)
                               for name, shp in shapes.items()}}
                specs.append(dict(
                    base, name=f"serve_decode_delta/B{b}/C{C}",
                    fn=suite["serve_decode_delta"],
                    args=(params, tok, pos, cache_for(b), delta, window),
                    static_argnums=(5,), weight_argnums=(0, 4),
                    meta=dict(common, kind="serve_decode_delta", batch=b,
                              capacity=C)))
        leaves = {name: SDS((L, capacity) + tuple(shp), jnp.float32)
                  for name, shp in shapes.items()}
        rows = {name: SDS(tuple(shp), jnp.float32)
                for name, shp in shapes.items()}
        specs.append(dict(
            base, name="serve_write_delta_entry",
            fn=jax.jit(_write_entry, donate_argnums=0),
            args=(leaves, SDS((), jnp.int32), SDS((), jnp.int32), rows),
            donate_argnums=(0,),
            meta=dict(common, kind="delta_write", donates=True)))
    for b in (slots, 2 * slots):
        tok, pos = toks_pos(b)
        stacked = jax.eval_shape(lambda t: stack_tree(t, b), params)
        dense_cache = jax.eval_shape(lambda: stack_tree(
            model.init_cache(1, max_seq, window=window, per_slot=True), b))
        specs.append(dict(
            base, name=f"serve_decode_dense/B{b}",
            fn=suite["serve_decode_dense"],
            args=(stacked, tok, pos, dense_cache, window),
            static_argnums=(4,),
            meta=dict(common, kind="serve_decode_dense", batch=b)))
    stacked = jax.eval_shape(lambda t: stack_tree(t, slots), params)
    specs.append(dict(
        base, name="serve_write_params", fn=suite["serve_write_params"],
        args=(stacked, params, SDS((), jnp.int32)),
        donate_argnums=(0,), weight_argnums=(0, 1),
        meta=dict(common, kind="dense_write", donates=True)))
    return specs


def stack_tree(tree, n: int):
    """n identical copies along a new leading axis (dense-baseline layout)."""
    return jax.tree.map(lambda x: jnp.repeat(jnp.asarray(x)[None], n, axis=0),
                        tree)
