from repro.serve.deltas import (DeltaRecord, DeltaStore,  # noqa: F401
                                delta_from_params, mask_index_map)
from repro.serve.engine import (DeltaOverlay, serve_suite,  # noqa: F401
                                stack_tree)
