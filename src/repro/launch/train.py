"""Distributed FL training driver (executes the fl_step on a real mesh).

On the container this runs on a small host mesh (set
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` yourself for a 4×2
mesh); on a TPU pod the same code runs on ``make_production_mesh()``.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --layers 4 --d-model 128 --rounds 20 --data-axis 4 --model-axis 2
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.api.strategy import SelectionContext, get_strategy
from repro.configs.base import RuntimeConfig, get_arch, reduced
from repro.core.strategies import ProbeReport
from repro.data.synthetic import FederatedTaskConfig, SyntheticFederatedData
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models.model import Model
from repro.sharding.fl_step import make_fl_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--per-client-batch", type=int, default=4)
    ap.add_argument("--strategy", default="ours_unified",
                    help="any registered strategy name (repro.api)")
    ap.add_argument("--budget", type=int, default=2)
    ap.add_argument("--lam", type=float, default=10.0)
    ap.add_argument("--lr", type=float, default=0.01)
    ap.add_argument("--data-axis", type=int, default=0,
                    help="0 = use the production mesh (dry-run scale)")
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--production", action="store_true")
    args = ap.parse_args()

    # resolve the strategy up front: unknown names fail fast with the
    # registered list + nearest-match suggestion
    strategy = get_strategy(args.strategy)

    if args.production:
        mesh = make_production_mesh()
        cfg = get_arch(args.arch)
    else:
        d = args.data_axis or max(len(jax.devices()) // args.model_axis, 1)
        mesh = make_host_mesh(d, args.model_axis)
        cfg = reduced(get_arch(args.arch), n_layers=args.layers,
                      d_model=args.d_model)
    model = Model(cfg, RuntimeConfig(remat=False, seq_chunk=max(args.seq, 16)))
    clients = int(np.prod([mesh.shape[a] for a in mesh.axis_names
                           if a in ("pod", "data")]))
    print(f"mesh={dict(mesh.shape)} cohort={clients} arch={cfg.name}")

    params = model.init(jax.random.PRNGKey(0))
    build = make_fl_train_step(model, mesh, zero3=True)
    step_fn, specs = build(jax.eval_shape(lambda: params))
    params = jax.device_put(params, jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P)))

    data = SyntheticFederatedData(FederatedTaskConfig(
        n_clients=clients, vocab_size=cfg.vocab_size, seq_len=args.seq,
        objective="lm", skew="feature"))
    L = model.n_selectable
    sizes = jnp.asarray(data.sizes[:clients].astype(np.float32))

    # selection probe runs on the simulator path (cheap, L floats/client)
    from repro.core.client import Client
    probe_client = Client(Model(cfg, RuntimeConfig(remat=False,
                                                   seq_chunk=max(args.seq, 16))))

    # the strategy's declared probe requirements trim the per-client probe
    reqs = tuple(k for k in ProbeReport.KEYS
                 if k in strategy.probe_requirements)

    for t in range(args.rounds):
        t0 = time.time()  # repro: allow[nondeterminism] -- round wall-clock telemetry only
        host_params = jax.device_get(params)
        if reqs:
            rows = [probe_client.probe(host_params, data.client_batch(i, 4),
                                       reqs)
                    for i in range(clients)]
            probe = ProbeReport.from_rows(rows)
        else:
            probe = ProbeReport(grad_sq_norms=np.zeros((clients, L)))
        ctx = SelectionContext(client_ids=np.arange(clients), round=t,
                               lam=args.lam, n_layers=L)
        masks = jnp.asarray(strategy.select(probe, args.budget, ctx))

        batch_np = np.stack([
            data.client_batch(i, args.per_client_batch)["tokens"]
            for i in range(clients)])
        batch = {"tokens": jnp.asarray(batch_np)}
        params, metrics = step_fn(params, batch, masks, sizes,
                                  jnp.float32(args.lr))
        print(f"[round {t:3d}] loss={float(metrics['loss']):.4f} "
              f"union={float(metrics['union_frac']):.2f} "
              f"({time.time() - t0:.2f}s)")  # repro: allow[nondeterminism] -- round wall-clock telemetry only


if __name__ == "__main__":
    main()
