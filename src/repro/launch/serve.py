"""Batched serving driver: slot-based continuous batching over decode_step.

A minimal production-shaped server loop: a fixed pool of B slots, each
holding one request; finished slots are refilled from the queue without
stalling the running batch (the KV cache is slot-indexed, so refills just
reset that slot's entries via position masking).

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --slots 4 --requests 10 --max-new 16
"""
from __future__ import annotations

import argparse
import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RuntimeConfig, get_arch, reduced
from repro.models.model import Model


@dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int
    generated: list[int] = field(default_factory=list)

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new


class SlotServer:
    """B decode slots over a single jitted decode_step."""

    def __init__(self, model: Model, params, slots: int, max_seq: int,
                 window: int = 0):
        self.model = model
        self.params = params
        self.slots = slots
        self.max_seq = max_seq
        self.window = window
        self.cache = model.init_cache(slots, max_seq, window=window)
        self.active: list[Request | None] = [None] * slots
        self.pos = np.zeros(slots, np.int32)        # per-slot position
        self._step = jax.jit(
            lambda p, tok, pos, c: model.decode_step(p, tok, pos, c,
                                                     window=window))

    def _admit(self, queue: list[Request]):
        for i in range(self.slots):
            if self.active[i] is None and queue:
                self.active[i] = queue.pop(0)
                self.pos[i] = 0

    def run(self, requests: list[Request], verbose: bool = False):
        queue = list(requests)
        done: list[Request] = []
        steps = 0
        t0 = time.time()
        while queue or any(r is not None for r in self.active):
            self._admit(queue)
            toks = np.zeros(self.slots, np.int32)
            for i, r in enumerate(self.active):
                if r is None:
                    continue
                p = int(self.pos[i])
                toks[i] = (r.prompt[p] if p < len(r.prompt)
                           else r.generated[-1])
            # NOTE: the batch shares one position scalar per step; slots are
            # aligned by admitting at pos 0 (slot-synchronous batching). A
            # fully position-independent cache is a straightforward extension
            # (per-slot pos vector into the cache update).
            pos = jnp.int32(int(self.pos.max(initial=0)))
            logits, self.cache = self._step(self.params, jnp.asarray(toks),
                                            pos, self.cache)
            nxt = np.asarray(jnp.argmax(logits, -1), np.int32)
            steps += 1
            for i, r in enumerate(self.active):
                if r is None:
                    continue
                self.pos[i] += 1
                if self.pos[i] >= len(r.prompt):
                    r.generated.append(int(nxt[i]))
                if r.done or self.pos[i] >= self.max_seq - 1:
                    done.append(r)
                    self.active[i] = None
            if verbose and steps % 8 == 0:
                print(f"  step {steps}: {sum(x is not None for x in self.active)}"
                      f" active, {len(queue)} queued, {len(done)} done")
        dt = time.time() - t0
        return done, {"steps": steps, "wall_s": dt,
                      "tok_per_s": sum(len(r.generated) for r in done) / dt}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--window", type=int, default=0)
    args = ap.parse_args()

    cfg = reduced(get_arch(args.arch))
    model = Model(cfg, RuntimeConfig(remat=False, seq_chunk=32))
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    reqs = [Request(i, rng.randint(0, cfg.vocab_size,
                                   args.prompt_len).tolist(), args.max_new)
            for i in range(args.requests)]
    server = SlotServer(model, params, args.slots,
                        args.prompt_len + args.max_new + 1,
                        window=args.window)
    done, stats = server.run(reqs, verbose=True)
    print(f"served {len(done)} requests in {stats['steps']} steps "
          f"({stats['tok_per_s']:.1f} tok/s on CPU)")
    for r in done[:3]:
        print(f"  req {r.rid}: gen={r.generated}")


if __name__ == "__main__":
    main()
