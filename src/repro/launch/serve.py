"""Batched serving driver: slot-based continuous batching over decode_step.

A minimal production-shaped server loop: a fixed pool of B slots, each
holding one request; finished slots are refilled from the queue without
stalling the running batch.  The KV cache is the ``per_slot`` layout
(models/model.py ``init_cache(per_slot=True)``), so every slot advances
its own position — refills never align the batch.

Three personalization modes (DESIGN.md §9):

* ``shared`` — every request decodes against the base parameters.
* ``delta``  — per-user selected-layer deltas ride a capacity-C
  :class:`repro.serve.DeltaOverlay`; ONE jitted decode program serves
  slots with *different* users' deltas.
* ``dense``  — the honest baseline: each slot holds the user's private
  full-parameter copy (materialised on refill), decode is vmapped over
  the stacked per-slot params.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --slots 4 --requests 10 --max-new 16 --mode delta --delta-layers 2
"""
from __future__ import annotations

import argparse
import time
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RuntimeConfig, get_arch, reduced
from repro.models.model import Model
from repro.serve import DeltaOverlay, DeltaStore, serve_suite, stack_tree


@dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int
    user_id: int = -1                       # -1: anonymous (base params)
    generated: list[int] = field(default_factory=list)

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new


class SlotServer:
    """B decode slots over a single jitted decode_step.

    ``mode``: "shared" | "delta" | "dense" (see module docstring); the
    latter two look requests' ``user_id`` up in ``store``.
    """

    def __init__(self, model: Model, params, slots: int, max_seq: int,
                 window: int = 0, *, mode: str = "shared",
                 store: Optional[DeltaStore] = None, capacity: int = 0,
                 admit_retries: int = 16, max_slot_retries: int = 2,
                 injector=None):
        assert mode in ("shared", "delta", "dense"), mode
        if mode != "shared" and store is None:
            raise ValueError(f"mode={mode!r} needs a DeltaStore")
        self.model = model
        self.params = params
        self.slots = slots
        self.max_seq = max_seq
        self.window = window
        self.mode = mode
        self.store = store
        self.suite = serve_suite(model)
        self.active: list[Request | None] = [None] * slots
        self.pos = np.zeros(slots, np.int32)        # per-slot position
        # degradation policy (DESIGN.md §12): a request that cannot admit
        # after admit_retries attempts, or whose slot is struck more than
        # max_slot_retries times, is dropped (self.dropped) instead of
        # livelocking the loop / killing the batch
        self.admit_retries = int(admit_retries)
        self.max_slot_retries = int(max_slot_retries)
        self.injector = injector
        self.dropped: list[Request] = []
        self._admit_attempts: dict[int, int] = {}   # rid -> failed admits
        self._fail_counts: dict[int, int] = {}      # rid -> slot strikes
        self._dropped_requests = 0
        self._slot_failures = 0
        if mode == "dense":
            # stacked per-slot state: private params + a batch-1 cache per slot
            self.bank = stack_tree(params, slots)
            self.cache = stack_tree(
                model.init_cache(1, max_seq, window=window, per_slot=True),
                slots)
        else:
            self.cache = model.init_cache(slots, max_seq, window=window,
                                          per_slot=True)
            self.overlay = (DeltaOverlay(model, capacity or slots,
                                         injector=injector)
                            if mode == "delta" else None)

    def _record(self, req: Request):
        if self.store is None or req.user_id < 0:
            return None
        return self.store.get(req.user_id)

    def _free(self, i: int) -> None:
        self.active[i] = None
        if self.mode == "delta":
            self.overlay.release(i)

    def _drop(self, req: Request, why: str) -> None:
        self.dropped.append(req)
        self._dropped_requests += 1
        self._admit_attempts.pop(req.rid, None)
        self._fail_counts.pop(req.rid, None)
        print(f"  dropping request {req.rid} (user {req.user_id}): {why}")

    def _admit(self, queue: list[Request]):
        for i in range(self.slots):
            if self.active[i] is not None or not queue:
                continue
            if self.mode == "delta":
                req = None
                while queue:
                    head = queue[0]
                    if self.overlay.try_admit(i, self._record(head)):
                        req = queue.pop(0)
                        break
                    # overlay full for this request: bounded retry, then
                    # drop — the old unconditional requeue livelocked the
                    # loop when the head request could never fit
                    n = self._admit_attempts.get(head.rid, 0) + 1
                    self._admit_attempts[head.rid] = n
                    if n > self.admit_retries:
                        queue.pop(0)
                        self._drop(head, f"no overlay capacity after "
                                         f"{n - 1} admit attempts")
                        continue    # head dropped: try the next request
                    break           # keep queued; retry after a release
                if req is None:
                    continue        # nothing admissible for this slot now
            else:
                req = queue.pop(0)
            self._admit_attempts.pop(req.rid, None)
            if self.mode == "dense":
                private = (self.store.materialize(self.params, req.user_id)
                           if req.user_id >= 0 else self.params)
                self.bank = self.suite["serve_write_params"](
                    self.bank, private, jnp.int32(i))
                self.cache = self.suite["serve_reset_slot"](
                    self.cache, jnp.int32(i), stacked=True)
            else:
                self.cache = self.suite["serve_reset_slot"](
                    self.cache, jnp.int32(i))
            self.active[i] = req
            self.pos[i] = 0

    def _decode(self, toks, pos):
        if self.mode == "shared":
            return self.suite["serve_decode"](self.params, toks, pos,
                                              self.cache, self.window)
        if self.mode == "delta":
            return self.suite["serve_decode_delta"](
                self.params, toks, pos, self.cache, self.overlay.device(),
                self.window)
        return self.suite["serve_decode_dense"](self.bank, toks, pos,
                                                self.cache, self.window)

    def run(self, requests: list[Request], verbose: bool = False):
        queue = list(requests)
        done: list[Request] = []
        steps = 0
        t0 = time.time()  # repro: allow[nondeterminism] -- serve wall-clock telemetry only
        while queue or any(r is not None for r in self.active):
            self._admit(queue)
            if queue and all(r is None for r in self.active):
                # nothing admitted onto an idle server: skip the decode —
                # admit attempts ramp every pass, so the stuck head is
                # dropped within admit_retries iterations (no livelock,
                # no RuntimeError: the batch degrades instead of dying)
                continue
            toks = np.zeros(self.slots, np.int32)
            for i, r in enumerate(self.active):
                if r is None:
                    continue
                p = int(self.pos[i])  # repro: allow[host-sync] -- self.pos is the host np position mirror, no device value
                toks[i] = (r.prompt[p] if p < len(r.prompt)
                           else r.generated[-1])
            # per-slot position vector: each slot decodes at its own stream
            # position; empty slots idle at 0 and are masked on refill
            logits, self.cache = self._decode(jnp.asarray(toks),
                                              jnp.asarray(self.pos))
            # repro: allow[host-sync] -- the serve loop's one sanctioned sync: greedy feedback, next token depends on this step's logits
            nxt = np.asarray(jnp.argmax(logits, -1), np.int32)
            steps += 1
            for i, r in enumerate(self.active):
                if r is None:
                    continue
                self.pos[i] += 1
                if self.pos[i] >= len(r.prompt):
                    r.generated.append(int(nxt[i]))  # repro: allow[host-sync] -- nxt already materialised at the sanctioned sync above
                if r.done or self.pos[i] >= self.max_seq - 1:
                    done.append(r)
                    self._free(i)
            if self.injector is not None and self.injector.enabled:
                # injected slot failures (DESIGN.md §12): the struck slot's
                # request loses its progress; bounded per-request retries
                # from scratch (generated cleared — admit resets pos/cache),
                # then dropped
                struck = self.injector.slot_faults(steps, self.slots)
                for i in np.flatnonzero(struck):
                    r = self.active[i]
                    if r is None:
                        continue
                    self._slot_failures += 1
                    self._free(int(i))  # repro: allow[host-sync] -- i is a host np index from the injector's host draw
                    n = self._fail_counts.get(r.rid, 0) + 1
                    self._fail_counts[r.rid] = n
                    if n > self.max_slot_retries:
                        self._drop(r, f"slot failed {n} times")
                    else:
                        r.generated.clear()
                        queue.append(r)
            if verbose and steps % 8 == 0:
                print(f"  step {steps}: {sum(x is not None for x in self.active)}"
                      f" active, {len(queue)} queued, {len(done)} done")
        dt = time.time() - t0  # repro: allow[nondeterminism] -- serve wall-clock telemetry only
        gen = sum(len(r.generated) for r in done)
        return done, {"steps": steps, "wall_s": dt, "gen_tokens": gen,
                      "tok_per_s": gen / dt if dt > 1e-9 else 0.0,
                      "dropped_requests": self._dropped_requests,
                      "slot_failures": self._slot_failures}


def demo_store(model: Model, params, users: int, layers_per_user: int,
               seed: int = 0) -> DeltaStore:
    """A store of synthetic per-user deltas: small noise on a random
    selected-layer subset per user (stand-in for real FL output)."""
    cfg = model.cfg
    store = DeltaStore(cfg)
    rng = np.random.RandomState(seed)
    for uid in range(users):
        layers = rng.choice(cfg.n_layers, size=min(layers_per_user,
                                                   cfg.n_layers),
                            replace=False)
        idx = np.sort(layers).astype(np.int32)
        tuned = dict(params)
        tuned["blocks"] = {
            name: np.asarray(leaf, np.float32)
            + 0.01 * np.isin(np.arange(leaf.shape[0]), idx).reshape(
                (-1,) + (1,) * (leaf.ndim - 1))
            * rng.standard_normal(leaf.shape).astype(np.float32)
            for name, leaf in params["blocks"].items()}
        store.put_from_params(uid, params, tuned, layers=idx)
    return store


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--window", type=int, default=0)
    ap.add_argument("--mode", default="shared",
                    choices=["shared", "delta", "dense"])
    ap.add_argument("--users", type=int, default=4)
    ap.add_argument("--delta-layers", type=int, default=2)
    ap.add_argument("--delta-capacity", type=int, default=0)
    args = ap.parse_args()

    cfg = reduced(get_arch(args.arch))
    model = Model(cfg, RuntimeConfig(remat=False, seq_chunk=32))
    params = model.init(jax.random.PRNGKey(0))
    store = (demo_store(model, params, args.users, args.delta_layers)
             if args.mode != "shared" else None)
    rng = np.random.RandomState(0)
    reqs = [Request(i, rng.randint(0, cfg.vocab_size,
                                   args.prompt_len).tolist(), args.max_new,
                    user_id=(i % args.users if store else -1))
            for i in range(args.requests)]
    server = SlotServer(model, params, args.slots,
                        args.prompt_len + args.max_new + 1,
                        window=args.window, mode=args.mode, store=store,
                        capacity=args.delta_capacity)
    done, stats = server.run(reqs, verbose=True)
    print(f"served {len(done)} requests in {stats['steps']} steps "
          f"[mode={args.mode}] ({stats['tok_per_s']:.1f} tok/s, "
          f"{stats['gen_tokens']} tokens in {stats['wall_s']:.2f}s)")
    for r in done[:3]:
        print(f"  req {r.rid} (user {r.user_id}): gen={r.generated}")


if __name__ == "__main__":
    main()
