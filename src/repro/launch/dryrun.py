import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × input-shape × mesh).

For each pair this:
  1. builds the production mesh (16×16 single-pod / 2×16×16 multi-pod),
  2. lowers the appropriate step (train_4k → FL train step; prefill_32k →
     prefill; decode shapes → serve step) against ShapeDtypeStruct inputs,
  3. compiles, prints ``memory_analysis()`` / ``cost_analysis()``,
  4. parses collective bytes out of the optimized HLO,
  5. writes ``experiments/dryrun/<arch>__<shape>__<mesh>.json`` for the
     roofline harness.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b \
      --shape train_4k [--multi-pod] [--all]
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (ASSIGNED_ARCHS, INPUT_SHAPES, RuntimeConfig,
                                get_arch)
from repro.launch import specs as S
from repro.launch.mesh import make_production_mesh
from repro.models.model import Model, count_params, count_active_params, init_params
from repro.analysis import costmodel as CM
from repro.sharding import hlo_analysis as H
from repro.sharding import rules
from repro.sharding.fl_step import make_fl_train_step
from repro.sharding.serve import make_prefill_step, make_serve_step

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

# Archs whose full-context attention cannot serve 500k tokens: they run the
# sliding-window variant (DESIGN.md §long_500k policy).
LONG_WINDOW = 4096
# Replicate-vs-ZeRO3 threshold: replicate the base when the per-chip copy
# (params/model_axis) stays under ~1.5 GB.
ZERO3_THRESHOLD_BYTES = 1.5e9


def pick_zero3(cfg, mesh) -> bool:
    shapes = jax.eval_shape(lambda k: init_params(cfg, k),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    nbytes = sum(np.prod(l.shape) * l.dtype.itemsize
                 for l in jax.tree.leaves(shapes))
    return nbytes / mesh.shape["model"] > ZERO3_THRESHOLD_BYTES


def window_for(cfg, shape) -> int:
    if shape.name == "long_500k" and cfg.family != "ssm":
        return cfg.sliding_window or LONG_WINDOW
    return 0


def lower_pair(arch_name: str, shape_name: str, multi_pod: bool,
               runtime: RuntimeConfig = RuntimeConfig(),
               sel_frac: float = 0.0):
    cfg = get_arch(arch_name)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    shard_hook = rules.make_shard_hook(mesh, cfg) if runtime.tp_constraints \
        else None
    model = Model(cfg, runtime, shard=shard_hook)
    zero3 = pick_zero3(cfg, mesh) and runtime.zero3
    n_chips = int(np.prod(list(mesh.shape.values())))

    params_shapes = jax.eval_shape(lambda k: init_params(cfg, k),
                                   jax.ShapeDtypeStruct((2,), jnp.uint32))

    sel_idx = None
    if sel_frac > 0:
        L = cfg.n_layers - cfg.first_dense
        R = max(1, int(round(L * sel_frac)))
        sel_idx = tuple(range(L - R, L))      # top-R layers, static

    t0 = time.time()  # repro: allow[nondeterminism] -- compile/lower timing telemetry only
    if shape.kind == "train":
        build = make_fl_train_step(model, mesh, zero3=zero3, sel_idx=sel_idx)
        step_fn, _ = build(params_shapes)
        batch, masks, sizes, lr = S.fl_round_specs(cfg, shape, mesh,
                                                   model.n_selectable)
        lowered = step_fn.lower(params_shapes, batch, masks, sizes, lr)
        tokens = shape.global_batch * shape.seq_len
    elif shape.kind == "prefill":
        build = make_prefill_step(model, mesh, zero3=zero3)
        batch = S.prefill_batch_specs(cfg, shape)
        fn, _ = build(params_shapes, batch)
        lowered = fn.lower(params_shapes, batch)
        tokens = shape.global_batch * shape.seq_len
    else:  # decode
        window = window_for(cfg, shape)
        build = make_serve_step(model, mesh, zero3=zero3, window=window)
        tok, pos, cache = S.decode_specs(model, shape, window=window)
        fn, _ = build(params_shapes, cache, shape.global_batch)
        lowered = fn.lower(params_shapes, tok, pos, cache)
        tokens = shape.global_batch
    t_lower = time.time() - t0  # repro: allow[nondeterminism] -- compile/lower timing telemetry only

    t0 = time.time()  # repro: allow[nondeterminism] -- compile/lower timing telemetry only
    compiled = lowered.compile()
    t_compile = time.time() - t0  # repro: allow[nondeterminism] -- compile/lower timing telemetry only

    mem = compiled.memory_analysis()
    hlo = compiled.as_text()

    # scan-aware per-DEVICE cost: the shared unrolled backend multiplies
    # while bodies by their trip counts, so these numbers line up with the
    # program auditor's CI-gated budgets (repro.analysis.program)
    t0 = time.time()  # repro: allow[nondeterminism] -- compile/lower timing telemetry only
    m = CM.analyze(hlo)
    unrolled = CM.unrolled_summary(hlo)
    t_analyze = time.time() - t0  # repro: allow[nondeterminism] -- compile/lower timing telemetry only
    flops = m.flops * n_chips            # whole-step totals
    hbm_bytes = m.hbm_bytes * n_chips
    coll_total = m.total_coll_bytes * n_chips
    terms = H.roofline_terms(flops, hbm_bytes, coll_total, n_chips)

    n_params = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params_shapes))
    # active-param fraction for MoE rooflines
    if cfg.n_experts:
        expert_frac = cfg.top_k / cfg.n_experts
        # expert leaf sizes
        e_sizes = sum(int(np.prod(l.shape))
                      for p, l in jax.tree_util.tree_flatten_with_path(params_shapes)[0]
                      if any(str(getattr(q, "key", "")).endswith(("wi_e", "wo_e"))
                             for q in p))
        n_active = int(n_params - e_sizes + e_sizes * expert_frac)
    else:
        n_active = n_params
    model_flops_factor = 6 if shape.kind == "train" else 2
    model_flops = model_flops_factor * n_active * tokens

    opts = []
    if runtime.tp_constraints:
        opts.append("tp")
    if runtime.remat_scores:
        opts.append("rematsc")
    if runtime.sel_upload and sel_idx is not None:
        opts.append(f"sel{len(sel_idx)}")
    if runtime.moe_local_dispatch:
        opts.append("moelocal")
    report = {
        "arch": arch_name, "shape": shape_name, "opts": opts,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips, "zero3": bool(zero3),
        "kind": shape.kind, "tokens": tokens,
        "n_params": int(n_params), "n_active_params": int(n_active),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "analyze_s": round(t_analyze, 2),
        "flops": flops, "hbm_bytes": hbm_bytes,
        "collective_bytes": coll_total,
        "collective_by_kind": {k: v * n_chips for k, v in m.coll_bytes.items()},
        "collective_counts": m.coll_counts,
        # per-device scan-unrolled summary, same keys as the audited
        # PROGRAM_BUDGETS.json side (repro.analysis.costmodel)
        "unrolled_cost_analysis": unrolled,
        "roofline": terms,
        "dominant": H.dominant_term(terms),
        "model_flops": model_flops,
        "useful_flops_frac": (model_flops / flops) if flops else None,
        "memory": {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "code_bytes": int(mem.generated_code_size_in_bytes),
        },
    }
    return report, compiled


def run_one(arch: str, shape: str, multi_pod: bool, save: bool = True,
            runtime: RuntimeConfig = RuntimeConfig(),
            sel_frac: float = 0.0) -> dict:
    report, compiled = lower_pair(arch, shape, multi_pod, runtime=runtime,
                                  sel_frac=sel_frac)
    print(json.dumps({k: v for k, v in report.items()
                      if k not in ("memory", "unrolled_cost_analysis")},
                     indent=None, default=str))
    print("memory_analysis:", report["memory"])
    if save:
        os.makedirs(OUT_DIR, exist_ok=True)
        suffix = ("__" + "-".join(report["opts"])) if report["opts"] else ""
        fname = f"{arch}__{shape}__{report['mesh']}{suffix}.json"
        with open(os.path.join(OUT_DIR, fname), "w") as f:
            json.dump(report, f, indent=1)
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--continue-on-error", action="store_true")
    ap.add_argument("--opt", action="store_true",
                    help="enable §Perf levers (tp constraints + chunk remat)")
    ap.add_argument("--sel-frac", type=float, default=0.0,
                    help="static selected-layer fraction for sel_upload")
    args = ap.parse_args()

    runtime = RuntimeConfig()
    if args.opt:
        runtime = RuntimeConfig(tp_constraints=True, remat_scores=True,
                                moe_local_dispatch=True,
                                sel_upload=args.sel_frac > 0)

    if args.all:
        archs = ASSIGNED_ARCHS if args.arch is None else [args.arch]
        shapes = list(INPUT_SHAPES) if args.shape is None else [args.shape]
        failures = []
        for a in archs:
            for s in shapes:
                try:
                    run_one(a, s, args.multi_pod,
                            runtime=runtime, sel_frac=args.sel_frac)
                except Exception as e:
                    failures.append((a, s, repr(e)))
                    traceback.print_exc()
                    if not args.continue_on_error:
                        raise
        if failures:
            print("FAILURES:", failures)
            raise SystemExit(1)
    else:
        run_one(args.arch, args.shape, args.multi_pod,
                runtime=runtime, sel_frac=args.sel_frac)


if __name__ == "__main__":
    main()
