"""Production mesh construction (TPU v5e: 16×16 = 256 chips/pod, 2 pods)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """(data, model) = (16, 16) single pod; (pod, data, model) = (2, 16, 16)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever local devices exist (tests / examples)."""
    n = len(jax.devices())
    assert data * model <= n, (data, model, n)
    return jax.make_mesh((data, model), ("data", "model"))
