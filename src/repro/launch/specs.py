"""ShapeDtypeStruct input stand-ins for every (arch × input-shape) pair.

No device allocation: the dry-run lowers against these.  Training batches
use the FL layout (clients, per_client, seq) where ``clients`` = product of
the mesh's client axes (pod×data); serve shapes follow the assignment table
verbatim.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models.model import Model

SDS = jax.ShapeDtypeStruct


def n_clients_on(mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in mesh.axis_names
                        if a in ("pod", "data")]))


def train_batch_specs(cfg: ArchConfig, shape: ShapeConfig, mesh) -> dict:
    """FL training batch: (clients, per_client, seq)."""
    clients = n_clients_on(mesh)
    assert shape.global_batch % clients == 0, (shape.global_batch, clients)
    pcb = shape.global_batch // clients
    S = shape.seq_len
    if cfg.family == "vlm":
        text = S - cfg.n_prefix_tokens
        return {"tokens": SDS((clients, pcb, text), jnp.int32),
                "patches": SDS((clients, pcb, cfg.n_prefix_tokens, cfg.d_model),
                               jnp.bfloat16)}
    if cfg.family == "audio":
        return {"tokens": SDS((clients, pcb, S), jnp.int32),
                "frames": SDS((clients, pcb, cfg.enc_seq, cfg.d_model),
                              jnp.bfloat16)}
    return {"tokens": SDS((clients, pcb, S), jnp.int32)}


def prefill_batch_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "vlm":
        return {"tokens": SDS((B, S - cfg.n_prefix_tokens), jnp.int32),
                "patches": SDS((B, cfg.n_prefix_tokens, cfg.d_model),
                               jnp.bfloat16)}
    if cfg.family == "audio":
        return {"tokens": SDS((B, S), jnp.int32),
                "frames": SDS((B, cfg.enc_seq, cfg.d_model), jnp.bfloat16)}
    return {"tokens": SDS((B, S), jnp.int32)}


def decode_specs(model: Model, shape: ShapeConfig, *, window: int = 0):
    """(tokens, pos, cache) ShapeDtypeStructs for serve_step."""
    cfg = model.cfg
    B = shape.global_batch
    cache = jax.eval_shape(
        lambda: model.init_cache(B, shape.seq_len, window=window))
    tokens = SDS((B,), jnp.int32)
    pos = SDS((), jnp.int32)
    return tokens, pos, cache


def fl_round_specs(cfg: ArchConfig, shape: ShapeConfig, mesh,
                   n_layers: int) -> tuple[dict, SDS, SDS, SDS]:
    """(batch, masks, sizes, lr) specs for the FL train step."""
    clients = n_clients_on(mesh)
    batch = train_batch_specs(cfg, shape, mesh)
    masks = SDS((clients, n_layers), jnp.float32)
    sizes = SDS((clients,), jnp.float32)
    lr = SDS((), jnp.float32)
    return batch, masks, sizes, lr
