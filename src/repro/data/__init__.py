from repro.data.synthetic import FederatedTaskConfig, SyntheticFederatedData  # noqa: F401
from repro.data.pretrain import pretrain  # noqa: F401
