"""Synthetic federated datasets with the paper's two non-IID patterns (§5.1).

The paper's datasets (CIFAR-10 / DomainNet / XGLUE-NC / QA) are not available
offline; we synthesise tasks with the same *heterogeneity structure*:

* **Label skew** (CIFAR-10 analogue): class proportions per client drawn from
  Dirichlet(α) (paper uses α=0.1); inputs are class-conditional token
  sequences — each class has its own token distribution, so the task is
  learnable and layer importance differs across classes.
* **Feature skew** (DomainNet/XGLUE analogue): each client belongs to one
  *domain*; a domain applies a fixed token permutation ("style") to the
  class-conditional sequences — P(x|y) shifts across clients while labels
  stay balanced.

Both variants support classification (pooled head) and LM (next-token)
objectives.  Sampling is numpy-based and deterministic per (seed, client).

Sampling paths (DESIGN.md §5):

* **vectorized** (default) — one batched draw per ``(client, call)``: labels
  via ``rng.choice``, class-conditional tokens via cumsum+searchsorted over
  ``class_probs``, signal/noise masks and noise tokens as whole-tensor draws.
  Each client keeps its own ``RandomState`` stream, so the vectorized and
  sequential round engines consume bit-identical data.
* **scalar oracle** (:meth:`SyntheticFederatedData._sample`) — consumes the
  rng stream in exactly the same order but applies the per-sample transforms
  in a Python loop; tests pin it bit-identical to the vectorized path.
* **legacy** (``legacy_sampling = True``) — the pre-streaming-pipeline
  per-sample loop (``rng.choice(p=...)`` per sample, per-round test-set
  resampling), kept as the baseline for the ``full_round`` micro-benchmark.

The held-out test set is drawn **once** (lazily, from a dedicated rng
stream) from the global mixture Σ_i α_i P_i; :meth:`test_batch` returns a
fixed slice of it, so per-round evaluation neither adds sampling noise nor
mutates the pretrain/test rng stream (it previously did both).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.core.state import (ClientStreamState, rng_state_from_arrays,
                              rng_state_to_arrays, sub_state)


@dataclass
class FederatedTaskConfig:
    n_clients: int = 100
    n_classes: int = 10
    vocab_size: int = 512
    seq_len: int = 32
    samples_per_client: int = 64
    skew: str = "label"              # label | feature
    dirichlet_alpha: float = 0.1
    n_domains: int = 5
    objective: str = "classification"  # classification | lm
    test_samples: int = 256
    seed: int = 0
    # class signal strength: fraction of positions carrying class-token signal
    signal: float = 0.5
    # feature skew severity: fraction of the vocabulary each domain permutes
    # (DomainNet-style shift: features partially transfer across domains)
    domain_strength: float = 0.3
    # modality: "tokens" (text) or "patches" (vision — CLIP-style stubbed
    # patch embeddings: class prototypes + per-domain linear style shift)
    modality: str = "tokens"
    patch_tokens: int = 8
    patch_dim: int = 64


class SyntheticFederatedData:
    """Generator for per-client batches and a held-out global test set.

    Implements the ``repro.api.Task`` protocol (``sizes`` /
    ``cohort_batches`` / ``test_batch``) consumed by the round engines and
    ``repro.api.Experiment``; it declares no plan-stage hooks, so cohort
    draws consume the server rng exactly as before the federation API
    existed (seed- and parity-stable).
    """

    def __init__(self, cfg: FederatedTaskConfig):
        self.cfg = cfg
        rng = np.random.RandomState(cfg.seed)
        C, V = cfg.n_classes, cfg.vocab_size

        # class-conditional token distributions: each class prefers a band of tokens
        logits = rng.randn(C, V) * 0.5
        for c in range(C):
            band = np.arange(V) % C == c
            logits[c, band] += 3.0
        self.class_probs = np.exp(logits) / np.exp(logits).sum(-1, keepdims=True)

        # domains: partial token permutations (feature shift preserving labels;
        # only `domain_strength` of the vocab is scrambled, so pretrained
        # features partially transfer — DomainNet-style)
        self.domain_perm = []
        for _ in range(cfg.n_domains):
            perm = np.arange(V)
            k = min(int(V * cfg.domain_strength), V)
            if k > 1:
                subset = rng.choice(V, size=k, replace=False)
                perm[subset] = perm[rng.permutation(subset)]
            self.domain_perm.append(perm)
        self.domain_perm.append(np.arange(V))   # identity (pretraining corpus)

        # client -> label distribution & domain
        if cfg.skew == "label":
            self.client_label_p = rng.dirichlet(
                np.full(C, cfg.dirichlet_alpha), size=cfg.n_clients)
            self.client_domain = np.zeros(cfg.n_clients, int)
        else:
            self.client_label_p = np.full((cfg.n_clients, C), 1.0 / C)
            self.client_domain = rng.randint(0, cfg.n_domains, cfg.n_clients)

        # heterogeneous dataset sizes d_i (log-normal, as in real FL)
        self.sizes = np.maximum(
            (cfg.samples_per_client *
             np.exp(rng.randn(cfg.n_clients) * 0.3)).astype(int), 8)

        # per-client data streams: flat draw counters + rng streams created
        # lazily on first touch (O(touched) host memory at 10⁵–10⁶ client
        # populations; each stream's seed depends only on (seed, i), so
        # laziness never changes a draw).  The depth-k round scheduler
        # prefetches rounds ahead of wall-clock execution; equality of the
        # positions (and of the streams' final states) across scheduled and
        # synchronous runs is the observable half of the stream-order
        # parity contract (tests/test_scheduler.py).
        self._streams = ClientStreamState(
            cfg.n_clients, lambda i, s=cfg.seed: s * 1000 + 7 * i + 1)
        self._test_rng = np.random.RandomState(cfg.seed + 999)

        if cfg.modality == "patches":
            # class prototypes in patch-embedding space + per-domain style
            # maps (identity-leaning linear shifts; last = pure identity).
            # Only `signal` of the patch positions carry class evidence and
            # the prototypes are weak relative to noise, so accuracy does
            # not saturate (strategies must actually adapt features).
            self.proto = rng.randn(C, cfg.patch_tokens, cfg.patch_dim) * 0.5
            self.patch_signal = rng.rand(cfg.patch_tokens) < cfg.signal
            self.proto[:, ~self.patch_signal] = 0.0
            self.domain_map = []
            for _ in range(cfg.n_domains):
                M = np.eye(cfg.patch_dim) + \
                    cfg.domain_strength * rng.randn(cfg.patch_dim, cfg.patch_dim) \
                    / np.sqrt(cfg.patch_dim)
                self.domain_map.append(M)
            self.domain_map.append(np.eye(cfg.patch_dim))
            self._maps = np.stack(self.domain_map)

        # vectorized-sampling tables: per-class / per-client inverse-cdf rows
        # (normalised exactly like np.random.choice: cumsum then /= last)
        self._perms = np.stack(self.domain_perm)
        cdf = np.cumsum(self.class_probs, axis=1)
        self._class_cdf = cdf / cdf[:, -1:]
        lcdf = np.cumsum(self.client_label_p, axis=1)
        self._label_cdf = lcdf / lcdf[:, -1:]

        # pre-streaming-pipeline sampling path, kept as the full_round
        # micro-benchmark baseline (per-sample loops + per-round test draws)
        self.legacy_sampling = False

        # held-out test set: drawn once (lazily) from a dedicated stream so
        # neither pretrain_batch nor legacy test_batch (both on _test_rng)
        # see a construction-time offset; test_batch() slices it
        self._heldout_rng = np.random.RandomState(cfg.seed + 424242)
        self._test_set: Optional[dict] = None

    # ------------------------------------------------------------------
    @property
    def n_clients(self) -> int:
        return self.cfg.n_clients

    @property
    def alpha(self) -> np.ndarray:
        """Relative sample sizes α_i = d_i / Σ d_j (Eq. 1)."""
        return self.sizes / self.sizes.sum()

    # -- vectorized path ------------------------------------------------
    def _cls_tokens(self, y: np.ndarray, u: np.ndarray) -> np.ndarray:
        """Inverse-cdf class-conditional tokens: searchsorted per class."""
        out = np.empty(u.shape, np.int64)
        for c in np.unique(y):
            m = y == c
            out[m] = np.searchsorted(self._class_cdf[c], u[m], side="right")
        return out

    def _sample_vec(self, rng: np.random.RandomState, label_p: np.ndarray,
                    domain: int, n: int) -> dict:
        """Whole-tensor draws; rng stream order: y, [eps | sig, u, noise]."""
        cfg = self.cfg
        y = rng.choice(cfg.n_classes, size=n, p=label_p)
        if cfg.modality == "patches":
            base = self.proto[y] + rng.randn(n, cfg.patch_tokens,
                                             cfg.patch_dim) * 1.5
            M = self.domain_map[domain if domain < len(self.domain_map)
                                else -1]
            patches = base @ M.T
            batch = {"patches": patches.astype(np.float32)}
            if cfg.objective == "classification":
                batch["label"] = y.astype(np.int32)
            return batch
        sig = rng.random_sample((n, cfg.seq_len))
        u = rng.random_sample((n, cfg.seq_len))
        noise = rng.randint(0, cfg.vocab_size, (n, cfg.seq_len))
        toks = np.where(sig < cfg.signal, self._cls_tokens(y, u), noise)
        toks = self.domain_perm[domain][toks].astype(np.int32)
        batch = {"tokens": toks}
        if cfg.objective == "classification":
            batch["label"] = y.astype(np.int32)
        return batch

    def _sample_mixture_vec(self, rng: np.random.RandomState,
                            owners: np.ndarray) -> dict:
        """Batched draw with per-sample (label_p, domain) given by owners."""
        cfg = self.cfg
        n = len(owners)
        u_y = rng.random_sample(n)
        y = np.empty(n, np.int64)
        for i in np.unique(owners):
            m = owners == i
            y[m] = np.searchsorted(self._label_cdf[i], u_y[m], side="right")
        domains = self.client_domain[owners]
        if cfg.modality == "patches":
            base = self.proto[y] + rng.randn(n, cfg.patch_tokens,
                                             cfg.patch_dim) * 1.5
            patches = np.einsum("npd,ned->npe", base, self._maps[domains])
            batch = {"patches": patches.astype(np.float32)}
            if cfg.objective == "classification":
                batch["label"] = y.astype(np.int32)
            return batch
        sig = rng.random_sample((n, cfg.seq_len))
        u = rng.random_sample((n, cfg.seq_len))
        noise = rng.randint(0, cfg.vocab_size, (n, cfg.seq_len))
        toks = np.where(sig < cfg.signal, self._cls_tokens(y, u), noise)
        toks = self._perms[domains[:, None], toks].astype(np.int32)
        batch = {"tokens": toks}
        if cfg.objective == "classification":
            batch["label"] = y.astype(np.int32)
        return batch

    # -- scalar parity oracle -------------------------------------------
    def _sample(self, rng: np.random.RandomState, label_p: np.ndarray,
                domain: int, n: int) -> dict:
        """Per-sample transform loop over the *same* stream as _sample_vec.

        Draws happen batched in the identical order (y, then eps or
        sig/u/noise); only the inverse-cdf lookup and masking run per sample.
        tests/test_synthetic_sampler.py pins this bit-identical to the
        vectorized path — the oracle for the whole-tensor transforms.
        """
        cfg = self.cfg
        y = rng.choice(cfg.n_classes, size=n, p=label_p)
        if cfg.modality == "patches":
            eps = rng.randn(n, cfg.patch_tokens, cfg.patch_dim)
            M = self.domain_map[domain if domain < len(self.domain_map)
                                else -1]
            patches = np.stack([(self.proto[y[k]] + eps[k] * 1.5) @ M.T
                                for k in range(n)])
            batch = {"patches": patches.astype(np.float32)}
            if cfg.objective == "classification":
                batch["label"] = y.astype(np.int32)
            return batch
        sig = rng.random_sample((n, cfg.seq_len))
        u = rng.random_sample((n, cfg.seq_len))
        noise = rng.randint(0, cfg.vocab_size, (n, cfg.seq_len))
        toks = np.empty((n, cfg.seq_len), np.int32)
        perm = self.domain_perm[domain]
        for k in range(n):
            cls_k = np.searchsorted(self._class_cdf[y[k]], u[k], side="right")
            toks[k] = perm[np.where(sig[k] < cfg.signal, cls_k, noise[k])]
        batch = {"tokens": toks}
        if cfg.objective == "classification":
            batch["label"] = y.astype(np.int32)
        return batch

    def _sample_mixture(self, rng: np.random.RandomState,
                        owners: np.ndarray) -> dict:
        """Scalar oracle for :meth:`_sample_mixture_vec` (same stream)."""
        cfg = self.cfg
        n = len(owners)
        u_y = rng.random_sample(n)
        y = np.array([np.searchsorted(self._label_cdf[i], u_y[k], side="right")
                      for k, i in enumerate(owners)], np.int64)
        domains = self.client_domain[owners]
        if cfg.modality == "patches":
            eps = rng.randn(n, cfg.patch_tokens, cfg.patch_dim)
            patches = np.stack([(self.proto[y[k]] + eps[k] * 1.5)
                                @ self._maps[domains[k]].T for k in range(n)])
            batch = {"patches": patches.astype(np.float32)}
            if cfg.objective == "classification":
                batch["label"] = y.astype(np.int32)
            return batch
        sig = rng.random_sample((n, cfg.seq_len))
        u = rng.random_sample((n, cfg.seq_len))
        noise = rng.randint(0, cfg.vocab_size, (n, cfg.seq_len))
        toks = np.empty((n, cfg.seq_len), np.int32)
        for k in range(n):
            cls_k = np.searchsorted(self._class_cdf[y[k]], u[k], side="right")
            toks[k] = self.domain_perm[domains[k]][
                np.where(sig[k] < cfg.signal, cls_k, noise[k])]
        batch = {"tokens": toks}
        if cfg.objective == "classification":
            batch["label"] = y.astype(np.int32)
        return batch

    # -- legacy (pre-pipeline) path -------------------------------------
    def _sample_legacy(self, rng: np.random.RandomState, label_p: np.ndarray,
                       domain: int, n: int) -> dict:
        cfg = self.cfg
        y = rng.choice(cfg.n_classes, size=n, p=label_p)
        if cfg.modality == "patches":
            base = self.proto[y] + rng.randn(n, cfg.patch_tokens,
                                             cfg.patch_dim) * 1.5
            M = self.domain_map[domain if domain < len(self.domain_map)
                                else -1]
            patches = base @ M.T
            batch = {"patches": patches.astype(np.float32)}
            if cfg.objective == "classification":
                batch["label"] = y.astype(np.int32)
            return batch
        toks = np.empty((n, cfg.seq_len), np.int32)
        for k in range(n):
            sig = rng.rand(cfg.seq_len) < cfg.signal
            cls_toks = rng.choice(cfg.vocab_size, size=cfg.seq_len,
                                  p=self.class_probs[y[k]])
            noise = rng.randint(0, cfg.vocab_size, cfg.seq_len)
            toks[k] = np.where(sig, cls_toks, noise)
        perm = self.domain_perm[domain]
        toks = perm[toks]
        batch = {"tokens": toks}
        if cfg.objective == "classification":
            batch["label"] = y.astype(np.int32)
        return batch

    # -- public API ------------------------------------------------------
    def _dispatch(self, rng, label_p, domain, n) -> dict:
        if self.legacy_sampling:
            return self._sample_legacy(rng, label_p, domain, n)
        return self._sample_vec(rng, label_p, domain, n)

    @property
    def _rngs(self) -> ClientStreamState:
        """Back-compat: ``data._rngs[i]`` still yields client i's stream."""
        return self._streams

    def stream_positions(self) -> np.ndarray:
        """(n_clients,) samples drawn per client stream so far — the
        cross-round bookkeeping the scheduler parity tests compare."""
        return self._streams.positions.copy()

    def state_dict(self) -> dict[str, np.ndarray]:
        """Flat-array resumable state: stream positions + the touched
        streams' rng states + the pretrain/legacy rng.  The held-out rng is
        deliberately absent — the fixed test set is that stream's first and
        only consumer, so a fresh task redraws it identically."""
        d = {f"streams/{k}": v for k, v in self._streams.state_dict().items()}
        d.update({f"test_rng/{k}": v
                  for k, v in rng_state_to_arrays(self._test_rng).items()})
        return d

    def load_state_dict(self, d: dict[str, np.ndarray]) -> None:
        self._streams.load_state_dict(sub_state(d, "streams/"))
        rng_state_from_arrays(sub_state(d, "test_rng/"), self._test_rng)

    def client_batch(self, i: int, batch_size: int) -> dict:
        """One minibatch from client i's distribution."""
        self._streams.advance(i, batch_size)
        return self._dispatch(self._streams.rng(i), self.client_label_p[i],
                              self.client_domain[i], batch_size)

    def client_batches(self, i: int, batch_size: int, n: int) -> dict:
        """``n`` stacked minibatches (leading axis = τ) for lax.scan.

        Vectorized path: ONE draw of ``n·batch_size`` samples reshaped to
        ``(n, batch_size, ...)`` — the per-batch Python loop only survives in
        legacy mode.
        """
        if self.legacy_sampling:
            bs = [self.client_batch(i, batch_size) for _ in range(n)]
            return {k: np.stack([b[k] for b in bs]) for k in bs[0]}
        self._streams.advance(i, n * batch_size)
        flat = self._sample_vec(self._streams.rng(i), self.client_label_p[i],
                                self.client_domain[i], n * batch_size)
        return {k: v.reshape((n, batch_size) + v.shape[1:])
                for k, v in flat.items()}

    def cohort_batches(self, cohort, batch_size: int, n: int) -> dict:
        """Stacked batches for a whole cohort: leaves (len(cohort), n, ...).

        Draws are identical to calling :meth:`client_batches` per cohort
        member in order (each client owns its RNG stream), so the vectorized
        and sequential engines consume the same data stream — the basis of
        the engine-parity guarantee (tests/test_round_engine.py).
        """
        per = [self.client_batches(int(i), batch_size, n) for i in cohort]
        return {k: np.stack([b[k] for b in per]) for k in per[0]}

    def pretrain_batch(self, batch_size: int) -> dict:
        """Balanced, identity-domain samples — the 'pretraining corpus'."""
        cfg = self.cfg
        label_p = np.full(cfg.n_classes, 1.0 / cfg.n_classes)
        identity = len(self.domain_perm) - 1
        return self._dispatch(self._test_rng, label_p, identity, batch_size)

    def _draw_test_set(self) -> dict:
        """The global-mixture held-out set, drawn once (dedicated stream)."""
        cfg = self.cfg
        owners = self._heldout_rng.choice(cfg.n_clients, size=cfg.test_samples,
                                          p=self.alpha)
        return self._sample_mixture_vec(self._heldout_rng, owners)

    def test_batch(self, batch_size: Optional[int] = None) -> dict:
        """Held-out batch from the *global* mixture Σ_i α_i P_i.

        Returns a fixed slice of the once-drawn test set, so repeated calls
        are deterministic and free of sampling noise.  Legacy mode
        reproduces the pre-pipeline behaviour exactly (fresh per-sample
        draws that mutate the test rng every call — `_test_rng` is never
        touched by the fixed set, so legacy streams match pre-PR
        bit-for-bit).
        """
        cfg = self.cfg
        n = batch_size or cfg.test_samples
        if self.legacy_sampling:
            rng = self._test_rng
            owners = rng.choice(cfg.n_clients, size=n, p=self.alpha)
            outs = [self._sample_legacy(rng, self.client_label_p[i],
                                        self.client_domain[i], 1)
                    for i in owners]
            return {k: np.concatenate([o[k] for o in outs]) for k in outs[0]}
        if n > cfg.test_samples:
            raise ValueError(
                f"test_batch({n}) exceeds the fixed held-out set "
                f"(test_samples={cfg.test_samples})")
        if self._test_set is None:
            self._test_set = self._draw_test_set()
        return {k: v[:n] for k, v in self._test_set.items()}
