"""Synthetic federated datasets with the paper's two non-IID patterns (§5.1).

The paper's datasets (CIFAR-10 / DomainNet / XGLUE-NC / QA) are not available
offline; we synthesise tasks with the same *heterogeneity structure*:

* **Label skew** (CIFAR-10 analogue): class proportions per client drawn from
  Dirichlet(α) (paper uses α=0.1); inputs are class-conditional token
  sequences — each class has its own token distribution, so the task is
  learnable and layer importance differs across classes.
* **Feature skew** (DomainNet/XGLUE analogue): each client belongs to one
  *domain*; a domain applies a fixed token permutation ("style") to the
  class-conditional sequences — P(x|y) shifts across clients while labels
  stay balanced.

Both variants support classification (pooled head) and LM (next-token)
objectives.  Sampling is numpy-based and deterministic per (seed, client).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np


@dataclass
class FederatedTaskConfig:
    n_clients: int = 100
    n_classes: int = 10
    vocab_size: int = 512
    seq_len: int = 32
    samples_per_client: int = 64
    skew: str = "label"              # label | feature
    dirichlet_alpha: float = 0.1
    n_domains: int = 5
    objective: str = "classification"  # classification | lm
    test_samples: int = 256
    seed: int = 0
    # class signal strength: fraction of positions carrying class-token signal
    signal: float = 0.5
    # feature skew severity: fraction of the vocabulary each domain permutes
    # (DomainNet-style shift: features partially transfer across domains)
    domain_strength: float = 0.3
    # modality: "tokens" (text) or "patches" (vision — CLIP-style stubbed
    # patch embeddings: class prototypes + per-domain linear style shift)
    modality: str = "tokens"
    patch_tokens: int = 8
    patch_dim: int = 64


class SyntheticFederatedData:
    """Generator for per-client batches and a held-out global test set."""

    def __init__(self, cfg: FederatedTaskConfig):
        self.cfg = cfg
        rng = np.random.RandomState(cfg.seed)
        C, V = cfg.n_classes, cfg.vocab_size

        # class-conditional token distributions: each class prefers a band of tokens
        logits = rng.randn(C, V) * 0.5
        for c in range(C):
            band = np.arange(V) % C == c
            logits[c, band] += 3.0
        self.class_probs = np.exp(logits) / np.exp(logits).sum(-1, keepdims=True)

        # domains: partial token permutations (feature shift preserving labels;
        # only `domain_strength` of the vocab is scrambled, so pretrained
        # features partially transfer — DomainNet-style)
        self.domain_perm = []
        for _ in range(cfg.n_domains):
            perm = np.arange(V)
            k = min(int(V * cfg.domain_strength), V)
            if k > 1:
                subset = rng.choice(V, size=k, replace=False)
                perm[subset] = perm[rng.permutation(subset)]
            self.domain_perm.append(perm)
        self.domain_perm.append(np.arange(V))   # identity (pretraining corpus)

        # client -> label distribution & domain
        if cfg.skew == "label":
            self.client_label_p = rng.dirichlet(
                np.full(C, cfg.dirichlet_alpha), size=cfg.n_clients)
            self.client_domain = np.zeros(cfg.n_clients, int)
        else:
            self.client_label_p = np.full((cfg.n_clients, C), 1.0 / C)
            self.client_domain = rng.randint(0, cfg.n_domains, cfg.n_clients)

        # heterogeneous dataset sizes d_i (log-normal, as in real FL)
        self.sizes = np.maximum(
            (cfg.samples_per_client *
             np.exp(rng.randn(cfg.n_clients) * 0.3)).astype(int), 8)

        self._rngs = [np.random.RandomState(cfg.seed * 1000 + 7 * i + 1)
                      for i in range(cfg.n_clients)]
        self._test_rng = np.random.RandomState(cfg.seed + 999)

        if cfg.modality == "patches":
            # class prototypes in patch-embedding space + per-domain style
            # maps (identity-leaning linear shifts; last = pure identity).
            # Only `signal` of the patch positions carry class evidence and
            # the prototypes are weak relative to noise, so accuracy does
            # not saturate (strategies must actually adapt features).
            self.proto = rng.randn(C, cfg.patch_tokens, cfg.patch_dim) * 0.5
            self.patch_signal = rng.rand(cfg.patch_tokens) < cfg.signal
            self.proto[:, ~self.patch_signal] = 0.0
            self.domain_map = []
            for _ in range(cfg.n_domains):
                M = np.eye(cfg.patch_dim) + \
                    cfg.domain_strength * rng.randn(cfg.patch_dim, cfg.patch_dim) \
                    / np.sqrt(cfg.patch_dim)
                self.domain_map.append(M)
            self.domain_map.append(np.eye(cfg.patch_dim))

    # ------------------------------------------------------------------
    @property
    def alpha(self) -> np.ndarray:
        """Relative sample sizes α_i = d_i / Σ d_j (Eq. 1)."""
        return self.sizes / self.sizes.sum()

    def _sample(self, rng: np.random.RandomState, label_p: np.ndarray,
                domain: int, n: int) -> dict:
        cfg = self.cfg
        y = rng.choice(cfg.n_classes, size=n, p=label_p)
        if cfg.modality == "patches":
            # patches = domain_style(prototype + noise); identity domain used
            # for pretraining (index -1)
            base = self.proto[y] + rng.randn(n, cfg.patch_tokens,
                                             cfg.patch_dim) * 1.5
            M = self.domain_map[domain if domain < len(self.domain_map)
                                else -1]
            patches = base @ M.T
            batch = {"patches": patches.astype(np.float32)}
            if cfg.objective == "classification":
                batch["label"] = y.astype(np.int32)
            return batch
        toks = np.empty((n, cfg.seq_len), np.int32)
        for k in range(n):
            sig = rng.rand(cfg.seq_len) < cfg.signal
            cls_toks = rng.choice(cfg.vocab_size, size=cfg.seq_len,
                                  p=self.class_probs[y[k]])
            noise = rng.randint(0, cfg.vocab_size, cfg.seq_len)
            toks[k] = np.where(sig, cls_toks, noise)
        perm = self.domain_perm[domain]
        toks = perm[toks]
        batch = {"tokens": toks}
        if cfg.objective == "classification":
            batch["label"] = y.astype(np.int32)
        return batch

    def client_batch(self, i: int, batch_size: int) -> dict:
        """One minibatch from client i's distribution."""
        return self._sample(self._rngs[i], self.client_label_p[i],
                            self.client_domain[i], batch_size)

    def client_batches(self, i: int, batch_size: int, n: int) -> dict:
        """``n`` stacked minibatches (leading axis = τ) for lax.scan."""
        bs = [self.client_batch(i, batch_size) for _ in range(n)]
        return {k: np.stack([b[k] for b in bs]) for k in bs[0]}

    def cohort_batches(self, cohort, batch_size: int, n: int) -> dict:
        """Stacked batches for a whole cohort: leaves (len(cohort), n, ...).

        Draws are identical to calling :meth:`client_batches` per cohort
        member in order (each client owns its RNG stream), so the vectorized
        and sequential engines consume the same data stream — the basis of
        the engine-parity guarantee (tests/test_round_engine.py).
        """
        per = [self.client_batches(int(i), batch_size, n) for i in cohort]
        return {k: np.stack([b[k] for b in per]) for k in per[0]}

    def pretrain_batch(self, batch_size: int) -> dict:
        """Balanced, identity-domain samples — the 'pretraining corpus'."""
        cfg = self.cfg
        label_p = np.full(cfg.n_classes, 1.0 / cfg.n_classes)
        identity = len(self.domain_perm) - 1
        return self._sample(self._test_rng, label_p, identity, batch_size)

    def test_batch(self, batch_size: Optional[int] = None) -> dict:
        """Held-out batch from the *global* mixture Σ_i α_i P_i."""
        cfg = self.cfg
        n = batch_size or cfg.test_samples
        rng = self._test_rng
        # mixture over clients weighted by alpha
        owners = rng.choice(cfg.n_clients, size=n, p=self.alpha)
        outs = []
        for i in owners:
            outs.append(self._sample(rng, self.client_label_p[i],
                                     self.client_domain[i], 1))
        return {k: np.concatenate([o[k] for o in outs]) for k in outs[0]}
