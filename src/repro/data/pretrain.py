"""Foundation-model surrogate: short AdamW pretraining on the balanced task.

The paper fine-tunes *pretrained* models (CLIP / XLM-R / LLaMA-2).  Offline
we cannot load those checkpoints, so experiments first pretrain the reduced
model on the *balanced global* distribution (no client skew) with AdamW —
producing a "foundation" initialisation whose layers have meaningfully
different fine-tuning importance — then run the paper's FL algorithm on the
non-IID clients with SGD, matching the paper's setup shape.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np

from repro.data.synthetic import SyntheticFederatedData
from repro.models.model import Model
from repro.optim import adamw, apply_updates

PyTree = Any


def pretrain(model: Model, params: PyTree, data: SyntheticFederatedData,
             steps: int = 150, lr: float = 3e-3, batch_size: int = 64,
             verbose: bool = False) -> PyTree:
    opt = adamw(lr)
    state = opt.init(params)

    @jax.jit
    def step(p, s, batch):
        loss, g = jax.value_and_grad(model.loss)(p, batch)
        u, s = opt.update(g, s, p)
        return apply_updates(p, u), s, loss

    for it in range(steps):
        batch = data.pretrain_batch(batch_size)   # balanced identity-domain corpus
        params, state, loss = step(params, state, batch)
        if verbose and (it + 1) % 50 == 0:
            print(f"  pretrain step {it+1}: loss {float(loss):.4f}")
    return params
