"""Mamba2 / SSD (state-space duality) blocks [arXiv:2405.21060].

The SSD insight — "the SSM scan *is* a semiseparable matmul" — maps directly
onto the TPU MXU: sequences are processed in chunks where the intra-chunk
work is dense matmuls and only a tiny (H,P,N) state crosses chunk boundaries
through a sequential recurrence.  :func:`ssd_chunked` is the jnp reference;
:mod:`repro.kernels.ssd_scan` is the Pallas TPU kernel with the same math.

Shapes: x (B,S,H,P) — H SSD heads of headdim P; dt (B,S,H); A_log (H,);
B/C (B,S,G,N) — G groups of state size N (broadcast over H//G heads).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models.blocks import rms_norm

Array = jax.Array


def segsum(x: Array) -> Array:
    """(..., T) -> (..., T, T): out[i,j] = sum_{k=j+1..i} x[k]; -inf above diag."""
    T = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    idx = jnp.arange(T)
    return jnp.where(idx[:, None] >= idx[None, :], diff, -jnp.inf)


def ssd_chunked(x: Array, dt: Array, A_log: Array, B: Array, C: Array,
                D: Optional[Array], chunk: int,
                initial_state: Optional[Array] = None):
    """Chunked SSD forward. Returns (y, final_state).

    y: (B,S,H,P); final_state: (B,H,P,N).
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    assert s % chunk == 0, f"seq {s} % chunk {chunk} != 0"
    nc, cl = s // chunk, chunk
    rep = h // g

    A = -jnp.exp(A_log.astype(jnp.float32))                   # (h,)
    dtf = dt.astype(jnp.float32)
    dA = dtf * A                                              # (b,s,h)
    xdt = (x.astype(jnp.float32) * dtf[..., None])            # (b,s,h,p)

    # broadcast groups over heads
    Bh = jnp.repeat(B.astype(jnp.float32), rep, axis=2)       # (b,s,h,n)
    Ch = jnp.repeat(C.astype(jnp.float32), rep, axis=2)

    # chunked views
    xc = xdt.reshape(b, nc, cl, h, p)
    Bc = Bh.reshape(b, nc, cl, h, n)
    Cc = Ch.reshape(b, nc, cl, h, n)
    dAc = dA.reshape(b, nc, cl, h)
    dAcs = jnp.cumsum(dAc, axis=2)                            # (b,nc,cl,h)

    # --- intra-chunk (dense matmuls; MXU work) ---------------------------
    L = jnp.exp(segsum(dAc.transpose(0, 1, 3, 2)))            # (b,nc,h,cl,cl)
    scores = jnp.einsum("bcqhn,bckhn->bchqk", Cc, Bc)
    y_diag = jnp.einsum("bchqk,bckhp->bcqhp", scores * L, xc)

    # --- chunk states ----------------------------------------------------
    decay_states = jnp.exp(dAcs[:, :, -1:, :] - dAcs)         # (b,nc,cl,h)
    states = jnp.einsum("bckhn,bckh,bckhp->bchpn", Bc, decay_states, xc)

    # --- inter-chunk recurrence (sequential over chunks) ------------------
    chunk_decay = jnp.exp(dAcs[:, :, -1, :])                  # (b,nc,h)
    state0 = (initial_state.astype(jnp.float32) if initial_state is not None
              else jnp.zeros((b, h, p, n), jnp.float32))

    def step(carry, inp):
        st_c, dec_c = inp                                     # (b,h,p,n), (b,h)
        prev = carry
        new = prev * dec_c[..., None, None] + st_c
        return new, prev                                      # emit state *entering* chunk

    final_state, prev_states = lax.scan(
        step, state0, (states.transpose(1, 0, 2, 3, 4),
                       chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)        # (b,nc,h,p,n)

    decay_out = jnp.exp(dAcs)                                 # (b,nc,cl,h)
    y_off = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp", Cc, prev_states, decay_out)

    y = (y_diag + y_off).reshape(b, s, h, p)
    if D is not None:
        y = y + x.astype(jnp.float32) * D.astype(jnp.float32)[None, None, :, None]
    return y.astype(x.dtype), final_state


def ssd_decode_step(state: Array, x: Array, dt: Array, A_log: Array,
                    B: Array, C: Array, D: Optional[Array]):
    """Single-token SSD update. x (B,1,H,P); state (B,H,P,N). O(1) in context."""
    b = x.shape[0]
    h, p = x.shape[2], x.shape[3]
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    A = -jnp.exp(A_log.astype(jnp.float32))
    dtf = dt.astype(jnp.float32)[:, 0]                        # (b,h)
    dA = jnp.exp(dtf * A)                                     # (b,h)
    Bh = jnp.repeat(B.astype(jnp.float32), rep, axis=2)[:, 0]  # (b,h,n)
    Ch = jnp.repeat(C.astype(jnp.float32), rep, axis=2)[:, 0]
    xf = x.astype(jnp.float32)[:, 0]                          # (b,h,p)
    new_state = (state.astype(jnp.float32) * dA[..., None, None]
                 + jnp.einsum("bhp,bhn,bh->bhpn", xf, Bh, dtf))
    y = jnp.einsum("bhn,bhpn->bhp", Ch, new_state)
    if D is not None:
        y = y + xf * D.astype(jnp.float32)[None, :, None]
    return y[:, None].astype(x.dtype), new_state.astype(state.dtype)


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------

def mamba2_param_shapes(cfg: ArchConfig) -> dict:
    d = cfg.d_model
    d_in = cfg.d_inner
    h = cfg.resolved_ssm_heads
    g, n, K = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_conv
    conv_dim = d_in + 2 * g * n
    return {
        "ln": (d,),
        "in_proj": (d, 2 * d_in + 2 * g * n + h),   # z | x | B | C | dt
        "conv_w": (K, conv_dim),
        "conv_b": (conv_dim,),
        "dt_bias": (h,),
        "A_log": (h,),
        "D": (h,),
        "gate_ln": (d_in,),
        "out_proj": (d_in, d),
    }


def _split_in_proj(zxbcdt: Array, cfg: ArchConfig):
    d_in = cfg.d_inner
    g, n, h = cfg.ssm_groups, cfg.ssm_state, cfg.resolved_ssm_heads
    idx = [d_in, 2 * d_in, 2 * d_in + g * n, 2 * d_in + 2 * g * n]
    z = zxbcdt[..., :idx[0]]
    xbc = zxbcdt[..., idx[0]:idx[3]]        # conv applies to x|B|C jointly
    dt = zxbcdt[..., idx[3]:]
    return z, xbc, dt


def _causal_conv(u: Array, w: Array, b: Array) -> Array:
    """Depthwise causal conv1d via K shifted adds. u: (B,S,Cd), w: (K,Cd)."""
    K = w.shape[0]
    pad = jnp.pad(u, ((0, 0), (K - 1, 0), (0, 0)))
    S = u.shape[1]
    out = sum(pad[:, k:k + S] * w[k] for k in range(K))
    return jax.nn.silu(out + b)


def _conv_decode(u: Array, conv_cache: Array, w: Array, b: Array):
    """u: (B,1,Cd); conv_cache: (B,K-1,Cd) holding previous inputs."""
    K = w.shape[0]
    window = jnp.concatenate([conv_cache, u], axis=1)          # (B,K,Cd)
    out = jnp.einsum("bkc,kc->bc", window, w)[:, None]
    new_cache = window[:, 1:]
    return jax.nn.silu(out + b), new_cache


def mamba2_fwd(p: dict, x: Array, cfg: ArchConfig, *,
               cache: Optional[dict] = None):
    """Mamba2 block (pre-norm, residual added by caller).

    cache: {"conv": (B,K-1,Cd), "state": (B,H,P,N)} for decode.
    Returns (out, new_cache).
    """
    B_, S, d = x.shape
    d_in = cfg.d_inner
    g, n = cfg.ssm_groups, cfg.ssm_state
    h = cfg.resolved_ssm_heads
    phead = d_in // h

    hid = rms_norm(x, p["ln"], cfg.norm_eps)
    z, xbc, dt = _split_in_proj(hid @ p["in_proj"], cfg)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))

    new_cache = None
    if cache is None:
        xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
        xs = xbc[..., :d_in].reshape(B_, S, h, phead)
        Bs = xbc[..., d_in:d_in + g * n].reshape(B_, S, g, n)
        Cs = xbc[..., d_in + g * n:].reshape(B_, S, g, n)
        y, _ = ssd_chunked(xs, dt, p["A_log"], Bs, Cs, p["D"],
                           min(cfg.ssm_chunk, S))
    else:
        xbc, conv_cache = _conv_decode(xbc, cache["conv"], p["conv_w"], p["conv_b"])
        xs = xbc[..., :d_in].reshape(B_, 1, h, phead)
        Bs = xbc[..., d_in:d_in + g * n].reshape(B_, 1, g, n)
        Cs = xbc[..., d_in + g * n:].reshape(B_, 1, g, n)
        y, state = ssd_decode_step(cache["state"], xs, dt, p["A_log"], Bs, Cs, p["D"])
        new_cache = {"conv": conv_cache, "state": state}

    y = y.reshape(B_, S, d_in)
    y = rms_norm(y * jax.nn.silu(z), p["gate_ln"], cfg.norm_eps)
    return y @ p["out_proj"], new_cache


def mamba2_cache_shapes(cfg: ArchConfig, batch: int) -> dict:
    d_in = cfg.d_inner
    h = cfg.resolved_ssm_heads
    conv_dim = d_in + 2 * cfg.ssm_groups * cfg.ssm_state
    return {
        "conv": (batch, cfg.ssm_conv - 1, conv_dim),
        "state": (batch, h, d_in // h, cfg.ssm_state),
    }
