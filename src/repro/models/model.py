"""Model facade: init / loss / prefill / decode for every assigned family.

Families and their block structure (all block stacks are scanned):

* ``dense``  — [attn, mlp] × L
* ``moe``    — [attn, moe] × L (optionally ``first_dense`` leading dense
  blocks — deepseek-v2); MLA attention when ``use_mla``
* ``ssm``    — [mamba2] × L
* ``hybrid`` — [mamba2] × L with ONE parameter-shared attention block applied
  after every ``attn_every`` SSM blocks (zamba2); the shared block has a
  distinct KV cache per application site
* ``vlm``    — stub patch embeddings prepended to token embeddings,
  prefix-LM masking (paligemma) or pooled classification (clip-vit)
* ``audio``  — whisper-style encoder-decoder with cross-attention; stub
  frame embeddings

The *selectable layer* set (the paper's ``m ∈ {0,1}^L``) is described by
:func:`layer_layout` — embedding / head / final norms are outside it
(paper §B.2 freezes them).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig, RuntimeConfig
from repro.models import blocks as B
from repro.models import mla as MLA
from repro.models import moe as MOE
from repro.models import ssd as SSD

Array = jax.Array
PyTree = Any


# ---------------------------------------------------------------------------
# Layer layout (mask segments)
# ---------------------------------------------------------------------------

class Segment(NamedTuple):
    path: str      # top-level key in params
    count: int     # number of mask entries (stacked leading dim, or 1)


def layer_layout(cfg: ArchConfig) -> tuple[Segment, ...]:
    """Mask segments, in mask-index order. Total == cfg.n_selectable_layers()."""
    segs: list[Segment] = []
    if cfg.has_encoder:
        segs.append(Segment("enc_blocks", cfg.n_enc_layers))
    if cfg.first_dense:
        segs.append(Segment("dense0", cfg.first_dense))
    segs.append(Segment("blocks", cfg.n_layers - cfg.first_dense))
    if cfg.family == "hybrid":
        segs.append(Segment("shared_attn", 1))
    assert sum(s.count for s in segs) == cfg.n_selectable_layers()
    return tuple(segs)


def supports_prefix_cut(cfg: ArchConfig) -> bool:
    """Whether the mask-aware compute engine can split this family's forward
    at a frozen-prefix layer index.

    Requires the selectable-layer mask order to be a *prefix* of the compute
    graph: true for the scanned stacks (dense/vlm/ssm/moe/audio).  False for
    ``hybrid`` — zamba2's shared attention block is applied interleaved
    through the whole depth, so layers below any cut still need gradients
    whenever the shared block is trainable.
    """
    return cfg.family != "hybrid"


def supports_delta_decode(cfg: ArchConfig) -> bool:
    """Whether :meth:`Model.decode_step` accepts a per-slot delta overlay.

    The overlay rides the ``blocks`` scan as capacity-C per-layer entries
    (DESIGN.md §9), which requires the plain scanned dense stack: attention
    + MLP blocks whose projections route through ``ops.base_delta_matmul``.
    MoE routing is cross-batch (capacity dropping couples slots) and
    ssm/hybrid blocks have no delta-aware projections yet.
    """
    return cfg.family in ("dense", "vlm")


def segment_cuts(cut: int, cfg: ArchConfig) -> dict[str, int]:
    """Per-segment frozen-prefix lengths for a global mask-index ``cut``.

    ``cut`` is in mask-index order (:func:`layer_layout`): segments entirely
    below it are fully frozen (cut == count), the segment containing it is
    split, segments above are fully trainable (cut == 0).
    """
    out, off = {}, 0
    for seg in layer_layout(cfg):
        out[seg.path] = min(max(int(cut) - off, 0), seg.count)
        off += seg.count
    return out


def trainable_slice(params: PyTree, cut: int, cfg: ArchConfig) -> PyTree:
    """Rows ``[cut_k:]`` of every selectable segment with trainable layers.

    This is the pytree the mask-aware τ-step scan carries — frozen prefix
    rows and the non-selectable groups (embed/head/norms) are excluded, so
    they are closed over as constants and get neither backward passes nor
    scan-carry traffic.  Fully frozen segments are omitted entirely.
    """
    cuts = segment_cuts(cut, cfg)
    out = {}
    for seg in layer_layout(cfg):
        c = cuts[seg.path]
        if c < seg.count:
            out[seg.path] = jax.tree.map(lambda a, c=c: a[c:],
                                         params[seg.path])
    return out


def split_mask(mask: Array, cfg: ArchConfig) -> dict[str, Array]:
    """Split an (L,)-mask into per-segment arrays keyed by param path."""
    out, off = {}, 0
    for seg in layer_layout(cfg):
        out[seg.path] = mask[off:off + seg.count]
        off += seg.count
    return out


def split_mask_matrix(mask_matrix: Array, cfg: ArchConfig) -> dict[str, Array]:
    """Split an (n, L) cohort mask/weight matrix into (n, count) segments.

    Column-axis analogue of :func:`split_mask`, used by the vectorized
    cohort engine to fuse Eq.(7) weighting over stacked delta pytrees.
    """
    out, off = {}, 0
    for seg in layer_layout(cfg):
        out[seg.path] = mask_matrix[:, off:off + seg.count]
        off += seg.count
    return out


def apply_layer_mask(tree: PyTree, mask: Array, cfg: ArchConfig,
                     frozen_zero: bool = True) -> PyTree:
    """Multiply per-layer subtrees of ``tree`` (grads/updates) by the mask.

    Non-selectable groups (embed, head, norms) are zeroed when
    ``frozen_zero`` (paper freezes them).
    """
    parts = split_mask(mask, cfg)
    out = {}
    for key, sub in tree.items():
        if key in parts:
            m = parts[key]
            if m.shape[0] == 1 and key == "shared_attn":
                out[key] = jax.tree.map(lambda x: x * m[0].astype(x.dtype), sub)
            else:
                out[key] = jax.tree.map(
                    lambda x: x * m.astype(x.dtype).reshape(
                        (m.shape[0],) + (1,) * (x.ndim - 1)), sub)
        else:
            if frozen_zero:
                out[key] = jax.tree.map(jnp.zeros_like, sub)
            else:
                out[key] = sub
    return out


# ---------------------------------------------------------------------------
# Parameter initialisation
# ---------------------------------------------------------------------------

def _block_shapes(cfg: ArchConfig, kind: str) -> dict:
    """Per-layer parameter shapes for one block of the given kind."""
    if kind == "dense":
        return {**_prefixed("attn_", B.attn_param_shapes(cfg)),
                **_prefixed("mlp_", B.mlp_param_shapes(cfg))}
    if kind == "moe":
        attn = MLA.mla_param_shapes(cfg) if cfg.use_mla else B.attn_param_shapes(cfg)
        return {**_prefixed("attn_", attn), **_prefixed("moe_", MOE.moe_param_shapes(cfg))}
    if kind == "moe_dense0":   # deepseek's first dense block: plain MLP sized 4x? use d_ff of shared? use 4*d
        attn = MLA.mla_param_shapes(cfg) if cfg.use_mla else B.attn_param_shapes(cfg)
        mlp = B.mlp_param_shapes(cfg, d_ff=cfg.d_ff * max(cfg.top_k + cfg.n_shared_experts, 1))
        return {**_prefixed("attn_", attn), **_prefixed("mlp_", mlp)}
    if kind == "ssm":
        return _prefixed("ssm_", SSD.mamba2_param_shapes(cfg))
    if kind == "attn_mlp_shared":  # zamba2 shared block
        return {**_prefixed("attn_", B.attn_param_shapes(cfg)),
                **_prefixed("mlp_", B.mlp_param_shapes(cfg))}
    if kind == "encdec":          # whisper decoder block
        return {**_prefixed("attn_", B.attn_param_shapes(cfg)),
                **_prefixed("xattn_", B.attn_param_shapes(cfg)),
                **_prefixed("mlp_", B.mlp_param_shapes(cfg))}
    raise ValueError(kind)


def _prefixed(prefix: str, shapes: dict) -> dict:
    return {prefix + k: v for k, v in shapes.items()}


def _take(p: dict, prefix: str) -> dict:
    n = len(prefix)
    return {k[n:]: v for k, v in p.items() if k.startswith(prefix)}


def init_params(cfg: ArchConfig, rng: Array) -> PyTree:
    dtype = jnp.dtype(cfg.dtype)
    keys = jax.random.split(rng, 8)
    d = cfg.d_model
    params: dict = {}

    # --- embeddings -------------------------------------------------------
    embed: dict = {}
    if cfg.task == "lm" or cfg.family != "vlm" or cfg.vocab_size:
        embed["tok"] = (jax.random.normal(keys[0], (cfg.vocab_size, d), jnp.float32)
                        * 0.02).astype(dtype)
    if cfg.family == "vlm":
        embed["patch_proj"] = (jax.random.normal(keys[1], (d, d), jnp.float32)
                               * 0.02).astype(dtype)
    if cfg.family == "audio":
        embed["frame_proj"] = (jax.random.normal(keys[1], (d, d), jnp.float32)
                               * 0.02).astype(dtype)
    params["embed"] = embed

    # --- block stacks ------------------------------------------------------
    if cfg.family in ("dense", "vlm"):
        params["blocks"] = B.init_stacked(keys[2], _block_shapes(cfg, "dense"),
                                          cfg.n_layers, dtype)
    elif cfg.family == "moe":
        if cfg.first_dense:
            params["dense0"] = B.init_stacked(
                keys[3], _block_shapes(cfg, "moe_dense0"), cfg.first_dense, dtype)
        params["blocks"] = B.init_stacked(
            keys[2], _block_shapes(cfg, "moe"), cfg.n_layers - cfg.first_dense, dtype)
    elif cfg.family == "ssm":
        params["blocks"] = B.init_stacked(keys[2], _block_shapes(cfg, "ssm"),
                                          cfg.n_layers, dtype)
    elif cfg.family == "hybrid":
        params["blocks"] = B.init_stacked(keys[2], _block_shapes(cfg, "ssm"),
                                          cfg.n_layers, dtype)
        params["shared_attn"] = B.init_stacked(
            keys[3], _block_shapes(cfg, "attn_mlp_shared"), 0, dtype)
    elif cfg.family == "audio":
        params["enc_blocks"] = B.init_stacked(keys[4], _block_shapes(cfg, "dense"),
                                              cfg.n_enc_layers, dtype)
        params["blocks"] = B.init_stacked(keys[2], _block_shapes(cfg, "encdec"),
                                          cfg.n_layers, dtype)
        params["enc_norm"] = jnp.zeros((d,), dtype)
    else:
        raise ValueError(cfg.family)

    params["final_norm"] = jnp.zeros((d,), dtype)

    # --- head --------------------------------------------------------------
    if cfg.task == "classification":
        params["head"] = (jax.random.normal(keys[5], (d, cfg.n_classes), jnp.float32)
                          * 0.02).astype(dtype)
    elif not cfg.tie_embeddings:
        params["head"] = (jax.random.normal(keys[5], (d, cfg.vocab_size), jnp.float32)
                          * 0.02).astype(dtype)
    return params


def count_params(params: PyTree) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


def count_active_params(cfg: ArchConfig, params: PyTree) -> int:
    """Active parameters per token (MoE: top_k of n_experts routed)."""
    total = count_params(params)
    if not cfg.n_experts:
        return total
    routed = sum(params_size
                 for name, params_size in _moe_expert_sizes(params).items())
    active_frac = cfg.top_k / cfg.n_experts
    return int(total - routed + routed * active_frac)


def _moe_expert_sizes(params: PyTree) -> dict[str, int]:
    out = {}
    blocks = params.get("blocks", {})
    for name in ("moe_wi_e", "moe_wo_e"):
        if name in blocks:
            out[name] = blocks[name].size
    return out


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def _maybe_remat(fn, runtime: RuntimeConfig):
    return jax.checkpoint(fn) if runtime.remat else fn


def _dense_block_fwd(p: dict, x: Array, cfg: ArchConfig, *, positions,
                     causal, window, prefix_len, seq_chunk,
                     cache=None, cache_pos=None, cross_kv=None,
                     remat_chunk=False, delta=None, delta_mode="jnp"):
    # delta: (slots (C,), {leaf_name: (C, *shape)}) — this layer's row of
    # the per-slot serving overlay; leaf names are split by sub-block prefix
    dslots = dattn = dmlp = None
    if delta is not None:
        dslots, dleaves = delta
        dattn = _take(dleaves, "attn_") or None
        dmlp = _take(dleaves, "mlp_") or None
    attn_out, new_kv = B.attention_fwd(
        _take(p, "attn_"), x, cfg, positions=positions, cache=cache,
        cache_pos=cache_pos, causal=causal, window=window,
        prefix_len=prefix_len, seq_chunk=seq_chunk, remat_chunk=remat_chunk,
        delta=dattn, delta_slots=dslots, delta_mode=delta_mode)
    x = x + attn_out
    if "xattn_ln" in p:   # whisper decoder cross-attention
        xo, _ = B.attention_fwd(_take(p, "xattn_"), x, cfg, positions=positions,
                                cross_kv=cross_kv, causal=False,
                                seq_chunk=seq_chunk)
        x = x + xo
    x = x + B.mlp_fwd(_take(p, "mlp_"), x, cfg, delta=dmlp,
                      delta_slots=dslots, delta_mode=delta_mode)
    return x, new_kv


def _moe_block_fwd(p: dict, x: Array, cfg: ArchConfig, *, positions, window,
                   seq_chunk, cache=None, cache_pos=None, shard=None,
                   remat_chunk=False, moe_local=False):
    if cfg.use_mla:
        attn_out, new_kv = MLA.mla_fwd(_take(p, "attn_"), x, cfg,
                                       positions=positions, cache=cache,
                                       cache_pos=cache_pos, window=window,
                                       seq_chunk=seq_chunk)
    else:
        attn_out, new_kv = B.attention_fwd(_take(p, "attn_"), x, cfg,
                                           positions=positions, cache=cache,
                                           cache_pos=cache_pos, causal=True,
                                           window=window, seq_chunk=seq_chunk,
                                           remat_chunk=remat_chunk)
    x = x + attn_out
    moe_out, stats = MOE.moe_fwd(_take(p, "moe_"), x, cfg, shard=shard,
                                 local_dispatch=moe_local)
    return x + moe_out, new_kv, stats.aux_loss


class Model:
    """Facade over one architecture: init, loss, prefill, decode."""

    def __init__(self, cfg: ArchConfig, runtime: RuntimeConfig = RuntimeConfig(),
                 shard: Optional[Callable] = None):
        cfg.validate()
        self.cfg = cfg
        self.runtime = runtime
        # custom shard callables change the lowering; the shared jit suite
        # cache (core/client.py) only serves default-sharded models
        self.custom_shard = shard is not None
        self.shard = shard or (lambda x, kind=None: x)

    # -- params ------------------------------------------------------------
    def init(self, rng: Array) -> PyTree:
        return init_params(self.cfg, rng)

    @property
    def n_selectable(self) -> int:
        return self.cfg.n_selectable_layers()

    # -- embedding ---------------------------------------------------------
    def _embed_tokens(self, params, tokens, pos_offset=0):
        cfg = self.cfg
        x = params["embed"]["tok"][tokens]
        if cfg.rope_theta == 0.0:
            # no RoPE (whisper / xlm-r / clip): sinusoidal absolute positions
            S = tokens.shape[1]
            pos = jnp.arange(S, dtype=jnp.int32) + pos_offset
            x = x + B.sinusoid_positions(pos, cfg.d_model).astype(x.dtype)
        return x * (cfg.d_model ** 0.5 if cfg.name.startswith(("gemma", "paligemma")) else 1.0)

    def _head(self, params, h):
        cfg = self.cfg
        h = B.rms_norm(h, params["final_norm"], cfg.norm_eps)
        if cfg.task == "classification":
            return h @ params["head"]
        w = params["embed"]["tok"].T if cfg.tie_embeddings else params["head"]
        logits = h @ w
        return B.softcap(logits, cfg.logit_softcap)

    # -- sequence forward (train / prefill) ---------------------------------
    def _split_scan(self, step, carry, full, idx, trainable, cut: int, rt):
        """Scan ``step`` over a stacked segment, split at frozen-prefix ``cut``.

        Dense path (``trainable is None``): one scan over ``full`` — exactly
        the pre-split program.  Mask-aware path: rows ``[:cut]`` come from
        ``full`` (constants w.r.t. the differentiated arguments, so AD saves
        no residuals and emits no backward for them) and rows ``[cut:]``
        come from ``trainable`` — the slice the τ-step scan carries.
        """
        f = _maybe_remat(step, rt)
        if trainable is None:
            carry, _ = lax.scan(f, carry, (full, idx))
            return carry
        if cut > 0:
            prefix = jax.tree.map(lambda a: lax.stop_gradient(a[:cut]), full)
            carry, _ = lax.scan(f, carry, (prefix, idx[:cut]))
        if cut < idx.shape[0]:
            carry, _ = lax.scan(f, carry, (trainable, idx[cut:]))
        return carry

    def forward_seq(self, params: PyTree, batch: dict, *,
                    window_override: Optional[int] = None,
                    layer_hook: Optional[Callable] = None,
                    trainable: Optional[PyTree] = None, cut: int = 0):
        """Full-sequence forward. Returns (hidden, aux_loss, prefix_len).

        ``layer_hook(per_layer_params, idx, segment)`` is applied to each
        scanned layer's (sliced) params — the distributed FL step uses it to
        ZeRO-gather each layer inside the scan and apply the Eq.(7)
        grad-scale, so no more than one layer's full weights ever
        materialise per device (DESIGN.md §4).

        ``trainable``/``cut`` select the mask-aware compute path (DESIGN.md
        §7): each selectable segment's scan is split at the static mask
        index ``cut`` — rows below it are read from ``params`` (frozen
        constants), rows at or above it from ``trainable`` (the
        :func:`trainable_slice` pytree the caller differentiates).  Only
        families with ``supports_prefix_cut(cfg)`` accept a trainable slice.
        """
        cfg, rt = self.cfg, self.runtime
        if trainable is not None and not supports_prefix_cut(cfg):
            raise ValueError(f"family {cfg.family!r} has no prefix-cut path")
        cuts = segment_cuts(cut, cfg) if trainable is not None else {}
        hook = layer_hook if layer_hook is not None else (lambda p, i, s: p)
        window = cfg.sliding_window if window_override is None else window_override
        aux = jnp.zeros((), jnp.float32)
        prefix_len = 0

        if cfg.family == "audio":
            return self._whisper_seq(params, batch, window,
                                     layer_hook if layer_hook is not None
                                     else (lambda p, i, s: p),
                                     trainable=trainable, cuts=cuts)

        if cfg.family == "vlm":
            patches = batch["patches"].astype(params["embed"]["patch_proj"].dtype)
            px = patches @ params["embed"]["patch_proj"]
            prefix_len = px.shape[1]
            if cfg.task == "classification":
                x = px
            else:
                tx = self._embed_tokens(params, batch["tokens"])
                x = jnp.concatenate([px, tx], axis=1)
        else:
            x = self._embed_tokens(params, batch["tokens"])

        S = x.shape[1]
        positions = jnp.arange(S, dtype=jnp.int32)
        causal = cfg.task == "lm"
        x = self.shard(x, "act_bsd")

        if cfg.family in ("dense", "vlm"):
            def step(carry, inp):
                p, idx = inp
                p = hook(p, idx, "blocks")
                h, _ = _dense_block_fwd(p, carry, cfg, positions=positions,
                                        causal=causal, window=window,
                                        prefix_len=prefix_len,
                                        seq_chunk=rt.seq_chunk,
                                        remat_chunk=rt.remat_scores)
                return self.shard(h, "act_bsd"), None
            x = self._split_scan(step, x, params["blocks"],
                                 jnp.arange(cfg.n_layers, dtype=jnp.int32),
                                 None if trainable is None
                                 else trainable.get("blocks"),
                                 cuts.get("blocks", 0), rt)

        elif cfg.family == "moe":
            if cfg.first_dense:
                def step0(carry, inp):
                    p, _ = inp
                    if cfg.use_mla:
                        ao, _ = MLA.mla_fwd(_take(p, "attn_"), carry, cfg,
                                            positions=positions, window=window,
                                            seq_chunk=rt.seq_chunk)
                    else:
                        ao, _ = B.attention_fwd(_take(p, "attn_"), carry, cfg,
                                                positions=positions, causal=True,
                                                window=window, seq_chunk=rt.seq_chunk)
                    h = carry + ao
                    h = h + B.mlp_fwd(_take(p, "mlp_"), h, cfg)
                    return self.shard(h, "act_bsd"), None
                x = self._split_scan(step0, x, params["dense0"],
                                     jnp.arange(cfg.first_dense,
                                                dtype=jnp.int32),
                                     None if trainable is None
                                     else trainable.get("dense0"),
                                     cuts.get("dense0", 0), rt)

            def step(carry, inp):
                p, idx = inp
                p = hook(p, idx, "blocks")
                h, a = carry
                h, _, aux_l = _moe_block_fwd(p, h, cfg, positions=positions,
                                             window=window, seq_chunk=rt.seq_chunk,
                                             shard=self.shard,
                                             remat_chunk=rt.remat_scores,
                                             moe_local=rt.moe_local_dispatch)
                return (self.shard(h, "act_bsd"), a + aux_l), None
            nb = cfg.n_layers - cfg.first_dense
            (x, aux) = self._split_scan(step, (x, aux), params["blocks"],
                                        jnp.arange(nb, dtype=jnp.int32),
                                        None if trainable is None
                                        else trainable.get("blocks"),
                                        cuts.get("blocks", 0), rt)

        elif cfg.family == "ssm":
            def step(carry, inp):
                p, idx = inp
                p = hook(p, idx, "blocks")
                out, _ = SSD.mamba2_fwd(_take(p, "ssm_"), carry, cfg)
                return self.shard(carry + out, "act_bsd"), None
            x = self._split_scan(step, x, params["blocks"],
                                 jnp.arange(cfg.n_layers, dtype=jnp.int32),
                                 None if trainable is None
                                 else trainable.get("blocks"),
                                 cuts.get("blocks", 0), rt)

        elif cfg.family == "hybrid":
            x = self._zamba_seq(params, x, positions, window, hook)

        return x, aux, prefix_len

    def _zamba_seq(self, params, x, positions, window, hook=lambda p, i, s: p):
        cfg, rt = self.cfg, self.runtime
        k = cfg.attn_every
        n_groups, rem = divmod(cfg.n_layers, k)
        blocks = params["blocks"]
        grouped = jax.tree.map(
            lambda a: a[:n_groups * k].reshape((n_groups, k) + a.shape[1:]), blocks)
        tail = jax.tree.map(lambda a: a[n_groups * k:], blocks)
        idx_g = jnp.arange(n_groups * k, dtype=jnp.int32).reshape(n_groups, k)
        idx_t = jnp.arange(n_groups * k, cfg.n_layers, dtype=jnp.int32)
        shared = params["shared_attn"]

        def mamba_step(carry, inp):
            p, idx = inp
            p = hook(p, idx, "blocks")
            out, _ = SSD.mamba2_fwd(_take(p, "ssm_"), carry, cfg)
            return self.shard(carry + out, "act_bsd"), None

        def group_step(carry, inp):
            pg, ig = inp
            h, _ = lax.scan(_maybe_remat(mamba_step, rt), carry, (pg, ig))
            h2, _ = _dense_block_fwd(shared, h, cfg, positions=positions,
                                     causal=True, window=window, prefix_len=0,
                                     seq_chunk=rt.seq_chunk,
                                     remat_chunk=rt.remat_scores)
            return self.shard(h2, "act_bsd"), None

        x, _ = lax.scan(group_step, x, (grouped, idx_g))
        if rem:
            x, _ = lax.scan(_maybe_remat(mamba_step, rt), x, (tail, idx_t))
        return x

    def _whisper_seq(self, params, batch, window, hook=lambda p, i, s: p,
                     trainable: Optional[PyTree] = None,
                     cuts: Optional[dict] = None):
        cfg, rt = self.cfg, self.runtime
        cuts = cuts if cuts is not None else {}
        frames = batch["frames"].astype(params["embed"]["frame_proj"].dtype)
        e = frames @ params["embed"]["frame_proj"]
        Se = e.shape[1]
        e = e + B.sinusoid_positions(jnp.arange(Se, dtype=jnp.int32),
                                     cfg.d_model).astype(e.dtype)
        enc_pos = jnp.arange(Se, dtype=jnp.int32)

        def enc_step(carry, inp):
            p, idx = inp
            p = hook(p, idx, "enc_blocks")
            h, _ = _dense_block_fwd(p, carry, cfg, positions=enc_pos,
                                    causal=False, window=0, prefix_len=0,
                                    seq_chunk=rt.seq_chunk,
                                    remat_chunk=rt.remat_scores)
            return self.shard(h, "act_bsd"), None
        e = self._split_scan(enc_step, e, params["enc_blocks"],
                             jnp.arange(cfg.n_enc_layers, dtype=jnp.int32),
                             None if trainable is None
                             else trainable.get("enc_blocks"),
                             cuts.get("enc_blocks", 0), rt)
        enc_out = B.rms_norm(e, params["enc_norm"], cfg.norm_eps)

        x = self._embed_tokens(params, batch["tokens"])
        S = x.shape[1]
        positions = jnp.arange(S, dtype=jnp.int32)

        def dec_step(carry, inp):
            p, idx = inp
            p = hook(p, idx, "blocks")
            cross_kv = B.make_cross_kv(_take(p, "xattn_"), enc_out, cfg)
            h, _ = _dense_block_fwd(p, carry, cfg, positions=positions,
                                    causal=True, window=window, prefix_len=0,
                                    seq_chunk=rt.seq_chunk, cross_kv=cross_kv,
                                    remat_chunk=rt.remat_scores)
            return self.shard(h, "act_bsd"), None
        x = self._split_scan(dec_step, x, params["blocks"],
                             jnp.arange(cfg.n_layers, dtype=jnp.int32),
                             None if trainable is None
                             else trainable.get("blocks"),
                             cuts.get("blocks", 0), rt)
        return x, jnp.zeros((), jnp.float32), 0

    # -- losses --------------------------------------------------------------
    def loss(self, params: PyTree, batch: dict, *,
             window_override: Optional[int] = None,
             layer_hook: Optional[Callable] = None,
             trainable: Optional[PyTree] = None, cut: int = 0) -> Array:
        h, aux, prefix_len = self.forward_seq(params, batch,
                                              window_override=window_override,
                                              layer_hook=layer_hook,
                                              trainable=trainable, cut=cut)
        return self.loss_from_hidden(params, h, aux, prefix_len, batch)

    def loss_from_hidden(self, params: PyTree, h: Array, aux: Array,
                         prefix_len: int, batch: dict) -> Array:
        """The loss tail on an already-computed hidden state — shared by
        :meth:`loss` and the single-forward eval (core/client.py), so eval
        loss and accuracy come from one ``forward_seq`` call."""
        cfg = self.cfg
        if cfg.task == "classification":
            pooled = jnp.mean(h, axis=1)
            logits = self._head(params, pooled[:, None])[:, 0].astype(jnp.float32)
            ce = -jnp.take_along_axis(jax.nn.log_softmax(logits, -1),
                                      batch["label"][:, None], axis=-1)
            return jnp.mean(ce) + aux

        tokens = batch["tokens"]
        text_h = h[:, prefix_len:] if prefix_len else h
        ce = self._lm_ce(params, text_h[:, :-1], tokens[:, 1:])
        return ce + aux

    def _lm_ce(self, params, h, targets, chunk: int = 1024) -> Array:
        """Chunked next-token cross-entropy (never materialises (B,S,V) f32)."""
        cfg = self.cfg
        h = B.rms_norm(h, params["final_norm"], cfg.norm_eps)
        w = params["embed"]["tok"].T if cfg.tie_embeddings and cfg.task == "lm" \
            else params["head"]
        S = h.shape[1]
        if S <= chunk or S % chunk != 0:
            logits = B.softcap(h @ w, cfg.logit_softcap).astype(jnp.float32)
            lse = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, targets[..., None], -1)[..., 0]
            return jnp.mean(lse - gold)

        nck = S // chunk
        hc = h.reshape(h.shape[0], nck, chunk, -1).transpose(1, 0, 2, 3)
        tc = targets.reshape(targets.shape[0], nck, chunk).transpose(1, 0, 2)

        def step(acc, inp):
            hi, ti = inp
            logits = B.softcap(hi @ w, cfg.logit_softcap).astype(jnp.float32)
            lse = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, ti[..., None], -1)[..., 0]
            return acc + jnp.sum(lse - gold), None

        tot, _ = lax.scan(step, jnp.zeros((), jnp.float32), (hc, tc))
        return tot / (targets.shape[0] * S)

    def logits_seq(self, params: PyTree, batch: dict) -> Array:
        """Full-sequence logits (prefill_32k lowers this)."""
        h, _, prefix_len = self.forward_seq(params, batch)
        if self.cfg.task == "classification":
            return self._head(params, jnp.mean(h, axis=1)[:, None])[:, 0]
        return self._head(params, h[:, -1:])[:, 0]   # last-position logits

    # -- decode ---------------------------------------------------------------
    def init_cache(self, batch: int, max_seq: int, *,
                   window: int = 0, dtype=None,
                   per_slot: bool = False) -> PyTree:
        """KV/state caches for decode. ``window`` caps attention cache size.

        ``per_slot=True`` builds the serving layout: ``pos`` gains a batch
        axis ((L, B, W) instead of (L, W)) so every slot tracks its own
        stream position — decode_step then takes a (B,) position vector and
        refills never have to align the batch (DESIGN.md §9).
        """
        cfg = self.cfg
        dt = jnp.dtype(dtype or cfg.dtype)
        W = min(window or max_seq, max_seq)
        Kh = cfg.n_kv_heads
        hd = cfg.resolved_head_dim if cfg.n_heads else 0

        def pos_full(*lead):
            shp = lead + ((batch, W) if per_slot else (W,))
            return jnp.full(shp, jnp.iinfo(jnp.int32).max, jnp.int32)

        def kv(n_layers):
            shp = (n_layers, batch, W, Kh, hd) if n_layers else (batch, W, Kh, hd)
            return {"k": jnp.zeros(shp, dt), "v": jnp.zeros(shp, dt),
                    "pos": pos_full(n_layers) if n_layers else pos_full()}

        if cfg.family in ("dense", "vlm"):
            return {"blocks": kv(cfg.n_layers)}
        if cfg.family == "moe":
            if cfg.use_mla:
                def mla_cache(n):
                    return {"ckv": jnp.zeros((n, batch, W, cfg.kv_lora_rank), dt),
                            "krope": jnp.zeros((n, batch, W, cfg.qk_rope_dim), dt),
                            "pos": pos_full(n)}
                c = {"blocks": mla_cache(cfg.n_layers - cfg.first_dense)}
                if cfg.first_dense:
                    c["dense0"] = mla_cache(cfg.first_dense)
                return c
            c = {"blocks": kv(cfg.n_layers - cfg.first_dense)}
            if cfg.first_dense:
                c["dense0"] = kv(cfg.first_dense)
            return c
        if cfg.family == "ssm":
            shp = SSD.mamba2_cache_shapes(cfg, batch)
            return {"blocks": {
                "conv": jnp.zeros((cfg.n_layers,) + shp["conv"], dt),
                "state": jnp.zeros((cfg.n_layers,) + shp["state"], dt)}}
        if cfg.family == "hybrid":
            shp = SSD.mamba2_cache_shapes(cfg, batch)
            n_groups = cfg.n_layers // cfg.attn_every
            return {"blocks": {
                        "conv": jnp.zeros((cfg.n_layers,) + shp["conv"], dt),
                        "state": jnp.zeros((cfg.n_layers,) + shp["state"], dt)},
                    "shared_attn": {
                        "k": jnp.zeros((n_groups, batch, W, Kh, hd), dt),
                        "v": jnp.zeros((n_groups, batch, W, Kh, hd), dt),
                        "pos": pos_full(n_groups)}}
        if cfg.family == "audio":
            return {"blocks": kv(cfg.n_layers),
                    "cross_kv": {
                        "k": jnp.zeros((cfg.n_layers, batch, cfg.enc_seq, Kh, hd), dt),
                        "v": jnp.zeros((cfg.n_layers, batch, cfg.enc_seq, Kh, hd), dt)}}
        raise ValueError(cfg.family)

    def reset_slot(self, cache: PyTree, slot, *, stacked: bool = False) -> PyTree:
        """Invalidate one batch slot of a decode cache (request refill).

        Position rows become int32-max (= "empty": ``k_valid`` masks every
        cached entry) and SSM conv/state rows are zeroed; k/v slabs are left
        in place — they are unreachable until overwritten.  ``stacked=True``
        addresses the dense per-user layout (leading batch axis from a
        vmapped decode) instead of the per-slot layout (batch axis second,
        after the layer axis).
        """
        imax = jnp.iinfo(jnp.int32).max

        def walk(tree):
            out = {}
            for key, val in tree.items():
                if isinstance(val, dict):
                    out[key] = walk(val)
                elif key == "pos":
                    out[key] = (val.at[slot].set(imax) if stacked
                                else val.at[:, slot].set(imax))
                elif key in ("conv", "state"):
                    out[key] = (val.at[slot].set(0) if stacked
                                else val.at[:, slot].set(0))
                else:
                    out[key] = val
            return out

        return walk(cache)

    def decode_step(self, params: PyTree, tokens: Array, pos: Array,
                    cache: PyTree, *, window: int = 0,
                    delta: Optional[dict] = None) -> tuple[Array, PyTree]:
        """One decode step. tokens: (B,) int32; pos: scalar int32, or a
        (B,) per-slot position vector over a ``per_slot`` cache (the
        serving layout — each slot advances independently).

        ``delta``: per-slot selected-layer overlay for the serving path
        (families with :func:`supports_delta_decode`): ``{"slots": (L, C)
        int32 owner ids (-1 = empty), "leaves": {name: (L, C, *shape)}}``
        — capacity-C delta entries per scanned layer, consumed inside the
        one jitted program so slots with *different* deltas batch together
        (DESIGN.md §9).

        Returns (logits (B,V), new_cache).
        """
        cfg, rt = self.cfg, self.runtime
        per_slot = jnp.ndim(pos) == 1
        if delta is not None and not supports_delta_decode(cfg):
            raise ValueError(f"family {cfg.family!r} has no delta-decode path")
        x = self._embed_tokens(params, tokens[:, None], pos_offset=0)
        if cfg.rope_theta == 0.0 or cfg.family == "audio":
            # sinusoidal position of the *current* slot
            sp = (B.sinusoid_positions(pos[:, None], cfg.d_model) if per_slot
                  else B.sinusoid_positions(pos[None], cfg.d_model)[None])
            x = params["embed"]["tok"][tokens[:, None]] + sp.astype(x.dtype)
        positions = (pos[:, None] if per_slot else pos[None]).astype(jnp.int32)
        w = window or cfg.sliding_window

        if cfg.family in ("dense", "vlm"):
            dmode = "pallas" if rt.use_pallas else "jnp"

            def step(carry, inp):
                p, kv = inp[:2]
                dl = (inp[2], inp[3]) if delta is not None else None
                h, new_kv = _dense_block_fwd(p, carry, cfg, positions=positions,
                                             causal=True, window=w, prefix_len=0,
                                             seq_chunk=rt.seq_chunk, cache=kv,
                                             cache_pos=pos, delta=dl,
                                             delta_mode=dmode)
                return h, new_kv
            xs = (params["blocks"], cache["blocks"])
            if delta is not None:
                xs = xs + (delta["slots"], delta["leaves"])
            x, new_kv = lax.scan(step, x, xs)
            new_cache = {"blocks": new_kv}

        elif cfg.family == "moe":
            new_cache = {}
            if cfg.first_dense:
                def step0(carry, inp):
                    p, kv = inp
                    if cfg.use_mla:
                        ao, nkv = MLA.mla_fwd(_take(p, "attn_"), carry, cfg,
                                              positions=positions, cache=kv,
                                              cache_pos=pos, window=w,
                                              seq_chunk=rt.seq_chunk)
                    else:
                        ao, nkv = B.attention_fwd(_take(p, "attn_"), carry, cfg,
                                                  positions=positions, cache=kv,
                                                  cache_pos=pos, causal=True,
                                                  window=w, seq_chunk=rt.seq_chunk)
                    h = carry + ao
                    h = h + B.mlp_fwd(_take(p, "mlp_"), h, cfg)
                    return h, nkv
                x, nkv0 = lax.scan(step0, x, (params["dense0"], cache["dense0"]))
                new_cache["dense0"] = nkv0

            def step(carry, inp):
                p, kv = inp
                h, nkv, _ = _moe_block_fwd(p, carry, cfg, positions=positions,
                                           window=w, seq_chunk=rt.seq_chunk,
                                           cache=kv, cache_pos=pos,
                                           shard=self.shard,
                                           moe_local=rt.moe_local_dispatch)
                return h, nkv
            x, nkv = lax.scan(step, x, (params["blocks"], cache["blocks"]))
            new_cache["blocks"] = nkv

        elif cfg.family == "ssm":
            def step(carry, inp):
                p, c = inp
                out, nc = SSD.mamba2_fwd(_take(p, "ssm_"), carry, cfg, cache=c)
                return carry + out, nc
            x, nc = lax.scan(step, x, (params["blocks"], cache["blocks"]))
            new_cache = {"blocks": nc}

        elif cfg.family == "hybrid":
            x, new_cache = self._zamba_decode(params, x, positions, pos, cache, w)

        elif cfg.family == "audio":
            def step(carry, inp):
                p, kv, xkv = inp
                h, nkv = _dense_block_fwd(p, carry, cfg, positions=positions,
                                          causal=True, window=w, prefix_len=0,
                                          seq_chunk=rt.seq_chunk, cache=kv,
                                          cache_pos=pos,
                                          cross_kv=(xkv["k"], xkv["v"]))
                return h, nkv
            x, nkv = lax.scan(step, x, (params["blocks"], cache["blocks"],
                                        cache["cross_kv"]))
            new_cache = {"blocks": nkv, "cross_kv": cache["cross_kv"]}
        else:
            raise ValueError(cfg.family)

        logits = self._head(params, x)[:, 0]
        return logits, new_cache

    def _zamba_decode(self, params, x, positions, pos, cache, w):
        cfg = self.cfg
        k = cfg.attn_every
        n_groups, rem = divmod(cfg.n_layers, k)
        blocks = params["blocks"]
        grouped = jax.tree.map(
            lambda a: a[:n_groups * k].reshape((n_groups, k) + a.shape[1:]), blocks)
        tail = jax.tree.map(lambda a: a[n_groups * k:], blocks)
        mcache = cache["blocks"]
        gcache = jax.tree.map(
            lambda a: a[:n_groups * k].reshape((n_groups, k) + a.shape[1:]), mcache)
        tcache = jax.tree.map(lambda a: a[n_groups * k:], mcache)
        shared = params["shared_attn"]

        def mamba_step(carry, inp):
            p, c = inp
            out, nc = SSD.mamba2_fwd(_take(p, "ssm_"), carry, cfg, cache=c)
            return carry + out, nc

        def group_step(carry, inp):
            pg, cg, kvg = inp
            h, ncg = lax.scan(mamba_step, carry, (pg, cg))
            h2, nkv = _dense_block_fwd(shared, h, cfg, positions=positions,
                                       causal=True, window=w, prefix_len=0,
                                       seq_chunk=self.runtime.seq_chunk,
                                       cache=kvg, cache_pos=pos)
            return h2, (ncg, nkv)

        x, (new_g, new_kv) = lax.scan(group_step, x,
                                      (grouped, gcache, cache["shared_attn"]))
        new_m = jax.tree.map(
            lambda a: a.reshape((n_groups * k,) + a.shape[2:]), new_g)
        if rem:
            x, new_t = lax.scan(mamba_step, x, (tail, tcache))
            new_m = jax.tree.map(lambda a, b: jnp.concatenate([a, b], 0),
                                 new_m, new_t)
        return x, {"blocks": new_m, "shared_attn": new_kv}
