"""Transformer building blocks: norms, RoPE, GQA/MQA attention, gated MLPs.

All block parameters live in *stacked* pytrees with a leading ``(L, ...)``
axis and are consumed through ``lax.scan`` (see ``models/model.py``).  That
keeps HLO size depth-independent and makes the paper's per-layer masking a
single ``(L,)`` broadcast on gradients.

Attention has two execution paths:

* ``full``   — plain einsum softmax, used for short sequences;
* ``chunked``— lax.scan over query chunks (memory O(chunk·S) instead of
  O(S²)); this is the XLA-native "flash" path used for prefill_32k.  The
  Pallas kernel in :mod:`repro.kernels.flash_attention` is the TPU-optimised
  equivalent, selected via ``RuntimeConfig.use_pallas``.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig

Array = jax.Array

# Large-negative constant for masking (safe in bf16/f32).
NEG_INF = -1e9


# ---------------------------------------------------------------------------
# Norms & activations
# ---------------------------------------------------------------------------

def rms_norm(x: Array, scale: Array, eps: float) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(dt)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "gelu_plain": jax.nn.gelu}[name]


def softcap(logits: Array, cap: float) -> Array:
    if cap and cap > 0:
        return cap * jnp.tanh(logits / cap)
    return logits


# ---------------------------------------------------------------------------
# Positions
# ---------------------------------------------------------------------------

def rope_tables(positions: Array, head_dim: int, theta: float):
    """cos/sin tables for rotary embedding at given integer positions."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (..., half)
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x: Array, cos: Array, sin: Array) -> Array:
    """x: (..., S, H, hd); cos/sin: (S, hd/2) (or broadcastable)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    # cos/sin need a heads axis: (S, 1, half)
    c = cos[..., None, :]
    s = sin[..., None, :]
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return out.astype(x.dtype)


def sinusoid_positions(positions: Array, d_model: int,
                       scale: float = 0.02) -> Array:
    """Sinusoidal position encodings computed on the fly (whisper/XLM stand-in).

    Scaled to the token-embedding init scale (0.02) so position signal does
    not swamp token signal at initialisation (learned position tables in the
    original models are initialised at the same scale).
    """
    half = d_model // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / max(half - 1, 1))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return scale * jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Attention cores
# ---------------------------------------------------------------------------

def _mask_bias(q_pos: Array, k_pos: Array, *, causal: bool, window: int,
               prefix_len: int = 0, k_valid: Optional[Array] = None) -> Array:
    """Additive mask bias (Q, K) from positions.

    ``prefix_len``: positions < prefix_len see each other bidirectionally
    (PaliGemma prefix-LM).  ``window``: sliding window (0 = unlimited).
    ``k_valid``: optional bool (K,) marking populated cache slots.
    """
    q = q_pos[:, None]
    k = k_pos[None, :]
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), dtype=bool)
    if causal:
        vis = k <= q
        if prefix_len:
            vis = vis | ((k < prefix_len) & (q < prefix_len))
        ok &= vis
    if window:
        ok &= (q - k) < window
    if k_valid is not None:
        ok &= k_valid[None, :]
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _mask_bias_per_slot(q_pos: Array, k_pos: Array, *, causal: bool,
                        window: int, k_valid: Array) -> Array:
    """Batched :func:`_mask_bias`: q_pos (B,Sq), k_pos/k_valid (B,Sk) →
    (B,Sq,Sk).  The serving decode path, where every slot sits at its own
    position in its own cache row."""
    return jax.vmap(lambda qp, kp, kv: _mask_bias(
        qp, kp, causal=causal, window=window, k_valid=kv)
    )(q_pos, k_pos, k_valid)


# ---------------------------------------------------------------------------
# Per-slot delta overlays (personalized-delta serving, DESIGN.md §9)
# ---------------------------------------------------------------------------

def per_slot_param(base: Array, drows: Array, slots: Array, B: int) -> Array:
    """Effective small parameter (norm scale / bias) per slot.

    base: (*shape,); drows: (C, *shape) capacity-C delta entries; slots:
    (C,) int32 owner per entry (-1 = empty).  Returns (B, 1, *shape) f32 —
    base + the slot's delta row (at most one entry per slot), broadcastable
    over the decode seq axis.
    """
    safe = jnp.maximum(slots, 0)
    m = (slots >= 0).astype(jnp.float32).reshape((-1,) + (1,) * base.ndim)
    add = jnp.zeros((B,) + base.shape, jnp.float32)
    add = add.at[safe].add(m * drows.astype(jnp.float32))
    return (base.astype(jnp.float32)[None] + add)[:, None].astype(base.dtype)


def attend_full(q: Array, k: Array, v: Array, bias: Array, scale: float) -> Array:
    """q: (B,Sq,H,hd)  k/v: (B,Sk,K,hd)  bias: (Sq,Sk) shared, or
    (B,Sq,Sk) per-slot (the serving decode path). GQA via reshape."""
    B, Sq, H, hd = q.shape
    Kh = k.shape[2]
    g = H // Kh
    qg = q.reshape(B, Sq, Kh, g, hd)
    logits = jnp.einsum("bqkgh,bskh->bkgqs", qg, k).astype(jnp.float32) * scale
    logits = logits + (bias[:, None, None] if bias.ndim == 3
                       else bias[None, None, None])
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w, v)
    return out.reshape(B, Sq, H, hd)


def attend_chunked(q: Array, k: Array, v: Array, *, q_positions: Array,
                   k_positions: Array, causal: bool, window: int,
                   prefix_len: int, chunk: int, scale: float,
                   remat_chunk: bool = False) -> Array:
    """Query-chunked attention: peak memory O(chunk × Sk) per head.

    Scans over query chunks; each chunk attends to the full key range with a
    position-derived mask.  Equivalent to attend_full (tested), usable at
    32k+ sequence lengths.

    ``remat_chunk`` checkpoints each chunk step so the backward pass
    recomputes per-chunk scores one at a time instead of materialising every
    chunk's (chunk × Sk) softmax simultaneously — the §Perf memory lever.
    """
    B, Sq, H, hd = q.shape
    nchunks = Sq // chunk
    assert Sq % chunk == 0, f"seq {Sq} not divisible by chunk {chunk}"
    qc = q.reshape(B, nchunks, chunk, H, hd).transpose(1, 0, 2, 3, 4)
    qpos = q_positions.reshape(nchunks, chunk)

    def step(_, inp):
        qi, pi = inp
        bias = _mask_bias(pi, k_positions, causal=causal, window=window,
                          prefix_len=prefix_len)
        oi = attend_full(qi, k, v, bias, scale)
        return None, oi

    if remat_chunk:
        step = jax.checkpoint(step)
    _, out = lax.scan(step, None, (qc, qpos))
    return out.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, hd)


# ---------------------------------------------------------------------------
# Attention block (params + forward)
# ---------------------------------------------------------------------------

def attn_param_shapes(cfg: ArchConfig) -> dict:
    d, H, Kh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    shapes = {
        "ln": (d,),
        "wq": (d, H * hd),
        "wk": (d, Kh * hd),
        "wv": (d, Kh * hd),
        "wo": (H * hd, d),
    }
    if cfg.qkv_bias:
        shapes.update({"bq": (H * hd,), "bk": (Kh * hd,), "bv": (Kh * hd,)})
    return shapes


def init_stacked(rng, shapes: dict, n: int, dtype, scale: float = 0.02) -> dict:
    """Initialise a stack of ``n`` layers of the given param shapes."""
    params = {}
    keys = jax.random.split(rng, len(shapes))
    for key, (name, shp) in zip(keys, sorted(shapes.items())):
        full = (n, *shp) if n else shp
        if name.startswith("b") or name == "ln" or name.endswith("_bias"):
            params[name] = jnp.zeros(full, dtype)
        elif name == "A_log":   # mamba2 A init: log of [1, 16)
            params[name] = jnp.log(
                jax.random.uniform(key, full, jnp.float32, 1.0, 16.0)).astype(dtype)
        elif name == "D":
            params[name] = jnp.ones(full, dtype)
        else:
            params[name] = (jax.random.normal(key, full, jnp.float32) * scale).astype(dtype)
    return params


def attention_fwd(p: dict, x: Array, cfg: ArchConfig, *,
                  positions: Array, cache: Optional[dict] = None,
                  cache_pos: Optional[Array] = None,
                  causal: bool = True, window: int = 0, prefix_len: int = 0,
                  cross_kv: Optional[tuple] = None, seq_chunk: int = 1024,
                  remat_chunk: bool = False, delta: Optional[dict] = None,
                  delta_slots: Optional[Array] = None,
                  delta_mode: str = "jnp"):
    """One attention sub-block (pre-norm, residual added by caller).

    cache: {"k": (B,W,Kh,hd), "v": ..., "pos": (W,) int32} — decode mode
    writes the current token at slot ``cache_pos % W`` and attends over the
    cache.  With a per-slot serving cache (``pos`` shaped (B, W),
    ``cache_pos``/``positions`` batched) every batch row sits at its own
    stream position.  cross_kv: precomputed (k, v) for encoder-decoder
    cross-attention.

    delta/delta_slots: capacity-C per-slot parameter deltas for this layer
    ({leaf_name: (C, *shape)} + (C,) owner slot ids, -1 = empty) — the
    personalized-delta serving overlay (DESIGN.md §9); projections route
    through :func:`repro.kernels.ops.base_delta_matmul`.
    """
    from repro.kernels import ops as _kops
    B, S, d = x.shape
    H, Kh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    scale = 1.0 / math.sqrt(hd)

    def proj(h_, name):
        if delta is not None and name in delta:
            return _kops.base_delta_matmul(h_, p[name], delta[name],
                                           delta_slots, mode=delta_mode)
        return h_ @ p[name]

    ln = p["ln"]
    if delta is not None and "ln" in delta:
        ln = per_slot_param(ln, delta["ln"], delta_slots, B)
    h = rms_norm(x, ln, cfg.norm_eps)
    q = proj(h, "wq").reshape(B, S, H, hd)
    if cross_kv is None:
        k = proj(h, "wk").reshape(B, S, Kh, hd)
        v = proj(h, "wv").reshape(B, S, Kh, hd)
    else:
        k, v = cross_kv
    if cfg.qkv_bias:
        def bias_term(name, nh):
            if delta is not None and name in delta:
                return per_slot_param(p[name], delta[name], delta_slots,
                                      B).reshape(B, 1, nh, hd)
            return p[name].reshape(nh, hd)
        q = q + bias_term("bq", H)
        if cross_kv is None:
            k = k + bias_term("bk", Kh)
            v = v + bias_term("bv", Kh)

    if cfg.rope_theta and cross_kv is None:
        cos_q, sin_q = rope_tables(positions, hd, cfg.rope_theta)
        q = apply_rope(q, cos_q, sin_q)
        k = apply_rope(k, cos_q, sin_q)
    elif cfg.rope_theta and cross_kv is not None:
        cos_q, sin_q = rope_tables(positions, hd, cfg.rope_theta)
        q = apply_rope(q, cos_q, sin_q)

    new_cache = None
    if cache is not None and cache["pos"].ndim == 2:
        # Per-slot serving decode: S == 1, cache_pos (B,), pos rows (B, W).
        # Each slot writes its token at its own ring index and attends only
        # over its own populated positions — refills never align the batch.
        W = cache["k"].shape[1]
        slot = (cache_pos % W).astype(jnp.int32)
        bidx = jnp.arange(B)
        ck = cache["k"].at[bidx, slot].set(k[:, 0].astype(cache["k"].dtype))
        cv = cache["v"].at[bidx, slot].set(v[:, 0].astype(cache["v"].dtype))
        cpos = cache["pos"].at[bidx, slot].set(cache_pos.astype(jnp.int32))
        k_valid = cpos <= cache_pos[:, None]
        bias = _mask_bias_per_slot(positions, cpos, causal=causal,
                                   window=window, k_valid=k_valid)
        out = attend_full(q, ck, cv, bias, scale)
        new_cache = {"k": ck, "v": cv, "pos": cpos}
    elif cache is not None:
        # Decode: S == 1. Write k/v at slot cache_pos % W, attend over cache.
        W = cache["k"].shape[1]
        slot = (cache_pos % W).astype(jnp.int32)
        ck = lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                      (0, slot, 0, 0))
        cv = lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                      (0, slot, 0, 0))
        cpos = lax.dynamic_update_slice(cache["pos"],
                                        cache_pos[None].astype(jnp.int32), (slot,))
        k_valid = cpos <= cache_pos          # populated & not future
        bias = _mask_bias(positions, cpos, causal=causal, window=window,
                          k_valid=k_valid)
        out = attend_full(q, ck, cv, bias, scale)
        new_cache = {"k": ck, "v": cv, "pos": cpos}
    else:
        k_positions = positions if cross_kv is None else \
            jnp.arange(k.shape[1], dtype=jnp.int32)
        use_causal = causal and cross_kv is None
        if S > seq_chunk and S % seq_chunk == 0:
            out = attend_chunked(q, k, v, q_positions=positions,
                                 k_positions=k_positions, causal=use_causal,
                                 window=window, prefix_len=prefix_len,
                                 chunk=seq_chunk, scale=scale,
                                 remat_chunk=remat_chunk)
        else:
            bias = _mask_bias(positions, k_positions, causal=use_causal,
                              window=window, prefix_len=prefix_len)
            out = attend_full(q, k, v, bias, scale)

    out = proj(out.reshape(B, S, H * hd), "wo")
    return out, new_cache


def make_cross_kv(p: dict, enc_out: Array, cfg: ArchConfig):
    """Precompute cross-attention k/v from encoder output (whisper prefill)."""
    B, Se, d = enc_out.shape
    Kh, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    k = (enc_out @ p["wk"]).reshape(B, Se, Kh, hd)
    v = (enc_out @ p["wv"]).reshape(B, Se, Kh, hd)
    if cfg.qkv_bias:
        k = k + p["bk"].reshape(Kh, hd)
        v = v + p["bv"].reshape(Kh, hd)
    return k, v


# ---------------------------------------------------------------------------
# MLP block
# ---------------------------------------------------------------------------

def mlp_param_shapes(cfg: ArchConfig, d_ff: Optional[int] = None) -> dict:
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    if cfg.mlp_act == "gelu_plain":           # non-gated (whisper, vit, roberta)
        return {"ln": (d,), "wi": (d, ff), "wo": (ff, d)}
    return {"ln": (d,), "wi": (d, 2 * ff), "wo": (ff, d)}   # gated: [gate|up]


def mlp_fwd(p: dict, x: Array, cfg: ArchConfig, *,
            delta: Optional[dict] = None,
            delta_slots: Optional[Array] = None,
            delta_mode: str = "jnp") -> Array:
    from repro.kernels import ops as _kops

    def proj(h_, name):
        if delta is not None and name in delta:
            return _kops.base_delta_matmul(h_, p[name], delta[name],
                                           delta_slots, mode=delta_mode)
        return h_ @ p[name]

    ln = p["ln"]
    if delta is not None and "ln" in delta:
        ln = per_slot_param(ln, delta["ln"], delta_slots, x.shape[0])
    h = rms_norm(x, ln, cfg.norm_eps)
    act = act_fn(cfg.mlp_act)
    if cfg.mlp_act == "gelu_plain":
        return proj(act(proj(h, "wi")), "wo")
    ff = p["wi"].shape[-1] // 2
    gu = proj(h, "wi")
    gate, up = gu[..., :ff], gu[..., ff:]
    return proj(act(gate) * up, "wo")
