"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

K/V are compressed into a low-rank latent ``c_kv`` (rank ``kv_lora_rank``)
plus a single shared RoPE key channel.  The decode KV cache stores only
``(c_kv, k_rope)`` — ``kv_lora + qk_rope_dim`` floats per token instead of
``2·H·hd`` — which is the arch's memory-roofline win for decode_32k.

At attention time the latent is re-expanded through ``w_ukv`` (the
"naive" formulation; the weight-absorbed matmul reordering is an equivalent
optimisation we note for §Perf but keep out of the reference path).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models.blocks import (NEG_INF, _mask_bias, _mask_bias_per_slot,
                                 apply_rope, rms_norm, rope_tables)

Array = jax.Array


def mla_param_shapes(cfg: ArchConfig) -> dict:
    d, H = cfg.d_model, cfg.n_heads
    qk = cfg.qk_nope_dim + cfg.qk_rope_dim
    return {
        "ln": (d,),
        "wq": (d, H * qk),
        "w_dkv": (d, cfg.kv_lora_rank),                    # down: x -> latent
        "kv_ln": (cfg.kv_lora_rank,),
        "w_krope": (d, cfg.qk_rope_dim),                   # shared rope key
        "w_ukv": (cfg.kv_lora_rank, H * (cfg.qk_nope_dim + cfg.v_head_dim)),
        "wo": (H * cfg.v_head_dim, d),
    }


def _expand_kv(p: dict, ckv: Array, cfg: ArchConfig):
    """(B,S,lora) -> k_nope (B,S,H,nope), v (B,S,H,v_dim)."""
    B, S, _ = ckv.shape
    H = cfg.n_heads
    kv = ckv @ p["w_ukv"]
    kv = kv.reshape(B, S, H, cfg.qk_nope_dim + cfg.v_head_dim)
    return kv[..., :cfg.qk_nope_dim], kv[..., cfg.qk_nope_dim:]


def mla_fwd(p: dict, x: Array, cfg: ArchConfig, *, positions: Array,
            cache: Optional[dict] = None, cache_pos: Optional[Array] = None,
            seq_chunk: int = 1024, window: int = 0):
    """MLA sub-block forward. Returns (out, new_cache)."""
    B, S, d = x.shape
    H = cfg.n_heads
    nope, rope_d, v_dim = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    scale = 1.0 / math.sqrt(nope + rope_d)

    h = rms_norm(x, p["ln"], cfg.norm_eps)
    q = (h @ p["wq"]).reshape(B, S, H, nope + rope_d)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    cos, sin = rope_tables(positions, rope_d, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)

    ckv = rms_norm(h @ p["w_dkv"], p["kv_ln"], cfg.norm_eps)   # (B,S,lora)
    k_rope = (h @ p["w_krope"]).reshape(B, S, 1, rope_d)
    k_rope = apply_rope(k_rope, cos, sin)

    new_cache = None
    if cache is not None:
        # --- absorbed-matmul decode (DeepSeek-V2 §2.1.2) -----------------
        # Fold w_ukv into the query/output side so attention runs directly
        # against the latent cache: no (B,W,H,nope+v) expansion per step.
        # Cost per token: O(W·lora) instead of O(W·H·(nope+v)).
        W = cache["ckv"].shape[1]
        slot = (cache_pos % W).astype(jnp.int32)
        if cache["pos"].ndim == 2:
            # per-slot serving cache: pos rows (B, W), cache_pos (B,) — each
            # slot writes its own ring index (see blocks.attention_fwd)
            bidx = jnp.arange(B)
            cckv = cache["ckv"].at[bidx, slot].set(
                ckv[:, 0].astype(cache["ckv"].dtype))
            ckr = cache["krope"].at[bidx, slot].set(
                k_rope[:, 0, 0].astype(cache["krope"].dtype))
            cpos = cache["pos"].at[bidx, slot].set(cache_pos.astype(jnp.int32))
            k_valid = cpos <= cache_pos[:, None]
            bias = _mask_bias_per_slot(positions, cpos, causal=True,
                                       window=window, k_valid=k_valid)
        else:
            cckv = lax.dynamic_update_slice(cache["ckv"],
                                            ckv.astype(cache["ckv"].dtype),
                                            (0, slot, 0))
            ckr = lax.dynamic_update_slice(cache["krope"],
                                           k_rope[:, :, 0].astype(cache["krope"].dtype),
                                           (0, slot, 0))
            cpos = lax.dynamic_update_slice(cache["pos"],
                                            cache_pos[None].astype(jnp.int32),
                                            (slot,))
            k_valid = cpos <= cache_pos
            bias = _mask_bias(positions, cpos, causal=True, window=window,
                              k_valid=k_valid)
        new_cache = {"ckv": cckv, "krope": ckr, "pos": cpos}

        lora = cfg.kv_lora_rank
        wk = p["w_ukv"].reshape(lora, H, nope + v_dim)[..., :nope]  # (l,H,n)
        wv = p["w_ukv"].reshape(lora, H, nope + v_dim)[..., nope:]  # (l,H,v)
        q_eff = jnp.einsum("bqhn,lhn->bqhl", q_nope, wk)            # latent q
        lg = (jnp.einsum("bqhl,bsl->bhqs", q_eff, cckv)
              + jnp.einsum("bqhr,bsr->bhqs", q_rope, ckr)).astype(jnp.float32)
        wgt = jax.nn.softmax(lg * scale + (bias[:, None] if bias.ndim == 3
                                           else bias[None, None]), axis=-1)
        ctx = jnp.einsum("bhqs,bsl->bqhl", wgt.astype(cckv.dtype), cckv)
        out = jnp.einsum("bqhl,lhv->bqhv", ctx, wv)
        out = out.reshape(B, S, H * v_dim) @ p["wo"]
        return out, new_cache
    else:
        k_nope, v = _expand_kv(p, ckv, cfg)
        k_r = k_rope
        bias = None
        Sk = S

    # logits = q_nope·k_nope + q_rope·k_rope  (rope part shared across heads)
    if bias is None and S > seq_chunk and S % seq_chunk == 0:
        # chunked prefill
        nck = S // seq_chunk
        qn = q_nope.reshape(B, nck, seq_chunk, H, nope).transpose(1, 0, 2, 3, 4)
        qr = q_rope.reshape(B, nck, seq_chunk, H, rope_d).transpose(1, 0, 2, 3, 4)
        qp = positions.reshape(nck, seq_chunk)

        def step(_, inp):
            qni, qri, pi = inp
            b = _mask_bias(pi, positions, causal=True, window=window)
            lg = (jnp.einsum("bqhn,bshn->bhqs", qni, k_nope)
                  + jnp.einsum("bqhr,bsxr->bhqs", qri, k_r)).astype(jnp.float32)
            w = jax.nn.softmax(lg * scale + b[None, None], axis=-1).astype(v.dtype)
            return None, jnp.einsum("bhqs,bshv->bqhv", w, v)

        _, out = lax.scan(step, None, (qn, qr, qp))
        out = out.transpose(1, 0, 2, 3, 4).reshape(B, S, H, v_dim)
    else:
        if bias is None:
            bias = _mask_bias(positions, positions, causal=True, window=window)
        lg = (jnp.einsum("bqhn,bshn->bhqs", q_nope, k_nope)
              + jnp.einsum("bqhr,bsxr->bhqs", q_rope, k_r)).astype(jnp.float32)
        w = jax.nn.softmax(lg * scale + bias[None, None], axis=-1).astype(v.dtype)
        out = jnp.einsum("bhqs,bshv->bqhv", w, v)

    out = out.reshape(B, S, H * v_dim) @ p["wo"]
    return out, new_cache
