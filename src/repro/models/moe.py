"""Mixture-of-Experts layer with sort-based token dispatch.

Two dispatch implementations:

* :func:`moe_fwd` — production path: top-k routing, *sort-based* dispatch to
  ``(E, C)`` capacity slots (argsort + gather/scatter).  FLOPs are dominated
  by the expert matmuls — no O(T²) one-hot dispatch einsums — so the roofline
  numbers reflect real MoE cost.  On a sharded mesh the (E,C,d) expert
  buffers carry the all-to-all.
* :func:`moe_fwd_dense` — reference path: computes *all* experts and combines
  with gate weights.  O(E/topk) more FLOPs, numerically exact for testing
  the dispatch path (tokens below capacity must match).

Load-balance auxiliary loss follows Switch-Transformer: E · Σ_e f_e · p_e.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models.blocks import act_fn, rms_norm

Array = jax.Array


class MoEStats(NamedTuple):
    aux_loss: Array        # scalar load-balance loss
    dropped_frac: Array    # fraction of routed tokens dropped by capacity


def moe_param_shapes(cfg: ArchConfig) -> dict:
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    gated = cfg.mlp_act != "gelu_plain"
    wi_cols = 2 * ff if gated else ff
    shapes = {
        "ln": (d,),
        "router": (d, E),
        "wi_e": (E, d, wi_cols),
        "wo_e": (E, ff, d),
    }
    if cfg.n_shared_experts:
        shapes.update({
            "wi_s": (d, wi_cols * cfg.n_shared_experts),
            "wo_s": (ff * cfg.n_shared_experts, d),
        })
    return shapes


def capacity(n_tokens: int, cfg: ArchConfig) -> int:
    c = int(math.ceil(n_tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor))  # repro: allow[host-sync] -- python scalar arithmetic on static token counts
    return max(c, cfg.top_k)


def _expert_ffn(x: Array, wi: Array, wo: Array, act_name: str,
                shard=None) -> Array:
    """x: (E, C, d), wi: (E, d, {1,2}ff), wo: (E, ff, d)."""
    act = act_fn(act_name)
    h = jnp.einsum("ecd,edf->ecf", x, wi)
    if shard is not None:
        h = shard(h, "expert_ecf")      # Megatron hidden layout hint
    if act_name != "gelu_plain":
        ff = wo.shape[1]
        h = act(h[..., :ff]) * h[..., ff:]
    else:
        h = act(h)
    return jnp.einsum("ecf,efd->ecd", h, wo)


def _route(h2d: Array, router: Array, cfg: ArchConfig):
    """Return (top_w, top_idx, aux_loss). h2d: (T, d)."""
    logits = (h2d @ router).astype(jnp.float32)          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_idx = lax.top_k(probs, cfg.top_k)         # (T, k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)
    # Switch load-balance loss: E * sum_e f_e * p_e
    E = cfg.n_experts
    f = jnp.mean(jax.nn.one_hot(top_idx, E, dtype=jnp.float32).sum(1), axis=0)
    p = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(f * p) * cfg.router_aux_weight
    return top_w.astype(h2d.dtype), top_idx, aux


def moe_fwd(p: dict, x: Array, cfg: ArchConfig, shard=None,
            local_dispatch: bool = False):
    """Sort-based MoE block. x: (B, S, d) → (B, S, d), MoEStats.

    ``local_dispatch``: route per sample (vmap over B) so the sort / capacity
    assignment never crosses the data-sharded batch dim — removes the
    global-sort collectives on a sharded mesh (§Perf).  Capacity becomes
    per-sample (ceil(S·k/E·cf)), the more common production semantics.
    """
    B, S, d = x.shape
    h = rms_norm(x, p["ln"], cfg.norm_eps)

    if local_dispatch:
        C = capacity(S, cfg)
        out2d, aux, dropped = jax.vmap(
            lambda hb: _dispatch_2d(p, hb, cfg, C, shard=None))(
                h.reshape(B, S, d))
        out2d = out2d.reshape(B, S, d)
        aux = jnp.mean(aux)
        dropped = jnp.mean(dropped)
    else:
        T = B * S
        C = capacity(T, cfg)
        out2d, aux, dropped = _dispatch_2d(p, h.reshape(T, d), cfg, C,
                                           shard=shard)
        out2d = out2d.reshape(B, S, d)

    # --- shared experts (always-on, deepseek-v2) -------------------------
    if cfg.n_shared_experts:
        act = act_fn(cfg.mlp_act)
        h2d = h.reshape(B * S, d)
        gu = h2d @ p["wi_s"]
        if cfg.mlp_act != "gelu_plain":
            ffs = p["wo_s"].shape[0]
            extra = (act(gu[..., :ffs]) * gu[..., ffs:]) @ p["wo_s"]
        else:
            extra = act(gu) @ p["wo_s"]
        out2d = out2d + extra.reshape(B, S, d)

    return out2d, MoEStats(aux, dropped)


def _dispatch_2d(p: dict, h2d: Array, cfg: ArchConfig, C: int, shard=None):
    """Core sort-based dispatch over flat tokens. h2d: (T, d)."""
    T, d = h2d.shape
    k, E = cfg.top_k, cfg.n_experts

    top_w, top_idx, aux = _route(h2d, p["router"], cfg)

    # --- sort-based dispatch --------------------------------------------
    n = T * k
    flat_e = top_idx.reshape(n)                          # expert of each (token, slot)
    flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    flat_w = top_w.reshape(n)
    order = jnp.argsort(flat_e, stable=True)             # group by expert
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    # rank within expert group: position - first index of this expert value
    first = jnp.searchsorted(se, se, side="left")
    rank = jnp.arange(n, dtype=jnp.int32) - first.astype(jnp.int32)
    keep = rank < C
    slot = jnp.where(keep, se * C + rank, E * C)         # E*C = drop bin

    # slot -> token tables (scatter; drop bin trimmed off)
    token_of = jnp.zeros(E * C + 1, jnp.int32).at[slot].set(st, mode="drop")[:-1]
    w_of = jnp.zeros(E * C + 1, h2d.dtype).at[slot].set(sw, mode="drop")[:-1]
    valid = jnp.zeros(E * C + 1, jnp.bool_).at[slot].set(keep, mode="drop")[:-1]

    expert_in = jnp.where(valid[:, None], h2d[token_of], 0).reshape(E, C, d)
    if shard is not None:
        expert_in = shard(expert_in, "expert_ecd")
    expert_out = _expert_ffn(expert_in, p["wi_e"], p["wo_e"], cfg.mlp_act,
                             shard=shard)
    if shard is not None:
        expert_out = shard(expert_out, "expert_ecd")
    flat_out = expert_out.reshape(E * C, d) * (w_of * valid)[:, None]

    out2d = jnp.zeros((T, d), h2d.dtype).at[token_of].add(flat_out)
    dropped = 1.0 - jnp.sum(keep.astype(jnp.float32)) / n
    return out2d, aux, dropped


def moe_fwd_dense(p: dict, x: Array, cfg: ArchConfig):
    """Reference: run every expert on every token, gate-combine (no capacity)."""
    B, S, d = x.shape
    T = B * S
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    h2d = h.reshape(T, d)
    top_w, top_idx, aux = _route(h2d, p["router"], cfg)

    all_out = _expert_ffn(
        jnp.broadcast_to(h2d, (cfg.n_experts, T, d)), p["wi_e"], p["wo_e"],
        cfg.mlp_act)                                      # (E, T, d)
    gates = jnp.zeros((T, cfg.n_experts), x.dtype)
    gates = gates.at[jnp.arange(T)[:, None], top_idx].set(top_w)
    out2d = jnp.einsum("te,etd->td", gates, all_out)

    if cfg.n_shared_experts:
        act = act_fn(cfg.mlp_act)
        gu = h2d @ p["wi_s"]
        if cfg.mlp_act != "gelu_plain":
            ffs = p["wo_s"].shape[0]
            out2d = out2d + (act(gu[..., :ffs]) * gu[..., ffs:]) @ p["wo_s"]
        else:
            out2d = out2d + act(gu) @ p["wo_s"]
    return out2d.reshape(B, S, d), MoEStats(aux, jnp.zeros(()))
