from repro.ckpt.checkpoint import (extract_delta,  # noqa: F401
                                   latest_step, load_checkpoint_arrays,
                                   restore_checkpoint, save_checkpoint,
                                   sweep_tmp_dirs)
