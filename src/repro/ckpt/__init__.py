from repro.ckpt.checkpoint import (all_checkpoint_steps,  # noqa: F401
                                   extract_delta, latest_intact_step,
                                   latest_step, load_checkpoint_arrays,
                                   restore_checkpoint, save_checkpoint,
                                   sweep_tmp_dirs, verify_checkpoint)
