from repro.ckpt.checkpoint import (latest_step,  # noqa: F401
                                   load_checkpoint_arrays,
                                   restore_checkpoint, save_checkpoint,
                                   sweep_tmp_dirs)
