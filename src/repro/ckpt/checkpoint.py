"""Dependency-free pytree checkpointing (orbax is not available offline).

Format: one ``step_<n>/`` directory per checkpoint containing

* ``arrays.npz``  — flattened leaves keyed by escaped tree paths
* ``manifest.json`` — tree structure, dtypes, FL round metadata

Atomic via write-to-tmp + rename.  Supports partial restore (e.g. restoring
only the selected-layer substack on resource-constrained clients — the
paper's clients never hold optimizer state for frozen layers).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Optional

import jax
import numpy as np

PyTree = Any
_SEP = "/"


def _flatten(tree: PyTree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(entry) -> str:
    if hasattr(entry, "key"):
        return str(entry.key)
    if hasattr(entry, "idx"):
        return f"[{entry.idx}]"
    return str(entry)


def save_checkpoint(directory: str, step: int, params: PyTree,
                    extra: Optional[dict] = None) -> str:
    os.makedirs(directory, exist_ok=True)
    target = os.path.join(directory, f"step_{step:08d}")
    flat = _flatten(params)
    manifest = {
        "step": step,
        "keys": sorted(flat.keys()),
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
        "shapes": {k: list(v.shape) for k, v in flat.items()},
        "extra": extra or {},
    }
    tmp = tempfile.mkdtemp(dir=directory)
    try:
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{k.replace("/", "|"): v for k, v in flat.items()})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        if os.path.exists(target):
            shutil.rmtree(target)
        os.rename(tmp, target)
    except Exception:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return target


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_")]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, template: PyTree,
                       step: Optional[int] = None) -> tuple[PyTree, dict]:
    """Restore into the structure of ``template`` (shapes must match)."""
    step = step if step is not None else latest_step(directory)
    assert step is not None, f"no checkpoints under {directory}"
    target = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(target, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(target, "arrays.npz")) as z:
        flat = {k.replace("|", "/"): z[k] for k in z.files}

    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(template)
    new_leaves = []
    for path, leaf in leaves_with_path:
        key = _SEP.join(_path_str(p) for p in path)
        arr = flat[key]
        assert tuple(arr.shape) == tuple(leaf.shape), \
            f"{key}: ckpt {arr.shape} vs template {leaf.shape}"
        new_leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, new_leaves), manifest
