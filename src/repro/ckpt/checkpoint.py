"""Dependency-free pytree checkpointing (orbax is not available offline).

Format: one ``step_<n>/`` directory per checkpoint containing

* ``arrays.npz``  — flattened leaves keyed by escaped tree paths
* ``manifest.json`` — tree structure, dtypes, FL round metadata

Atomic via write-to-tmp + rename; orphaned ``tmp*`` dirs from interrupted
saves are swept on the next save.  Supports partial restore
(``partial=True``: template keys absent from the archive keep the template
leaf — e.g. restoring only the selected-layer substack on
resource-constrained clients, which never hold optimizer state for frozen
layers).  The returned manifest reports ``restored`` / ``skipped`` key
lists either way.

Self-healing (DESIGN.md §12): the manifest carries a per-array crc32
``checksums`` map; :func:`verify_checkpoint` detects torn writes, media
bitflips and mangled manifests without deserialising into a template, and
:func:`latest_intact_step` scans newest-first for the first checkpoint
that still verifies — the restore-time fallback ``FLServer.restore_state``
uses to survive a corrupted latest step.  Checkpoints written before the
checksum field verify structurally (manifest + loadable arrays + key set)
and are trusted otherwise.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import zlib
from typing import Any, Optional

import jax
import numpy as np

PyTree = Any
_SEP = "/"


def _flatten(tree: PyTree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(entry) -> str:
    if hasattr(entry, "key"):
        return str(entry.key)
    if hasattr(entry, "idx"):
        return f"[{entry.idx}]"
    return str(entry)


def sweep_tmp_dirs(directory: str) -> list[str]:
    """Remove orphaned ``tmp*`` dirs left behind by interrupted saves."""
    swept = []
    if not os.path.isdir(directory):
        return swept
    for d in os.listdir(directory):
        path = os.path.join(directory, d)
        if d.startswith("tmp") and os.path.isdir(path):
            shutil.rmtree(path, ignore_errors=True)
            swept.append(path)
    return swept


def save_checkpoint(directory: str, step: int, params: PyTree,
                    extra: Optional[dict] = None) -> str:
    os.makedirs(directory, exist_ok=True)
    sweep_tmp_dirs(directory)
    target = os.path.join(directory, f"step_{step:08d}")
    flat = _flatten(params)
    manifest = {
        "step": step,
        "keys": sorted(flat.keys()),
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
        "shapes": {k: list(v.shape) for k, v in flat.items()},
        # per-array integrity: lets verify_checkpoint catch silent media
        # damage (bitflips) that np.load would happily deserialise
        "checksums": {k: zlib.crc32(np.ascontiguousarray(v).tobytes())
                      for k, v in flat.items()},
        "extra": extra or {},
    }
    tmp = tempfile.mkdtemp(dir=directory)
    try:
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{k.replace("/", "|"): v for k, v in flat.items()})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        if os.path.exists(target):
            shutil.rmtree(target)
        os.rename(tmp, target)
    except Exception:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return target


def all_checkpoint_steps(directory: str) -> list[int]:
    """Every ``step_*/`` step under ``directory``, ascending."""
    if not os.path.isdir(directory):
        return []
    steps = []
    for d in os.listdir(directory):
        if not d.startswith("step_"):
            continue
        try:
            steps.append(int(d.split("_")[1]))
        except (IndexError, ValueError):
            continue            # stray non-checkpoint entry, not ours to judge
    return sorted(steps)


def latest_step(directory: str) -> Optional[int]:
    steps = all_checkpoint_steps(directory)
    return steps[-1] if steps else None


def verify_checkpoint(directory: str, step: int) -> tuple[bool, str]:
    """Is checkpoint ``step`` intact?  Returns ``(ok, why)``.

    Checks, in damage-detection order: manifest parses, ``arrays.npz``
    deserialises, the key set matches the manifest, and (when the manifest
    carries ``checksums`` — checkpoints from before the field verify
    structurally only) every array's crc32 matches.  Never raises on
    damage — a corrupt checkpoint is an expected input here, and the
    caller (``latest_intact_step``) needs the verdict, not the traceback.
    """
    target = os.path.join(directory, f"step_{step:08d}")
    try:
        with open(os.path.join(target, "manifest.json")) as f:
            manifest = json.load(f)
    except (OSError, ValueError) as e:
        return False, f"manifest unreadable: {e}"
    try:
        with np.load(os.path.join(target, "arrays.npz")) as z:
            flat = {k.replace("|", "/"): z[k] for k in z.files}
    except Exception as e:  # repro: allow[exception-swallow] -- np.load raises zipfile/OSError/ValueError zoo on torn archives; verdict returned, not ignored
        return False, f"arrays unreadable: {e}"
    missing = set(manifest.get("keys", [])) - set(flat)
    if missing:
        return False, f"arrays missing keys: {sorted(missing)[:3]}"
    for key, want in manifest.get("checksums", {}).items():
        if key not in flat:
            continue            # already reported via the key-set check
        got = zlib.crc32(np.ascontiguousarray(flat[key]).tobytes())
        if got != want:
            return False, f"checksum mismatch on {key!r}"
    return True, "ok"


def latest_intact_step(directory: str
                       ) -> tuple[Optional[int], list[tuple[int, str]]]:
    """Newest checkpoint that verifies, plus the ``(step, why)`` list of
    newer ones skipped as corrupt.  ``(None, skipped)`` when nothing
    survives — resume from scratch."""
    skipped: list[tuple[int, str]] = []
    for step in reversed(all_checkpoint_steps(directory)):
        ok, why = verify_checkpoint(directory, step)
        if ok:
            return step, skipped
        skipped.append((step, why))
    return None, skipped


def load_checkpoint_arrays(directory: str, step: Optional[int] = None
                           ) -> tuple[dict[str, np.ndarray], dict]:
    """The raw flat ``{path: array}`` archive + manifest, no template."""
    step = step if step is not None else latest_step(directory)
    assert step is not None, f"no checkpoints under {directory}"
    target = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(target, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(target, "arrays.npz")) as z:
        flat = {k.replace("|", "/"): z[k] for k in z.files}
    return flat, manifest


def extract_delta(directory: str, base_params: PyTree, cfg,
                  step: Optional[int] = None, *,
                  layers=None, atol: float = 0.0):
    """Diff a saved FL round against ``base_params`` into a sparse
    :class:`repro.serve.deltas.DeltaRecord` — the export path from a round
    checkpoint to the personalized-delta serving store (DESIGN.md §9).

    Handles both bare-params checkpoints and FLServer's wrapped trees
    (keys prefixed ``params/``).  ``layers``: global mask indices to
    export; ``None`` auto-detects the rows that moved by more than
    ``atol`` — exactly the client's selected layers.
    """
    from repro.serve.deltas import delta_from_params  # lazy: serve -> ckpt

    flat, _ = load_checkpoint_arrays(directory, step)
    prefix = "params/" if any(k.startswith("params/") for k in flat) else ""
    tuned: dict[str, dict[str, np.ndarray]] = {}
    for key, arr in flat.items():
        if prefix and not key.startswith(prefix):
            continue
        parts = key[len(prefix):].split(_SEP)
        if len(parts) != 2:
            continue
        seg, leaf = parts
        tuned.setdefault(seg, {})[leaf] = arr
    return delta_from_params(base_params, tuned, cfg, layers=layers,
                             atol=atol)


def restore_checkpoint(directory: str, template: PyTree,
                       step: Optional[int] = None, *,
                       partial: bool = False) -> tuple[PyTree, dict]:
    """Restore into the structure of ``template`` (shapes must match).

    With ``partial=True``, template keys absent from the archive keep the
    template leaf instead of raising.  The manifest gains ``restored`` and
    ``skipped`` lists of tree paths.
    """
    flat, manifest = load_checkpoint_arrays(directory, step)

    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(template)
    new_leaves, restored, skipped = [], [], []
    for path, leaf in leaves_with_path:
        key = _SEP.join(_path_str(p) for p in path)
        if key not in flat:
            if not partial:
                raise KeyError(
                    f"{key!r} missing from checkpoint "
                    f"{directory} step {manifest['step']} "
                    f"(pass partial=True to keep the template leaf)")
            skipped.append(key)
            new_leaves.append(leaf)
            continue
        arr = flat[key]
        assert tuple(arr.shape) == tuple(leaf.shape), \
            f"{key}: ckpt {arr.shape} vs template {leaf.shape}"
        restored.append(key)
        new_leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    manifest["restored"] = restored
    manifest["skipped"] = skipped
    return jax.tree_util.tree_unflatten(treedef, new_leaves), manifest
