"""Client-side local training (Eq. 2-4) and the selection-probe step (§4.2).

Everything is jit-compiled once per architecture and reused across rounds
and clients — masks, batches and learning rate are runtime arrays.

Two execution granularities share the same per-client math:

* per-client: :meth:`Client.local_update` / :meth:`Client.probe` — one jit
  call per cohort member (the sequential oracle).
* per-cohort: :meth:`Client.cohort_update` / :meth:`Client.probe_cohort` —
  the vectorized engine: ``jax.vmap`` over the stacked cohort axis, with the
  Eq.(5)-(7) weighted aggregation and Eq.(6) apply fused into the same XLA
  program, so one round's hot path is a single dispatch (the single-host
  analogue of the mesh step in sharding/fl_step.py).
* fused probe+update: :meth:`Client.probe_update_cohort` — one program that
  runs the cohort update *and* the next round's selection probe on the
  updated params; the streaming round pipeline (core/server.py) uses it
  when every round re-selects (``selection_period == 1``).

Probes are requirement-trimmed: every probe entry point takes a static
``reqs`` tuple (the strategy's declared ``probe_requirements``) and
computes only those stats, plus an optional static ``score_fn`` — a
strategy's device-side scoring fused into the same XLA program
(repro.api.strategy, DESIGN.md §6).

Jit caches are hoisted out of ``Client`` instances into a module-level
cache keyed on ``(ArchConfig, RuntimeConfig)`` (both frozen/hashable), so
benchmark sweeps and multi-server runs that rebuild ``FLServer``/``Client``
for the same architecture share compiled programs instead of recompiling.
Static shapes and τ are handled by jax's own per-function cache, which the
shared callables make global.  Models with a custom ``shard`` callable
bypass the cache (their lowering differs).  ``jit_cache_stats()`` exposes
hit/miss counters for tests and benchmarks.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import masks as M
from repro.core.strategies import PROBE_KEYS
from repro.kernels import ops
from repro.models.model import (Model, apply_layer_mask, segment_cuts,
                                split_mask, trainable_slice)

Array = jax.Array
PyTree = Any


# -- module-level jit suite cache -------------------------------------------
_JIT_CACHE: dict = {}
_JIT_STATS = {"hits": 0, "misses": 0, "uncached": 0}

def jit_cache_stats() -> dict:
    """Hit/miss counters + entry count for the shared jit suite cache, plus
    per-entry-point compiled-program counts (``programs``): how many
    distinct traces each suite function holds across every cached suite.
    The mask-aware engine's entries are keyed on the static prefix cut, so
    their counts are bounded by the number of distinct cuts seen — at most
    L+1 — and must not grow round over round (tests/test_jit_cache.py).
    """
    programs: dict[str, int] = {}
    for suite in _JIT_CACHE.values():
        for name, fn in suite.items():
            programs[name] = programs.get(name, 0) + fn._cache_size()
    return dict(_JIT_STATS, entries=len(_JIT_CACHE), programs=programs)


def _dev_f32(x) -> Array:
    """Stage a host value (np array / python scalar) on device as f32 via an
    *explicit* transfer.  The raw wrappers take host-produced masks/sizes/lr
    every round; ``jnp.asarray(x, jnp.float32)`` routes python scalars
    through an implicit transfer that ``jax.transfer_guard("disallow")``
    (REPRO_STRICT=1) rejects, while ``device_put`` of a host-final np value
    is sanctioned."""
    return jax.device_put(np.asarray(x, np.float32))  # repro: allow[host-sync] -- h2d staging of host-final round inputs, not a device sync


def _dev_i32(x) -> Array:
    """Integer twin of :func:`_dev_f32` (fault-code rows et al.)."""
    return jax.device_put(np.asarray(x, np.int32))  # repro: allow[host-sync] -- h2d staging of host-final round inputs, not a device sync


def masked_suffix_sgd(trainable: PyTree, grads: PyTree, mask: Array, lr,
                      cut: int, cfg, *, mode: str | None = None) -> PyTree:
    """Fused Eq.(3) apply on the trainable suffix slice — the mask-aware
    τ-scan's hot-path call site for kernels/masked_update.py.

    Each segment's stacked leaves get one row-mask-scaled AXPY
    (θ ← θ − η·m(l)·g) through :func:`repro.kernels.ops.masked_sgd_update`:
    the Pallas kernel on TPU, its bit-identical pure-jnp fallback elsewhere
    (``mode`` forces either; tests/test_kernels.py pins the parity).
    """
    cuts = segment_cuts(cut, cfg)
    mparts = split_mask(mask, cfg)
    out = {}
    for path, sub in trainable.items():
        m = mparts[path][cuts[path]:]
        out[path] = ops.masked_sgd_update(sub, grads[path], m, lr, mode=mode)
    return out


def clear_jit_cache() -> None:
    _JIT_CACHE.clear()
    for k in _JIT_STATS:
        _JIT_STATS[k] = 0


# -- program-auditor enumeration hook ---------------------------------------

def _abstract_batch(cfg, lead: tuple, seq: int) -> dict:
    """ShapeDtypeStruct batch with leading axes ``lead`` (family-aware)."""
    SDS = jax.ShapeDtypeStruct
    dt = jnp.dtype(cfg.dtype)
    if cfg.family == "vlm":
        batch = {"patches": SDS(lead + (cfg.n_prefix_tokens, cfg.d_model), dt)}
        if cfg.task == "lm":
            batch["tokens"] = SDS(
                lead + (max(seq - cfg.n_prefix_tokens, 4),), jnp.int32)
        else:
            batch["label"] = SDS(lead, jnp.int32)
        return batch
    if cfg.family == "audio":
        return {"frames": SDS(lead + (cfg.enc_seq, cfg.d_model), dt),
                "tokens": SDS(lead + (seq,), jnp.int32)}
    return {"tokens": SDS(lead + (seq,), jnp.int32)}


def suite_program_specs(model: "Model", *, cohort: int = 2, tau: int = 2,
                        batch: int = 2, seq: int = 16, sel_batches: int = 1,
                        cuts: "tuple | None" = None) -> list[dict]:
    """Shape-only audit specs for every training-suite program family.

    One dict per program the jit cache can hold for this (cfg, runtime):
    the dense round step, every masked-cut variant (``cuts`` defaults to
    all L+1, including the cut=L forward-only program), the cohort probe,
    and the fused probe+update (dense + one masked representative).  The
    program auditor (repro.analysis.program) lowers each entry's ``fn`` on
    its abstract ``args`` — nothing here allocates or executes.  Plain
    dicts, not analysis types: core must not import the auditor.
    """
    client = Client(model)
    cfg = model.cfg
    SDS = jax.ShapeDtypeStruct
    from repro.models.model import init_params
    params = jax.eval_shape(lambda k: init_params(cfg, k),
                            SDS((2,), jnp.uint32))
    L = model.n_selectable
    batches = _abstract_batch(cfg, (cohort, tau, batch), seq)
    pbatches = _abstract_batch(cfg, (cohort, sel_batches, batch), seq)
    masks = SDS((cohort, L), jnp.float32)
    sizes = SDS((cohort,), jnp.float32)
    lr = SDS((), jnp.float32)
    reqs = ("grad_sq_norms",)
    if cuts is None:
        cuts = tuple(range(L + 1))
    # training entries deliberately do NOT donate: params feed the probe /
    # sequential-oracle paths of the same round (meta records it so the
    # donation contract skips them)
    base = dict(static_argnums=(), donate_argnums=(), weight_argnums=(0,))
    specs = [
        dict(base, name="fl_step", fn=client._cohort_update,
             args=(params, batches, masks, sizes, lr),
             meta={"kind": "fl_step", "single_host": True}),
        dict(base, name="probe", fn=client._probe_cohort,
             args=(params, pbatches, reqs, None), static_argnums=(2, 3),
             meta={"kind": "probe", "single_host": True}),
        dict(base, name="probe_update", fn=client._probe_update_cohort,
             args=(params, batches, masks, sizes, lr, pbatches, reqs, None),
             static_argnums=(6, 7),
             meta={"kind": "probe_update", "single_host": True}),
        # the fault path's one extra variant (DESIGN.md §12): survivor
        # mask / corruption codes / guard scales are runtime arrays
        dict(base, name="fl_step_guarded",
             fn=client._cohort_update_guarded,
             args=(params, batches, masks, sizes, lr,
                   SDS((cohort,), jnp.float32), SDS((cohort,), jnp.int32),
                   SDS((), jnp.float32), SDS((), jnp.float32)),
             meta={"kind": "fl_step_guarded", "single_host": True}),
    ]
    mid = cuts[len(cuts) // 2] if cuts else 0
    for cut in cuts:
        specs.append(dict(
            base, name=f"fl_step_masked/cut{cut}",
            fn=client._cohort_update_masked,
            args=(params, batches, masks, sizes, lr, int(cut)),
            static_argnums=(5,),
            meta={"kind": "fl_step_masked", "cut": int(cut),
                  "n_selectable": L, "single_host": True}))
    specs.append(dict(
        base, name=f"probe_update_masked/cut{mid}",
        fn=client._probe_update_cohort_masked,
        args=(params, batches, masks, sizes, lr, pbatches, int(mid), reqs,
              None),
        static_argnums=(6, 7, 8),
        meta={"kind": "probe_update_masked", "cut": int(mid),
              "single_host": True}))
    return specs


def probe_stats_dict(stats) -> dict[str, np.ndarray]:
    """Materialise a probe result to host numpy.  Accepts the stat dict the
    probe impls return, or the legacy (sq, mean, var, p_sq) 4-tuple."""
    if isinstance(stats, dict):
        return {k: np.asarray(v) for k, v in stats.items()}
    sq, mean, var, p_sq = stats
    return {"grad_sq_norms": np.asarray(sq), "grad_means": np.asarray(mean),
            "grad_vars": np.asarray(var), "param_sq_norms": np.asarray(p_sq)}


class Client:
    """Stateless executor for local training; data is passed per call."""

    def __init__(self, model: Model):
        self.model = model
        self.cfg = model.cfg
        # The compiled suite depends only on (cfg, runtime): Model is a
        # stateless facade, so a suite built against the first Model seen
        # for this key serves every later instance with the same configs.
        key = (None if getattr(model, "custom_shard", False)
               else (model.cfg, model.runtime))
        suite = _JIT_CACHE.get(key) if key is not None else None
        if suite is None:
            # probe entries take static (reqs, score_fn) tail args: jax
            # caches one trace per distinct requirement set / score fn, so
            # requirement-trimmed probes and fused device scoring share the
            # same suite entry (strategy singletons keep identities stable)
            # training entries deliberately never donate params: the same
            # round's params buffer also feeds the probe and the sequential
            # oracle paths, and Δ needs θ^{t,0} after the scan — donation
            # is owned by the serve write programs
            suite = {
                "local_update": jax.jit(self._local_update_impl),  # repro: allow[donation-miss] -- params reused by the probe/oracle paths in the same round
                "probe": jax.jit(self._probe_impl, static_argnums=(2, 3)),  # repro: allow[donation-miss] -- probe is read-only over params
                "eval": jax.jit(self._eval_impl),  # repro: allow[donation-miss] -- eval is read-only over params
                "cohort_update": jax.jit(self._cohort_update_impl),  # repro: allow[donation-miss] -- Δ = θ^{t,0} − θ^{t,τ} needs the pre-round params alive
                # fault path (DESIGN.md §12): the ONE guarded variant —
                # survivors/codes/scales are runtime arrays, so every
                # fault pattern replays this single compiled program
                "cohort_update_guarded": jax.jit(  # repro: allow[donation-miss] -- Δ = θ^{t,0} − θ^{t,τ} needs the pre-round params alive
                    self._cohort_update_guarded_impl),
                # mask-aware engine: one program variant per static prefix
                # cut (≤ L+1 total; jit_cache_stats()["programs"] pins it)
                "cohort_update_masked": jax.jit(  # repro: allow[donation-miss] -- Δ = θ^{t,0} − θ^{t,τ} needs the pre-round params alive
                    self._cohort_update_masked_impl, static_argnums=(5,)),
                "probe_cohort": jax.jit(self._probe_cohort_impl,  # repro: allow[donation-miss] -- probe is read-only over params
                                        static_argnums=(2, 3)),
                "probe_update_cohort": jax.jit(self._probe_update_cohort_impl,  # repro: allow[donation-miss] -- Δ = θ^{t,0} − θ^{t,τ} needs the pre-round params alive
                                               static_argnums=(6, 7)),
                "probe_update_cohort_masked": jax.jit(  # repro: allow[donation-miss] -- Δ = θ^{t,0} − θ^{t,τ} needs the pre-round params alive
                    self._probe_update_cohort_masked_impl,
                    static_argnums=(6, 7, 8)),
            }
            if key is None:
                _JIT_STATS["uncached"] += 1
            else:
                _JIT_CACHE[key] = suite
                _JIT_STATS["misses"] += 1
        else:
            _JIT_STATS["hits"] += 1
        self._local_update = suite["local_update"]
        self._probe = suite["probe"]
        self._eval = suite["eval"]
        self._cohort_update = suite["cohort_update"]
        self._cohort_update_guarded = suite["cohort_update_guarded"]
        self._cohort_update_masked = suite["cohort_update_masked"]
        self._probe_cohort = suite["probe_cohort"]
        self._probe_update_cohort = suite["probe_update_cohort"]
        self._probe_update_cohort_masked = suite["probe_update_cohort_masked"]
        # kernel dispatch for the masked hot path: the real Pallas kernels
        # only when the runtime opts in (TPU), the bit-identical jnp
        # fallback otherwise — pallas interpret mode inside a vmapped τ-scan
        # would dominate the round on CPU
        self._kernel_mode = "pallas" if model.runtime.use_pallas else "jnp"

    # -- Eq. (3)-(4): τ masked SGD steps, return accumulated update ---------
    def _local_update_impl(self, params: PyTree, batches: PyTree,
                           mask: Array, lr: Array):
        model, cfg = self.model, self.cfg

        def step(p, batch):
            loss, g = jax.value_and_grad(model.loss)(p, batch)
            g = apply_layer_mask(g, mask, cfg)
            new_p = jax.tree.map(lambda a, b: a - lr * b.astype(a.dtype), p, g)
            return new_p, loss

        p_final, losses = jax.lax.scan(step, params, batches)
        # Δ_i^t = (θ^{t,0} − θ^{t,τ}) / η  = Σ_k Σ_{l∈L_i} g_{i,l}
        delta = jax.tree.map(lambda a, b: (a - b).astype(jnp.float32) / lr,
                             params, p_final)
        return delta, jnp.mean(losses)

    def local_update(self, params, batches, mask, lr) -> tuple[PyTree, float]:
        """batches: pytree stacked on axis 0 with length τ."""
        delta, loss = self._local_update(params, batches,
                                         jnp.asarray(mask, jnp.float32),
                                         jnp.asarray(lr, jnp.float32))
        return delta, float(loss)

    # -- vectorized cohort round: vmap(τ-step scan) + fused Eq.(5)-(7) ------
    def _cohort_update_impl(self, params: PyTree, batches: PyTree,
                            masks: Array, sizes: Array, lr: Array):
        from repro.core import aggregation as agg

        def one(b, m):
            return self._local_update_impl(params, b, m, lr)

        # deltas: stacked (n, ...) pytree; losses: (n,)
        deltas, losses = jax.vmap(one)(batches, masks)
        weights = M.aggregation_weights(masks, sizes)        # (n, L), Eq. 7
        update = agg.aggregate_stacked(deltas, weights, self.cfg)
        new_params = agg.apply_update(params, update, lr)
        return new_params, losses

    # -- fault-guarded cohort round: survivor reweighting + finite guard ----
    def _cohort_update_guarded_impl(self, params: PyTree, batches: PyTree,
                                    masks: Array, sizes: Array, lr: Array,
                                    survivors: Array, codes: Array,
                                    explode_scale: Array, max_delta_sq: Array):
        """The ONE masked round-step variant the fault path adds
        (DESIGN.md §12): identical local math to ``_cohort_update_impl``,
        then injected corruption (``codes``), the device-side finite
        guard, and survivor-reweighted Eq.(5)-(7) aggregation — all of it
        runtime data, so one compiled program serves every fault pattern
        and a no-fault call (survivors=1, codes=0) computes exactly the
        dense step's params.

        Returns ``(new_params, losses, ok)``: ``ok`` (n,) f32 marks the
        rows that actually aggregated (alive AND finite AND under the
        norm threshold).  Dead/quarantined rows are zeroed *before* the
        contraction (0-weight × NaN = NaN otherwise) and their sizes
        zeroed in the Eq.(7) renormalisation — a layer all of whose
        selectors died gets weight 0 everywhere and the global params
        pass through bit-exact (θ − η·0 = θ).
        """
        from repro.core import aggregation as agg

        def one(b, m):
            return self._local_update_impl(params, b, m, lr)

        deltas, losses = jax.vmap(one)(batches, masks)
        deltas = agg.corrupt_delta_rows(deltas, codes, explode_scale)
        ok = agg.finite_row_mask(deltas, max_delta_sq) * survivors
        deltas = agg.zero_delta_rows(deltas, ok)
        weights = M.aggregation_weights(masks, sizes * ok)   # survivors only
        update = agg.aggregate_stacked(deltas, weights, self.cfg)
        new_params = agg.apply_update(params, update, lr)
        return new_params, losses, ok

    def cohort_update_guarded_raw(self, params, batches, masks, sizes, lr,
                                  survivors, codes, explode_scale,
                                  max_delta_sq):
        """Async fault-guarded round step (device arrays, no sync)."""
        return self._cohort_update_guarded(
            params, batches, _dev_f32(masks), _dev_f32(sizes), _dev_f32(lr),
            _dev_f32(survivors), _dev_i32(codes), _dev_f32(explode_scale),
            _dev_f32(max_delta_sq))

    def cohort_update_guarded(self, params, batches, masks, sizes, lr,
                              survivors, codes, explode_scale, max_delta_sq
                              ) -> tuple[PyTree, np.ndarray, np.ndarray]:
        """Blocking :meth:`cohort_update_guarded_raw`: np losses + ok."""
        new_params, losses, ok = self.cohort_update_guarded_raw(
            params, batches, masks, sizes, lr, survivors, codes,
            explode_scale, max_delta_sq)
        # repro: allow[host-sync] -- fault accounting is a sanctioned round-boundary sync (DESIGN.md §12)
        return new_params, np.asarray(losses), np.asarray(ok)

    # -- mask-aware cohort round: frozen-prefix split at a static cut --------
    def _cohort_update_masked_impl(self, params: PyTree, batches: PyTree,
                                   masks: Array, sizes: Array, lr: Array,
                                   cut: int):
        """The mask-aware engine's round step (DESIGN.md §7).

        ``cut`` (static) is the round's prefix cut — the smallest layer any
        cohort member trains.  The forward below it runs as a frozen
        constant scan: no backward pass, no saved activations; embeddings,
        head and norms (frozen by the paper) are likewise never
        differentiated.  The τ-step scan carries only the trainable suffix
        slice; Δ and the Eq.(5)-(7) aggregation are computed over that
        slice and scattered back into the full tree.  One program compiles
        per distinct cut (≤ L+1 variants), pinned by ``jit_cache_stats``.
        """
        from repro.core import aggregation as agg

        model, cfg = self.model, self.cfg
        if cut >= model.n_selectable:
            # all-empty masks: nothing trains — forward-only losses (the
            # dense path's zero-masked steps never move params either)
            def one(b):
                def step(carry, batch):
                    return carry, model.loss(params, batch)
                _, losses = jax.lax.scan(step, 0, b)
                return jnp.mean(losses)

            return params, jax.vmap(one)(batches)

        tr0 = trainable_slice(params, cut, cfg)
        mode = self._kernel_mode

        def one(b, m):
            def step(tr, batch):
                loss, g = jax.value_and_grad(
                    lambda t: model.loss(params, batch, trainable=t,
                                         cut=cut))(tr)
                new_tr = masked_suffix_sgd(tr, g, m, lr, cut, cfg, mode=mode)
                return new_tr, loss

            tr_fin, losses = jax.lax.scan(step, tr0, b)
            delta = jax.tree.map(lambda a, z: (a - z).astype(jnp.float32) / lr,
                                 tr0, tr_fin)
            return delta, jnp.mean(losses)

        deltas, losses = jax.vmap(one)(batches, masks)
        weights = M.aggregation_weights(masks, sizes)        # (n, L), Eq. 7
        update = agg.aggregate_stacked_suffix(deltas, weights, cut, self.cfg)
        new_params = agg.apply_update_suffix(params, update, lr, cut,
                                             self.cfg)
        return new_params, losses

    def cohort_update_raw(self, params, batches, masks, sizes, lr,
                          cut: "int | None" = None):
        """Async variant: returns device arrays without forcing a sync, so
        the streaming pipeline can overlap host sampling with the in-flight
        XLA program (jax dispatches asynchronously).

        ``cut=None`` runs the dense program (every layer differentiated —
        the pre-mask-aware behaviour); an integer cut dispatches the
        mask-aware program for that frozen-prefix depth.
        """
        args = (params, batches, _dev_f32(masks), _dev_f32(sizes),
                _dev_f32(lr))
        if cut is None:
            return self._cohort_update(*args)
        return self._cohort_update_masked(*args, int(cut))  # repro: allow[host-sync] -- cut is a static python int, not a device value

    def cohort_update(self, params, batches, masks, sizes, lr,
                      cut: "int | None" = None) -> tuple[PyTree, np.ndarray]:
        """One fused round step for the whole cohort.

        batches: pytree with leading (cohort, τ) axes (``cohort_batches``);
        masks: (cohort, L); sizes: (cohort,) client dataset sizes d_i;
        cut: optional static prefix cut (see :meth:`cohort_update_raw`).
        Returns (new global params, per-client mean local losses).  Matches
        the sequential local_update → aggregate → apply_update composition
        within fp tolerance (see tests/test_round_engine.py) — with or
        without the mask-aware cut (tests/test_masked_engine.py).
        """
        new_params, losses = self.cohort_update_raw(params, batches, masks,
                                                    sizes, lr, cut)
        return new_params, np.asarray(losses)

    # -- selection probe: layer-wise gradient stats on one batch ------------
    def _probe_impl(self, params: PyTree, batch: PyTree,
                    reqs: tuple = PROBE_KEYS, score_fn=None):
        """Gradient stats for one batch, trimmed to the requested keys.

        ``reqs`` (static) is the strategy's ``probe_requirements``: only the
        requested stats are computed — SNR-only strategies skip the param
        norms, ``ours`` skips mean/var entirely (a cheaper reduction).  Keys
        not requested are never part of the program (XLA sees only the
        returned outputs).
        """
        g = jax.grad(self.model.loss)(params, batch)
        out: dict[str, Array] = {}
        if "grad_means" in reqs or "grad_vars" in reqs:
            sq, mean, var = M.per_layer_stats(g, self.cfg)
            out["grad_sq_norms"] = sq
            out["grad_means"] = mean
            out["grad_vars"] = var
        elif "grad_sq_norms" in reqs:
            # the fused layer_grad_norm kernel (TPU) / its pinned jnp
            # fallback — the probe itself stays dense across all L layers:
            # next round's selection needs utilities for every layer,
            # trained or not (DESIGN.md §7)
            out["grad_sq_norms"] = M.per_layer_sq_norms(
                g, self.cfg, mode=self._kernel_mode)
        if "param_sq_norms" in reqs:
            out["param_sq_norms"] = M.per_layer_param_sq_norms(
                params, self.cfg, mode=self._kernel_mode)
        return {k: v for k, v in out.items() if k in reqs}

    def probe(self, params, batch,
              reqs: tuple = PROBE_KEYS) -> dict[str, np.ndarray]:
        return probe_stats_dict(self._probe(params, batch, tuple(reqs), None))

    def _probe_cohort_impl(self, params: PyTree, batches: PyTree,
                           reqs: tuple = PROBE_KEYS, score_fn=None):
        def one_client(cb):
            outs = jax.vmap(lambda b: self._probe_impl(params, b, reqs))(cb)
            # mean over the selection_batches axis == the sequential
            # accumulate-then-divide in FLServer.probe_round
            return {k: v.mean(0) for k, v in outs.items()}

        stats = jax.vmap(one_client)(batches)
        if score_fn is not None:
            # strategy's device-side scoring fused into the same program;
            # applied to the *meaned* stats, exactly like the host path
            stats = dict(stats, scores=score_fn(stats))
        return stats

    def probe_cohort_raw(self, params, batches, reqs: tuple = PROBE_KEYS,
                         score_fn=None):
        """Async variant of :meth:`probe_cohort` (device arrays)."""
        return self._probe_cohort(params, batches, tuple(reqs), score_fn)

    def probe_cohort(self, params, batches, reqs: tuple = PROBE_KEYS,
                     score_fn=None) -> dict[str, np.ndarray]:
        """Batched probe: one vmapped grad+stats call over the whole cohort.

        batches: pytree with leading (cohort, selection_batches) axes.
        Returns (cohort, L) arrays for the requested stat keys (plus
        ``"scores"`` when a device score_fn is fused in).
        """
        return probe_stats_dict(
            self._probe_cohort(params, batches, tuple(reqs), score_fn))

    # -- fused probe+update: one program per round ---------------------------
    def _probe_update_cohort_impl(self, params: PyTree, batches: PyTree,
                                  masks: Array, sizes: Array, lr: Array,
                                  probe_batches: PyTree,
                                  reqs: tuple = PROBE_KEYS, score_fn=None):
        new_params, losses = self._cohort_update_impl(params, batches, masks,
                                                      sizes, lr)
        # next round's selection probe, on the *updated* params — identical
        # math to dispatching probe_cohort(new_params, ...) separately
        stats = self._probe_cohort_impl(new_params, probe_batches, reqs,
                                        score_fn)
        return new_params, losses, stats

    def _probe_update_cohort_masked_impl(self, params: PyTree, batches: PyTree,
                                         masks: Array, sizes: Array, lr: Array,
                                         probe_batches: PyTree, cut: int,
                                         reqs: tuple = PROBE_KEYS,
                                         score_fn=None):
        new_params, losses = self._cohort_update_masked_impl(
            params, batches, masks, sizes, lr, cut)
        # the probe stays dense: selection utilities are needed for all L
        # layers, including the ones this round froze
        stats = self._probe_cohort_impl(new_params, probe_batches, reqs,
                                        score_fn)
        return new_params, losses, stats

    def probe_update_cohort_raw(self, params, batches, masks, sizes, lr,
                                probe_batches, reqs: tuple = PROBE_KEYS,
                                score_fn=None, cut: "int | None" = None):
        """Cohort update + next-round probe as ONE XLA program (async).

        probe_batches: (next_cohort, selection_batches, ...) pytree;
        cut: optional static prefix cut (see :meth:`cohort_update_raw`).
        Returns (new_params, losses, stats-dict) device arrays.
        """
        args = (params, batches, _dev_f32(masks), _dev_f32(sizes),
                _dev_f32(lr), probe_batches)
        if cut is None:
            return self._probe_update_cohort(*args, tuple(reqs), score_fn)
        # repro: allow[host-sync] -- cut is a static python int, not a device value
        return self._probe_update_cohort_masked(*args, int(cut), tuple(reqs),
                                                score_fn)

    # -- evaluation -----------------------------------------------------------
    def _eval_impl(self, params: PyTree, batch: PyTree):
        """One forward for both loss and accuracy: the hidden state is
        computed once and shared between the loss tail
        (``Model.loss_from_hidden``) and the accuracy logits — labeled
        batches used to pay for ``model.loss`` *and* a second
        ``forward_seq`` (regression test: tests/test_masked_engine.py)."""
        model = self.model
        h, aux, prefix_len = model.forward_seq(params, batch)
        loss = model.loss_from_hidden(params, h, aux, prefix_len, batch)
        acc = jnp.zeros(())
        if "label" in batch:
            logits = model._head(params, jnp.mean(h, axis=1)[:, None])[:, 0]
            acc = jnp.mean((jnp.argmax(logits, -1)
                            == batch["label"]).astype(jnp.float32))
        return loss, acc

    def evaluate_raw(self, params, batch):
        """Async variant of :meth:`evaluate` (device scalars)."""
        return self._eval(params, batch)

    def evaluate(self, params, batch) -> tuple[float, float]:
        loss, acc = self._eval(params, batch)
        return float(loss), float(acc)
