"""Client-side local training (Eq. 2-4) and the selection-probe step (§4.2).

Everything is jit-compiled once per architecture and reused across rounds
and clients — masks, batches and learning rate are runtime arrays.

Two execution granularities share the same per-client math:

* per-client: :meth:`Client.local_update` / :meth:`Client.probe` — one jit
  call per cohort member (the sequential oracle).
* per-cohort: :meth:`Client.cohort_update` / :meth:`Client.probe_cohort` —
  the vectorized engine: ``jax.vmap`` over the stacked cohort axis, with the
  Eq.(5)-(7) weighted aggregation and Eq.(6) apply fused into the same XLA
  program, so one round's hot path is a single dispatch (the single-host
  analogue of the mesh step in sharding/fl_step.py).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import masks as M
from repro.models.model import Model, apply_layer_mask

Array = jax.Array
PyTree = Any


class Client:
    """Stateless executor for local training; data is passed per call."""

    def __init__(self, model: Model):
        self.model = model
        self.cfg = model.cfg
        self._local_update = jax.jit(self._local_update_impl)
        self._probe = jax.jit(self._probe_impl)
        self._eval = jax.jit(self._eval_impl)
        self._cohort_update = jax.jit(self._cohort_update_impl)
        self._probe_cohort = jax.jit(self._probe_cohort_impl)

    # -- Eq. (3)-(4): τ masked SGD steps, return accumulated update ---------
    def _local_update_impl(self, params: PyTree, batches: PyTree,
                           mask: Array, lr: Array):
        model, cfg = self.model, self.cfg

        def step(p, batch):
            loss, g = jax.value_and_grad(model.loss)(p, batch)
            g = apply_layer_mask(g, mask, cfg)
            new_p = jax.tree.map(lambda a, b: a - lr * b.astype(a.dtype), p, g)
            return new_p, loss

        p_final, losses = jax.lax.scan(step, params, batches)
        # Δ_i^t = (θ^{t,0} − θ^{t,τ}) / η  = Σ_k Σ_{l∈L_i} g_{i,l}
        delta = jax.tree.map(lambda a, b: (a - b).astype(jnp.float32) / lr,
                             params, p_final)
        return delta, jnp.mean(losses)

    def local_update(self, params, batches, mask, lr) -> tuple[PyTree, float]:
        """batches: pytree stacked on axis 0 with length τ."""
        delta, loss = self._local_update(params, batches,
                                         jnp.asarray(mask, jnp.float32),
                                         jnp.asarray(lr, jnp.float32))
        return delta, float(loss)

    # -- vectorized cohort round: vmap(τ-step scan) + fused Eq.(5)-(7) ------
    def _cohort_update_impl(self, params: PyTree, batches: PyTree,
                            masks: Array, sizes: Array, lr: Array):
        from repro.core import aggregation as agg

        def one(b, m):
            return self._local_update_impl(params, b, m, lr)

        # deltas: stacked (n, ...) pytree; losses: (n,)
        deltas, losses = jax.vmap(one)(batches, masks)
        weights = M.aggregation_weights(masks, sizes)        # (n, L), Eq. 7
        update = agg.aggregate_stacked(deltas, weights, self.cfg)
        new_params = agg.apply_update(params, update, lr)
        return new_params, losses

    def cohort_update(self, params, batches, masks, sizes,
                      lr) -> tuple[PyTree, np.ndarray]:
        """One fused round step for the whole cohort.

        batches: pytree with leading (cohort, τ) axes (``cohort_batches``);
        masks: (cohort, L); sizes: (cohort,) client dataset sizes d_i.
        Returns (new global params, per-client mean local losses).  Matches
        the sequential local_update → aggregate → apply_update composition
        within fp tolerance (see tests/test_round_engine.py).
        """
        new_params, losses = self._cohort_update(
            params, batches, jnp.asarray(masks, jnp.float32),
            jnp.asarray(sizes, jnp.float32), jnp.asarray(lr, jnp.float32))
        return new_params, np.asarray(losses)

    # -- selection probe: layer-wise gradient stats on one batch ------------
    def _probe_impl(self, params: PyTree, batch: PyTree):
        g = jax.grad(self.model.loss)(params, batch)
        sq, mean, var = M.per_layer_stats(g, self.cfg)
        p_sq = M.per_layer_param_sq_norms(params, self.cfg)
        return sq, mean, var, p_sq

    def probe(self, params, batch) -> dict[str, np.ndarray]:
        sq, mean, var, p_sq = self._probe(params, batch)
        return {"grad_sq_norms": np.asarray(sq), "grad_means": np.asarray(mean),
                "grad_vars": np.asarray(var), "param_sq_norms": np.asarray(p_sq)}

    def _probe_cohort_impl(self, params: PyTree, batches: PyTree):
        def one_client(cb):
            sq, mean, var, p_sq = jax.vmap(
                lambda b: self._probe_impl(params, b))(cb)
            # mean over the selection_batches axis == the sequential
            # accumulate-then-divide in FLServer._probe_cohort
            return sq.mean(0), mean.mean(0), var.mean(0), p_sq.mean(0)

        return jax.vmap(one_client)(batches)

    def probe_cohort(self, params, batches) -> dict[str, np.ndarray]:
        """Batched probe: one vmapped grad+stats call over the whole cohort.

        batches: pytree with leading (cohort, selection_batches) axes.
        Returns (cohort, L) stat arrays, same keys as :meth:`probe`.
        """
        sq, mean, var, p_sq = self._probe_cohort(params, batches)
        return {"grad_sq_norms": np.asarray(sq), "grad_means": np.asarray(mean),
                "grad_vars": np.asarray(var), "param_sq_norms": np.asarray(p_sq)}

    # -- evaluation -----------------------------------------------------------
    def _eval_impl(self, params: PyTree, batch: PyTree):
        loss = self.model.loss(params, batch)
        acc = jnp.zeros(())
        if "label" in batch:
            cfg = self.model.cfg
            h, _, _ = self.model.forward_seq(params, batch)
            logits = self.model._head(params, jnp.mean(h, axis=1)[:, None])[:, 0]
            acc = jnp.mean((jnp.argmax(logits, -1) == batch["label"]).astype(jnp.float32))
        return loss, acc

    def evaluate(self, params, batch) -> tuple[float, float]:
        loss, acc = self._eval(params, batch)
        return float(loss), float(acc)
