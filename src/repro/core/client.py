"""Client-side local training (Eq. 2-4) and the selection-probe step (§4.2).

Everything is jit-compiled once per architecture and reused across rounds
and clients — masks, batches and learning rate are runtime arrays.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import masks as M
from repro.models.model import Model, apply_layer_mask

Array = jax.Array
PyTree = Any


class Client:
    """Stateless executor for local training; data is passed per call."""

    def __init__(self, model: Model):
        self.model = model
        self.cfg = model.cfg
        self._local_update = jax.jit(self._local_update_impl)
        self._probe = jax.jit(self._probe_impl)
        self._eval = jax.jit(self._eval_impl)

    # -- Eq. (3)-(4): τ masked SGD steps, return accumulated update ---------
    def _local_update_impl(self, params: PyTree, batches: PyTree,
                           mask: Array, lr: Array):
        model, cfg = self.model, self.cfg

        def step(p, batch):
            loss, g = jax.value_and_grad(model.loss)(p, batch)
            g = apply_layer_mask(g, mask, cfg)
            new_p = jax.tree.map(lambda a, b: a - lr * b.astype(a.dtype), p, g)
            return new_p, loss

        p_final, losses = jax.lax.scan(step, params, batches)
        # Δ_i^t = (θ^{t,0} − θ^{t,τ}) / η  = Σ_k Σ_{l∈L_i} g_{i,l}
        delta = jax.tree.map(lambda a, b: (a - b).astype(jnp.float32) / lr,
                             params, p_final)
        return delta, jnp.mean(losses)

    def local_update(self, params, batches, mask, lr) -> tuple[PyTree, float]:
        """batches: pytree stacked on axis 0 with length τ."""
        delta, loss = self._local_update(params, batches,
                                         jnp.asarray(mask, jnp.float32),
                                         jnp.asarray(lr, jnp.float32))
        return delta, float(loss)

    # -- selection probe: layer-wise gradient stats on one batch ------------
    def _probe_impl(self, params: PyTree, batch: PyTree):
        g = jax.grad(self.model.loss)(params, batch)
        sq, mean, var = M.per_layer_stats(g, self.cfg)
        p_sq = M.per_layer_param_sq_norms(params, self.cfg)
        return sq, mean, var, p_sq

    def probe(self, params, batch) -> dict[str, np.ndarray]:
        sq, mean, var, p_sq = self._probe(params, batch)
        return {"grad_sq_norms": np.asarray(sq), "grad_means": np.asarray(mean),
                "grad_vars": np.asarray(var), "param_sq_norms": np.asarray(p_sq)}

    # -- evaluation -----------------------------------------------------------
    def _eval_impl(self, params: PyTree, batch: PyTree):
        loss = self.model.loss(params, batch)
        acc = jnp.zeros(())
        if "label" in batch:
            cfg = self.model.cfg
            h, _, _ = self.model.forward_seq(params, batch)
            logits = self.model._head(params, jnp.mean(h, axis=1)[:, None])[:, 0]
            acc = jnp.mean((jnp.argmax(logits, -1) == batch["label"]).astype(jnp.float32))
        return loss, acc

    def evaluate(self, params, batch) -> tuple[float, float]:
        loss, acc = self._eval(params, batch)
        return float(loss), float(acc)
