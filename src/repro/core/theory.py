"""Estimators for the convergence-theory quantities of §4.1.

* ``E_t1 = ‖Σ_{l∉L_t} ∇_l f(θ^t)‖²``  — importance of the *unselected* layers
  (Lemma 4.6, first term).
* ``E_t2 = Σ_{l∈L_t} χ²_{w_{t,l}‖α} κ_l²`` — heterogeneous-selection term.
* ``κ_l`` — per-layer gradient diversity (Assumption 4.3), estimated as the
  max over clients of ‖∇_l f(θ) − ∇_l f_i(θ)‖.
* ``σ_l`` — stochastic-gradient deviation (Assumption 4.2), estimated from
  repeated minibatch draws.
* :func:`theorem_4_7_rhs` — evaluates the error-floor expression so tests
  and experiments can check the *qualitative* claim: the floor grows with
  E_t1 + E_t2, vanishes under full selection + uniform cohort.

These run on the single-host simulator (small models); they require
per-client full-batch gradients which would be impractical at pod scale —
exactly why the paper's strategy estimates them with minibatch norms.
"""
from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import masks as M
from repro.core.masks import aggregation_weights, chi_divergence, union_mask
from repro.models.model import Model

Array = jax.Array
PyTree = Any


def global_gradient(model: Model, params: PyTree, client_batches: Sequence,
                    alpha: np.ndarray) -> PyTree:
    """∇f(θ) = Σ_i α_i ∇f_i(θ) (full-batch per client)."""
    total = None
    g_fn = jax.jit(jax.grad(model.loss))  # repro: allow[jit-outside-cache] -- offline theory utility (Assumption 2 estimates), not a hot path
    for a, batch in zip(alpha, client_batches):
        g = g_fn(params, batch)
        g = jax.tree.map(lambda x: a * x.astype(jnp.float32), g)
        total = g if total is None else jax.tree.map(jnp.add, total, g)
    return total


def per_client_gradients(model: Model, params: PyTree,
                         client_batches: Sequence) -> list[PyTree]:
    g_fn = jax.jit(jax.grad(model.loss))  # repro: allow[jit-outside-cache] -- offline theory utility (Assumption 2 estimates), not a hot path
    return [g_fn(params, b) for b in client_batches]


def e_t1(model: Model, global_grad: PyTree, union: np.ndarray) -> float:
    """‖Σ_{l∉L_t} ∇_l f‖² — computed from per-layer squared norms.

    Layer subtrees are disjoint parameter blocks, so the squared norm of the
    concatenation equals the sum of per-layer squared norms.
    """
    sq = np.asarray(M.per_layer_sq_norms(global_grad, model.cfg))
    return float(np.sum(sq * (1.0 - union)))


def kappa_per_layer(model: Model, global_grad: PyTree,
                    client_grads: Sequence[PyTree]) -> np.ndarray:
    """κ_l ≥ max_i ‖∇_l f − ∇_l f_i‖ (Assumption 4.3 tight estimate)."""
    worst = None
    for g_i in client_grads:
        diff = jax.tree.map(lambda a, b: a - b.astype(jnp.float32),
                            global_grad, g_i)
        sq = np.asarray(M.per_layer_sq_norms(diff, model.cfg))
        worst = sq if worst is None else np.maximum(worst, sq)
    return np.sqrt(worst)


def e_t2(mask_matrix: np.ndarray, sizes: np.ndarray, kappa: np.ndarray,
         population_alpha: np.ndarray | None = None,
         cohort_idx: np.ndarray | None = None) -> float:
    """Σ_{l∈L_t} χ²_{w_l‖α} κ_l² (Lemma 4.6 second term).

    If ``population_alpha``/``cohort_idx`` are given, weights are embedded
    into the full population (non-sampled clients have w=0) as in the
    paper's analysis; otherwise α is taken over the cohort.
    """
    W_cohort = np.asarray(aggregation_weights(mask_matrix, sizes))
    union = union_mask(mask_matrix)
    if population_alpha is not None:
        N = population_alpha.shape[0]
        W = np.zeros((N, mask_matrix.shape[1]), np.float32)
        W[cohort_idx] = W_cohort
        alpha = population_alpha
    else:
        W = W_cohort
        alpha = sizes / sizes.sum()
    chi = np.asarray(chi_divergence(jnp.asarray(W), jnp.asarray(alpha)))
    return float(np.sum(chi * (kappa ** 2) * union))


def theorem_4_7_rhs(f0: float, f_star: float, *, eta: float, gamma: float,
                    T: int, sigma_sq: float, e1_sum: float, e2_sum: float) -> float:
    """RHS of Eq. (15) (τ=1). Requires C = 1 − γη > 0."""
    C = 1.0 - gamma * eta
    assert C > 0, "learning rate too large for the bound"
    term_opt = 2.0 / (eta * C * T) * (f0 - f_star)
    term_noise = 2.0 * gamma * eta / C * sigma_sq
    term_bias = (1.0 / (gamma * eta * C) + 2.0) * (e1_sum + e2_sum) / T
    return term_opt + term_noise + term_bias


def sigma_per_layer(model: Model, params: PyTree, batches: Sequence,
                    full_batch) -> np.ndarray:
    """σ_l estimate: max over minibatches of ‖g_l(ξ) − ∇_l f‖."""
    g_fn = jax.jit(jax.grad(model.loss))  # repro: allow[jit-outside-cache] -- offline theory utility (Assumption 2 estimates), not a hot path
    g_full = g_fn(params, full_batch)
    worst = None
    for b in batches:
        g = g_fn(params, b)
        diff = jax.tree.map(lambda a, c: a.astype(jnp.float32) - c.astype(jnp.float32),
                            g, g_full)
        sq = np.asarray(M.per_layer_sq_norms(diff, model.cfg))
        worst = sq if worst is None else np.maximum(worst, sq)
    return np.sqrt(worst)
