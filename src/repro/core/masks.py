"""Masking vectors m_i^t ∈ {0,1}^L and per-layer gradient utilities (§3).

The selected layer set of client i is L_i^t = {l : m_i^t(l) = 1}; the round's
union is L_t = ∪_i L_i^t.  Aggregation weights (Eq. 7) are computed from the
cohort's mask matrix and relative sample sizes.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def mask_from_indices(indices, n_layers: int) -> np.ndarray:
    m = np.zeros(n_layers, dtype=np.float32)
    m[np.asarray(list(indices), dtype=int)] = 1.0
    return m


def indices_from_mask(mask) -> tuple[int, ...]:
    return tuple(int(i) for i in np.nonzero(np.asarray(mask) > 0)[0])


def union_mask(mask_matrix: np.ndarray) -> np.ndarray:
    """L_t = ∪_i L_i^t from the (cohort, L) mask matrix."""
    return (np.asarray(mask_matrix).sum(0) > 0).astype(np.float32)  # repro: allow[host-sync] -- mask matrices are host np by contract (select stage)


def first_trainable_layer(mask_matrix: np.ndarray) -> int:
    """Host-side prefix cut for the mask-aware compute engine (DESIGN.md §7).

    The smallest mask index any cohort member selects this round: layers
    below it are frozen for *everyone*, so the round's update program can
    skip their backward pass entirely.  An all-empty mask matrix returns L
    (nothing trainable — the forward-only program variant).
    """
    cols = np.flatnonzero(np.asarray(mask_matrix).sum(0) > 0)  # repro: allow[host-sync] -- mask matrices are host np by contract (select stage)
    return int(cols[0]) if cols.size else int(np.asarray(mask_matrix).shape[-1])  # repro: allow[host-sync] -- host np indices, no device value


def aggregation_weights(mask_matrix: Array, sizes: Array) -> Array:
    """Eq. (7): w_{i,l} = d_i·m_i(l) / Σ_j d_j·m_j(l)   (0 where denom is 0).

    mask_matrix: (n, L) 0/1;  sizes: (n,) client dataset sizes d_i.
    Returns (n, L) float32.
    """
    mm = jnp.asarray(mask_matrix, jnp.float32)
    d = jnp.asarray(sizes, jnp.float32)[:, None]
    denom = jnp.sum(mm * d, axis=0, keepdims=True)          # (1, L)
    return jnp.where(denom > 0, mm * d / jnp.where(denom > 0, denom, 1.0), 0.0)


def chi_divergence(weights: Array, alpha: Array) -> Array:
    """χ²_{w_l ‖ α} = Σ_i (w_{i,l} − α_i)² / α_i per layer (Lemma 4.6).

    weights: (n, L) realized aggregation weights over the *population*
    (non-cohort clients have w = 0); alpha: (n,) data ratios over the same
    index set.
    """
    a = jnp.asarray(alpha, jnp.float32)[:, None]
    return jnp.sum((weights - a) ** 2 / a, axis=0)          # (L,)


# ---------------------------------------------------------------------------
# Per-layer gradient norms (the strategy inputs)
# ---------------------------------------------------------------------------

def per_layer_sq_norms(grads: Any, cfg, *, mode: str | None = None,
                       interpret: bool | None = None) -> Array:
    """‖g_{i,l}‖² for every selectable layer l — the L-vector clients upload.

    Works on the stacked-parameter layout: each segment's leaves carry a
    leading (count,) axis; reduction is over all remaining axes.  This is
    the probe reduction of the selection step, routed through the fused
    Pallas kernel (kernels/layer_grad_norm.py via kernels.ops): the real
    kernel on TPU, its bit-identical pure-jnp fallback elsewhere.  ``mode``
    forces ``"pallas"``/``"jnp"`` (the kernel-parity tests pin both against
    each other in interpret mode).
    """
    from repro.kernels import ops
    from repro.models.model import layer_layout
    parts = []
    for seg in layer_layout(cfg):
        sub = grads[seg.path]
        if seg.path == "shared_attn":   # unstacked single block: one row
            sub = jax.tree.map(lambda x: x[None], sub)
        parts.append(ops.layer_grad_norms(sub, mode=mode,
                                          interpret=interpret))
    return jnp.concatenate(parts)


def per_layer_param_sq_norms(params: Any, cfg, *, mode: str | None = None,
                             interpret: bool | None = None) -> Array:
    """‖θ_l‖² per layer (for the RGN baseline)."""
    return per_layer_sq_norms(params, cfg, mode=mode, interpret=interpret)


def per_layer_stats(grads: Any, cfg) -> tuple[Array, Array, Array]:
    """(sq_norm, mean, var) of gradient elements per layer (for SNR)."""
    from repro.models.model import layer_layout
    sq, mean, var = [], [], []
    for seg in layer_layout(cfg):
        leaves = [x.astype(jnp.float32) for x in jax.tree.leaves(grads[seg.path])]
        if seg.path == "shared_attn":
            n = sum(x.size for x in leaves)
            s1 = sum(jnp.sum(x) for x in leaves)
            s2 = sum(jnp.sum(jnp.square(x)) for x in leaves)
            mu = s1 / n
            sq.append(s2[None]); mean.append(mu[None])
            var.append((s2 / n - mu ** 2)[None])
        else:
            n = sum(int(np.prod(x.shape[1:])) for x in leaves)
            s1 = sum(jnp.sum(x, axis=tuple(range(1, x.ndim))) for x in leaves)
            s2 = sum(jnp.sum(jnp.square(x), axis=tuple(range(1, x.ndim)))
                     for x in leaves)
            mu = s1 / n
            sq.append(s2); mean.append(mu)
            var.append(s2 / n - mu ** 2)
    return jnp.concatenate(sq), jnp.concatenate(mean), jnp.concatenate(var)


def count_layer_params(params: Any, cfg) -> np.ndarray:
    """Number of parameters per selectable layer (cost model R(m))."""
    from repro.models.model import layer_layout
    out = []
    for seg in layer_layout(cfg):
        leaves = jax.tree.leaves(params[seg.path])
        if seg.path == "shared_attn":
            out.append(np.array([sum(x.size for x in leaves)]))  # repro: allow[host-sync] -- shape-only accounting, computed once per run
        else:
            per = sum(int(np.prod(x.shape[1:])) for x in leaves)  # repro: allow[host-sync] -- static shape arithmetic, no device value
            out.append(np.full(seg.count, per))
    return np.concatenate(out).astype(np.int64)
