"""The FL server: Algorithm 1 (selective layer fine-tuning in FL).

Single-host simulator with exact paper semantics: arbitrary per-client
masks, τ local steps, per-layer weighted aggregation, strategy-driven layer
selection with a configurable period.  The distributed pjit path
(sharding/fl_step.py) executes the same round math cohort-parallel on the
production mesh.

Two round engines (``FLServer(..., engine=...)``):

* ``"vectorized"`` (default) — the hot path is one XLA program per round:
  a single jitted step that vmaps the τ-step local update across the
  cohort and fuses the Eq.(5)-(7) weighted aggregation and Eq.(6) apply
  (Client.cohort_update); the selection probe is likewise one vmapped call
  over (cohort, selection_batches) (Client.probe_cohort).
* ``"sequential"`` — the paper-literal per-client loop, retained as the
  parity oracle.  Both engines draw identical per-client data and produce
  identical masks and params within fp tolerance
  (tests/test_round_engine.py).

A round is composed of explicit pipeline stages (DESIGN.md §5):

    plan → sample → probe → select → update → eval

:meth:`FLServer.run_round` executes them synchronously; the default
:meth:`FLServer.run` path for the vectorized engine streams them instead
(``pipeline=True``) through :class:`repro.core.scheduler.RoundScheduler`
— a depth-k lookahead pipeline (``pipeline_depth``, default 1): rounds
t+1..t+k are planned and sampled on the host while round t's jitted update
is still in flight (jax async dispatch), the host (P1) solve runs on a
background thread overlapped with the in-flight program, the t+1 selection
probe is dispatched on the not-yet-materialised updated params so it
overlaps the update on-device, and — when every round re-selects
(``selection_period == 1``) — probe and update are fused into a single XLA
program (Client.probe_update_cohort).  The scheduler consumes every host
rng and per-client data stream in exactly the same order as the
synchronous loop, so results are unchanged (tests/test_round_engine.py,
tests/test_scheduler.py).

Selection-period caching is per client id: probe statistics are cached at
refresh rounds (``t % selection_period == 0``) and masks are re-derived
every round from the *current* cohort's cached stats and budgets; cohort
members without cached stats are probed on demand.  (The previous
implementation reused the first ``len(cohort)`` mask rows computed for a
different cohort — wrong budgets and wrong clients.)

Pluggable seams (DESIGN.md §6): the strategy is resolved from the registry
(``fl.strategy`` string or a ``Strategy`` instance via the ``strategy``
kwarg) — its declared ``probe_requirements`` trim what the probes compute,
and score-based strategies fuse their device-side scoring into the
vectorized probe program.  ``data`` is any ``repro.api.Task``; its optional
``available_clients`` / ``drop_stragglers`` hooks act at the plan stage.
New code should construct servers through ``repro.api.Experiment``.
"""
from __future__ import annotations

import math
import time
import warnings
from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import numpy as np

from repro.api.strategy import SelectionContext, Strategy, get_strategy
from repro.configs.base import FLConfig
from repro.core import aggregation as agg
from repro.core import masks as M
from repro.core.client import Client, probe_stats_dict
from repro.core.solver import greedy_rows
from repro.core.state import (ClientStateStore, rng_state_from_arrays,
                              rng_state_to_arrays, sub_state)
from repro.core.strategies import ProbeReport
from repro.faults.injector import TransientFault, coerce_injector
from repro.models.model import Model, supports_prefix_cut

PyTree = Any

# kept for back-compat; the engines now consult Strategy.probe_requirements
PROBE_STRATEGIES = ("snr", "rgn", "ours", "ours_unified")


@dataclass
class RoundRecord:
    round: int
    test_loss: float
    test_acc: float
    train_loss: float
    mask_matrix: np.ndarray
    cohort: np.ndarray
    union_frac: float
    uploaded_params: int
    wall_s: float


@dataclass
class History:
    records: list[RoundRecord] = field(default_factory=list)

    @staticmethod
    def _finite(r: RoundRecord) -> bool:
        return all(math.isfinite(v)
                   for v in (r.test_loss, r.test_acc, r.train_loss))

    def summary(self) -> dict:
        """Aggregate stats over the run.  Rounds poisoned by a non-finite
        loss/acc (e.g. an all-quarantined fault round) are *excluded* from
        final/best aggregates — NaN would silently propagate through them
        — and surfaced as ``nonfinite_rounds`` instead."""
        if not self.records:
            return {"final_loss": None, "final_acc": None, "best_acc": None,
                    "rounds": 0, "uploaded_params_total": 0,
                    "nonfinite_rounds": 0}
        clean = [r for r in self.records if self._finite(r)]
        last = clean[-1] if clean else None
        return {"final_loss": last.test_loss if last else None,
                "final_acc": last.test_acc if last else None,
                "best_acc": max(r.test_acc for r in clean) if clean else None,
                "rounds": len(self.records),
                "uploaded_params_total": sum(r.uploaded_params
                                             for r in self.records),
                "nonfinite_rounds": len(self.records) - len(clean)}

    def selection_heatmap(self) -> np.ndarray:
        """(T, L) count of clients selecting each layer — Figure 2 analogue."""
        return np.stack([r.mask_matrix.sum(0) for r in self.records])

    def to_json(self) -> dict:
        """JSON-serialisable dict (benchmarks/report.py consumes these)."""
        return {
            "summary": self.summary(),
            "records": [{
                "round": r.round, "test_loss": r.test_loss,
                "test_acc": r.test_acc, "train_loss": r.train_loss,
                "mask_matrix": np.asarray(r.mask_matrix).astype(int).tolist(),
                "cohort": np.asarray(r.cohort).astype(int).tolist(),
                "union_frac": r.union_frac,
                "uploaded_params": r.uploaded_params,
                "wall_s": r.wall_s,
            } for r in self.records]}

    @classmethod
    def from_json(cls, d: dict) -> "History":
        """Inverse of :meth:`to_json` (checkpoint restore path).  Mask and
        cohort entries come back as arrays of the engine's dtypes, so
        resumed histories compare equal to uninterrupted ones."""
        hist = cls()
        for r in d["records"]:
            hist.records.append(RoundRecord(
                round=int(r["round"]), test_loss=float(r["test_loss"]),
                test_acc=float(r["test_acc"]),
                train_loss=float(r["train_loss"]),
                mask_matrix=np.asarray(r["mask_matrix"], np.float32),
                cohort=np.asarray(r["cohort"], np.int64),
                union_frac=float(r["union_frac"]),
                uploaded_params=int(r["uploaded_params"]),
                wall_s=float(r["wall_s"])))
        return hist


@dataclass
class RoundPlan:
    """Host-side round schedule: who participates and who gets probed."""
    t: int
    cohort: np.ndarray
    budgets: np.ndarray
    sizes: np.ndarray
    probe_ids: np.ndarray    # cohort members needing a fresh probe (cohort order)
    refresh: bool            # full re-probe round (t % selection_period == 0)


@dataclass
class SampledRound:
    """All host-drawn data for one round (prefetchable ahead of time)."""
    plan: RoundPlan
    update_batches: dict                    # leaves (cohort, τ, B, ...)
    probe_batches: Optional[dict]           # leaves (len(probe_ids), sel, B, ...)


ENGINES = ("vectorized", "sequential")


class FLServer:
    def __init__(self, model: Model, fl: FLConfig,
                 data: "Task",
                 rng: Optional[np.random.RandomState] = None,
                 engine: str = "vectorized",
                 pipeline: Optional[bool] = None,
                 pipeline_depth: int = 1,
                 strategy: "Optional[Strategy | str]" = None,
                 mask_aware: Optional[bool] = None,
                 checkpoint_dir: Optional[str] = None,
                 checkpoint_every: int = 10,
                 faults: "Optional[object]" = None,
                 solver_deadline_s: Optional[float] = None):
        if engine not in ENGINES:
            raise ValueError(f"engine must be one of {ENGINES}, got {engine!r}")
        if solver_deadline_s is not None and solver_deadline_s <= 0:
            raise ValueError(
                f"solver_deadline_s must be > 0, got {solver_deadline_s}")
        if pipeline_depth < 1:
            raise ValueError(f"pipeline_depth must be >= 1, got {pipeline_depth}")
        if checkpoint_every < 1:
            raise ValueError(
                f"checkpoint_every must be >= 1, got {checkpoint_every}")
        if mask_aware and not supports_prefix_cut(model.cfg):
            raise ValueError(
                f"mask_aware=True but family {model.cfg.family!r} has no "
                f"prefix-cut path (models.model.supports_prefix_cut)")
        if mask_aware and engine != "vectorized":
            raise ValueError("mask_aware=True requires engine='vectorized' "
                             "(the sequential oracle stays dense)")
        self.model = model
        self.fl = fl
        self.data = data
        self.client = Client(model)
        self.rng = rng or np.random.RandomState(fl.seed)
        self.engine = engine
        # streaming round pipeline (vectorized engine only): depth-k host
        # prefetch + async solve + probe/update overlap, same results.
        # pipeline_depth = how many rounds ahead the scheduler plans/samples
        # (1 = the classic double buffer).
        self.pipeline = (engine == "vectorized") if pipeline is None else pipeline
        self.pipeline_depth = pipeline_depth
        # mask-aware compute engine (DESIGN.md §7): the vectorized update
        # skips the frozen-prefix backward, keyed on a static cut derived
        # from the round's masks.  Auto: on wherever the family's compute
        # order admits a prefix cut; the sequential oracle stays dense.
        self.mask_aware = (engine == "vectorized"
                           and supports_prefix_cut(model.cfg)
                           if mask_aware is None else bool(mask_aware))
        self.L = model.n_selectable
        self.layer_costs = None      # optional per-layer cost vector for (P1)
        # registry-resolved strategy (fl.strategy is the back-compat string
        # path; a Strategy instance or name passed here takes precedence)
        self.strategy = get_strategy(strategy if strategy is not None
                                     else fl.strategy)
        unknown = set(self.strategy.probe_requirements) - set(ProbeReport.KEYS)
        if unknown:
            raise ValueError(
                f"strategy {self.strategy.name!r} declares unknown "
                f"probe_requirements {sorted(unknown)}; the probe computes "
                f"{ProbeReport.KEYS}")
        # the probe computes only what the strategy declared it needs
        self._probe_reqs = tuple(k for k in ProbeReport.KEYS
                                 if k in self.strategy.probe_requirements)
        # device-side scoring fuses into the vectorized probe program; the
        # sequential oracle scores the uploaded stats on the host instead
        self._score_fn = (self.strategy.device_score_fn()
                          if engine == "vectorized" else None)
        # all per-client-id cross-round state — the probe-stat cache
        # (selection_period > 1, generation-invalidated at refresh), the
        # warm-start mask rows (a hint for the next (P1) solve via
        # SelectionContext.init; never cleared — solve outputs stay
        # budget-exact regardless), and last-seen rounds — lives in one
        # flat-array store indexed by client id: O(cohort) gather/scatter
        # per round at any population size, and the unit of round-boundary
        # checkpointing (save_state/restore_state)
        self.state = ClientStateStore(fl.n_clients, self.L)
        self._layer_params: Optional[np.ndarray] = None
        # _select_memo — (inputs-key, masks) of the last host solve; an
        # identical (cohort, budgets, stats, init) round skips the solve
        # entirely (the "unchanged utilities" early exit).  Deliberately
        # not checkpointed: a hit requires byte-identical inputs, under
        # which the solve is deterministic — dropping it on restore can
        # only change solve counters, never masks.
        # select_stats counts solves vs memo hits for tests/benchmarks.
        self._select_memo: Optional[tuple] = None
        self.select_stats = {"solves": 0, "memo_hits": 0,
                             "partial_warm_starts": 0,
                             "all_straggler_rounds": 0,
                             "quarantined_rows": 0, "dead_clients": 0,
                             "solver_timeouts": 0, "dispatch_retries": 0,
                             "ckpt_fallbacks": 0}
        self._straggler_warned = False
        # fault injection + graceful degradation (DESIGN.md §12): a
        # FaultPlan/FaultInjector (None = no injector).  A wired-but-
        # disabled injector never touches the round path — bit-identical
        # to no injector at all (tests/test_faults.py).
        self._injector = coerce_injector(faults)
        # optional *real* wall-clock deadline on the background (P1) solve
        # (scheduler path only; best-effort by nature — the deterministic
        # stall path is FaultPlan.stall_rate through select_round)
        self.solver_deadline_s = solver_deadline_s
        # round-boundary checkpointing (None = off): state is saved every
        # checkpoint_every completed rounds and at the end of run()
        self.checkpoint_dir = checkpoint_dir
        self.checkpoint_every = checkpoint_every

    @property
    def _warm_masks(self):
        """Read-only dict-like view of the store's warm-mask rows
        (back-compat: iteration yields client ids, ``[id]``/``get`` return
        row copies)."""
        return self.state.warm_masks

    @property
    def needs_probe(self) -> bool:
        return bool(self._probe_reqs)

    # -- fault machinery (DESIGN.md §12) ---------------------------------
    @property
    def _faults_active(self) -> bool:
        return self._injector is not None and self._injector.enabled

    def _dispatch(self, t: int, fn, *args):
        """Run a round dispatch with bounded retry-with-backoff over
        *injected* transient failures.  Only :class:`TransientFault`
        retries — anything else is a real bug and propagates.  After
        ``max_dispatch_retries`` exhausted retries the fault re-raises:
        a permanently failing dispatch must kill the run loudly, not
        degrade it silently."""
        if not self._faults_active:
            return fn(*args)
        plan = self._injector.plan
        attempt = 0
        while True:
            try:
                self._injector.maybe_fail_dispatch(t, attempt)
                return fn(*args)
            except TransientFault:
                attempt += 1
                self.select_stats["dispatch_retries"] += 1
                if attempt > plan.max_dispatch_retries:
                    raise
                if plan.retry_backoff_s > 0:
                    time.sleep(plan.retry_backoff_s * (2 ** (attempt - 1)))

    # -- stage 1: plan ---------------------------------------------------
    def _budgets(self, cohort: np.ndarray) -> np.ndarray:
        return np.array([self.fl.budget_of(int(i)) for i in cohort])

    def _plan_for(self, cohort: np.ndarray, t: int) -> RoundPlan:
        fl = self.fl
        needs_probe = self.needs_probe
        refresh = needs_probe and t % fl.selection_period == 0
        if refresh:
            probe_ids = np.asarray(cohort)
        elif needs_probe:
            probe_ids = self.state.missing_stats(np.asarray(cohort))
        else:
            probe_ids = np.zeros((0,), np.int64)
        return RoundPlan(t=t, cohort=cohort, budgets=self._budgets(cohort),
                         sizes=self.data.sizes[cohort], probe_ids=probe_ids,
                         refresh=refresh)

    def plan_round(self, t: int) -> RoundPlan:
        """Draw the round-t cohort, honouring the task's plan-stage hooks.

        Tasks may expose ``available_clients(t, rng) -> ids`` (per-round
        availability: the cohort is drawn from the returned pool) and
        ``drop_stragglers(t, cohort, rng) -> keep-mask`` (members that fail
        to report this round are dropped before probing/budgeting).  Tasks
        without hooks — e.g. ``SyntheticFederatedData`` — consume the server
        rng exactly as before, so seeds and parity are unchanged.
        """
        avail = getattr(self.data, "available_clients", None)
        pool = avail(t, self.rng) if callable(avail) else None
        if pool is None:                 # full availability: legacy rng path
            cohort = self.rng.choice(self.fl.n_clients,
                                     size=self.fl.cohort_size, replace=False)
        else:
            pool = np.asarray(pool)
            if pool.size == 0:
                # an empty cohort would reach aggregation/np.mean(losses)
                # and crash with an opaque error several stages later —
                # fail at the plan stage with the actual cause instead
                raise ValueError(
                    f"available_clients returned an empty pool for round "
                    f"{t}: no cohort can be drawn (the task's availability "
                    f"hook must return at least one client id, or None for "
                    f"full availability)")
            k = min(self.fl.cohort_size, len(pool))
            cohort = pool[self.rng.choice(len(pool), size=k, replace=False)]
        drop = getattr(self.data, "drop_stragglers", None)
        if callable(drop):
            keep = np.asarray(drop(t, cohort, self.rng), bool)
            if keep.shape != cohort.shape:
                raise ValueError(
                    f"drop_stragglers returned keep-mask of shape "
                    f"{keep.shape} for a round-{t} cohort of shape "
                    f"{cohort.shape}")
            if keep.any():               # never drop the whole cohort
                cohort = cohort[keep]
            else:
                # every member straggled: the round runs on the full cohort
                # (an empty round would crash downstream), but no longer
                # silently — a run dominated by these is not degrading the
                # way its straggler model says it should
                self.select_stats["all_straggler_rounds"] += 1
                if not self._straggler_warned:
                    warnings.warn(
                        f"round {t}: drop_stragglers marked the entire "
                        f"cohort; running it in full instead (counted in "
                        f"select_stats['all_straggler_rounds']; warning "
                        f"once per server)", stacklevel=2)
                    self._straggler_warned = True
        return self._plan_for(cohort, t)

    # -- stage 2: sample (host; prefetchable) ----------------------------
    def sample_round(self, plan: RoundPlan) -> SampledRound:
        """Draw all of this round's data.  Per-client stream order is probe
        batches first, then update batches — the order both engines consume
        them in, and the order the synchronous loop draws them in."""
        fl = self.fl
        probe_b = (self.data.cohort_batches(plan.probe_ids, fl.batch_size,
                                            fl.selection_batches)
                   if len(plan.probe_ids) else None)
        update_b = self.data.cohort_batches(plan.cohort, fl.batch_size,
                                            fl.local_steps)
        # Explicit h2d here, in the (pipelined, overlapped) sample stage:
        # handing raw np batches to the jitted engines would be an implicit
        # transfer at dispatch time — REPRO_STRICT's transfer guard rejects
        # exactly that, and the copy would land in the hot segment.
        update_b = jax.device_put(update_b)
        probe_b = jax.device_put(probe_b) if probe_b is not None else None
        return SampledRound(plan=plan, update_batches=update_b,
                            probe_batches=probe_b)

    # -- stage 3: probe (device) -----------------------------------------
    def probe_round(self, params: PyTree,
                    sampled: SampledRound) -> Optional[dict[str, np.ndarray]]:
        """Stat rows for ``plan.probe_ids`` (engine-specific compute)."""
        if sampled.probe_batches is None:
            return None
        if self.engine == "vectorized":
            return self.client.probe_cohort(params, sampled.probe_batches,
                                            self._probe_reqs, self._score_fn)
        nb = self.fl.selection_batches
        rows: list[dict[str, np.ndarray]] = []
        for r in range(len(sampled.plan.probe_ids)):
            acc = None
            for b in range(nb):
                batch = jax.tree.map(lambda x, r=r, b=b: x[r, b],
                                     sampled.probe_batches)
                out = self.client.probe(params, batch, self._probe_reqs)
                acc = out if acc is None else {k: acc[k] + out[k] for k in out}
            rows.append({k: v / nb for k, v in acc.items()})
        return {k: np.stack([r[k] for r in rows]) for k in rows[0]}

    # -- stage 4: select (host) ------------------------------------------
    def _warm_init(self, cohort: np.ndarray, probe: ProbeReport,
                   budgets: np.ndarray) -> Optional[np.ndarray]:
        """Warm-start rows for an iterative host solve: the cohort's
        previous converged masks.  Cohorts with *unseen* members no longer
        bail to a full cold start — unseen rows are greedily filled with
        the solver's own cold-start masks (``solver.greedy_rows`` on this
        round's utilities), so one new client cannot discard every other
        member's warm start (``select_stats["partial_warm_starts"]``
        counts these rounds)."""
        if not self.strategy.host or not self.state.has_warm:
            return None
        rows, valid = self.state.warm_rows(cohort)
        if not valid.all():
            if probe.grad_sq_norms is None:
                return None      # no utilities to greedy-fill from
            G = np.asarray(probe.grad_sq_norms)
            budgets = np.broadcast_to(np.asarray(budgets), (len(rows),))
            missing = np.flatnonzero(~valid)
            rows[missing] = greedy_rows(G[missing], budgets[missing],
                                        costs=self.layer_costs)
            self.select_stats["partial_warm_starts"] += 1
        return rows

    def _memo_key(self, plan: RoundPlan, probe: ProbeReport,
                  init: Optional[np.ndarray]) -> tuple:
        """Exact-inputs key for the host-solve memo: cohort ids, budgets, λ,
        layer costs, every present probe stat AND the warm-start init rows,
        byte-compared (no fp tolerance).

        The init must be part of the key: an iterative solver that stopped
        at ``max_iters`` without converging is *not* a pure function of the
        other inputs — a replay would freeze masks a real (differently
        warm-started) solve could still advance.  Since the warm rows are
        the previous solve's output, the memo simply starts hitting one
        round later, once the masks reach a fixed point.
        """
        stat_bytes = tuple(
            (k, v.tobytes()) for k, v in (
                (k, getattr(probe, k)) for k in (*ProbeReport.KEYS, "scores"))
            if v is not None)
        costs = (None if self.layer_costs is None
                 else np.asarray(self.layer_costs, np.float64).tobytes())
        return (np.asarray(plan.cohort, np.int64).tobytes(),
                np.asarray(plan.budgets, np.float64).tobytes(),
                float(self.fl.lam), costs, stat_bytes,
                None if init is None else init.astype(np.float32).tobytes())

    def select_round(self, plan: RoundPlan,
                     stats: Optional[dict[str, np.ndarray]]) -> np.ndarray:
        """Derive the round's masks (host).  For host strategies (the (P1)
        solvers) two accelerations apply, shared by the synchronous loop and
        the pipelined scheduler so parity is preserved by construction:
        a per-client-id warm start (``SelectionContext.init`` — a hint a
        strategy is free to ignore) and, for strategies declaring
        ``memoizable_select``, an early exit when (cohort, budgets,
        utilities) are byte-identical to the previous solve.
        """
        fl = self.fl
        if plan.refresh:
            self.state.clear_stats()     # generation bump: O(1), any n
        if stats is not None:
            self.state.set_stat_rows(plan.probe_ids, stats)
        if self.needs_probe:
            probe = ProbeReport(**self.state.stat_rows(plan.cohort))
        else:
            probe = ProbeReport(grad_sq_norms=np.zeros((len(plan.cohort),
                                                        self.L), np.float32))
        ctx = SelectionContext(client_ids=np.asarray(plan.cohort),
                               round=plan.t, lam=fl.lam,
                               costs=self.layer_costs, n_layers=self.L,
                               init=self._warm_init(plan.cohort, probe,
                                                    plan.budgets))
        if not self.strategy.host:
            return self.strategy.select(probe, plan.budgets, ctx)
        if self._faults_active and self._injector.solver_stalls(plan.t):
            # injected solver stall: the (P1) solve missed its deadline —
            # degrade to warm/greedy fallback masks instead of blocking
            # the round (deterministic per (seed, t); engine-uniform)
            return self._select_fallback(plan, probe)
        # the early exit only applies to strategies declaring their select
        # round-independent (Strategy.memoizable_select) — a custom host
        # strategy with e.g. an annealing schedule must never be replayed
        memoizable = getattr(self.strategy, "memoizable_select", False)
        key = self._memo_key(plan, probe, ctx.init) if memoizable else None
        if memoizable and self._select_memo is not None \
                and self._select_memo[0] == key:
            self.select_stats["memo_hits"] += 1
            masks = self._select_memo[1].copy()
        else:
            masks = self.strategy.select(probe, plan.budgets, ctx)
            self.select_stats["solves"] += 1
            if memoizable:
                self._select_memo = (key, masks.copy())
        self.state.set_warm_rows(plan.cohort, masks, t=plan.t)
        return masks

    def _select_fallback(self, plan: RoundPlan,
                         probe: Optional[ProbeReport]) -> np.ndarray:
        """Deadline-degraded masks: each member's previous converged mask
        (warm row) where one exists, a greedy solve on this round's
        utilities for unseen members, zeros (a forward-only round) when
        neither is available.  The memo is invalidated — fallback masks
        are not a solve output and must never be replayed as one — but
        they DO become the next round's warm start, exactly like real
        masks, so a recovered solver resumes from where degradation left
        the cohort."""
        self.select_stats["solver_timeouts"] += 1
        rows, valid = self.state.warm_rows(plan.cohort)
        if not valid.all() and probe is not None \
                and probe.grad_sq_norms is not None:
            G = np.asarray(probe.grad_sq_norms)
            budgets = np.broadcast_to(np.asarray(plan.budgets), (len(rows),))
            missing = np.flatnonzero(~valid)
            rows[missing] = greedy_rows(G[missing], budgets[missing],
                                        costs=self.layer_costs)
        self._select_memo = None
        self.state.set_warm_rows(plan.cohort, rows, t=plan.t)
        return rows

    def _fallback_rows(self, plan: RoundPlan) -> np.ndarray:
        """Read-only fallback for the scheduler's *real* wall-clock
        deadline (``solver_deadline_s``): warm rows where valid, zeros
        elsewhere.  Deliberately touches no store/memo state — the late
        solve is still running on the solver thread and remains the
        single writer (RoundScheduler joins it before anything reads
        what it wrote)."""
        self.select_stats["solver_timeouts"] += 1
        rows, _ = self.state.warm_rows(plan.cohort)
        return rows

    def select_masks(self, params: PyTree, cohort: np.ndarray,
                     t: int) -> np.ndarray:
        """Compat wrapper: plan + probe + select for an externally drawn
        cohort.  Masks always correspond to *this* cohort's clients and
        budgets (per-client stat caching — no stale rows).  Only probe
        batches are drawn — the caller owns the update draws."""
        plan = self._plan_for(np.asarray(cohort), t)
        probe_b = (self.data.cohort_batches(plan.probe_ids, self.fl.batch_size,
                                            self.fl.selection_batches)
                   if len(plan.probe_ids) else None)
        stats = self.probe_round(params, SampledRound(plan, {}, probe_b))
        return self.select_round(plan, stats)

    # -- stage 5: update (device) ----------------------------------------
    def _cut_for(self, masks: np.ndarray) -> Optional[int]:
        """The round's static prefix cut for the mask-aware engine, or None
        for the dense program.  Computed on host from the selected masks —
        selection always completes before update dispatch, in both the
        synchronous loop and the streaming scheduler."""
        return M.first_trainable_layer(masks) if self.mask_aware else None

    def update_round(self, params: PyTree, sampled: SampledRound,
                     masks: np.ndarray) -> tuple[PyTree, np.ndarray]:
        fl, plan = self.fl, sampled.plan
        if self._faults_active:
            return self._update_round_faulty(params, sampled, masks)
        if self.engine == "vectorized":
            return self.client.cohort_update(params, sampled.update_batches,
                                             masks, plan.sizes, fl.lr,
                                             cut=self._cut_for(masks))
        deltas, losses = [], []
        for row in range(len(plan.cohort)):
            batches = jax.tree.map(lambda x, row=row: x[row],
                                   sampled.update_batches)
            delta, loss = self.client.local_update(params, batches,
                                                   masks[row], fl.lr)
            deltas.append(delta)
            losses.append(loss)
        update = agg.aggregate(deltas, masks, plan.sizes, self.model.cfg)
        return agg.apply_update(params, update, fl.lr), np.asarray(losses)

    # -- stage 5, fault path (DESIGN.md §12) ------------------------------
    def _update_round_faulty(self, params: PyTree, sampled: SampledRound,
                             masks: np.ndarray
                             ) -> tuple[PyTree, np.ndarray]:
        """The round step with the injector live: mid-round client death
        (survivor-reweighted Eq.(7)), injected delta corruption, and the
        finite guard that quarantines poisoned rows before they touch the
        global params.  The vectorized engine runs the ONE guarded jitted
        variant (``cohort_update_guarded`` — survivors/codes are runtime
        arrays, no per-fault recompiles); the sequential engine is the
        survivors-only oracle the parity tests compare against.  Reported
        ``losses`` cover the rows that actually aggregated (``[nan]``
        when the whole cohort died — the record surfaces the poisoned
        round instead of faking a finite loss)."""
        fl, plan = self.fl, sampled.plan
        inj = self._injector
        fp = inj.plan
        survivors, codes = inj.round_faults(plan.t, len(plan.cohort))
        if self.engine == "vectorized":
            params, losses, ok = self._dispatch(
                plan.t, self.client.cohort_update_guarded, params,
                sampled.update_batches, masks, plan.sizes, fl.lr,
                survivors, codes, fp.explode_scale, fp.max_delta_sq)
        else:
            params, losses, ok = self._dispatch(
                plan.t, self._sequential_guarded, params, sampled, masks,
                survivors, codes)
        self._account_faults(survivors, ok)
        kept = np.asarray(losses)[np.asarray(ok) > 0]
        return params, (kept if kept.size
                        else np.asarray([np.nan], np.float32))

    def _sequential_guarded(self, params: PyTree, sampled: SampledRound,
                            masks: np.ndarray, survivors: np.ndarray,
                            codes: np.ndarray
                            ) -> tuple[PyTree, np.ndarray, np.ndarray]:
        """Paper-literal fault oracle: per-client updates, host-side
        corruption + finite guard, then Eq.(5)-(7) over exactly the
        surviving finite rows — the ground truth the guarded vectorized
        program must match (tests/test_faults.py parity (c))."""
        fl, plan = self.fl, sampled.plan
        fp = self._injector.plan
        deltas, losses = [], []
        for row in range(len(plan.cohort)):
            batches = jax.tree.map(lambda x, row=row: x[row],
                                   sampled.update_batches)
            delta, loss = self.client.local_update(params, batches,
                                                   masks[row], fl.lr)
            deltas.append(delta)
            losses.append(loss)
        ok = np.asarray(survivors, np.float32).copy()
        for i, code in enumerate(np.asarray(codes, np.int32)):
            if code:
                deltas[i] = self._corrupt_host(deltas[i], int(code),
                                               fp.explode_scale)
            finite, sq = True, np.float32(0.0)
            for leaf in jax.tree.leaves(deltas[i]):
                a = np.asarray(leaf, np.float32)  # repro: allow[host-sync] -- the sequential oracle is host-side by definition
                finite = finite and bool(np.isfinite(a).all())
                sq = np.float32(sq + a.astype(np.float32).ravel().dot(
                    a.astype(np.float32).ravel()))
            if not finite or not sq <= fp.max_delta_sq:
                ok[i] = 0.0
        idx = np.flatnonzero(ok > 0)
        if idx.size:                     # all-quarantined round: θ unchanged
            update = agg.aggregate([deltas[i] for i in idx],
                                   np.asarray(masks)[idx], plan.sizes[idx],
                                   self.model.cfg)
            params = agg.apply_update(params, update, fl.lr)
        return params, np.asarray(losses), ok

    @staticmethod
    def _corrupt_host(delta: PyTree, code: int, scale: float) -> PyTree:
        """Host twin of ``aggregation.corrupt_delta_rows`` for one client's
        delta tree (sequential oracle)."""
        if code == 3:
            return jax.tree.map(
                lambda x: np.asarray(x, np.float32) * np.float32(scale),
                delta)
        fill = np.nan if code == 1 else np.inf
        return jax.tree.map(
            lambda x: np.full_like(np.asarray(x, np.float32), fill), delta)

    def _account_faults(self, survivors: np.ndarray,
                        ok: np.ndarray) -> None:
        survivors = np.asarray(survivors)
        ok = np.asarray(ok)  # repro: allow[host-sync] -- fault accounting at the round boundary (sanctioned sync)
        self.select_stats["dead_clients"] += int((survivors <= 0).sum())
        self.select_stats["quarantined_rows"] += int(
            ((ok <= 0) & (survivors > 0)).sum())

    # -- stage 6: eval + record ------------------------------------------
    def _ensure_layer_params(self, params: PyTree) -> None:
        """Shape-only per-layer param counts; computed once, params not kept."""
        if self._layer_params is None:
            self._layer_params = M.count_layer_params(params, self.model.cfg)

    def _make_record(self, plan: RoundPlan, masks: np.ndarray,
                     train_loss: float, test_loss: float, test_acc: float,
                     wall_s: float) -> RoundRecord:
        # repro: allow[host-sync] -- round-boundary record finalisation on host np masks (lazy _finalize)
        uploaded = int(sum(int(masks[r] @ self._layer_params)
                           for r in range(len(plan.cohort))))
        return RoundRecord(
            round=plan.t, test_loss=test_loss, test_acc=test_acc,
            train_loss=train_loss, mask_matrix=masks, cohort=plan.cohort,
            union_frac=float(M.union_mask(masks).mean()),  # repro: allow[host-sync] -- host np mask matrix, no device value
            uploaded_params=uploaded, wall_s=wall_s)

    # ------------------------------------------------------------------
    def run_round(self, params: PyTree, t: int) -> tuple[PyTree, RoundRecord]:
        """One synchronous round: plan → sample → probe → select → update →
        eval.  The streaming :meth:`run` loop produces identical results."""
        t0 = time.time()  # repro: allow[nondeterminism] -- wall_s telemetry only, never an input to round math
        plan = self.plan_round(t)
        sampled = self.sample_round(plan)
        stats = self.probe_round(params, sampled)
        masks = self.select_round(plan, stats)
        self._ensure_layer_params(params)
        params, losses = self.update_round(params, sampled, masks)
        test_loss, test_acc = self.client.evaluate(params,
                                                   self.data.test_batch())
        rec = self._make_record(plan, masks, float(np.mean(losses)),
                                test_loss, test_acc, time.time() - t0)  # repro: allow[nondeterminism] -- wall_s telemetry only
        return params, rec

    # -- round-boundary checkpointing ------------------------------------
    def _is_ckpt_round(self, t_next: int, T: int) -> bool:
        """Save once ``t_next`` rounds have completed?  Boundaries fall
        every ``checkpoint_every`` rounds plus the end of the run."""
        if self.checkpoint_dir is None:
            return False
        return t_next % self.checkpoint_every == 0 or t_next == T

    def save_state(self, params: PyTree, t_next: int,
                   history: History) -> str:
        """Checkpoint the full resumable state after ``t_next`` completed
        rounds: params, the client-state store, the server rng, and (when
        the task exposes ``state_dict``) the task's stream state, as one
        flat-array tree; History and select_stats ride the manifest."""
        from repro.ckpt import save_checkpoint
        tree = {"params": params,
                "client": self.state.state_dict(),
                "server_rng": rng_state_to_arrays(self.rng)}
        task_sd = getattr(self.data, "state_dict", None)
        if callable(task_sd):
            tree["task"] = task_sd()
        extra = {"round": t_next, "history": history.to_json(),
                 "select_stats": dict(self.select_stats)}
        path = save_checkpoint(self.checkpoint_dir, t_next, tree, extra=extra)
        if self._faults_active:          # post-save media damage (DESIGN.md §12)
            self._injector.maybe_corrupt_checkpoint(path, t_next)
        return path

    def restore_state(self, params_template: PyTree,
                      step: Optional[int] = None
                      ) -> Optional[tuple[PyTree, int, History]]:
        """Restore the latest (or ``step``) checkpoint into this server.

        Returns ``(params, completed_rounds, history)``, or None when the
        checkpoint dir is unset/empty.  Params restore strictly against the
        template (shape-checked); store/rng/task namespaces restore
        byte-exact, so ``run(params, start=completed_rounds)`` continues
        bit-identically on masks.

        Self-healing (DESIGN.md §12): with no explicit ``step``, the scan
        verifies manifests + per-array checksums newest-first and resumes
        from the latest *intact* checkpoint, counting the fallback in
        ``select_stats["ckpt_fallbacks"]`` and warning with the skipped
        steps.  An explicit ``step`` is trusted as asked-for (corruption
        there surfaces as the underlying load error)."""
        from repro.ckpt import (latest_intact_step, load_checkpoint_arrays,
                                restore_checkpoint)
        if self.checkpoint_dir is None:
            return None
        fell_back = False
        if step is None:
            step, skipped = latest_intact_step(self.checkpoint_dir)
            if skipped:
                fell_back = True
                detail = "; ".join(f"step {s}: {why}" for s, why in skipped)
                warnings.warn(
                    f"skipping corrupt checkpoint(s) [{detail}]; resuming "
                    f"from {'step %d' % step if step is not None else 'scratch'}",
                    RuntimeWarning, stacklevel=2)
        if step is None:
            return None
        restored, _ = restore_checkpoint(self.checkpoint_dir,
                                         {"params": params_template}, step)
        flat, manifest = load_checkpoint_arrays(self.checkpoint_dir, step)
        self.state.load_state_dict(sub_state(flat, "client/"))
        rng_state_from_arrays(sub_state(flat, "server_rng/"), self.rng)
        task_state = sub_state(flat, "task/")
        task_ld = getattr(self.data, "load_state_dict", None)
        if task_state and callable(task_ld):
            task_ld(task_state)
        self._select_memo = None         # value-safe to drop (see __init__)
        extra = manifest["extra"]
        self.select_stats.update(extra.get("select_stats", {}))
        if fell_back:                    # after the update: the restored
            self.select_stats["ckpt_fallbacks"] += 1   # dict must not clobber it
        return (restored["params"], int(extra["round"]),
                History.from_json(extra["history"]))

    def run(self, params: PyTree, rounds: Optional[int] = None,
            verbose: bool = False, *, start: int = 0,
            history: Optional[History] = None) -> tuple[PyTree, History]:
        """Run rounds ``start..rounds-1`` (``start``/``history`` come from
        :meth:`restore_state` on resume), checkpointing at boundaries when
        ``checkpoint_dir`` is set."""
        T = rounds if rounds is not None else self.fl.rounds
        # legacy sampling redraws the test set every round (mutating
        # _test_rng) — hoisting eval data out of the loop would change its
        # semantics, so legacy runs always take the synchronous path
        legacy = getattr(self.data, "legacy_sampling", False)
        if self.engine == "vectorized" and self.pipeline and not legacy \
                and T > start:
            from repro.core.scheduler import RoundScheduler
            return RoundScheduler(self, depth=self.pipeline_depth).run(
                params, T, verbose, start=start, history=history)
        hist = history if history is not None else History()
        for t in range(start, T):
            params, rec = self.run_round(params, t)
            hist.records.append(rec)
            if verbose:
                self._print_round(rec)
            if self._is_ckpt_round(t + 1, T):
                self.save_state(params, t + 1, hist)
        return params, hist

    # -- streaming pipeline (repro.core.scheduler.RoundScheduler) ---------
    @staticmethod
    def _stats_np(stats_dev) -> Optional[dict[str, np.ndarray]]:
        """Materialise a raw probe result (the pipeline's one sync point)."""
        if stats_dev is None:
            return None
        return probe_stats_dict(stats_dev)

    def _finalize(self, entry: tuple) -> RoundRecord:
        plan, masks, losses, loss_dev, acc_dev, wall_s = entry
        # repro: allow[host-sync] -- the round boundary: lazy record finalisation is the sanctioned d2h point
        return self._make_record(plan, masks, float(np.mean(np.asarray(losses))),
                                 float(loss_dev), float(acc_dev), wall_s)  # repro: allow[host-sync] -- same round-boundary materialisation

    @staticmethod
    def _print_round(rec: RoundRecord) -> None:
        print(f"[round {rec.round:3d}] test_loss={rec.test_loss:.4f} "
              f"acc={rec.test_acc:.4f} union={rec.union_frac:.2f} "
              f"({rec.wall_s:.2f}s)")
