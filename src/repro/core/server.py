"""The FL server: Algorithm 1 (selective layer fine-tuning in FL).

Single-host simulator with exact paper semantics: arbitrary per-client
masks, τ local steps, per-layer weighted aggregation, strategy-driven layer
selection with a configurable period.  The distributed pjit path
(sharding/fl_step.py) executes the same round math cohort-parallel on the
production mesh.

Two round engines (``FLServer(..., engine=...)``):

* ``"vectorized"`` (default) — the hot path is one XLA program per round:
  a single jitted step that vmaps the τ-step local update across the
  cohort and fuses the Eq.(5)-(7) weighted aggregation and Eq.(6) apply
  (Client.cohort_update); the selection probe is likewise one vmapped call
  over (cohort, selection_batches) (Client.probe_cohort).
* ``"sequential"`` — the paper-literal per-client loop, retained as the
  parity oracle.  Both engines draw identical per-client data and produce
  identical masks and params within fp tolerance
  (tests/test_round_engine.py).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import numpy as np

from repro.configs.base import FLConfig
from repro.core import aggregation as agg
from repro.core import masks as M
from repro.core.client import Client
from repro.core.strategies import ProbeReport, select
from repro.data.synthetic import SyntheticFederatedData
from repro.models.model import Model

PyTree = Any


@dataclass
class RoundRecord:
    round: int
    test_loss: float
    test_acc: float
    train_loss: float
    mask_matrix: np.ndarray
    cohort: np.ndarray
    union_frac: float
    uploaded_params: int
    wall_s: float


@dataclass
class History:
    records: list[RoundRecord] = field(default_factory=list)

    def summary(self) -> dict:
        last = self.records[-1]
        best_acc = max(r.test_acc for r in self.records)
        return {"final_loss": last.test_loss, "final_acc": last.test_acc,
                "best_acc": best_acc, "rounds": len(self.records),
                "uploaded_params_total": sum(r.uploaded_params for r in self.records)}

    def selection_heatmap(self) -> np.ndarray:
        """(T, L) count of clients selecting each layer — Figure 2 analogue."""
        return np.stack([r.mask_matrix.sum(0) for r in self.records])


ENGINES = ("vectorized", "sequential")


class FLServer:
    def __init__(self, model: Model, fl: FLConfig,
                 data: SyntheticFederatedData,
                 rng: Optional[np.random.RandomState] = None,
                 engine: str = "vectorized"):
        if engine not in ENGINES:
            raise ValueError(f"engine must be one of {ENGINES}, got {engine!r}")
        self.model = model
        self.fl = fl
        self.data = data
        self.client = Client(model)
        self.rng = rng or np.random.RandomState(fl.seed)
        self.engine = engine
        self.L = model.n_selectable
        self.layer_costs = None      # optional per-layer cost vector for (P1)
        self._cached_masks: Optional[np.ndarray] = None

    # ------------------------------------------------------------------
    def _budgets(self, cohort: np.ndarray) -> np.ndarray:
        return np.array([self.fl.budget_of(int(i)) for i in cohort])

    def _probe_cohort(self, params: PyTree, cohort: np.ndarray) -> ProbeReport:
        if self.engine == "vectorized":
            batches = self.data.cohort_batches(cohort, self.fl.batch_size,
                                               self.fl.selection_batches)
            return ProbeReport(**self.client.probe_cohort(params, batches))
        rows = {"grad_sq_norms": [], "grad_means": [], "grad_vars": [],
                "param_sq_norms": []}
        for i in cohort:
            acc = None
            for _ in range(self.fl.selection_batches):
                batch = self.data.client_batch(int(i), self.fl.batch_size)
                r = self.client.probe(params, batch)
                acc = r if acc is None else \
                    {k: acc[k] + r[k] for k in r}
            for k in rows:
                rows[k].append(acc[k] / self.fl.selection_batches)
        return ProbeReport(
            grad_sq_norms=np.stack(rows["grad_sq_norms"]),
            param_sq_norms=np.stack(rows["param_sq_norms"]),
            grad_means=np.stack(rows["grad_means"]),
            grad_vars=np.stack(rows["grad_vars"]))

    def select_masks(self, params: PyTree, cohort: np.ndarray,
                     t: int) -> np.ndarray:
        fl = self.fl
        budgets = self._budgets(cohort)
        needs_probe = fl.strategy in ("snr", "rgn", "ours", "ours_unified")
        if needs_probe and t % fl.selection_period == 0:
            probe = self._probe_cohort(params, cohort)
            masks = select(fl.strategy, probe, budgets, lam=fl.lam,
                           costs=self.layer_costs)
            self._cached_masks = masks
        elif needs_probe and self._cached_masks is not None:
            masks = self._cached_masks[:len(cohort)]
        else:
            probe = ProbeReport(grad_sq_norms=np.zeros((len(cohort), self.L)))
            masks = select(fl.strategy, probe, budgets, lam=fl.lam)
        return masks

    # ------------------------------------------------------------------
    def run_round(self, params: PyTree, t: int) -> tuple[PyTree, RoundRecord]:
        fl = self.fl
        cohort = self.rng.choice(fl.n_clients, size=fl.cohort_size, replace=False)
        t0 = time.time()
        masks = self.select_masks(params, cohort, t)

        sizes = self.data.sizes[cohort]
        if self.engine == "vectorized":
            batches = self.data.cohort_batches(cohort, fl.batch_size,
                                               fl.local_steps)
            params, losses = self.client.cohort_update(params, batches, masks,
                                                       sizes, fl.lr)
        else:
            deltas, losses = [], []
            for row, i in enumerate(cohort):
                batches = self.data.client_batches(int(i), fl.batch_size,
                                                   fl.local_steps)
                delta, loss = self.client.local_update(params, batches,
                                                       masks[row], fl.lr)
                deltas.append(delta)
                losses.append(loss)
            update = agg.aggregate(deltas, masks, sizes, self.model.cfg)
            params = agg.apply_update(params, update, fl.lr)

        # metrics
        test = self.data.test_batch()
        test_loss, test_acc = self.client.evaluate(params, test)
        layer_params = M.count_layer_params(params, self.model.cfg)
        uploaded = int(sum(int(masks[r] @ layer_params) for r in range(len(cohort))))
        rec = RoundRecord(
            round=t, test_loss=test_loss, test_acc=test_acc,
            train_loss=float(np.mean(losses)), mask_matrix=masks,
            cohort=cohort, union_frac=float(M.union_mask(masks).mean()),
            uploaded_params=uploaded, wall_s=time.time() - t0)
        return params, rec

    def run(self, params: PyTree, rounds: Optional[int] = None,
            verbose: bool = False) -> tuple[PyTree, History]:
        hist = History()
        for t in range(rounds or self.fl.rounds):
            params, rec = self.run_round(params, t)
            hist.records.append(rec)
            if verbose:
                print(f"[round {t:3d}] test_loss={rec.test_loss:.4f} "
                      f"acc={rec.test_acc:.4f} union={rec.union_frac:.2f} "
                      f"({rec.wall_s:.2f}s)")
        return params, hist
