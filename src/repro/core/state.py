"""Population-scale client state: flat arrays indexed by client id.

Everything the federation keeps *per client id* across rounds lives here,
stored as flat numpy arrays sized for populations of 10⁵–10⁶ clients with
O(cohort) per-round access (DESIGN.md §8):

* :class:`ClientStateStore` — the server-side store: warm-start mask rows,
  the probe-stat cache (selection_period > 1), and last-seen round markers.
  Replaces the ad-hoc ``FLServer._warm_masks`` / ``_stats_cache`` dicts.
  Per-round operations are vectorized gathers/scatters over the cohort's
  ids; cache invalidation is a generation counter bump (O(1), never an
  O(population) sweep).
* :class:`ClientStreamState` — the task-side store: per-client data-stream
  draw counters (flat int64) plus the numpy rng streams themselves, created
  *lazily* on first touch so a 10⁶-client task costs O(touched ≤
  rounds·cohort) rather than O(population) to construct and to checkpoint.

Both serialize to flat ``{name: np.ndarray}`` dicts (``state_dict`` /
``load_state_dict``) consumed by the round-boundary checkpoints
(``ckpt/checkpoint.py`` via ``FLServer.save_state``): restoring them is
byte-exact, which is what makes kill-at-round-t + resume reproduce the
uninterrupted run bit-identically on masks (tests/test_checkpoint.py).

The rng helpers pack ``np.random.RandomState`` (MT19937) state to arrays
and back, so every host stream — the server's cohort rng and each touched
client's data stream — rides the same npz checkpoint as the params.

Cohort rows scale past one device through the ``sharding/fl_step.py``
shard_map machinery: :meth:`ClientStateStore.warm_rows_device` places the
gathered rows on a mesh sharded over the client axes (one cohort member per
(pod×data) coordinate), and plain host arrays on a 1-device mesh — the
single-device path is bit-identical to the host gather.
"""
from __future__ import annotations

from typing import Iterator, Optional

import numpy as np

__all__ = ["ClientStateStore", "ClientStreamState",
           "rng_state_to_arrays", "rng_state_from_arrays", "sub_state"]


# ---------------------------------------------------------------------------
# RandomState (MT19937) <-> flat arrays
# ---------------------------------------------------------------------------

def rng_state_to_arrays(rng: np.random.RandomState) -> dict[str, np.ndarray]:
    """Pack an MT19937 RandomState's full state into checkpointable arrays."""
    name, keys, pos, has_gauss, cached = rng.get_state()
    if name != "MT19937":            # RandomState is always MT19937
        raise ValueError(f"unsupported bit generator {name!r}")
    return {"keys": np.asarray(keys, np.uint32),
            "pos": np.asarray(pos, np.int64),
            "has_gauss": np.asarray(has_gauss, np.int64),
            "cached_gaussian": np.asarray(cached, np.float64)}


def rng_state_from_arrays(d: dict[str, np.ndarray],
                          rng: Optional[np.random.RandomState] = None
                          ) -> np.random.RandomState:
    """Restore (into ``rng`` if given, else a fresh RandomState)."""
    rng = rng if rng is not None else np.random.RandomState()  # repro: allow[nondeterminism] -- state is fully overwritten by set_state below
    rng.set_state(("MT19937", np.asarray(d["keys"], np.uint32),
                   int(d["pos"]), int(d["has_gauss"]),
                   float(d["cached_gaussian"])))
    return rng


def sub_state(d: dict[str, np.ndarray], prefix: str) -> dict[str, np.ndarray]:
    """The ``prefix``-namespaced slice of a flat state dict, prefix stripped."""
    return {k[len(prefix):]: v for k, v in d.items() if k.startswith(prefix)}


# ---------------------------------------------------------------------------
# Task-side per-client stream state
# ---------------------------------------------------------------------------

class ClientStreamState:
    """Per-client data streams: flat draw counters + lazy rng streams.

    ``seed_fn(i)`` gives client i's stream seed; the RandomState itself is
    only materialised when the client is first touched (a sampled cohort
    member), so host memory and checkpoint size are O(touched clients), not
    O(population).  Supports ``streams[i]`` indexing for parity with the old
    eager ``_rngs`` list.
    """

    def __init__(self, n_clients: int, seed_fn):
        self.n = int(n_clients)
        self._seed_fn = seed_fn
        self.positions = np.zeros(self.n, np.int64)   # samples drawn so far
        self._rngs: dict[int, np.random.RandomState] = {}

    def rng(self, i: int) -> np.random.RandomState:
        i = int(i)
        r = self._rngs.get(i)
        if r is None:
            r = self._rngs[i] = np.random.RandomState(self._seed_fn(i))
        return r

    __getitem__ = rng

    def advance(self, i: int, k: int) -> None:
        self.positions[int(i)] += k

    def touched(self) -> np.ndarray:
        """Sorted ids whose streams have been materialised."""
        return np.array(sorted(self._rngs), np.int64)

    # -- checkpointing ---------------------------------------------------
    def state_dict(self) -> dict[str, np.ndarray]:
        ids = self.touched()
        packed = [rng_state_to_arrays(self._rngs[int(i)]) for i in ids]
        return {
            "positions": self.positions.copy(),
            "ids": ids,
            "keys": (np.stack([p["keys"] for p in packed])
                     if len(packed) else np.zeros((0, 624), np.uint32)),
            "pos": np.array([p["pos"] for p in packed], np.int64),
            "has_gauss": np.array([p["has_gauss"] for p in packed], np.int64),
            "cached_gaussian": np.array([p["cached_gaussian"] for p in packed],
                                        np.float64),
        }

    def load_state_dict(self, d: dict[str, np.ndarray]) -> None:
        positions = np.asarray(d["positions"], np.int64)
        if positions.shape != (self.n,):
            raise ValueError(f"stream positions shape {positions.shape} != "
                             f"({self.n},) — population size changed?")
        self.positions = positions.copy()
        self._rngs = {}
        ids = np.asarray(d["ids"], np.int64)
        for r, i in enumerate(ids):
            self._rngs[int(i)] = rng_state_from_arrays(
                {"keys": d["keys"][r], "pos": d["pos"][r],
                 "has_gauss": d["has_gauss"][r],
                 "cached_gaussian": d["cached_gaussian"][r]})


# ---------------------------------------------------------------------------
# Server-side per-client state
# ---------------------------------------------------------------------------

class _WarmMaskView:
    """Read-only dict-like view of the warm-mask rows (back-compat for the
    old ``FLServer._warm_masks`` dict: iteration over ids, ``[i]``/``get``)."""

    def __init__(self, store: "ClientStateStore"):
        self._store = store

    def __iter__(self) -> Iterator[int]:
        return iter(int(i) for i in self._store.warm_ids())

    def __len__(self) -> int:
        return int(self._store._n_warm)

    def __bool__(self) -> bool:
        return self._store.has_warm

    def __contains__(self, i) -> bool:
        return bool(self._store._warm_valid[int(i)])

    def __getitem__(self, i) -> np.ndarray:
        i = int(i)
        if not self._store._warm_valid[i]:
            raise KeyError(i)
        return self._store._warm[i].copy()

    def get(self, i, default=None):
        i = int(i)  # repro: allow[host-sync] -- host int client id, no device value
        return self._store._warm[i].copy() \
            if self._store._warm_valid[i] else default


class ClientStateStore:
    """Flat per-client-id state for the FL server, O(cohort) per round.

    Layout (all indexed by client id, population ``n``):

    * ``warm``       — (n, L) float32 warm-start mask rows + (n,) validity
    * ``stats``      — one (n, L) float32 array per probe-stat key, lazily
      allocated on first scatter; validity is a per-client int64 *stamp*
      against a generation counter, so a refresh (``clear_stats``) is a
      counter bump — O(1) regardless of population
    * ``last_seen``  — (n,) int64 round at which each client last received
      masks (-1 = never selected)
    """

    def __init__(self, n_clients: int, n_layers: int):
        self.n = int(n_clients)
        self.L = int(n_layers)
        self._warm = np.zeros((self.n, self.L), np.float32)
        self._warm_valid = np.zeros(self.n, bool)
        self._n_warm = 0
        self._stats: dict[str, np.ndarray] = {}
        self._stats_stamp = np.zeros(self.n, np.int64)   # valid iff == _gen
        self._gen = 1                                    # 0 = never written
        self._gen_keys: tuple[str, ...] = ()
        self.last_seen = np.full(self.n, -1, np.int64)

    # -- warm-start mask rows -------------------------------------------
    @property
    def has_warm(self) -> bool:
        return self._n_warm > 0

    @property
    def warm_masks(self) -> _WarmMaskView:
        return _WarmMaskView(self)

    def warm_ids(self) -> np.ndarray:
        return np.flatnonzero(self._warm_valid)

    def warm_rows(self, cohort) -> tuple[np.ndarray, np.ndarray]:
        """(rows (k, L) float32, valid (k,) bool) for the cohort's ids.
        Rows are fresh copies; invalid rows are zeros."""
        ids = np.asarray(cohort, np.int64)  # repro: allow[host-sync] -- cohort ids are host np; the store is host-resident by design
        return self._warm[ids].copy(), self._warm_valid[ids].copy()

    def set_warm_rows(self, cohort, masks: np.ndarray,
                      t: Optional[int] = None) -> None:
        ids = np.asarray(cohort, np.int64)
        masks = np.asarray(masks, np.float32)
        if masks.shape != (len(ids), self.L):
            raise ValueError(f"mask rows {masks.shape} != "
                             f"({len(ids)}, {self.L})")
        self._warm[ids] = masks
        self._n_warm += int((~self._warm_valid[ids]).sum())
        self._warm_valid[ids] = True
        if t is not None:
            self.last_seen[ids] = t

    def warm_rows_device(self, cohort, mesh=None):
        """The cohort's warm rows as a device array; with ``mesh``, sharded
        over the client axes via the fl_step shard_map machinery (one row
        per mesh client coordinate).  ``mesh=None`` (single device) returns
        the same values unsharded — bit-identical to the host gather."""
        import jax.numpy as jnp
        rows, valid = self.warm_rows(cohort)
        if mesh is None:
            return jnp.asarray(rows), valid
        from repro.sharding.fl_step import shard_cohort_rows
        return shard_cohort_rows(mesh, rows), valid

    # -- probe-stat cache ------------------------------------------------
    def clear_stats(self) -> None:
        """Invalidate every cached stat row — a generation bump, O(1)."""
        self._gen += 1
        self._gen_keys = ()

    def stats_valid(self, cohort) -> np.ndarray:
        ids = np.asarray(cohort, np.int64)
        return self._stats_stamp[ids] == self._gen

    def missing_stats(self, cohort) -> np.ndarray:
        """Cohort members without current-generation stats, cohort order."""
        cohort = np.asarray(cohort)
        return cohort[~self.stats_valid(cohort)]

    def set_stat_rows(self, cohort, stats: dict[str, np.ndarray]) -> None:
        """Scatter probe-stat rows for ``cohort`` (row r -> cohort[r])."""
        ids = np.asarray(cohort, np.int64)
        if not len(ids):
            return
        keys = tuple(stats.keys())
        for k in keys:
            rows = np.asarray(stats[k], np.float32)
            arr = self._stats.get(k)
            if arr is None or arr.shape[1:] != rows.shape[1:]:
                arr = self._stats[k] = np.zeros((self.n,) + rows.shape[1:],
                                                np.float32)
            arr[ids] = rows
        # mirror ProbeReport.from_rows: a stat participates only if every
        # scatter this generation carried it
        self._gen_keys = (keys if not self._gen_keys
                          else tuple(k for k in self._gen_keys if k in keys))
        self._stats_stamp[ids] = self._gen

    def stat_rows(self, cohort) -> dict[str, np.ndarray]:
        """Gather the cohort's cached stat rows (all must be current)."""
        ids = np.asarray(cohort, np.int64)
        missing = self._stats_stamp[ids] != self._gen
        if missing.any():
            raise KeyError(f"no cached stats for client ids "
                           f"{ids[missing].tolist()} (generation {self._gen})")
        return {k: self._stats[k][ids] for k in self._gen_keys}

    # -- checkpointing ---------------------------------------------------
    def state_dict(self) -> dict[str, np.ndarray]:
        d = {
            "warm": self._warm.copy(),
            "warm_valid": self._warm_valid.copy(),
            "stats_stamp": self._stats_stamp.copy(),
            "gen": np.asarray(self._gen, np.int64),
            "gen_keys": np.asarray(self._gen_keys, dtype=np.str_),
            "last_seen": self.last_seen.copy(),
        }
        for k, v in self._stats.items():
            d[f"stat/{k}"] = v.copy()
        return d

    def load_state_dict(self, d: dict[str, np.ndarray]) -> None:
        warm = np.asarray(d["warm"], np.float32)
        if warm.shape != (self.n, self.L):
            raise ValueError(f"warm-mask store {warm.shape} != "
                             f"({self.n}, {self.L}) — population or layer "
                             f"count changed?")
        self._warm = warm.copy()
        self._warm_valid = np.asarray(d["warm_valid"], bool).copy()
        self._n_warm = int(self._warm_valid.sum())
        self._stats_stamp = np.asarray(d["stats_stamp"], np.int64).copy()
        self._gen = int(d["gen"])
        self._gen_keys = tuple(str(k) for k in np.asarray(d["gen_keys"]))
        self.last_seen = np.asarray(d["last_seen"], np.int64).copy()
        self._stats = {k[len("stat/"):]: np.asarray(v, np.float32).copy()
                       for k, v in d.items() if k.startswith("stat/")}
