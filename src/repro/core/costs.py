"""Computational & communication cost model (§4.3, Table 3).

    Cost_sel  = b(L−1) + bRτ = b(Rτ + L − 1)      (Eq. 16)
    Cost_full = bLτ                                (Eq. 17)
    comms_sel / comms_full = R / L                 (uniform layer sizes)

plus exact per-layer accounting (non-uniform layer sizes, selection period,
probe batch count) used by benchmarks/table3.py to reproduce the paper's
cost table structure on our architectures.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class CostReport:
    compute_flops: float          # backward FLOPs per client per round
    select_flops: float           # the selection step's share
    transmit_bits: float          # upload per client per round
    ratio_compute: float          # vs full fine-tuning
    ratio_transmit: float


def backward_cost_uniform(L: int, R: int, tau: int, b: float = 1.0,
                          *, sel_period: int = 1, sel_batches: int = 1,
                          local_batches: int = 1,
                          bits_per_param: int = 32) -> CostReport:
    """Eq. (16)/(17) with the §4.3 extensions (Sel. Period / Sel. Batch).

    ``b`` = backward FLOPs per layer per batch.  The probe uses
    ``sel_batches`` batches every ``sel_period`` rounds; fine-tuning uses
    ``local_batches`` per step.  Layers are uniform with one abstract
    parameter each, so upload = R selected layers × ``bits_per_param``
    — actual bits, same unit as ``backward_cost_exact``; the dimensionless
    R/L lives in ``ratio_transmit``.
    """
    select = b * (L - 1) * (sel_batches / local_batches) / sel_period
    finetune = b * R * tau
    full = b * L * tau
    return CostReport(
        compute_flops=select + finetune,
        select_flops=select,
        transmit_bits=(R / L) * bits_per_param * L,
        ratio_compute=(select + finetune) / full,
        ratio_transmit=R / L,
    )


def backward_cost_exact(layer_params: np.ndarray, mask: np.ndarray, tau: int,
                        *, bits_per_param: int = 32, tokens_per_batch: int = 1,
                        sel_period: int = 1, sel_batches: int = 1) -> CostReport:
    """Exact accounting from per-layer parameter counts.

    Backward FLOPs per layer ≈ 4·params·tokens (dL/dx and dL/dW matmuls);
    upload = selected parameter count × bits.
    """
    flops_l = 4.0 * layer_params.astype(np.float64) * tokens_per_batch
    L = layer_params.shape[0]
    R_params = float(np.sum(layer_params * mask))
    select = float(np.sum(flops_l[:-1])) * sel_batches / sel_period
    finetune = float(np.sum(flops_l * mask)) * tau
    full = float(np.sum(flops_l)) * tau
    return CostReport(
        compute_flops=select + finetune,
        select_flops=select,
        transmit_bits=R_params * bits_per_param,
        ratio_compute=(select + finetune) / full,
        ratio_transmit=R_params / float(np.sum(layer_params)),
    )
