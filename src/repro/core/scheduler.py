"""Depth-k lookahead round scheduler with async (P1) solver overlap.

Replaces the hand-rolled double buffer that used to live in
``FLServer._run_pipelined``: the streaming round pipeline is now a
subsystem that (a) plans and samples rounds t+1..t+k on the host while the
round-t device program is still in flight, (b) runs the host layer-selection
solve — materialising the probe stats and solving (P1) — on a background
thread so it overlaps both the in-flight XLA program and the host-side
prefetch, and (c) keeps the device-side structure of the double buffer:
the t+1 selection probe rides round t's update program (fused into one XLA
program when ``selection_period == 1``, chained on the params future
otherwise).

Parity contract (tests/test_scheduler.py, tests/test_round_engine.py): the
scheduler is a pure *scheduling* change — cohorts and masks are
bit-identical to the synchronous :meth:`FLServer.run_round` loop and params
agree within fp tolerance, at every depth, including under Task
availability/straggler hooks.  Three orderings pin when work may fire:

* **Server rng** — ``plan_round`` consumes the server RandomState (cohort
  draw + availability/straggler hooks), so plans must fire in round order.
  The prefetch queue issues them strictly ascending.
* **Per-client data streams** — each client's rng must see round t's draws
  (probe before update) before round t+1's.  ``sample_round`` draws a whole
  round at enqueue time, so queue order preserves stream order.
* **Stats-cache reads** — with ``selection_period > 1`` a non-refresh
  ``plan_round(t+1)`` reads the per-client stats cache as left by
  select(t), so its plan may only fire once that select completed
  (:meth:`RoundScheduler._can_plan`).  Refresh rounds and probe-free
  strategies are cache-free and may plan arbitrarily deep — with
  ``selection_period == 1`` the full depth-k lookahead is always available.

The select stage itself never touches an rng and only the solver thread
mutates the server's stats/warm-mask caches (one solve in flight at a
time), so running it concurrently with host sampling is race-free.

``wall_s`` in pipelined records is the *host* time per round (async-select
submit → dispatch complete, including the prefetch that ran inside the
round), not device latency: in-flight rounds report milliseconds and the
end-of-run drain is excluded, so ``sum(wall_s)`` ≤ total elapsed run time
(pinned in tests/test_scheduler.py).  ``verbose=True`` never syncs the
just-dispatched round: round t's record is printed at the end of iteration
t+1, when its program has long been retired — printing no longer destroys
the overlap it is reporting on.
"""
from __future__ import annotations

import time
from collections import deque

import jax
from concurrent.futures import ThreadPoolExecutor
from concurrent.futures import TimeoutError as FutureTimeout
from typing import Any, Optional

from repro.core.server import (FLServer, History, RoundRecord, SampledRound)

PyTree = Any


class RoundScheduler:
    """Depth-k streaming executor for ``FLServer``'s round stages.

    ``depth`` is how many rounds ahead of the in-flight round the host
    plans and samples; ``depth=1`` reproduces the classic double buffer.
    A scheduler instance drives one ``run`` at a time (it owns a
    single-worker solver thread for the duration of the run).
    """

    def __init__(self, server: FLServer, depth: int = 1):
        if depth < 1:
            raise ValueError(f"pipeline depth must be >= 1, got {depth}")
        self.server = server
        self.depth = depth
        self._queue: deque[SampledRound] = deque()   # rounds, t ascending
        self._next_plan = 0          # next round index to plan (rng order)
        self._selected_through = -1  # highest t whose select completed
        self._barrier = -1           # next unsaved checkpoint boundary
        self._late = None            # (future, t) of a deadline-missed solve

    # -- host prefetch ----------------------------------------------------
    def _next_barrier(self, after: int, T: int) -> int:
        """The first checkpoint boundary past ``after`` (T+1 = none left).
        Planning round b consumes the server rng and client data streams,
        so rounds at/after an unsaved boundary must not be planned — a
        checkpoint written at b would otherwise capture post-b draws and
        break bit-exact resume."""
        srv = self.server
        if srv.checkpoint_dir is None:
            return T + 1
        b = after + 1
        while b <= T and not srv._is_ckpt_round(b, T):
            b += 1
        return b if b <= T else T + 1

    def _can_plan(self, t: int) -> bool:
        """May ``plan_round(t)`` fire now?  Plans always fire in t order
        (queue discipline); additionally a non-refresh plan's probe_ids
        read the stats cache as left by select(t-1), and no plan may cross
        an unsaved checkpoint boundary (:meth:`_next_barrier`)."""
        srv = self.server
        if t >= self._barrier:
            return False
        if not srv.needs_probe or t % srv.fl.selection_period == 0:
            return True
        return self._selected_through >= t - 1

    def _prefetch(self, T: int, want: int) -> None:
        """Top the queue up to ``want`` pending rounds (plan + sample)."""
        while (self._next_plan < T and len(self._queue) < want
               and self._can_plan(self._next_plan)):
            plan = self.server.plan_round(self._next_plan)
            self._queue.append(self.server.sample_round(plan))
            self._next_plan += 1

    # -- async select -----------------------------------------------------
    def _join_late(self, block: bool) -> None:
        """Join a deadline-missed solve (DESIGN.md §12).  The late solver
        thread is still the store's single writer — once it lands, its
        warm-row/stats-cache writes unblock the cache-dependent plans that
        :meth:`_can_plan` kept gated on ``_selected_through``.  Called
        non-blocking each iteration and blocking before a checkpoint save
        (the barrier must capture a settled store)."""
        if self._late is None:
            return
        fut, t_late = self._late
        if not block and not fut.done():
            return
        fut.result()
        self._selected_through = max(self._selected_through, t_late)
        self._late = None

    def _select(self, plan, stats_dev):
        """Solver-thread body: materialise the probe stats (the pipeline's
        one device sync) and run the host selection.  Mutates only the
        server's stats/warm-mask caches — reads of those by the main thread
        are gated on this select having completed (:meth:`_can_plan`)."""
        srv = self.server
        return srv.select_round(plan, srv._stats_np(stats_dev))

    # -- the round loop ---------------------------------------------------
    def run(self, params: PyTree, T: int, verbose: bool, start: int = 0,
            history: Optional[History] = None) -> tuple[PyTree, History]:
        srv = self.server
        fl, client = srv.fl, srv.client
        reqs, score_fn = srv._probe_reqs, srv._score_fn
        fuse = srv.needs_probe and fl.selection_period == 1
        srv._ensure_layer_params(params)
        # hoisted once for the whole run; explicit h2d so the per-round
        # evaluate_raw dispatch never pays (or strict-mode-trips on) an
        # implicit np→device transfer
        test = jax.device_put(srv.data.test_batch())

        self._next_plan = start
        self._selected_through = start - 1
        self._barrier = self._next_barrier(start, T)
        prefix = list(history.records) if history is not None else []

        self._prefetch(T, self.depth)
        sampled = self._queue.popleft()              # round `start`
        stats_dev = (client.probe_cohort_raw(params, sampled.probe_batches,
                                             reqs, score_fn)
                     if sampled.probe_batches is not None else None)
        pending: list = []       # raw entries; finalized lazily (verbose)
        printed = 0              # pending entries already printed (in order)
        pool = ThreadPoolExecutor(max_workers=1,
                                  thread_name_prefix="p1-solver")
        try:
            for t in range(start, T):
                t0 = time.time()  # repro: allow[nondeterminism] -- wall_s telemetry only, never an input to round math
                self._join_late(block=False)
                plan = sampled.plan
                # the host solve (stats sync + (P1)) overlaps the in-flight
                # device program *and* the prefetch below
                masks_fut = pool.submit(self._select, plan, stats_dev)
                # lookahead: sample rounds t+1..t+depth whose plans are
                # cache-free while the solver thread works
                self._prefetch(T, self.depth)
                if srv.solver_deadline_s is None:
                    masks = masks_fut.result()
                    self._selected_through = t
                else:
                    try:
                        masks = masks_fut.result(
                            timeout=srv.solver_deadline_s)
                        self._selected_through = t
                    except FutureTimeout:
                        # degrade, don't stall: round t proceeds on the
                        # warm-start rows while the solve finishes in the
                        # background (read-only fallback — the solver
                        # thread stays the store's single writer).
                        # _selected_through is NOT bumped, so cache-
                        # dependent plans stay gated until _join_late.
                        masks = srv._fallback_rows(plan)
                        self._late = (masks_fut, t)
                # cache-dependent plans (selection_period > 1, non-refresh)
                # unblock once select(t) has landed in the stats cache
                self._prefetch(T, self.depth)

                # mask-aware engine: the static prefix cut is derived from
                # the just-solved masks, so the update program skips the
                # frozen layers' backward (None = dense; DESIGN.md §7)
                cut = srv._cut_for(masks)
                nxt = self._queue[0] if self._queue else None
                nstats = None
                if srv._faults_active:
                    # fault path (DESIGN.md §12): the guarded round step
                    # replaces the fused/chained dispatch — ONE extra
                    # compiled program, survivors/codes as runtime arrays
                    params, losses = srv._update_round_faulty(
                        params, sampled, masks)
                    if nxt is not None and nxt.probe_batches is not None:
                        nstats = client.probe_cohort_raw(
                            params, nxt.probe_batches, reqs, score_fn)
                elif fuse and nxt is not None and \
                        nxt.probe_batches is not None:
                    # round t+1's probe rides round t's update program
                    params, losses, nstats = client.probe_update_cohort_raw(
                        params, sampled.update_batches, masks, plan.sizes,
                        fl.lr, nxt.probe_batches, reqs, score_fn, cut=cut)
                else:
                    params, losses = client.cohort_update_raw(
                        params, sampled.update_batches, masks, plan.sizes,
                        fl.lr, cut=cut)
                    if nxt is not None and nxt.probe_batches is not None:
                        # chained on the params future: overlaps the update
                        # on-device, no host round-trip in between
                        nstats = client.probe_cohort_raw(
                            params, nxt.probe_batches, reqs, score_fn)
                loss_dev, acc_dev = client.evaluate_raw(params, test)
                pending.append((plan, masks, losses, loss_dev, acc_dev,
                                time.time() - t0))  # repro: allow[nondeterminism] -- wall_s telemetry only
                if verbose:
                    # print up to the *previous* round: its program has
                    # retired, so materialising it cannot stall the round
                    # just dispatched (printing used to sync every round)
                    while printed < len(pending) - 1:
                        if not isinstance(pending[printed], RoundRecord):
                            pending[printed] = srv._finalize(pending[printed])
                        srv._print_round(pending[printed])
                        printed += 1
                if t + 1 == self._barrier:
                    # checkpoint boundary: the prefetch gate drained the
                    # queue here (no round past the boundary was planned),
                    # so syncing params + pending records captures exactly
                    # the synchronous loop's state after round t
                    self._join_late(block=True)
                    for i in range(len(pending)):
                        if not isinstance(pending[i], RoundRecord):
                            pending[i] = srv._finalize(pending[i])
                    srv.save_state(params, t + 1,
                                   History(records=prefix + pending))
                    self._barrier = self._next_barrier(t + 1, T)
                    self._prefetch(T, self.depth)
                    if self._queue:
                        # restart the stream: the boundary round's probe
                        # runs standalone on the just-saved params (same
                        # math as the fused/chained dispatch — pinned by
                        # the engine-parity tests)
                        sampled = self._queue.popleft()
                        stats_dev = (client.probe_cohort_raw(
                            params, sampled.probe_batches, reqs, score_fn)
                            if sampled.probe_batches is not None else None)
                elif self._queue:
                    sampled, stats_dev = self._queue.popleft(), nstats
        finally:
            pool.shutdown(wait=True)

        hist = History(records=prefix)
        for i, p in enumerate(pending):              # end-of-run drain
            rec = p if isinstance(p, RoundRecord) else srv._finalize(p)
            if verbose and i >= printed:
                srv._print_round(rec)
            hist.records.append(rec)
        return params, hist
