"""Solver for the paper's layer-selection problem (P1), §4.2.

    max_{m_i}  Σ_{i∈S_t} Σ_{l∈L_i^t} ‖g_{i,l}(θ^t; ξ_i^t)‖²
               − (λ/2) Σ_{i∈S_t} Σ_{j≠i} ‖m_i^t − m_j^t‖₁
    s.t.       R(m_i^t) ≤ R_i^t  ∀ i∈S_t

This is a small integer program over (|S_t| × L) binary variables that the
*server* solves each selection round (inputs are the L-vectors of gradient
norms the clients upload — L floats per client, §4.2).

Note: the paper's (P1) display renders the penalty with a squared ℓ1 norm
while the accompanying text introduces it as the plain ℓ1 regulariser
"Σ_{j≠i}‖m_i − m_j‖₁".  We implement the ℓ1 form (default), which makes the
objective layer-separable given the other clients' masks, plus the squared
variant for ablation.

Solvers:

* :func:`solve_icm` — iterated conditional modes (block coordinate ascent):
  per client, the conditional objective is separable per layer, so the
  conditional argmax under a knapsack budget is a greedy top-k by utility
  density.  Monotone in the objective ⇒ converges to a fixed point.
* :func:`solve_unified` — the λ→∞ limit: one global ranking by
  Σ_i ‖g_{i,l}‖², each client takes its top-R_i prefix (all clients agree
  on ordering ⇒ maximal overlap, χ divergence minimised for equal budgets).
"""
from __future__ import annotations

import numpy as np


def _pick_topk_budget(util: np.ndarray, costs: np.ndarray, budget: float) -> np.ndarray:
    """Greedy knapsack: pick layers by utility density until budget exhausted.

    The constraint R(m_i) ≤ R_i is hard: when the budget does not admit even
    the cheapest layer the result is the *empty* mask — the client sits the
    round out (its delta is zero and Eq. 7 gives it zero aggregation weight)
    rather than silently training a layer it cannot afford.  (The previous
    fallback forced ``argmin(costs)`` regardless of cost, violating the
    budget.)  With any affordable layer the greedy scan always selects at
    least one, so masks stay non-empty whenever the budget admits one.
    """
    m = np.zeros(util.shape[0], dtype=np.float32)
    density = util / np.maximum(costs, 1e-12)
    order = np.argsort(-density)
    spent = 0.0
    for l in order:
        if util[l] <= 0 and spent > 0:
            break   # never select negative-utility layers beyond the first
        if spent + costs[l] <= budget + 1e-9:
            m[l] = 1.0
            spent += costs[l]
    return m


def greedy_rows(G: np.ndarray, budgets, *,
                costs: np.ndarray | None = None) -> np.ndarray:
    """Per-row greedy-knapsack masks — the ICM solver's cold-start init,
    exposed so the round engines can greedily fill *unseen* members of a
    warm-start matrix instead of discarding the whole cohort's warm rows
    (FLServer._warm_init).  Budget-exact per row (:func:`_pick_topk_budget`).
    """
    n, L = G.shape
    budgets = np.broadcast_to(np.asarray(budgets, np.float64), (n,))
    costs = np.ones(L) if costs is None else np.asarray(costs, np.float64)
    return np.stack([_pick_topk_budget(G[i], costs, budgets[i])
                     for i in range(n)])


def objective(G: np.ndarray, masks: np.ndarray, lam: float,
              penalty: str = "l1") -> float:
    """The (P1) objective value for a candidate mask matrix."""
    gain = float(np.sum(G * masks))
    diff = np.abs(masks[:, None, :] - masks[None, :, :]).sum(-1)   # (n,n) ℓ1
    if penalty == "l1_sq":
        diff = diff ** 2
    pen = 0.5 * lam * (diff.sum() - np.trace(diff))
    return gain - pen


def solve_icm(G: np.ndarray, budgets, lam: float, *,
              costs: np.ndarray | None = None, penalty: str = "l1",
              max_iters: int = 50, init: np.ndarray | None = None):
    """Block coordinate ascent on (P1).

    G: (n, L) per-client per-layer squared gradient norms.
    budgets: scalar or (n,) — R_i, in units of ``costs`` (default: #layers).
    init: optional (n, L) warm-start mask matrix (e.g. the previous selection
    round's converged masks, keyed by client id — the round engines pass it
    via ``SelectionContext.init``).  A warm start that is already a fixed
    point of the conditional updates converges in one sweep, so solver
    iterations shrink as training stabilises.  Every returned row comes from
    :func:`_pick_topk_budget`, so the budget constraint holds regardless of
    the init.
    Returns (masks (n,L) float32, objective value, n_iters).
    """
    n, L = G.shape
    budgets = np.broadcast_to(np.asarray(budgets, np.float64), (n,))
    costs = np.ones(L) if costs is None else np.asarray(costs, np.float64)
    if init is not None and init.shape != (n, L):
        raise ValueError(f"init shape {init.shape} != {(n, L)}")
    masks = init.copy().astype(np.float32) if init is not None else \
        greedy_rows(G, budgets, costs=costs)

    for it in range(max_iters):
        changed = False
        for i in range(n):
            others = masks.sum(0) - masks[i]                  # Σ_{j≠i} m_j(l)
            if penalty == "l1":
                # ∂pen/∂m_i(l) = λ Σ_{j≠i} (1 − 2 m_j(l))
                util = G[i] - lam * ((n - 1) - 2.0 * others)
            else:  # l1_sq: linearise around current disagreement (heuristic)
                disagree = np.abs(masks[i][None, :] - masks).sum(-1)  # (n,)
                util = G[i] - lam * ((n - 1) - 2.0 * others) * (1.0 + disagree.mean())
            new = _pick_topk_budget(util, costs, budgets[i])
            if not np.array_equal(new, masks[i]):
                masks[i] = new
                changed = True
        if not changed:
            return masks, objective(G, masks, lam, penalty), it + 1
    return masks, objective(G, masks, lam, penalty), max_iters


def solve_unified(G: np.ndarray, budgets, *, costs: np.ndarray | None = None):
    """λ→∞: shared ranking by aggregate gradient norm; per-client prefix.

    The prefix scan only takes layers that fit the remaining budget, so
    R(m_i) ≤ R_i holds for every client; a budget that admits no layer at
    all yields the empty row (same contract as :func:`_pick_topk_budget`).
    """
    n, L = G.shape
    budgets = np.broadcast_to(np.asarray(budgets, np.float64), (n,))
    costs = np.ones(L) if costs is None else np.asarray(costs, np.float64)
    total = G.sum(0)
    order = np.argsort(-total / np.maximum(costs, 1e-12))
    masks = np.zeros((n, L), np.float32)
    for i in range(n):
        spent = 0.0
        for l in order:
            if spent + costs[l] <= budgets[i] + 1e-9:
                masks[i, l] = 1.0
                spent += costs[l]
    return masks


# Named solver lookup, so host strategies (repro.api.strategy) can be
# parameterised by solver without hard-wiring callables.
SOLVERS = {"icm": solve_icm, "unified": solve_unified}


def get_solver(name: str):
    """Resolve a (P1) solver by name ('icm' | 'unified')."""
    try:
        return SOLVERS[name]
    except KeyError:
        raise ValueError(f"unknown (P1) solver {name!r} "
                         f"(available: {', '.join(sorted(SOLVERS))})") from None
