"""The paper's contribution: selective layer fine-tuning in FL."""
from repro.core.masks import (aggregation_weights, chi_divergence,  # noqa: F401
                              mask_from_indices, per_layer_sq_norms, union_mask)
from repro.core.solver import solve_icm, solve_unified, objective  # noqa: F401
from repro.core.strategies import ALL_STRATEGIES, ProbeReport, select  # noqa: F401
from repro.core.server import FLServer, History  # noqa: F401
from repro.core.client import Client  # noqa: F401
