"""The paper's contribution: selective layer fine-tuning in FL."""
from repro.core.masks import (aggregation_weights, chi_divergence,  # noqa: F401
                              mask_from_indices, per_layer_sq_norms, union_mask)
from repro.core.solver import solve_icm, solve_unified, objective  # noqa: F401
from repro.core.strategies import ALL_STRATEGIES, ProbeReport, select  # noqa: F401

__all__ = [
    "aggregation_weights", "chi_divergence", "mask_from_indices",
    "per_layer_sq_norms", "union_mask", "solve_icm", "solve_unified",
    "objective", "ALL_STRATEGIES", "ProbeReport", "select",
    "FLServer", "History", "Client",
]


def __getattr__(name):
    # Lazy (PEP 562): the strategy registry (repro.api.strategy) imports
    # repro.core.solver/strategies at module level, and the server imports
    # the registry back — resolving the server side on first access keeps
    # both import orders cycle-free.
    if name in ("FLServer", "History"):
        from repro.core import server
        return getattr(server, name)
    if name == "Client":
        from repro.core.client import Client
        return Client
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
