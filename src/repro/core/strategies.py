"""Layer-selection probe report + the back-compat ``select`` shim (§5.1).

Strategies themselves live in the registry (``repro.api.strategy``):

* ``top``    — last R layers (near the output) [Kovaleva+19, Lee+19b]
* ``bottom`` — first R layers (near the input) [Lee+22]
* ``both``   — R/2 top + R/2 bottom [Xiao+23] (undefined for R=1, as in Table 1)
* ``snr``    — highest |mean(g)| / var(g) per layer [Mahsereci+17]
* ``rgn``    — highest ‖g_l‖ / ‖θ_l‖ (relative gradient norm) [Lee+22]
* ``full``   — all layers (the paper's performance benchmark)
* ``ours``   — solve (P1) with local gradient norms + λ consistency
  regulariser (solve_icm), the paper's proposed strategy
* ``ours_unified`` (alias ``unified``) — the λ→∞ fast path

:func:`select` keeps the original string-dispatch signature as a thin shim
over the registry, so existing callers (and the pinned parity tests) are
untouched; new code should resolve strategies with
``repro.api.get_strategy`` and drive them through ``repro.api.Experiment``.

Every strategy maps a :class:`ProbeReport` (what clients upload at the start
of a selection round) + per-client budgets → a (cohort, L) mask matrix.
Strategies declare ``probe_requirements`` so clients compute (and upload)
only the stats actually consumed — a report may therefore carry any subset
of the stat fields, plus optional device-computed ``scores``.
"""
from __future__ import annotations

from dataclasses import dataclass, fields
from typing import Optional

import numpy as np

PROBE_KEYS = ("grad_sq_norms", "param_sq_norms", "grad_means", "grad_vars")


@dataclass
class ProbeReport:
    """Per-cohort probe statistics (rows = cohort clients, cols = layers).

    All fields are optional — a requirements-trimmed probe fills only what
    the strategy asked for.  ``scores`` holds device-computed per-layer
    scores when the strategy's scoring fused into the probe program.
    """

    grad_sq_norms: Optional[np.ndarray] = None    # (n, L): ‖g_{i,l}‖²
    param_sq_norms: Optional[np.ndarray] = None   # (n, L): ‖θ_l‖² (RGN)
    grad_means: Optional[np.ndarray] = None       # (n, L): mean(g_l)  (SNR)
    grad_vars: Optional[np.ndarray] = None        # (n, L): var(g_l)   (SNR)
    scores: Optional[np.ndarray] = None           # (n, L): fused scores

    KEYS = PROBE_KEYS

    @classmethod
    def from_rows(cls, rows: "list[dict[str, np.ndarray]]") -> "ProbeReport":
        """Stack per-client stat dicts (one row per cohort member).

        Only keys present (and non-None) in *every* row are stacked — rows
        from a requirements-trimmed probe simply omit the unused stats.
        """
        names = [f.name for f in fields(cls)]
        return cls(**{k: np.stack([r[k] for r in rows]) for k in names
                      if all(r.get(k) is not None for r in rows)})

    def _shape(self) -> tuple[int, int]:
        for f in fields(self):
            v = getattr(self, f.name)
            if v is not None:
                return v.shape
        raise ValueError("empty ProbeReport: no stat field is set")

    @property
    def n(self) -> int:
        return self._shape()[0]

    @property
    def L(self) -> int:
        return self._shape()[1]

    def take(self, rows) -> "ProbeReport":
        """Row-subset view (e.g. one mixture member's cohort rows)."""
        idx = np.asarray(rows)
        return ProbeReport(**{
            f.name: (None if getattr(self, f.name) is None
                     else getattr(self, f.name)[idx])
            for f in fields(self)})


def _positional(n: int, L: int, budgets, mode: str) -> np.ndarray:
    budgets = np.broadcast_to(np.asarray(budgets, int), (n,))
    masks = np.zeros((n, L), np.float32)
    for i in range(n):
        R = min(int(budgets[i]), L)
        if mode == "top":
            masks[i, L - R:] = 1.0
        elif mode == "bottom":
            masks[i, :R] = 1.0
        elif mode == "both":
            lo = R // 2
            hi = R - lo
            if lo:
                masks[i, :lo] = 1.0
            masks[i, L - hi:] = 1.0
        else:
            raise ValueError(mode)
    return masks


def _score_topk(scores: np.ndarray, budgets) -> np.ndarray:
    n, L = scores.shape
    budgets = np.broadcast_to(np.asarray(budgets, int), (n,))
    masks = np.zeros((n, L), np.float32)
    for i in range(n):
        R = min(int(budgets[i]), L)
        masks[i, np.argsort(-scores[i])[:R]] = 1.0
    return masks


def select(strategy: str, probe: ProbeReport, budgets, *,
           lam: float = 10.0, costs: Optional[np.ndarray] = None,
           eps: float = 1e-12) -> np.ndarray:
    """Return the (cohort, L) mask matrix for the given strategy.

    Back-compat shim: delegates to the registry
    (``repro.api.get_strategy(strategy).select``).  Unknown names raise
    :class:`repro.api.UnknownStrategyError` with the registered names and a
    nearest-match suggestion.
    """
    from repro.api.strategy import SelectionContext, get_strategy
    strat = get_strategy(strategy)
    n = probe.n
    ctx = SelectionContext(client_ids=np.arange(n), lam=lam, costs=costs,
                           n_layers=probe.L, eps=eps)
    return strat.select(probe, budgets, ctx)


ALL_STRATEGIES = ("top", "bottom", "both", "snr", "rgn", "ours", "full")
