"""Layer-selection strategies (§5.1): the paper's method and all baselines.

Every strategy maps a :class:`ProbeReport` (what clients upload at the start
of a selection round) + per-client budgets → a (cohort, L) mask matrix.

* ``top``    — last R layers (near the output) [Kovaleva+19, Lee+19b]
* ``bottom`` — first R layers (near the input) [Lee+22]
* ``both``   — R/2 top + R/2 bottom [Xiao+23] (undefined for R=1, as in Table 1)
* ``snr``    — highest |mean(g)| / var(g) per layer [Mahsereci+17]
* ``rgn``    — highest ‖g_l‖ / ‖θ_l‖ (relative gradient norm) [Lee+22]
* ``full``   — all layers (the paper's performance benchmark)
* ``ours``   — solve (P1) with local gradient norms + λ consistency
  regulariser (solve_icm), the paper's proposed strategy
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.core.solver import solve_icm, solve_unified


@dataclass
class ProbeReport:
    """Per-cohort probe statistics (rows = cohort clients, cols = layers)."""
    grad_sq_norms: np.ndarray                 # (n, L): ‖g_{i,l}‖²
    param_sq_norms: Optional[np.ndarray] = None   # (n, L): ‖θ_l‖² (RGN)
    grad_means: Optional[np.ndarray] = None       # (n, L): mean(g_l)  (SNR)
    grad_vars: Optional[np.ndarray] = None        # (n, L): var(g_l)   (SNR)

    KEYS = ("grad_sq_norms", "param_sq_norms", "grad_means", "grad_vars")

    @classmethod
    def from_rows(cls, rows: "list[dict[str, np.ndarray]]") -> "ProbeReport":
        """Stack per-client stat dicts (one row per cohort member)."""
        return cls(**{k: np.stack([r[k] for r in rows]) for k in cls.KEYS})

    @property
    def n(self) -> int:
        return self.grad_sq_norms.shape[0]

    @property
    def L(self) -> int:
        return self.grad_sq_norms.shape[1]


def _positional(n: int, L: int, budgets, mode: str) -> np.ndarray:
    budgets = np.broadcast_to(np.asarray(budgets, int), (n,))
    masks = np.zeros((n, L), np.float32)
    for i in range(n):
        R = min(int(budgets[i]), L)
        if mode == "top":
            masks[i, L - R:] = 1.0
        elif mode == "bottom":
            masks[i, :R] = 1.0
        elif mode == "both":
            lo = R // 2
            hi = R - lo
            if lo:
                masks[i, :lo] = 1.0
            masks[i, L - hi:] = 1.0
        else:
            raise ValueError(mode)
    return masks


def _score_topk(scores: np.ndarray, budgets) -> np.ndarray:
    n, L = scores.shape
    budgets = np.broadcast_to(np.asarray(budgets, int), (n,))
    masks = np.zeros((n, L), np.float32)
    for i in range(n):
        R = min(int(budgets[i]), L)
        masks[i, np.argsort(-scores[i])[:R]] = 1.0
    return masks


def select(strategy: str, probe: ProbeReport, budgets, *,
           lam: float = 10.0, costs: Optional[np.ndarray] = None,
           eps: float = 1e-12) -> np.ndarray:
    """Return the (cohort, L) mask matrix for the given strategy."""
    n, L = probe.n, probe.L
    if strategy == "full":
        return np.ones((n, L), np.float32)
    if strategy in ("top", "bottom", "both"):
        return _positional(n, L, budgets, strategy)
    if strategy == "snr":
        assert probe.grad_means is not None and probe.grad_vars is not None
        snr = np.abs(probe.grad_means) / (probe.grad_vars + eps)
        return _score_topk(snr, budgets)
    if strategy == "rgn":
        assert probe.param_sq_norms is not None
        rgn = np.sqrt(probe.grad_sq_norms) / (np.sqrt(probe.param_sq_norms) + eps)
        return _score_topk(rgn, budgets)
    if strategy == "ours":
        masks, _, _ = solve_icm(probe.grad_sq_norms, budgets, lam, costs=costs)
        return masks
    if strategy == "ours_unified":      # λ→∞ fast path (production default)
        return solve_unified(probe.grad_sq_norms, budgets, costs=costs)
    raise ValueError(f"unknown strategy {strategy!r}")


ALL_STRATEGIES = ("top", "bottom", "both", "snr", "rgn", "ours", "full")
