"""Federated aggregation, Eq. (5)-(7).

Given the cohort's accumulated updates Δ_i^t (Eq. 4), the mask matrix and
sample sizes, form the global update

    Δ^t = Σ_{l∈L_t} Σ_{i∈S_t} w_{i,l}^t Δ_{i,l}^t ,
    θ^{t+1} = θ^t − η Δ^t .

The per-layer weights w_{i,l} (Eq. 7) renormalise over exactly the clients
that selected layer l.  Two simulator paths compute the same sum:

* :func:`aggregate` — the sequential oracle: explicit per-client pytrees,
  one scale-and-add per cohort member (paper-literal, easy to audit).
* :func:`aggregate_stacked` — the vectorized engine's path: one einsum
  contraction over the stacked (n, ...) delta pytree, traceable inside a
  single jitted round step (core/client.py ``cohort_update``).

The distributed path fuses the same weighting into a single backward pass
via gradient scaling (sharding/fl_step.py).
"""
from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.core.masks import aggregation_weights
from repro.models.model import layer_layout, split_mask, split_mask_matrix

Array = jax.Array
PyTree = Any


def scale_by_layer(tree: PyTree, scale_vec: Array, cfg) -> PyTree:
    """Multiply each selectable layer's subtree by its entry of scale_vec (L,).

    Frozen groups (embed/head/norms) are zeroed — they carry no update.
    """
    parts = split_mask(scale_vec, cfg)
    out = {}
    for key, sub in tree.items():
        if key in parts:
            s = parts[key]
            if key == "shared_attn":
                out[key] = jax.tree.map(lambda x: x * s[0].astype(x.dtype), sub)
            else:
                out[key] = jax.tree.map(
                    lambda x: x * s.astype(x.dtype).reshape(
                        (s.shape[0],) + (1,) * (x.ndim - 1)), sub)
        else:
            out[key] = jax.tree.map(jnp.zeros_like, sub)
    return out


def aggregate(deltas: Sequence[PyTree], mask_matrix: Array, sizes: Array,
              cfg) -> PyTree:
    """Eq. (5): Δ^t = Σ_l Σ_i w_{i,l} Δ_{i,l}."""
    W = aggregation_weights(mask_matrix, sizes)          # (n, L)
    total = None
    for i, d in enumerate(deltas):
        scaled = scale_by_layer(d, W[i], cfg)
        total = scaled if total is None else jax.tree.map(jnp.add, total, scaled)
    return total


def aggregate_stacked(deltas: PyTree, weights: Array, cfg) -> PyTree:
    """Eq. (5) over a *stacked* cohort delta pytree (leaves carry a leading
    (n,) client axis, as produced by ``jax.vmap`` of the local update).

    weights: the (n, L) Eq.(7) matrix from :func:`aggregation_weights`.
    Returns the unstacked global update; frozen groups (embed/head/norms)
    are zeroed, matching :func:`aggregate`.
    """
    parts = split_mask_matrix(weights, cfg)                  # path -> (n, c)
    out = {}
    for key, sub in deltas.items():
        if key in parts:
            w = parts[key]
            if key == "shared_attn":   # unstacked single block: (n,) weight
                out[key] = jax.tree.map(
                    lambda x: jnp.einsum("n,n...->...", w[:, 0],
                                         x.astype(jnp.float32)), sub)
            else:
                out[key] = jax.tree.map(
                    lambda x: jnp.einsum("nc,nc...->c...", w,
                                         x.astype(jnp.float32)), sub)
        else:
            out[key] = jax.tree.map(
                lambda x: jnp.zeros(x.shape[1:], jnp.float32), sub)
    return out


def apply_update(params: PyTree, update: PyTree, lr: float) -> PyTree:
    """Eq. (6): θ^{t+1} = θ^t − η Δ^t."""
    return jax.tree.map(lambda p, u: (p - lr * u.astype(p.dtype)), params, update)
