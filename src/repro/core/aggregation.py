"""Federated aggregation, Eq. (5)-(7).

Given the cohort's accumulated updates Δ_i^t (Eq. 4), the mask matrix and
sample sizes, form the global update

    Δ^t = Σ_{l∈L_t} Σ_{i∈S_t} w_{i,l}^t Δ_{i,l}^t ,
    θ^{t+1} = θ^t − η Δ^t .

The per-layer weights w_{i,l} (Eq. 7) renormalise over exactly the clients
that selected layer l.  Two simulator paths compute the same sum:

* :func:`aggregate` — the sequential oracle: explicit per-client pytrees,
  one scale-and-add per cohort member (paper-literal, easy to audit).
* :func:`aggregate_stacked` — the vectorized engine's path: one einsum
  contraction over the stacked (n, ...) delta pytree, traceable inside a
  single jitted round step (core/client.py ``cohort_update``).

The distributed path fuses the same weighting into a single backward pass
via gradient scaling (sharding/fl_step.py).
"""
from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.core.masks import aggregation_weights
from repro.models.model import (layer_layout, segment_cuts, split_mask,
                                split_mask_matrix)

Array = jax.Array
PyTree = Any


def scale_by_layer(tree: PyTree, scale_vec: Array, cfg) -> PyTree:
    """Multiply each selectable layer's subtree by its entry of scale_vec (L,).

    Frozen groups (embed/head/norms) are zeroed — they carry no update.
    """
    parts = split_mask(scale_vec, cfg)
    out = {}
    for key, sub in tree.items():
        if key in parts:
            s = parts[key]
            if key == "shared_attn":
                out[key] = jax.tree.map(lambda x: x * s[0].astype(x.dtype), sub)
            else:
                out[key] = jax.tree.map(
                    lambda x: x * s.astype(x.dtype).reshape(
                        (s.shape[0],) + (1,) * (x.ndim - 1)), sub)
        else:
            out[key] = jax.tree.map(jnp.zeros_like, sub)
    return out


def aggregate(deltas: Sequence[PyTree], mask_matrix: Array, sizes: Array,
              cfg) -> PyTree:
    """Eq. (5): Δ^t = Σ_l Σ_i w_{i,l} Δ_{i,l}."""
    W = aggregation_weights(mask_matrix, sizes)          # (n, L)
    total = None
    for i, d in enumerate(deltas):
        scaled = scale_by_layer(d, W[i], cfg)
        total = scaled if total is None else jax.tree.map(jnp.add, total, scaled)
    return total


def aggregate_stacked(deltas: PyTree, weights: Array, cfg) -> PyTree:
    """Eq. (5) over a *stacked* cohort delta pytree (leaves carry a leading
    (n,) client axis, as produced by ``jax.vmap`` of the local update).

    weights: the (n, L) Eq.(7) matrix from :func:`aggregation_weights`.
    Returns the unstacked global update; frozen groups (embed/head/norms)
    are zeroed, matching :func:`aggregate`.
    """
    parts = split_mask_matrix(weights, cfg)                  # path -> (n, c)
    out = {}
    for key, sub in deltas.items():
        if key in parts:
            w = parts[key]
            if key == "shared_attn":   # unstacked single block: (n,) weight
                out[key] = jax.tree.map(
                    lambda x: jnp.einsum("n,n...->...", w[:, 0],
                                         x.astype(jnp.float32)), sub)
            else:
                out[key] = jax.tree.map(
                    lambda x: jnp.einsum("nc,nc...->c...", w,
                                         x.astype(jnp.float32)), sub)
        else:
            out[key] = jax.tree.map(
                lambda x: jnp.zeros(x.shape[1:], jnp.float32), sub)
    return out


def apply_update(params: PyTree, update: PyTree, lr: float) -> PyTree:
    """Eq. (6): θ^{t+1} = θ^t − η Δ^t."""
    return jax.tree.map(lambda p, u: (p - lr * u.astype(p.dtype)), params, update)


# ---------------------------------------------------------------------------
# Mask-aware (prefix-cut) aggregation: Eq. (5)-(6) over the trainable slice
# ---------------------------------------------------------------------------

def aggregate_stacked_suffix(deltas: PyTree, weights: Array, cut: int,
                             cfg) -> PyTree:
    """Eq. (5) over the *trainable suffix* only (DESIGN.md §7).

    ``deltas``: the ``trainable_slice``-shaped pytree with a leading (n,)
    client axis, as produced by ``jax.vmap`` of the mask-aware local update
    — each segment carries only its rows at or above the prefix cut.
    ``weights``: the full (n, L) Eq.(7) matrix (frozen columns are all-zero
    by construction, so nothing is lost by never contracting them).
    Returns the suffix-shaped global update; the frozen prefix and the
    non-selectable groups carry no update and are left to
    :func:`apply_update_suffix` to pass through untouched.
    """
    parts = split_mask_matrix(weights, cfg)                  # path -> (n, c)
    cuts = segment_cuts(cut, cfg)
    out = {}
    for key, sub in deltas.items():
        w = parts[key][:, cuts[key]:]
        out[key] = jax.tree.map(
            lambda x, w=w: jnp.einsum("nc,nc...->c...", w,
                                      x.astype(jnp.float32)), sub)
    return out


def apply_update_suffix(params: PyTree, update: PyTree, lr: float, cut: int,
                        cfg) -> PyTree:
    """Eq. (6) on the trainable suffix, scattered back into the full tree.

    Matches :func:`apply_update` bit-for-bit: suffix rows get the identical
    ``p − η·u`` expression; frozen rows — where the dense path computes
    ``p − η·0 = p`` exactly — pass through untouched.
    """
    cuts = segment_cuts(cut, cfg)
    out = {}
    for key, sub in params.items():
        if key not in update:
            out[key] = sub
            continue
        c = cuts[key]

        def upd(p, u, c=c):
            new = p[c:] - lr * u.astype(p.dtype)
            return new if c == 0 else jnp.concatenate([p[:c], new], axis=0)

        out[key] = jax.tree.map(upd, sub, update[key])
    return out


def apply_delta_rows(params: PyTree, rows: dict, deltas: dict,
                     scale: float = 1.0) -> PyTree:
    """Scatter additive per-layer delta rows into the full tree.

    The row-indexed analogue of :func:`apply_update_suffix` for
    personalized-delta serving (DESIGN.md §9): ``rows`` maps a segment path
    to the (k,) local layer indices a user fine-tuned, ``deltas`` to the
    matching ``{leaf_name: (k, *shape)}`` delta rows.  Segments absent from
    ``rows`` pass through untouched — exactly the frozen layers.
    """
    out = {}
    for key, sub in params.items():
        if key not in rows:
            out[key] = sub
            continue
        idx = jnp.asarray(rows[key], jnp.int32)
        out[key] = jax.tree.map(
            lambda p, d: p.at[idx].add(
                scale * jnp.asarray(d).astype(p.dtype)),
            sub, deltas[key])
    return out
