"""Federated aggregation, Eq. (5)-(7).

Given the cohort's accumulated updates Δ_i^t (Eq. 4), the mask matrix and
sample sizes, form the global update

    Δ^t = Σ_{l∈L_t} Σ_{i∈S_t} w_{i,l}^t Δ_{i,l}^t ,
    θ^{t+1} = θ^t − η Δ^t .

The per-layer weights w_{i,l} (Eq. 7) renormalise over exactly the clients
that selected layer l.  Two simulator paths compute the same sum:

* :func:`aggregate` — the sequential oracle: explicit per-client pytrees,
  one scale-and-add per cohort member (paper-literal, easy to audit).
* :func:`aggregate_stacked` — the vectorized engine's path: one einsum
  contraction over the stacked (n, ...) delta pytree, traceable inside a
  single jitted round step (core/client.py ``cohort_update``).

The distributed path fuses the same weighting into a single backward pass
via gradient scaling (sharding/fl_step.py).
"""
from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.core.masks import aggregation_weights
from repro.models.model import (layer_layout, segment_cuts, split_mask,
                                split_mask_matrix)

Array = jax.Array
PyTree = Any


def scale_by_layer(tree: PyTree, scale_vec: Array, cfg) -> PyTree:
    """Multiply each selectable layer's subtree by its entry of scale_vec (L,).

    Frozen groups (embed/head/norms) are zeroed — they carry no update.
    """
    parts = split_mask(scale_vec, cfg)
    out = {}
    for key, sub in tree.items():
        if key in parts:
            s = parts[key]
            if key == "shared_attn":
                out[key] = jax.tree.map(lambda x: x * s[0].astype(x.dtype), sub)
            else:
                out[key] = jax.tree.map(
                    lambda x: x * s.astype(x.dtype).reshape(
                        (s.shape[0],) + (1,) * (x.ndim - 1)), sub)
        else:
            out[key] = jax.tree.map(jnp.zeros_like, sub)
    return out


def aggregate(deltas: Sequence[PyTree], mask_matrix: Array, sizes: Array,
              cfg) -> PyTree:
    """Eq. (5): Δ^t = Σ_l Σ_i w_{i,l} Δ_{i,l}."""
    W = aggregation_weights(mask_matrix, sizes)          # (n, L)
    total = None
    for i, d in enumerate(deltas):
        scaled = scale_by_layer(d, W[i], cfg)
        total = scaled if total is None else jax.tree.map(jnp.add, total, scaled)
    return total


def aggregate_stacked(deltas: PyTree, weights: Array, cfg) -> PyTree:
    """Eq. (5) over a *stacked* cohort delta pytree (leaves carry a leading
    (n,) client axis, as produced by ``jax.vmap`` of the local update).

    weights: the (n, L) Eq.(7) matrix from :func:`aggregation_weights`.
    Returns the unstacked global update; frozen groups (embed/head/norms)
    are zeroed, matching :func:`aggregate`.
    """
    parts = split_mask_matrix(weights, cfg)                  # path -> (n, c)
    out = {}
    for key, sub in deltas.items():
        if key in parts:
            w = parts[key]
            if key == "shared_attn":   # unstacked single block: (n,) weight
                out[key] = jax.tree.map(
                    lambda x: jnp.einsum("n,n...->...", w[:, 0],
                                         x.astype(jnp.float32)), sub)
            else:
                out[key] = jax.tree.map(
                    lambda x: jnp.einsum("nc,nc...->c...", w,
                                         x.astype(jnp.float32)), sub)
        else:
            out[key] = jax.tree.map(
                lambda x: jnp.zeros(x.shape[1:], jnp.float32), sub)
    return out


def apply_update(params: PyTree, update: PyTree, lr: float) -> PyTree:
    """Eq. (6): θ^{t+1} = θ^t − η Δ^t."""
    return jax.tree.map(lambda p, u: (p - lr * u.astype(p.dtype)), params, update)


# ---------------------------------------------------------------------------
# Fault-guarded aggregation helpers (DESIGN.md §12): injected corruption +
# the device-side finite guard, all runtime data through ONE jitted program
# ---------------------------------------------------------------------------

def _bcast(row: Array, leaf: Array) -> Array:
    """(n,) row values broadcast against an (n, ...) stacked leaf."""
    return row.reshape((row.shape[0],) + (1,) * (leaf.ndim - 1))


def corrupt_delta_rows(deltas: PyTree, codes: Array,
                       explode_scale) -> PyTree:
    """Apply per-row injected corruption to a stacked (n, ...) delta tree.

    ``codes`` (n,) int32 uses :data:`repro.faults.CORRUPT_CODES`:
    0 = clean, 1 = NaN-fill, 2 = Inf-fill, 3 = ×``explode_scale``.  Codes
    are runtime data, so every fault pattern replays the same compiled
    round program (the no-recompile contract, jit_cache_stats pinned).
    """
    codes = jnp.asarray(codes, jnp.int32)

    def one(x):
        c = _bcast(codes, x)
        x = jnp.where(c == 3, x * jnp.asarray(explode_scale, x.dtype), x)
        x = jnp.where(c == 2, jnp.inf, x)
        return jnp.where(c == 1, jnp.nan, x)

    return jax.tree.map(one, deltas)


def finite_row_mask(deltas: PyTree, max_sq) -> Array:
    """(n,) f32 quarantine mask over a stacked delta tree: 1 where every
    leaf entry of the row is finite AND the row's total Δ sq-norm is at
    most ``max_sq`` (accumulated in f32, like everything else on device —
    an exploding row that overflows f32 reads as non-finite and is
    quarantined by the first predicate).
    """
    leaves = jax.tree.leaves(deltas)
    fin = None
    sq = None
    for x in leaves:
        x = x.astype(jnp.float32)
        axes = tuple(range(1, x.ndim))
        f = jnp.all(jnp.isfinite(x), axis=axes)
        s = jnp.sum(x * x, axis=axes)
        fin = f if fin is None else fin & f
        sq = s if sq is None else sq + s
    ok = fin & (sq <= jnp.asarray(max_sq, jnp.float32))
    return ok.astype(jnp.float32)


def zero_delta_rows(deltas: PyTree, ok: Array) -> PyTree:
    """Zero the rows ``ok`` marks dead/quarantined.  Mandatory before the
    Eq.(5) contraction: a zero Eq.(7) weight does NOT neutralise a NaN/Inf
    delta (0·NaN = NaN inside the einsum) — the rows must be zeroed
    *before* they meet the weights."""
    ok = jnp.asarray(ok, jnp.float32)
    return jax.tree.map(
        lambda x: jnp.where(_bcast(ok, x) > 0, x, jnp.zeros((), x.dtype)),
        deltas)


# ---------------------------------------------------------------------------
# Mask-aware (prefix-cut) aggregation: Eq. (5)-(6) over the trainable slice
# ---------------------------------------------------------------------------

def aggregate_stacked_suffix(deltas: PyTree, weights: Array, cut: int,
                             cfg) -> PyTree:
    """Eq. (5) over the *trainable suffix* only (DESIGN.md §7).

    ``deltas``: the ``trainable_slice``-shaped pytree with a leading (n,)
    client axis, as produced by ``jax.vmap`` of the mask-aware local update
    — each segment carries only its rows at or above the prefix cut.
    ``weights``: the full (n, L) Eq.(7) matrix (frozen columns are all-zero
    by construction, so nothing is lost by never contracting them).
    Returns the suffix-shaped global update; the frozen prefix and the
    non-selectable groups carry no update and are left to
    :func:`apply_update_suffix` to pass through untouched.
    """
    parts = split_mask_matrix(weights, cfg)                  # path -> (n, c)
    cuts = segment_cuts(cut, cfg)
    out = {}
    for key, sub in deltas.items():
        w = parts[key][:, cuts[key]:]
        out[key] = jax.tree.map(
            lambda x, w=w: jnp.einsum("nc,nc...->c...", w,
                                      x.astype(jnp.float32)), sub)
    return out


def apply_update_suffix(params: PyTree, update: PyTree, lr: float, cut: int,
                        cfg) -> PyTree:
    """Eq. (6) on the trainable suffix, scattered back into the full tree.

    Matches :func:`apply_update` bit-for-bit: suffix rows get the identical
    ``p − η·u`` expression; frozen rows — where the dense path computes
    ``p − η·0 = p`` exactly — pass through untouched.
    """
    cuts = segment_cuts(cut, cfg)
    out = {}
    for key, sub in params.items():
        if key not in update:
            out[key] = sub
            continue
        c = cuts[key]

        def upd(p, u, c=c):
            new = p[c:] - lr * u.astype(p.dtype)
            return new if c == 0 else jnp.concatenate([p[:c], new], axis=0)

        out[key] = jax.tree.map(upd, sub, update[key])
    return out


def apply_delta_rows(params: PyTree, rows: dict, deltas: dict,
                     scale: float = 1.0) -> PyTree:
    """Scatter additive per-layer delta rows into the full tree.

    The row-indexed analogue of :func:`apply_update_suffix` for
    personalized-delta serving (DESIGN.md §9): ``rows`` maps a segment path
    to the (k,) local layer indices a user fine-tuned, ``deltas`` to the
    matching ``{leaf_name: (k, *shape)}`` delta rows.  Segments absent from
    ``rows`` pass through untouched — exactly the frozen layers.
    """
    out = {}
    for key, sub in params.items():
        if key not in rows:
            out[key] = sub
            continue
        idx = jnp.asarray(rows[key], jnp.int32)
        out[key] = jax.tree.map(
            lambda p, d: p.at[idx].add(
                scale * jnp.asarray(d).astype(p.dtype)),
            sub, deltas[key])
    return out
