"""Fused masked-SGD parameter update — Pallas TPU kernel.

Eq. (3)/(6): θ_l ← θ_l − η · m(l) · g_l applied to the stacked-(L, …)
layout.  Fusing the (L,) mask broadcast with the AXPY means one HBM
read-modify-write per parameter instead of materialising the masked
gradient; the mask scalar for the row is prefetched into SMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _masked_update_kernel(mask_ref, lr_ref, p_ref, g_ref, out_ref):
    m = mask_ref[0]          # scalar mask for this layer row (SMEM)
    lr = lr_ref[0]
    p = p_ref[...]
    g = g_ref[...].astype(jnp.float32)
    out_ref[...] = (p.astype(jnp.float32) - lr * m * g).astype(out_ref.dtype)


def masked_sgd_update_2d_jnp(p: jax.Array, g: jax.Array, mask: jax.Array,
                             lr) -> jax.Array:
    """Pure-jnp fallback for :func:`masked_sgd_update_2d` — the off-TPU hot
    path.  Elementwise with the kernel's exact expression order
    ``p − ((lr·m)·g)`` in f32, so the two are bit-identical (pinned in
    tests/test_kernels.py)."""
    lr_ = jnp.asarray(lr, jnp.float32)
    m = mask.astype(jnp.float32).reshape(
        (mask.shape[0],) + (1,) * (p.ndim - 1))
    return (p.astype(jnp.float32)
            - lr_ * m * g.astype(jnp.float32)).astype(p.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def masked_sgd_update_2d(p: jax.Array, g: jax.Array, mask: jax.Array,
                         lr, *, block: int = 4096,
                         interpret: bool = False) -> jax.Array:
    """p, g: (L, F); mask: (L,); lr scalar. Returns updated (L, F)."""
    L, F = p.shape
    block = min(block, F)
    pad = (-F) % block
    if pad:
        p = jnp.pad(p, ((0, 0), (0, pad)))
        g = jnp.pad(g, ((0, 0), (0, pad)))
    nb = (F + pad) // block
    lr_arr = jnp.asarray([lr], jnp.float32)
    mask = mask.astype(jnp.float32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(L, nb),
        in_specs=[
            pl.BlockSpec((1, block), lambda l, b, *_: (l, b)),
            pl.BlockSpec((1, block), lambda l, b, *_: (l, b)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda l, b, *_: (l, b)),
    )

    def kernel(mask_s, lr_s, p_ref, g_ref, out_ref):
        l = pl.program_id(0)
        m = mask_s[l]
        lr_ = lr_s[0]
        out_ref[...] = (p_ref[...].astype(jnp.float32)
                        - lr_ * m * g_ref[...].astype(jnp.float32)
                        ).astype(out_ref.dtype)

    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(p.shape, p.dtype),
        interpret=interpret,
    )(mask, lr_arr, p, g)
    return out[:, :F] if pad else out
