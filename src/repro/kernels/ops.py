"""Public jit'd wrappers over the Pallas kernels.

These adapt model-level pytrees / shapes to the kernels' flat layouts and
fall back to interpret mode off-TPU (``interpret=None`` ⇒ auto-detect).
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.kernels import delta_matmul as _dmm
from repro.kernels import flash_attention as _fa
from repro.kernels import layer_grad_norm as _lgn
from repro.kernels import masked_update as _mu
from repro.kernels import ssd_scan as _ssd

PyTree = Any


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def _resolve_mode(mode: Optional[str], interpret: Optional[bool]) -> str:
    """Kernel dispatch for the FL hot paths: ``"pallas"`` (the TPU kernel;
    interpret mode off-TPU) or ``"jnp"`` (the pure-jnp fallback, pinned
    bit-identical to the kernel).  ``None`` auto-selects: the real kernel
    on TPU, the fallback elsewhere — unless ``interpret`` was passed
    explicitly, which forces the kernel (the kernel-test path)."""
    if mode is not None:
        if mode not in ("pallas", "jnp"):
            raise ValueError(f"mode must be 'pallas' or 'jnp', got {mode!r}")
        return mode
    if interpret is not None:
        return "pallas"
    return "pallas" if jax.default_backend() == "tpu" else "jnp"


# ---------------------------------------------------------------------------
# flash attention (model layout: q/k/v (B, S, H, D))
# ---------------------------------------------------------------------------

def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: Optional[bool] = None,
                    mode: Optional[str] = None):
    """Model-layout wrapper: q (B,S,H,D), k/v (B,S,K,D) → (B,S,H,D).

    The Pallas kernel on TPU, the bit-identical blocked jnp fallback
    elsewhere (``mode`` forces either; tests/test_kernels.py pins the
    parity)."""
    m = _resolve_mode(mode, interpret)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    if m == "jnp":
        out = _fa.flash_attention_jnp(qt, kt, vt, causal=causal,
                                      window=window, block_q=block_q,
                                      block_k=block_k)
    else:
        out = _fa.flash_attention(qt, kt, vt, causal=causal, window=window,
                                  block_q=block_q, block_k=block_k,
                                  interpret=_auto_interpret(interpret))
    return out.transpose(0, 2, 1, 3)


# ---------------------------------------------------------------------------
# SSD (model layout: x (B,S,H,P), dt (B,S,H), A_log (H,), B/C (B,S,G,N))
# ---------------------------------------------------------------------------

def ssd(x, dt, A_log, Bmat, Cmat, D, *, chunk: int = 128,
        interpret: Optional[bool] = None, mode: Optional[str] = None):
    """Model-layout SSD wrapper; the Pallas kernel on TPU, the
    bit-identical chunked jnp fallback elsewhere (``mode`` forces
    either)."""
    m = _resolve_mode(mode, interpret)
    b, s, h, p = x.shape
    g, n = Bmat.shape[2], Bmat.shape[3]
    rep = h // g
    xbh = x.transpose(0, 2, 1, 3).reshape(b * h, s, p)
    dtbh = dt.transpose(0, 2, 1).reshape(b * h, s)
    A = -jnp.exp(A_log.astype(jnp.float32))
    Abh = jnp.tile(A, b)
    Dbh = jnp.tile(D.astype(jnp.float32), b)
    Bh = jnp.repeat(Bmat, rep, axis=2).transpose(0, 2, 1, 3).reshape(b * h, s, n)
    Ch = jnp.repeat(Cmat, rep, axis=2).transpose(0, 2, 1, 3).reshape(b * h, s, n)
    # kernel applies y += x*D with *undiscretised* x
    if m == "jnp":
        y = _ssd.ssd_scan_jnp(xbh, dtbh, Abh, Bh, Ch, Dbh, chunk=chunk)
    else:
        y = _ssd.ssd_scan(xbh, dtbh, Abh, Bh, Ch, Dbh, chunk=chunk,
                          interpret=_auto_interpret(interpret))
    return y.reshape(b, h, s, p).transpose(0, 2, 1, 3)


# ---------------------------------------------------------------------------
# per-layer gradient norms over a stacked pytree
# ---------------------------------------------------------------------------

def layer_grad_norms(stacked_grads: PyTree, *, block: int = 4096,
                     interpret: Optional[bool] = None,
                     mode: Optional[str] = None) -> jax.Array:
    """Σ over leaves of row-wise ‖·‖² for (L, …) stacked leaves → (L,).

    The probe reduction of the mask-aware engine (core/masks.py routes
    ``per_layer_sq_norms`` here): the Pallas kernel on TPU, the
    bit-identical pure-jnp fallback elsewhere (``mode`` forces either).
    """
    m = _resolve_mode(mode, interpret)
    it = _auto_interpret(interpret)
    total = None
    for leaf in jax.tree.leaves(stacked_grads):
        L = leaf.shape[0]
        flat = leaf.reshape(L, -1)
        if m == "jnp":
            sq = _lgn.layer_sq_norms_2d_jnp(flat, block=block)
        else:
            sq = _lgn.layer_sq_norms_2d(flat, block=block, interpret=it)
        total = sq if total is None else total + sq
    return total


# ---------------------------------------------------------------------------
# fused masked SGD update over a stacked pytree
# ---------------------------------------------------------------------------

def masked_sgd_update(stacked_params: PyTree, stacked_grads: PyTree,
                      mask: jax.Array, lr, *, block: int = 4096,
                      interpret: Optional[bool] = None,
                      mode: Optional[str] = None) -> PyTree:
    """Fused Eq.(3) apply θ_l ← θ_l − η·m(l)·g_l over a stacked pytree.

    The apply step of the mask-aware engine's τ-scan (core/client.py):
    the Pallas kernel on TPU, the bit-identical pure-jnp fallback
    elsewhere (``mode`` forces either).
    """
    m = _resolve_mode(mode, interpret)
    it = _auto_interpret(interpret)

    def upd(p, g):
        if m == "jnp":
            return _mu.masked_sgd_update_2d_jnp(p, g, mask, lr)
        L = p.shape[0]
        out = _mu.masked_sgd_update_2d(p.reshape(L, -1), g.reshape(L, -1),
                                       mask, lr, block=block, interpret=it)
        return out.reshape(p.shape)

    return jax.tree.map(upd, stacked_params, stacked_grads)


# ---------------------------------------------------------------------------
# fused base + per-slot delta matmul (personalized-delta serving)
# ---------------------------------------------------------------------------

def base_delta_matmul(x, w, dw, slots, *, block_f=None,
                      interpret: Optional[bool] = None,
                      mode: Optional[str] = None):
    """``y[b] = x[b] @ w + Σ_{e: slots[e]==b} x[b] @ dw[e]`` — the serving
    decode projection with per-slot selected-layer deltas (DESIGN.md §9).

    x: (B, 1, d) decode activations (or (B, d)); w: (d, f) shared base
    weight; dw: (C, d, f) capacity-C per-layer delta entries; slots: (C,)
    int32 slot owner per entry, -1 = empty.  The Pallas kernel on TPU, the
    bit-identical pure-jnp fallback elsewhere (``mode`` forces either).
    """
    m = _resolve_mode(mode, interpret)
    squeeze = x.ndim == 3
    if squeeze:
        assert x.shape[1] == 1, "delta decode projections are single-token"
        x2 = x[:, 0]
    else:
        x2 = x
    if m == "jnp":
        out = _dmm.base_delta_matmul_2d_jnp(x2, w, dw, slots, block_f=block_f)
    else:
        out = _dmm.base_delta_matmul_2d(x2, w, dw, slots, block_f=block_f,
                                        interpret=_auto_interpret(interpret))
    return out[:, None] if squeeze else out
