"""Pallas TPU kernels (validated in interpret mode on CPU).

Each kernel: <name>.py (pl.pallas_call + BlockSpec), with jit'd wrappers in
ops.py and pure-jnp oracles in ref.py.
"""
from repro.kernels import ops, ref  # noqa: F401
