"""Mamba2 SSD chunked scan — Pallas TPU kernel.

The SSD insight (arXiv:2405.21060) is that the selective-SSM recurrence is a
semiseparable matmul: split the sequence into chunks; *within* a chunk the
output is dense matmuls (MXU work — C·Bᵀ ⊙ decay, then @ x); *across* chunks
only an (P, N) state per head flows through a sequential recurrence.

TPU mapping: grid = (B·H, n_chunks) with the chunk axis executed
sequentially per core; the carried state lives in VMEM scratch, so the
recurrence never round-trips HBM.  Chunk = 128 keeps every matmul
MXU-shaped for typical P=64, N=128.

Layout (per head, groups pre-broadcast by the wrapper):
  x (BH, S, P), dt (BH, S), A (BH,), Bmat/Cmat (BH, S, N), D (BH,)
  → y (BH, S, P).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, y_ref, state_scr,
                *, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0].astype(jnp.float32)            # (chunk, P)
    dt = dt_ref[0].astype(jnp.float32)          # (chunk,)
    A = a_ref[0].astype(jnp.float32)            # scalar
    Bm = b_ref[0].astype(jnp.float32)           # (chunk, N)
    Cm = c_ref[0].astype(jnp.float32)           # (chunk, N)
    D = d_ref[0].astype(jnp.float32)            # scalar

    dA = dt * A                                 # (chunk,)
    dAcs = jnp.cumsum(dA)                       # (chunk,)
    xdt = x * dt[:, None]

    # intra-chunk: L[i,j] = exp(sum_{k=j+1..i} dA_k) for i >= j
    seg = dAcs[:, None] - dAcs[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(ii >= jj, jnp.exp(seg), 0.0)

    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())))   # (chunk, chunk)
    y = jax.lax.dot((scores * L).astype(xdt.dtype), xdt)             # (chunk, P)

    # inter-chunk contribution from carried state
    state = state_scr[...]                                           # (P, N)
    decay_out = jnp.exp(dAcs)[:, None]                               # (chunk, 1)
    y = y + (jax.lax.dot_general(Cm, state, (((1,), (1,)), ((), ())))
             * decay_out)

    # state update: state' = state·exp(dAcs[-1]) + Σ_t decay_t · x_t ⊗ B_t
    decay_states = jnp.exp(dAcs[-1] - dAcs)[:, None]                 # (chunk, 1)
    new_state = (state * jnp.exp(dAcs[-1])
                 + jax.lax.dot_general(xdt * decay_states, Bm,
                                       (((0,), (0,)), ((), ()))))    # (P, N)
    state_scr[...] = new_state

    y_ref[0] = (y + x * D).astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, Bmat, Cmat, D, *, chunk: int = 128,
             interpret: bool = False):
    """Per-head SSD. x (BH,S,P); dt (BH,S); A/D (BH,); B/C (BH,S,N)."""
    BH, S, P = x.shape
    N = Bmat.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk

    grid = (BH, nc)
    specs = dict(
        x=pl.BlockSpec((1, chunk, P), lambda b, c: (b, c, 0)),
        dt=pl.BlockSpec((1, chunk), lambda b, c: (b, c)),
        a=pl.BlockSpec((1,), lambda b, c: (b,)),
        bc=pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
    )
    return pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=grid,
        in_specs=[specs["x"], specs["dt"], specs["a"], specs["bc"],
                  specs["bc"], specs["a"]],
        out_specs=specs["x"],
        out_shape=jax.ShapeDtypeStruct((BH, S, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, Bmat, Cmat, D)


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan_jnp(x, dt, A, Bmat, Cmat, D, *, chunk: int = 128):
    """Pure-jnp fallback replaying the kernel's chunked semiseparable form.

    Same chunk decomposition, same intra-chunk L-masked matmuls, same
    carried (P, N) state recurrence (the kernel's sequential chunk axis as
    a ``lax.scan``) — bit-identical to the Pallas kernel
    (tests/test_kernels.py pins it), unlike the token-sequential oracle in
    ref.py which is only allclose.  This is what
    :func:`repro.kernels.ops.ssd` dispatches to off-TPU (``mode="jnp"``)."""
    BH, S, P = x.shape
    N = Bmat.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk

    xf = x.astype(jnp.float32).reshape(BH, nc, chunk, P).transpose(1, 0, 2, 3)
    dtf = dt.astype(jnp.float32).reshape(BH, nc, chunk).transpose(1, 0, 2)
    Af = A.astype(jnp.float32)
    Bf = Bmat.astype(jnp.float32).reshape(BH, nc, chunk, N) \
        .transpose(1, 0, 2, 3)
    Cf = Cmat.astype(jnp.float32).reshape(BH, nc, chunk, N) \
        .transpose(1, 0, 2, 3)
    Df = D.astype(jnp.float32)

    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)

    def step(state, inp):                      # state: (BH, P, N)
        xc, dtc, bc, cc = inp                  # (BH,chunk,P),(BH,chunk),(BH,chunk,N)×2
        dA = dtc * Af[:, None]
        dAcs = jnp.cumsum(dA, axis=-1)
        xdt = xc * dtc[..., None]
        seg = dAcs[:, :, None] - dAcs[:, None, :]
        L = jnp.where(ii[None] >= jj[None], jnp.exp(seg), 0.0)
        scores = jax.lax.dot_general(
            cc, bc, (((2,), (2,)), ((0,), (0,))))          # (BH,chunk,chunk)
        y = jax.lax.dot_general(
            scores * L, xdt, (((2,), (1,)), ((0,), (0,))))  # (BH,chunk,P)
        decay_out = jnp.exp(dAcs)[..., None]
        y = y + jax.lax.dot_general(
            cc, state, (((2,), (2,)), ((0,), (0,)))) * decay_out
        decay_states = jnp.exp(dAcs[:, -1][:, None] - dAcs)[..., None]
        new_state = (state * jnp.exp(dAcs[:, -1])[:, None, None]
                     + jax.lax.dot_general(xdt * decay_states, bc,
                                           (((1,), (1,)), ((0,), (0,)))))
        return new_state, y + xc * Df[:, None, None]

    state0 = jnp.zeros((BH, P, N), jnp.float32)
    _, ys = jax.lax.scan(step, state0, (xf, dtf, Bf, Cf))
    return ys.transpose(1, 0, 2, 3).reshape(BH, S, P).astype(x.dtype)
