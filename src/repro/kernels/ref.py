"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q (B,H,S,D), k/v (B,K,S,D) → (B,H,S,D)."""
    B, H, S, D = q.shape
    K = k.shape[1]
    g = H // K
    kf = jnp.repeat(k, g, axis=1)
    vf = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kf.astype(jnp.float32)) / np.sqrt(D)
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    ok = jnp.ones((S, S), bool)
    if causal:
        ok &= j <= i
    if window:
        ok &= (i - j) < window
    s = jnp.where(ok, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", w, vf.astype(jnp.float32)).astype(q.dtype)


def ssd_scan_ref(x, dt, A, Bmat, Cmat, D):
    """Sequential (exact) SSM recurrence. x (BH,S,P); B/C (BH,S,N); A/D (BH,)."""
    BH, S, P = x.shape
    N = Bmat.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Af = A.astype(jnp.float32)

    def step(state, inp):
        xt, dtt, bt, ct = inp                    # (BH,P),(BH,),(BH,N),(BH,N)
        decay = jnp.exp(dtt * Af)                # (BH,)
        state = state * decay[:, None, None] + \
            jnp.einsum("bp,bn,b->bpn", xt, bt, dtt)
        y = jnp.einsum("bn,bpn->bp", ct, state)
        return state, y

    state0 = jnp.zeros((BH, P, N), jnp.float32)
    _, ys = jax.lax.scan(step, state0,
                         (xf.transpose(1, 0, 2), dtf.T,
                          Bmat.astype(jnp.float32).transpose(1, 0, 2),
                          Cmat.astype(jnp.float32).transpose(1, 0, 2)))
    y = ys.transpose(1, 0, 2) + xf * D.astype(jnp.float32)[:, None, None]
    return y.astype(x.dtype)


def layer_sq_norms_ref(g2d: jax.Array) -> jax.Array:
    """Row-wise squared norms of (L, F)."""
    return jnp.sum(jnp.square(g2d.astype(jnp.float32)), axis=1)


def masked_sgd_update_ref(p, g, mask, lr):
    """(L,F) masked SGD update."""
    m = mask.astype(jnp.float32)[:, None]
    return (p.astype(jnp.float32)
            - lr * m * g.astype(jnp.float32)).astype(p.dtype)
