"""Blocked (flash) attention forward — Pallas TPU kernel.

TPU adaptation of the standard streaming-softmax attention: the grid's last
axis walks key blocks *sequentially* (TPU grids execute the trailing axis
in order on a core), carrying the running max / normaliser / accumulator in
VMEM scratch, so the (S×S) score matrix never exists in HBM.  Block shapes
are MXU-aligned (multiples of 128 on the contracting dims by default).

Supports causal masking, sliding windows and GQA (kv heads < q heads, the
kv block index map folds the head-group mapping).

Layout: q (B, H, S, D), k/v (B, K, S, D)  →  out (B, H, S, D).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int,
                  block_q: int, block_k: int, n_k_blocks: int):
    qi = pl.program_id(1)          # query-block index
    ki = pl.program_id(2)          # key-block index (sequential)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k

    # Skip key blocks that are fully masked for this query block.  A block
    # contains a visible (q,k) pair iff k_min <= q_max (causal) and
    # q_min - k_max < window (sliding window).
    needed = True
    if causal:
        needed = k_start <= q_start + block_q - 1
    if window:
        needed = jnp.logical_and(
            needed, q_start - (k_start + block_k - 1) < window)

    @pl.when(needed)
    def _compute():
        q = q_ref[0].astype(jnp.float32)          # (block_q, D)
        k = k_ref[0].astype(jnp.float32)          # (block_k, D)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        ok = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            ok &= k_pos <= q_pos
        if window:
            ok &= (q_pos - k_pos) < window
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_scr[...]                        # (block_q, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        # fully-masked rows keep m == NEG_INF; exp(NEG_INF - NEG_INF) would
        # be 1, so explicitly zero masked entries.
        p = jnp.where(ok, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(p.astype(v.dtype), v)
        m_scr[...] = m_new

    @pl.when(ki == n_k_blocks - 1)
    def _finalize():
        denom = jnp.where(l_scr[...] > 0, l_scr[...], 1.0)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """q: (B,H,S,D), k/v: (B,K,S,D) with H % K == 0. Returns (B,H,S,D)."""
    B, H, S, D = q.shape
    K = k.shape[1]
    assert H % K == 0, (H, K)
    group = H // K
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0
    nq, nk = S // block_q, S // block_k
    scale = 1.0 / math.sqrt(D)

    grid = (B * H, nq, nk)

    def q_map(bh, qi, ki):
        return (bh, qi, 0)

    def kv_map(bh, qi, ki):
        b, h = bh // H, bh % H
        return (b * K + h // group, ki, 0)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, n_k_blocks=nk)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, D), q_map),
            pl.BlockSpec((1, block_k, D), kv_map),
            pl.BlockSpec((1, block_k, D), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), q_map),
        out_shape=jax.ShapeDtypeStruct((B * H, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),   # running normaliser l
            pltpu.VMEM((block_q, D), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q.reshape(B * H, S, D), k.reshape(B * K, S, D), v.reshape(B * K, S, D))
    return out.reshape(B, H, S, D)
