"""Blocked (flash) attention forward — Pallas TPU kernel.

TPU adaptation of the standard streaming-softmax attention: the grid's last
axis walks key blocks *sequentially* (TPU grids execute the trailing axis
in order on a core), carrying the running max / normaliser / accumulator in
VMEM scratch, so the (S×S) score matrix never exists in HBM.  Block shapes
are MXU-aligned (multiples of 128 on the contracting dims by default).

Supports causal masking, sliding windows and GQA (kv heads < q heads, the
kv block index map folds the head-group mapping).

Layout: q (B, H, S, D), k/v (B, K, S, D)  →  out (B, H, S, D).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int,
                  block_q: int, block_k: int, n_k_blocks: int):
    qi = pl.program_id(1)          # query-block index
    ki = pl.program_id(2)          # key-block index (sequential)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k

    # Skip key blocks that are fully masked for this query block.  A block
    # contains a visible (q,k) pair iff k_min <= q_max (causal) and
    # q_min - k_max < window (sliding window).
    needed = True
    if causal:
        needed = k_start <= q_start + block_q - 1
    if window:
        needed = jnp.logical_and(
            needed, q_start - (k_start + block_k - 1) < window)

    @pl.when(needed)
    def _compute():
        q = q_ref[0].astype(jnp.float32)          # (block_q, D)
        k = k_ref[0].astype(jnp.float32)          # (block_k, D)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        ok = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            ok &= k_pos <= q_pos
        if window:
            ok &= (q_pos - k_pos) < window
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_scr[...]                        # (block_q, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        # fully-masked rows keep m == NEG_INF; exp(NEG_INF - NEG_INF) would
        # be 1, so explicitly zero masked entries.
        p = jnp.where(ok, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(p.astype(v.dtype), v)
        m_scr[...] = m_new

    @pl.when(ki == n_k_blocks - 1)
    def _finalize():
        denom = jnp.where(l_scr[...] > 0, l_scr[...], 1.0)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """q: (B,H,S,D), k/v: (B,K,S,D) with H % K == 0. Returns (B,H,S,D)."""
    B, H, S, D = q.shape
    K = k.shape[1]
    assert H % K == 0, (H, K)
    group = H // K
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0
    nq, nk = S // block_q, S // block_k
    scale = 1.0 / math.sqrt(D)

    grid = (B * H, nq, nk)

    def q_map(bh, qi, ki):
        return (bh, qi, 0)

    def kv_map(bh, qi, ki):
        b, h = bh // H, bh % H
        return (b * K + h // group, ki, 0)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, n_k_blocks=nk)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, D), q_map),
            pl.BlockSpec((1, block_k, D), kv_map),
            pl.BlockSpec((1, block_k, D), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), q_map),
        out_shape=jax.ShapeDtypeStruct((B * H, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),   # running normaliser l
            pltpu.VMEM((block_q, D), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q.reshape(B * H, S, D), k.reshape(B * K, S, D), v.reshape(B * K, S, D))
    return out.reshape(B, H, S, D)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k"))
def flash_attention_jnp(q, k, v, *, causal: bool = True, window: int = 0,
                        block_q: int = 128, block_k: int = 128):
    """Pure-jnp fallback replaying the kernel's blocked streaming softmax.

    Same block decomposition, same f32 accumulation, same masked-row
    handling, with the kernel's sequential key-block axis as a
    ``lax.scan`` — so the result is bit-identical to the Pallas kernel
    (tests/test_kernels.py pins it), not merely allclose like the dense
    oracle in ref.py.  This is what :func:`repro.kernels.ops.flash_attention`
    dispatches to off-TPU (``mode="jnp"``)."""
    B, H, S, D = q.shape
    K = k.shape[1]
    assert H % K == 0, (H, K)
    group = H // K
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0
    nq, nk = S // block_q, S // block_k
    scale = 1.0 / math.sqrt(D)

    qf = q.reshape(B * H, nq, block_q, D).astype(jnp.float32)
    kf = jnp.repeat(k, group, axis=1).reshape(B * H, nk, block_k, D) \
        .astype(jnp.float32)
    vf = jnp.repeat(v, group, axis=1).reshape(B * H, nk, block_k, D) \
        .astype(jnp.float32)
    q_pos = (jnp.arange(nq, dtype=jnp.int32)[:, None] * block_q
             + jnp.arange(block_q, dtype=jnp.int32)[None, :])   # (nq, bq)

    def kblock(carry, inp):
        m_prev, l_prev, acc = carry
        kb, vb, ki = inp                       # (BH, bk, D) ×2, scalar
        s = jax.lax.dot_general(
            qf, kb, (((3,), (2,)), ((0,), (0,)))) * scale   # (BH,nq,bq,bk)
        k_pos = ki * block_k + jnp.arange(block_k, dtype=jnp.int32)
        ok = jnp.ones((nq, block_q, block_k), jnp.bool_)
        if causal:
            ok &= k_pos[None, None, :] <= q_pos[:, :, None]
        if window:
            ok &= (q_pos[:, :, None] - k_pos[None, None, :]) < window
        s = jnp.where(ok[None], s, NEG_INF)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.where(ok[None], jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + jax.lax.dot_general(
            p, vb, (((3,), (1,)), ((0,), (0,))))
        return (m_new, l_new, acc), None

    init = (jnp.full((B * H, nq, block_q, 1), NEG_INF, jnp.float32),
            jnp.zeros((B * H, nq, block_q, 1), jnp.float32),
            jnp.zeros((B * H, nq, block_q, D), jnp.float32))
    (m_f, l_f, acc_f), _ = jax.lax.scan(
        kblock, init, (kf.transpose(1, 0, 2, 3), vf.transpose(1, 0, 2, 3),
                       jnp.arange(nk, dtype=jnp.int32)))
    denom = jnp.where(l_f > 0, l_f, 1.0)
    return (acc_f / denom).astype(q.dtype).reshape(B, H, S, D)
