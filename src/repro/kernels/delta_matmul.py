"""Fused "base + per-slot delta" matmul — Pallas TPU kernel.

The personalized-delta serving path (serve/engine.py, DESIGN.md §9)
batch-decodes B slots against ONE shared weight ``w`` while a small
capacity-C entry table carries the per-slot selected-layer deltas active at
the current layer:

    y[b] = x[b] @ w  +  Σ_{e : slots[e] == b}  x[b] @ dw[e]

Entries with ``slots[e] == -1`` are padding (masked to a zero correction).
The serving invariant is ≤ 1 entry per (slot, layer) — a client selects a
layer at most once — so per output row there is at most one correction term
and the accumulation order is immaterial.

Why this shape wins over per-user dense params: the base product streams
``w`` ONCE for the whole batch (B·d·f MACs at full weight reuse), and the
correction streams only the C ≤ B active delta slabs, so per-step weight
traffic is (1 + C)·d·f instead of the B·d·f of B private weight copies.
At the paper's operating point (a few selected layers of L) C ≪ B.

The pure-jnp fallback replays the kernel's exact blocking and per-entry
``dynamic_slice → add → dynamic_update_slice`` expression in f32, so the
two are bit-identical (pinned in tests/test_kernels.py), following the
masked_update.py / layer_grad_norm.py pattern.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _blocked(f: int, block_f) -> tuple[int, int, int]:
    bf = f if block_f is None else min(int(block_f), f)  # repro: allow[host-sync] -- static block-shape arithmetic at trace time
    pad = (-f) % bf
    return bf, pad, (f + pad) // bf


def _entry_accumulate(acc, x, dw, slots, C: int, d: int, bf: int):
    """Shared entry loop: sequential per-entry row correction on ``acc``.

    Both the kernel body and the jnp fallback run this exact expression
    order (dynamic_slice, masked add, dynamic_update_slice per entry), which
    is what makes them bit-identical.
    """
    for e in range(C):
        se = slots[e]
        safe = jnp.maximum(se, 0).astype(jnp.int32)
        m = (se >= 0).astype(jnp.float32)
        xrow = lax.dynamic_slice(x, (safe, 0), (1, d))
        corr = jnp.dot(xrow, dw[e], preferred_element_type=jnp.float32)
        cur = lax.dynamic_slice(acc, (safe, 0), (1, bf))
        acc = lax.dynamic_update_slice(acc, cur + m * corr, (safe, 0))
    return acc


def base_delta_matmul_2d_jnp(x: jax.Array, w: jax.Array, dw: jax.Array,
                             slots: jax.Array, *, block_f=None) -> jax.Array:
    """Pure-jnp fallback for :func:`base_delta_matmul_2d` — the off-TPU
    serving hot path.  x: (B, d); w: (d, f); dw: (C, d, f); slots: (C,)
    int32 with -1 padding.  Returns (B, f) in x.dtype."""
    B, d = x.shape
    f = w.shape[1]
    C = dw.shape[0]
    bf, pad, nb = _blocked(f, block_f)
    xf = x.astype(jnp.float32)
    wf = w.astype(jnp.float32)
    dwf = dw.astype(jnp.float32)
    if pad:
        wf = jnp.pad(wf, ((0, 0), (0, pad)))
        dwf = jnp.pad(dwf, ((0, 0), (0, 0), (0, pad)))
    slots = slots.astype(jnp.int32)
    cols = []
    for j in range(nb):
        wj = wf[:, j * bf:(j + 1) * bf]
        dwj = dwf[:, :, j * bf:(j + 1) * bf]
        acc = jnp.dot(xf, wj, preferred_element_type=jnp.float32)
        acc = _entry_accumulate(acc, xf, dwj, slots, C, d, bf)
        cols.append(acc)
    out = jnp.concatenate(cols, axis=1) if nb > 1 else cols[0]
    return out[:, :f].astype(x.dtype)


@functools.partial(jax.jit, static_argnames=("block_f", "interpret"))
def base_delta_matmul_2d(x: jax.Array, w: jax.Array, dw: jax.Array,
                         slots: jax.Array, *, block_f=None,
                         interpret: bool = False) -> jax.Array:
    """x: (B, d); w: (d, f); dw: (C, d, f); slots: (C,) int32 (-1 = pad).

    Grid over f-blocks; the full x block and the C delta slabs for the
    current f-block sit in VMEM, the entry slot ids are scalar-prefetched
    into SMEM.  Returns (B, f) in x.dtype.
    """
    B, d = x.shape
    f = w.shape[1]
    C = dw.shape[0]
    bf, pad, nb = _blocked(f, block_f)
    xf = x.astype(jnp.float32)
    wf = w.astype(jnp.float32)
    dwf = dw.astype(jnp.float32)
    if pad:
        wf = jnp.pad(wf, ((0, 0), (0, pad)))
        dwf = jnp.pad(dwf, ((0, 0), (0, 0), (0, pad)))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((B, d), lambda j, *_: (0, 0)),
            pl.BlockSpec((d, bf), lambda j, *_: (0, j)),
            pl.BlockSpec((C, d, bf), lambda j, *_: (0, 0, j)),
        ],
        out_specs=pl.BlockSpec((B, bf), lambda j, *_: (0, j)),
    )

    def kernel(slots_s, x_ref, w_ref, dw_ref, out_ref):
        acc = jnp.dot(x_ref[...], w_ref[...],
                      preferred_element_type=jnp.float32)
        acc = _entry_accumulate(acc, x_ref[...], dw_ref, slots_s, C, d, bf)
        out_ref[...] = acc

    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, f + pad), jnp.float32),
        interpret=interpret,
    )(slots.astype(jnp.int32), xf, wf, dwf)
    return out[:, :f].astype(x.dtype)
