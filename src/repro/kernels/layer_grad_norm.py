"""Fused per-layer squared gradient norm — Pallas TPU kernel.

The paper's selection step (§4.2) needs ‖g_{i,l}‖² for every selectable
layer l, every selection round.  On the stacked-(L, …) gradient layout this
is a row-wise reduction over possibly hundreds of MB; doing it leaf-by-leaf
launches L×leaves reductions and re-reads HBM.  This kernel streams each
stacked leaf once: grid = (L, n_feature_blocks), feature axis sequential,
accumulating into an f32 (1,1) VMEM scratch, writing the row result on the
last block.

The wrapper (ops.layer_grad_norms) flattens each stacked leaf to (L, F),
pads F to the block size, and sums results across leaves.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _sqnorm_kernel(g_ref, out_ref, acc_scr, *, n_blocks: int):
    bi = pl.program_id(1)

    @pl.when(bi == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    g = g_ref[...].astype(jnp.float32)
    acc_scr[0, 0] += jnp.sum(g * g)

    @pl.when(bi == n_blocks - 1)
    def _fin():
        out_ref[0] = acc_scr[0, 0]


def layer_sq_norms_2d_jnp(g: jax.Array, *, block: int = 4096) -> jax.Array:
    """Pure-jnp fallback for :func:`layer_sq_norms_2d` — the off-TPU hot
    path.  Replays the kernel's accumulation order exactly (per-block f32
    sums, then a sequential left fold across blocks), so the two are
    bit-identical (pinned in tests/test_kernels.py)."""
    L, F = g.shape
    blk = min(block, F)
    pad = (-F) % blk
    if pad:
        g = jnp.pad(g, ((0, 0), (0, pad)))
    nb = (F + pad) // blk
    gb = g.astype(jnp.float32).reshape(L, nb, blk)
    per_block = jnp.sum(gb * gb, axis=2)               # (L, nb)
    if nb == 1:
        return per_block[:, 0]
    # the kernel's sequential left fold across blocks, as an O(1)-size
    # graph (an unrolled Python loop would emit nb adds per leaf)
    return jax.lax.fori_loop(
        1, nb,
        lambda b, acc: acc + jax.lax.dynamic_index_in_dim(
            per_block, b, axis=1, keepdims=False),
        per_block[:, 0])


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def layer_sq_norms_2d(g: jax.Array, *, block: int = 4096,
                      interpret: bool = False) -> jax.Array:
    """Row-wise squared norms of a (L, F) array (F padded to block size)."""
    L, F = g.shape
    block = min(block, F)
    pad = (-F) % block
    if pad:
        g = jnp.pad(g, ((0, 0), (0, pad)))
        F += pad
    nb = F // block
    return pl.pallas_call(
        functools.partial(_sqnorm_kernel, n_blocks=nb),
        grid=(L, nb),
        in_specs=[pl.BlockSpec((1, block), lambda l, b: (l, b))],
        out_specs=pl.BlockSpec((1,), lambda l, b: (l,)),
        out_shape=jax.ShapeDtypeStruct((L,), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, 1), jnp.float32)],
        interpret=interpret,
    )(g)
