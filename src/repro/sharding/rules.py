"""Parameter / activation / cache partition rules for the production mesh.

Mesh axes: ``('data', 'model')`` single-pod, ``('pod', 'data', 'model')``
multi-pod.  The *client* axes (pod×data) carry the FL cohort — one client
per (pod,data) coordinate — and double as the ZeRO-3 storage axis for the
frozen model base.  The ``model`` axis is Megatron-style tensor parallelism
(heads / ff / vocab / experts) and stays in XLA's auto-sharding hands.

Rules are name-based over the stacked-parameter layout; every rule returns
a PartitionSpec of the same rank as the leaf.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig

PyTree = Any

DATA = "data"     # ZeRO-3 / client axis
MODEL = "model"   # tensor-parallel axis


def _divisible(n: int, axis_size: int) -> bool:
    return axis_size > 0 and n % axis_size == 0


_SUBBLOCK_PREFIXES = ("attn_", "xattn_", "mlp_", "moe_", "ssm_")


def param_spec(path: tuple[str, ...], leaf, cfg: ArchConfig, *,
               zero3: bool, mesh_shape: dict[str, int]) -> P:
    """PartitionSpec for one parameter leaf (stacked or not)."""
    name = path[-1]
    for pref in _SUBBLOCK_PREFIXES:      # stacked blocks prefix their leaves
        if name.startswith(pref):
            name = name[len(pref):]
            break
    group = path[0]
    shape = leaf.shape
    dsz, msz = mesh_shape.get(DATA, 1), mesh_shape.get(MODEL, 1)
    d_axis = DATA if zero3 else None

    stacked = group in ("blocks", "enc_blocks", "dense0")
    off = 1 if stacked else 0          # leading (L,) axis never sharded

    def spec(*dims):
        full = [None] * off + list(dims)
        full += [None] * (len(shape) - len(full))
        # drop axes that do not divide (tuple entries: product must divide)
        out = []
        for dim, ax in zip(shape, full):
            if isinstance(ax, tuple):
                size = int(np.prod([mesh_shape.get(a, 1) for a in ax]))
                if not _divisible(dim, size):
                    ax = tuple(a for a in ax if a == MODEL) or None
                    if isinstance(ax, tuple):
                        ax = ax[0] if _divisible(dim, msz) else None
            elif ax == DATA and not _divisible(dim, dsz):
                ax = None
            elif ax == MODEL and not _divisible(dim, msz):
                ax = None
            out.append(ax)
        return P(*out)

    # The ZeRO-3 ('data') axis is CO-LOCATED with 'model' on the tensor-
    # parallel dim (Megatron column/row dim): contraction dims stay
    # unsharded, so consumers gather the weight shard per layer instead of
    # all-reducing activations against an in-place-sharded contraction —
    # the pathology the first roofline pass exposed (EXPERIMENTS.md §Perf).
    tp = (MODEL, DATA) if zero3 else MODEL

    # --- embeddings / head --------------------------------------------------
    if group == "embed":
        if name == "tok":
            return spec(tp, None)                  # (V, d)
        return spec(None, tp)                      # projectors (d, d)
    if group == "head":
        return spec(None, tp)                      # (d, V) or (d, classes)
    if group in ("final_norm", "enc_norm"):
        return P(None)

    # --- attention (column: qkv — row: wo, both on the H·hd dim) -----------
    if name in ("wq", "wk", "wv", "w_dkv", "w_krope"):
        return spec(None, tp)                      # (…, d, H·hd)
    if name == "w_ukv":
        return spec(None, tp)                      # (…, lora, H·(nope+v))
    if name == "wo":
        return spec(tp, None)                      # (…, H·hd, d)
    if name in ("bq", "bk", "bv"):
        return spec(MODEL)

    # --- dense MLP (column: wi — row: wo, both on the ff dim) ----------------
    if name == "wi" or name == "wi_s":
        return spec(None, tp)                      # (…, d, 2ff)
    if name == "wo" or name == "wo_s":
        return spec(tp, None)                      # (…, ff, d)

    # --- MoE ------------------------------------------------------------------
    if name == "router":
        return spec(None, None)                    # (…, d, E)
    if name == "wi_e":                             # (…, E, d, F)
        if _divisible(cfg.n_experts, msz):
            return spec(MODEL, None, DATA if zero3 else None)
        return spec(None, None, tp)
    if name == "wo_e":                             # (…, E, F, d)
        if _divisible(cfg.n_experts, msz):
            return spec(MODEL, DATA if zero3 else None, None)
        return spec(None, tp, None)

    # --- SSM --------------------------------------------------------------------
    if name == "in_proj":
        return spec(None, tp)                      # (…, d, zxbcdt)
    if name == "out_proj":
        return spec(tp, None)                      # (…, d_in, d)
    if name == "conv_w":
        return spec(None, MODEL)                   # (…, K, conv_dim)
    if name == "conv_b":
        return spec(MODEL)

    # small vectors (ln / dt_bias / A_log / D / gate_ln / kv_ln)
    return P(*([None] * len(shape)))


def params_pytree_specs(cfg: ArchConfig, params_shapes: PyTree, *,
                        zero3: bool, mesh_shape: dict[str, int]) -> PyTree:
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shapes)
    specs = []
    for path, leaf in flat:
        keys = tuple(p.key for p in path if hasattr(p, "key"))
        specs.append(param_spec(keys, leaf, cfg, zero3=zero3,
                                mesh_shape=mesh_shape))
    return jax.tree_util.tree_unflatten(treedef, specs)


# ---------------------------------------------------------------------------
# Batch / cache specs
# ---------------------------------------------------------------------------

def client_axes(mesh) -> tuple[str, ...]:
    return tuple(n for n in mesh.axis_names if n in ("pod", DATA))


def batch_spec_train(mesh) -> P:
    """FL training batch (clients, per_client, seq): clients over pod×data."""
    return P(client_axes(mesh))


def batch_spec_serve(mesh, batch: int) -> P:
    """Inference batch dim over the client axes when divisible."""
    ca = client_axes(mesh)
    n = int(np.prod([mesh.shape[a] for a in ca]))
    return P(ca) if batch % n == 0 else P(None)


def cache_specs(cfg: ArchConfig, cache_shapes: PyTree, mesh,
                batch: int) -> PyTree:
    """KV/state cache specs: batch over client axes, heads-or-seq over model."""
    msz = mesh.shape[MODEL]
    ca = client_axes(mesh)
    n = int(np.prod([mesh.shape[a] for a in ca]))
    b_ax = ca if batch % n == 0 else None

    def spec_for(path, leaf):
        name = path[-1]
        shape = leaf.shape
        # layouts: kv (L,B,W,K,hd) | pos (L,W) | mla ckv (L,B,W,lora)
        # ssm conv (L,B,K-1,Cd) | ssm state (L,B,H,P,N) | shared (G,B,W,K,hd)
        if name == "pos":
            return P(*([None] * len(shape)))
        if name in ("k", "v"):
            L_, B_, W_, K_, hd_ = shape
            kv_ax = MODEL if _divisible(K_, msz) else None
            w_ax = MODEL if kv_ax is None and _divisible(W_, msz) else None
            return P(None, b_ax, w_ax, kv_ax, None)
        if name == "ckv" or name == "krope":
            L_, B_, W_, R_ = shape
            r_ax = MODEL if _divisible(R_, msz) else None
            return P(None, b_ax, None, r_ax)
        if name == "conv":
            return P(None, b_ax, None, MODEL if _divisible(shape[-1], msz) else None)
        if name == "state":
            L_, B_, H_, P_, N_ = shape
            h_ax = MODEL if _divisible(H_, msz) else None
            return P(None, b_ax, h_ax, None, None)
        return P(*([None] * len(shape)))

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shapes)
    specs = []
    for path, leaf in flat:
        keys = tuple(p.key for p in path if hasattr(p, "key"))
        specs.append(spec_for(keys, leaf))
    return jax.tree_util.tree_unflatten(treedef, specs)


def named(mesh, specs: PyTree) -> PyTree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def make_shard_hook(mesh, cfg: ArchConfig):
    """Activation sharding-constraint hook for Model (auto 'model' axis).

    Used by the §Perf-optimised paths; the naive baseline passes no hook.
    """
    msz = mesh.shape[MODEL]
    expert_parallel = cfg.n_experts and cfg.n_experts % msz == 0

    def shard(x, kind=None):
        spec = None
        if kind == "expert_ecf":          # expert hidden (E, C, ff)
            spec = P(MODEL, None, None) if expert_parallel \
                else P(None, None, MODEL)
        elif kind == "expert_ecd":        # expert in/out (E, C, d)
            spec = P(MODEL, None, None) if expert_parallel else None
        if spec is None:
            return x
        try:
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, spec))
        except Exception:
            return x

    return shard


def zero3_gather_axis(spec: P) -> Optional[int]:
    """Index of the client/ZeRO axis in a param spec (None if replicated)."""
    for i, entry in enumerate(spec):
        names = entry if isinstance(entry, tuple) else (entry,)
        if DATA in names:
            return i
    return None
