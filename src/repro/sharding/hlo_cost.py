"""Back-compat shim: the scan-aware HLO cost model moved to
:mod:`repro.analysis.costmodel` (the shared unrolled-cost backend for the
roofline dry-runs AND the program auditor, DESIGN.md §11).  Import from
there; this module re-exports the public surface for existing callers.
"""
from repro.analysis.costmodel import (  # noqa: F401
    HloCostModel, Metrics, Op, analyze, donation_aliases, dtype_census,
    shape_bytes, shape_elems, top_collectives, top_hbm_ops,
    transfer_op_counts)

__all__ = [
    "HloCostModel", "Metrics", "Op", "analyze", "donation_aliases",
    "dtype_census", "shape_bytes", "shape_elems", "top_collectives",
    "top_hbm_ops", "transfer_op_counts",
]
