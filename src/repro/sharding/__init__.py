from repro.sharding import rules  # noqa: F401
from repro.sharding.fl_step import (make_fl_train_step,  # noqa: F401
                                    make_fl_train_step_tau)
from repro.sharding.serve import make_prefill_step, make_serve_step  # noqa: F401
