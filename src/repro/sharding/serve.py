"""Inference steps on the production mesh: prefill and single-token decode.

Plain pjit (no client-manual region — inference of the fine-tuned global
model has no per-client aggregation).  Parameter storage reuses the training
rules (ZeRO-3 over 'data' for archs too big to replicate; XLA inserts the
per-layer gathers inside the scan), KV caches shard batch over the client
axes and heads-or-sequence over 'model' (rules.cache_specs).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.model import Model
from repro.sharding import rules


def make_prefill_step(model: Model, mesh, *, zero3: bool = True):
    cfg = model.cfg
    mesh_shape = {n: mesh.shape[n] for n in mesh.axis_names}
    ca = rules.client_axes(mesh)

    def build(params_shapes, batch_shapes):
        specs = rules.params_pytree_specs(cfg, params_shapes, zero3=zero3,
                                          mesh_shape=mesh_shape)
        batch0 = jax.tree.leaves(batch_shapes)[0].shape[0]
        b_spec = rules.batch_spec_serve(mesh, batch0)

        def prefill(params, batch):
            return model.logits_seq(params, batch)

        in_sh = (rules.named(mesh, specs),
                 jax.tree.map(lambda _: NamedSharding(mesh, b_spec),
                              batch_shapes))
        out_sh = NamedSharding(mesh, b_spec)
        return jax.jit(prefill, in_shardings=in_sh, out_shardings=out_sh), specs

    return build


def make_serve_step(model: Model, mesh, *, zero3: bool = True,
                    window: int = 0):
    """Single-token decode with a KV cache of the target context length."""
    cfg = model.cfg
    mesh_shape = {n: mesh.shape[n] for n in mesh.axis_names}

    def build(params_shapes, cache_shapes, batch: int):
        specs = rules.params_pytree_specs(cfg, params_shapes, zero3=zero3,
                                          mesh_shape=mesh_shape)
        c_specs = rules.cache_specs(cfg, cache_shapes, mesh, batch)
        b_spec = rules.batch_spec_serve(mesh, batch)

        def serve(params, tokens, pos, cache):
            logits, new_cache = model.decode_step(params, tokens, pos, cache,
                                                  window=window)
            next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return next_tok, logits, new_cache

        in_sh = (rules.named(mesh, specs),
                 NamedSharding(mesh, b_spec),
                 NamedSharding(mesh, P()),
                 rules.named(mesh, c_specs))
        out_sh = (NamedSharding(mesh, b_spec),
                  NamedSharding(mesh, b_spec),
                  rules.named(mesh, c_specs))
        return jax.jit(serve, in_shardings=in_sh, out_shardings=out_sh), \
            (specs, c_specs)

    return build
