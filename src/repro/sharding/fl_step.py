"""The distributed FL round step: Algorithm 1 on the production mesh.

Mapping (DESIGN.md §4): one cohort client per (pod×data) mesh coordinate;
``model`` axis = tensor parallelism (left to XLA auto-sharding).  The step
is a *partial-manual* ``jax.shard_map``: manual over the client axes, auto
over ``model``.

The per-(client, layer) aggregation of Eq. (5)-(7) is fused into a single
backward pass with two tricks validated in isolation:

1. **grad-scale**: ``gscale(x, c) = x·c + stop_grad(x·(1−c))`` has value
   ``x`` and gradient scaled by ``c``.  Applying it per layer to the
   (gathered) parameters with ``c = w_{i,l}`` makes client i's weight-
   gradient contribution exactly ``w_{i,l}·g_{i,l}``.
2. **differentiable ZeRO-3 gather**: the frozen base is stored sharded over
   the client axes; ``all_gather`` inside the loss is differentiated to a
   ``psum_scatter`` — which *is* the Eq. (5) sum over clients, landing the
   aggregated update already in storage layout.

Selective-layer savings appear structurally: with ``upload_selected_only``
the backward collective runs over the selected sub-stack only (R/L of the
bytes — the paper's communication claim, visible in §Roofline).

τ > 1 local steps keep per-client copies of the *selected sub-stack only*
(the union set is static per selection period) — the frozen base stays
shared/sharded, which is what makes a 314B cohort member fit one v5e chip.
"""
from __future__ import annotations

import functools
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models.model import Model, layer_layout, split_mask
from repro.sharding import rules

PyTree = Any


def _shard_map(f, *, mesh, in_specs, out_specs, axis_names, check_vma):
    """``jax.shard_map`` compat: old jax exposes it under jax.experimental
    with ``check_rep``/``auto`` instead of ``check_vma``/``axis_names``.

    On old jax the partial-manual form (auto over 'model') trips an XLA
    SPMD-partitioner check (``IsManualSubgroup`` mismatch, observed on
    0.4.37 CPU), so the fallback goes FULLY manual: the model axis carries
    no spec members in the client-only in_specs, every model coordinate
    runs the same replicated per-client compute, and the client-axis psums
    are untouched — identical values, just no tensor parallelism."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, axis_names=axis_names,
                             check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as esm
    return esm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


def shard_cohort_rows(mesh, rows: PyTree) -> PyTree:
    """Place per-cohort-member rows on the mesh, leading (cohort) axis
    sharded over the client axes — the DESIGN.md §4 mapping (one cohort
    member per pod×data coordinate) applied to gathered client-state rows
    (warm-start masks, probe stats) so cohort size scales with the mesh.

    Rows whose cohort axis does not divide the client-axis extent are
    replicated instead (values unchanged either way, so the single-device
    path is bit-identical to the host gather).  Accepts a single array or
    any pytree of (cohort, ...) arrays.
    """
    caxes = rules.client_axes(mesh)
    n = int(np.prod([mesh.shape[a] for a in caxes])) if caxes else 1

    def place(x):
        x = jnp.asarray(x)
        spec = P(caxes) if x.ndim and n > 1 and x.shape[0] % n == 0 \
            else P(*([None] * x.ndim))
        return jax.device_put(x, NamedSharding(mesh, spec))

    return jax.tree.map(place, rows)


def gscale(x, c):
    """Value x, gradient scaled by c (c may broadcast)."""
    c = c.astype(x.dtype)
    return x * c + lax.stop_gradient(x * (1.0 - c))


def _client_mask_scales(mask_row: jnp.ndarray, d_i: jnp.ndarray,
                        caxes: Sequence[str]) -> jnp.ndarray:
    """Eq. (7): w_{i,l} for this shard's client, via a psum over the cohort."""
    dm = mask_row * d_i
    denom = lax.psum(dm, caxes)
    return jnp.where(denom > 0, dm / jnp.where(denom > 0, denom, 1.0), 0.0)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def _zgather(x, ax: int):
    """ZeRO-3 all-gather whose backward psum_scatters in f32.

    Two reasons: (a) Eq.(5)'s cohort sum should accumulate in f32 even for
    bf16 params; (b) XLA:CPU's AllReducePromotion pass crashes on the
    16-bit reduce-scatter jax would otherwise emit (observed on 0.8.2),
    while f32 collectives are handled fine.
    """
    return lax.all_gather(x, rules.DATA, axis=ax, tiled=True)


def _zgather_fwd(x, ax):
    return _zgather(x, ax), jnp.zeros((0,), x.dtype)   # dtype carrier


def _zgather_bwd(ax, dtype_carrier, ct):
    g = lax.psum_scatter(ct.astype(jnp.float32), rules.DATA,
                         scatter_dimension=ax, tiled=True)
    return (g.astype(dtype_carrier.dtype),)


_zgather.defvjp(_zgather_fwd, _zgather_bwd)


def _gather_leaf(x, spec: P, caxes: Sequence[str]):
    """All-gather the ZeRO-3 ('data') axis of a param leaf (differentiable)."""
    ax = rules.zero3_gather_axis(spec)
    if ax is None:
        return x
    return _zgather(x, ax)


def _residual_psum_axes(spec: P, caxes: Sequence[str]) -> tuple[str, ...]:
    """Client axes whose Eq.(5) sum is NOT covered by the gather backward.

    The ZeRO-3 all_gather differentiates to a psum_scatter over 'data' only;
    replicated leaves (and the 'pod' axis) need an explicit psum.
    """
    covered = {rules.DATA} if rules.zero3_gather_axis(spec) is not None else set()
    return tuple(a for a in caxes if a not in covered)


def _scale_tree(tree: PyTree, w: jnp.ndarray, cfg: ArchConfig,
                freeze_rest: bool, skip: tuple[str, ...] = ()) -> PyTree:
    """Apply gscale per selectable layer; freeze (stop_grad) other groups.

    Segments in ``skip`` are left untouched (the per-layer scan hook scales
    them inside the loop)."""
    parts = split_mask(w, cfg)
    out = {}
    for key, sub in tree.items():
        if key in skip:
            out[key] = sub
        elif key in parts:
            c = parts[key]
            if key == "shared_attn":
                out[key] = jax.tree.map(lambda x: gscale(x, c[0]), sub)
            else:
                out[key] = jax.tree.map(
                    lambda x: gscale(x, c.reshape((c.shape[0],) + (1,) *
                                                  (x.ndim - 1))), sub)
        elif freeze_rest:
            out[key] = jax.tree.map(lax.stop_gradient, sub)
        else:
            out[key] = sub
    return out


# Stacked segments whose ZeRO gather + Eq.(7) scaling happen per layer
# *inside* the scan (so at most one layer's full weights exist per device).
HOOKED_SEGMENTS = ("blocks", "enc_blocks")


def _model_only(spec: P, drop_lead: int = 0) -> P:
    """Keep only 'model' members of a spec (optionally dropping lead dims)."""
    out = []
    for e in list(spec)[drop_lead:]:
        names = e if isinstance(e, tuple) else (e,)
        kept = tuple(n for n in names if n == rules.MODEL)
        out.append(kept[0] if kept else None)
    return P(*out)


def make_fl_train_step(model: Model, mesh, *, zero3: bool = True,
                       freeze_nonlayers: bool = True,
                       window_override: Optional[int] = None,
                       sel_idx: Optional[tuple[int, ...]] = None):
    """Build the jit-able FL round step (τ=1, FedSGD semantics).

    Signature of the returned fn:
        step(params, batch, masks, sizes, lr) -> (new_params, metrics)
    with batch["tokens"]: (clients, per_client, seq) etc., masks: (clients, L),
    sizes: (clients,), all sharded over the client axes.

    §Perf levers (RuntimeConfig):
    * ``tp_constraints`` — constrain gathered params to their Megatron
      'model'-axis layout inside the manual region, so XLA tensor-parallelises
      the per-client compute instead of replicating it 16×.
    * ``sel_upload`` (+ static ``sel_idx``) — only the selected sub-stack's
      rows flow through the differentiable gather, so the Eq.(5) backward
      collective carries R/L of the bytes (the paper's upload saving, made
      structural).
    """
    cfg = model.cfg
    rt = model.runtime
    caxes = rules.client_axes(mesh)
    mesh_shape = {n: mesh.shape[n] for n in mesh.axis_names}
    tp_specs_cache = {}

    def _tp_constrain(p_full, skip=()):
        """Megatron layout hints on the model axis (auto region)."""
        if not tp_specs_cache:
            tp_specs_cache["specs"] = rules.params_pytree_specs(
                cfg, p_full, zero3=False, mesh_shape=mesh_shape)
        specs = tp_specs_cache["specs"]
        out = {}
        for key, sub in p_full.items():
            if key in skip:
                out[key] = sub
                continue
            out[key] = jax.tree.map(
                lambda x, s: lax.with_sharding_constraint(
                    x, jax.sharding.NamedSharding(mesh, s)),
                sub, specs[key], is_leaf=lambda x: isinstance(x, P))
        return out

    def step(params, param_specs, batch, masks, sizes, lr):
        mask_row = masks[0]                       # (L,) this client
        d_i = sizes[0]
        w = _client_mask_scales(mask_row, d_i, caxes)       # (L,)
        w_parts = split_mask(w, cfg)
        my_batch = jax.tree.map(lambda x: x[0], batch)

        hooked = tuple(k for k in HOOKED_SEGMENTS if k in params)

        def layer_hook(pl, idx, segment):
            """Per-layer ZeRO gather + Eq.(7) grad-scale, inside the scan."""
            if segment not in hooked:
                return pl
            c = w_parts[segment][idx]
            specs = param_specs[segment]
            out = {}
            for nm, xv in pl.items():
                ax = rules.zero3_gather_axis(specs[nm])
                if ax is not None:
                    xv = _zgather(xv, ax - 1)    # stacked L dim was sliced off
                if rt.tp_constraints:
                    # re-pin the Megatron 'model' layout: the manual gather
                    # above erases auto-sharding knowledge, and without it
                    # GSPMD replicates the layer compute across 'model'
                    mspec = _model_only(specs[nm], drop_lead=1)
                    xv = lax.with_sharding_constraint(
                        xv, jax.sharding.NamedSharding(mesh, mspec))
                out[nm] = gscale(xv, c)
            return out

        def gather_all(p, with_grad=True, skip=()):
            g = {}
            for key, sub in p.items():
                if key in skip:
                    g[key] = sub
                    continue
                g[key] = jax.tree.map(
                    lambda x, s: _gather_leaf(x, s, caxes), sub,
                    param_specs[key], is_leaf=lambda x: isinstance(x, P))
            return g if with_grad else jax.tree.map(lax.stop_gradient, g)

        if rt.sel_upload and sel_idx is not None:
            # Structural R/L upload: gradient (and its psum_scatter) flows
            # only through the selected rows of the block stack.
            sel = jnp.asarray(sel_idx, jnp.int32)

            def loss_fn(p):
                frozen_full = gather_all(p, with_grad=False)
                sel_rows = jax.tree.map(
                    lambda x, s: _gather_leaf(x, s, caxes),
                    jax.tree.map(lambda a: a[sel], p["blocks"]),
                    jax.tree.map(lambda s: s, param_specs["blocks"]),
                    is_leaf=lambda x: isinstance(x, P))
                blocks = jax.tree.map(
                    lambda full, r: full.at[sel].set(r),
                    frozen_full["blocks"], sel_rows)
                p_full = {**frozen_full, "blocks": blocks}
                if rt.tp_constraints:
                    p_full = _tp_constrain(p_full)
                p_eff = _scale_tree(p_full, w, cfg, freeze_nonlayers)
                return model.loss(p_eff, my_batch,
                                  window_override=window_override)
        else:
            def loss_fn(p):
                # stacked block segments stay sharded here — the per-layer
                # scan hook gathers + scales them one layer at a time
                p_full = gather_all(p, skip=hooked)
                if rt.tp_constraints:
                    p_full = _tp_constrain(p_full, skip=hooked)
                p_eff = _scale_tree(p_full, w, cfg, freeze_nonlayers,
                                    skip=hooked)
                return model.loss(p_eff, my_batch,
                                  window_override=window_override,
                                  layer_hook=layer_hook)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        # Eq. (5) cohort sum: the ZeRO-3 gather backward psum_scatters over
        # 'data'; any remaining client axes (replicated leaves, 'pod') get an
        # explicit psum.  Contributions are already w_{i,l}-scaled.
        def _cohort_sum(g, s):
            ra = _residual_psum_axes(s, caxes)
            if not ra:
                return g
            # f32 psum: accuracy + XLA:CPU 16-bit all-reduce promotion bug
            return lax.psum(g.astype(jnp.float32), ra)

        if rt.sel_upload and sel_idx is not None:
            # replicated-storage upload saving: psum only the R selected
            # rows of the block stack (grads are zero elsewhere), the
            # paper's R/L communication claim made structural.
            sel = jnp.asarray(sel_idx, jnp.int32)

            def _sel_sum(g, s):
                ra = _residual_psum_axes(s, caxes)
                if not ra:
                    return g
                rows = lax.psum(g[sel].astype(jnp.float32), ra)
                return jnp.zeros(g.shape, rows.dtype).at[sel].set(rows)

            gb = {k: jax.tree.map(_sel_sum, grads[k], param_specs[k],
                                  is_leaf=lambda x: isinstance(x, P))
                  for k in grads if k == "blocks"}
            rest = {k: jax.tree.map(_cohort_sum, grads[k], param_specs[k],
                                    is_leaf=lambda x: isinstance(x, P))
                    for k in grads if k != "blocks"}
            grads = {**rest, **gb}
        else:
            grads = jax.tree.map(_cohort_sum, grads, param_specs,
                                 is_leaf=lambda x: isinstance(x, P))
        # Eq. (6): θ ← θ − η Δ   (Δ = Σ_i w_il g_il, masked by construction)
        new_params = jax.tree.map(
            lambda pp, g: (pp - lr * g.astype(jnp.float32)).astype(pp.dtype),
            params, grads)
        mean_loss = lax.pmean(loss, caxes)
        union = lax.psum(mask_row, caxes) > 0
        metrics = {"loss": mean_loss,
                   "union_frac": jnp.mean(union.astype(jnp.float32))}
        return new_params, metrics

    def build(params_or_shapes):
        """Return (jitted_fn, in_shardings, out_shardings) for this arch."""
        specs = rules.params_pytree_specs(cfg, params_or_shapes,
                                          zero3=zero3, mesh_shape=mesh_shape)
        # shard_map in_specs: client axes only (model axis stays auto);
        # tuple entries like ('model','data') keep only the client member
        def manual_only(s: P) -> P:
            out = []
            for e in s:
                names = e if isinstance(e, tuple) else (e,)
                kept = tuple(n for n in names if n in caxes)
                out.append(kept[0] if kept else None)
            return P(*out)

        p_manual = jax.tree.map(manual_only, specs,
                                is_leaf=lambda x: isinstance(x, P))
        cl = P(caxes)
        b_spec = P(caxes)        # shard only the leading (clients,) dim

        smapped = _shard_map(
            lambda p, b, m, sz, lr_: step(p, specs, b, m, sz, lr_),
            mesh=mesh,
            in_specs=(p_manual,
                      jax.tree.map(lambda _: b_spec, _batch_template(cfg)),
                      P(caxes, None), cl, P()),
            out_specs=(p_manual, {"loss": P(), "union_frac": P()}),
            axis_names=set(caxes),
            check_vma=False,
        )
        in_sh = (rules.named(mesh, specs),
                 jax.tree.map(lambda _: NamedSharding(mesh, b_spec),
                              _batch_template(cfg)),
                 NamedSharding(mesh, P(caxes, None)),
                 NamedSharding(mesh, cl),
                 NamedSharding(mesh, P()))
        out_sh = (rules.named(mesh, specs),
                  {"loss": NamedSharding(mesh, P()),
                   "union_frac": NamedSharding(mesh, P())})
        return jax.jit(smapped, in_shardings=in_sh, out_shardings=out_sh), specs

    return build


def make_fl_train_step_tau(model: Model, mesh, *, sel_idx: tuple[int, ...],
                           tau: int, zero3: bool = True,
                           window_override: Optional[int] = None):
    """τ>1 local steps (Eq. 3-4, Theorem A.2) on the production mesh.

    Memory model = the paper's: each client holds *local copies of the
    selected sub-stack only* (R rows, gathered once per round); the frozen
    base stays ZeRO-sharded and is re-gathered per layer with stop_gradient
    — so local backward passes run **collective-free**, and the only
    cross-client traffic is the Eq.(5) upload of R rows (w-weighted
    psum_scatter back into storage layout).

    Returned fn: step(params, batch, masks, sizes, lr) with batch leaves
    shaped (clients, tau, per_client, ...), masks (clients, L).
    """
    cfg = model.cfg
    rt = model.runtime
    caxes = rules.client_axes(mesh)
    mesh_shape = {n: mesh.shape[n] for n in mesh.axis_names}
    sel_arr = np.asarray(sel_idx, np.int32)

    def step(params, param_specs, batch, masks, sizes, lr):
        mask_row = masks[0]
        d_i = sizes[0]
        w = _client_mask_scales(mask_row, d_i, caxes)           # (L,)
        w_parts = split_mask(w, cfg)
        mask_parts = split_mask(mask_row, cfg)
        my_batch = jax.tree.map(lambda x: x[0], batch)          # (tau, ...)
        sel = jnp.asarray(sel_arr)
        blocks_specs = param_specs["blocks"]

        def gather_rows(blocks):
            """Selected rows, gathered to full width (differentiable).

            Rows keep their leading (R,) dim, so the gather axis is the
            same index as in the stacked spec."""
            out = {}
            for nm, xv in blocks.items():
                ax = rules.zero3_gather_axis(blocks_specs[nm])
                rows = xv[sel]
                if ax is not None:
                    rows = _zgather(rows, ax)
                if rt.tp_constraints:
                    mspec = _model_only(blocks_specs[nm], drop_lead=0)
                    rows = lax.with_sharding_constraint(
                        rows, jax.sharding.NamedSharding(mesh, mspec))
                out[nm] = rows
            return out

        sel_rows0 = jax.tree.map(lax.stop_gradient,
                                 gather_rows(params["blocks"]))

        # frozen groups: gathered once, stop-grad
        others = {k: v for k, v in params.items() if k != "blocks"}
        others_full = {}
        for key, sub in others.items():
            others_full[key] = jax.tree.map(
                lambda x, s: lax.stop_gradient(_gather_leaf(x, s, caxes)),
                sub, param_specs[key], is_leaf=lambda x: isinstance(x, P))

        def layer_hook_for(local_rows):
            def hook(pl, idx, segment):
                if segment != "blocks":
                    return pl
                slot = jnp.clip(jnp.searchsorted(sel, idx), 0, sel.shape[0] - 1)
                is_sel = sel[slot] == idx
                out = {}
                for nm, xv in pl.items():
                    ax = rules.zero3_gather_axis(blocks_specs[nm])
                    stale = xv
                    if ax is not None:
                        stale = _zgather(stale, ax - 1)
                    stale = lax.stop_gradient(stale)
                    if rt.tp_constraints:
                        mspec = _model_only(blocks_specs[nm], drop_lead=1)
                        stale = lax.with_sharding_constraint(
                            stale, jax.sharding.NamedSharding(mesh, mspec))
                    out[nm] = jnp.where(is_sel, local_rows[nm][slot], stale)
                return out
            return hook

        m_sel = mask_parts["blocks"][sel]                        # (R,)

        def local_step(rows, microbatch):
            def loss_fn(r):
                return model.loss(others_full | {"blocks": params["blocks"]},
                                  microbatch,
                                  window_override=window_override,
                                  layer_hook=layer_hook_for(r))
            loss, g = jax.value_and_grad(loss_fn)(rows)
            # Eq.(3): client updates only ITS selected layers
            new_rows = jax.tree.map(
                lambda r, gg: (r.astype(jnp.float32) - lr
                               * gg.astype(jnp.float32)
                               * m_sel.reshape((-1,) + (1,) * (r.ndim - 1))
                               ).astype(r.dtype),
                rows, g)
            return new_rows, loss

        rows_final, losses = lax.scan(local_step, sel_rows0, my_batch)

        # Eq.(4)/(5): Δ_i rows, w-weighted, psum_scattered back to storage
        w_sel = w_parts["blocks"][sel]
        new_blocks = {}
        for nm, xv in params["blocks"].items():
            delta = ((sel_rows0[nm] - rows_final[nm]).astype(jnp.float32)
                     / lr)                                        # Σ_k g
            delta = delta * w_sel.reshape((-1,) + (1,) * (delta.ndim - 1))
            ax = rules.zero3_gather_axis(blocks_specs[nm])
            if ax is not None:
                agg = lax.psum_scatter(delta, rules.DATA,
                                       scatter_dimension=ax, tiled=True)
            else:
                agg = lax.psum(delta, caxes)
            if ax is not None and len(caxes) > 1:   # 'pod' residual
                agg = lax.psum(agg, tuple(a for a in caxes if a != rules.DATA))
            new_blocks[nm] = xv.at[sel].add(
                (-lr * agg).astype(xv.dtype))

        new_params = {**params, "blocks": new_blocks}
        metrics = {"loss": lax.pmean(jnp.mean(losses), caxes),
                   "union_frac": jnp.mean(
                       (lax.psum(mask_row, caxes) > 0).astype(jnp.float32))}
        return new_params, metrics

    def build(params_or_shapes):
        specs = rules.params_pytree_specs(cfg, params_or_shapes,
                                          zero3=zero3, mesh_shape=mesh_shape)

        def manual_only(s: P) -> P:
            out = []
            for e in s:
                names = e if isinstance(e, tuple) else (e,)
                kept = tuple(n for n in names if n in caxes)
                out.append(kept[0] if kept else None)
            return P(*out)

        p_manual = jax.tree.map(manual_only, specs,
                                is_leaf=lambda x: isinstance(x, P))
        cl = P(caxes)
        b_spec = P(caxes)
        smapped = _shard_map(
            lambda p, b, m, sz, lr_: step(p, specs, b, m, sz, lr_),
            mesh=mesh,
            in_specs=(p_manual,
                      jax.tree.map(lambda _: b_spec, _batch_template(cfg)),
                      P(caxes, None), cl, P()),
            out_specs=(p_manual, {"loss": P(), "union_frac": P()}),
            axis_names=set(caxes),
            check_vma=False,
        )
        in_sh = (rules.named(mesh, specs),
                 jax.tree.map(lambda _: NamedSharding(mesh, b_spec),
                              _batch_template(cfg)),
                 NamedSharding(mesh, P(caxes, None)),
                 NamedSharding(mesh, cl),
                 NamedSharding(mesh, P()))
        out_sh = (rules.named(mesh, specs),
                  {"loss": NamedSharding(mesh, P()),
                   "union_frac": NamedSharding(mesh, P())})
        return jax.jit(smapped, in_shardings=in_sh, out_shardings=out_sh), specs

    return build


def _batch_template(cfg: ArchConfig) -> dict:
    """Structure-only template of the training batch for spec mapping."""
    t = {"tokens": 0}
    if cfg.family == "vlm":
        t["patches"] = 0
        if cfg.task == "classification":
            t = {"patches": 0, "label": 0}
    elif cfg.family == "audio":
        t["frames"] = 0
    elif cfg.task == "classification":
        t["label"] = 0
    return t
