"""Roofline-term extraction from compiled HLO.

``cost_analysis()`` gives FLOPs and HBM bytes; collective traffic is not in
there, so we parse the optimized HLO text and sum operand sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

PEAK_FLOPS = 197e12       # bf16 per chip
HBM_BW = 819e9            # bytes/s per chip
ICI_BW = 50e9             # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# shapes like  bf16[2,4096,128]  or tuple elements; capture dtype + dims
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\][^ ]*))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclass
class CollectiveStats:
    bytes_by_kind: dict = field(default_factory=dict)
    count_by_kind: dict = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def collective_stats(hlo_text: str) -> CollectiveStats:
    """Sum *operand* bytes of collective ops in optimized HLO text.

    Operand shapes appear inline in optimized dumps:
      %ag = bf16[16,128]{1,0} all-gather(bf16[2,128]{1,0} %p), ...
    For ops whose operands are not annotated we fall back to output size.
    """
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        kind = m.group(2)
        # operand section = inside the first (...) after the op name
        start = line.index(m.group(2) + "(") if m.group(2) + "(" in line else -1
        if start >= 0:
            rest = line[start + len(kind) + 1:]
            depth = 1
            out = []
            for ch in rest:
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        break
                out.append(ch)
            operand_text = "".join(out)
        else:
            operand_text = ""
        nbytes = _shape_bytes(operand_text)
        if nbytes == 0:
            nbytes = _shape_bytes(m.group(1))     # fall back to output shape
        stats.bytes_by_kind[kind] = stats.bytes_by_kind.get(kind, 0) + nbytes
        stats.count_by_kind[kind] = stats.count_by_kind.get(kind, 0) + 1
    return stats


def roofline_terms(flops: float, hbm_bytes: float, coll_bytes: float,
                   n_chips: int) -> dict:
    """The three per-step roofline terms, in seconds."""
    return {
        "compute_s": flops / (n_chips * PEAK_FLOPS),
        "memory_s": hbm_bytes / (n_chips * HBM_BW),
        "collective_s": coll_bytes / (n_chips * ICI_BW),
    }


def dominant_term(terms: dict) -> str:
    return max(("compute_s", "memory_s", "collective_s"),
               key=lambda k: terms[k]).replace("_s", "")
