"""Roofline-term extraction from compiled HLO.

``cost_analysis()`` gives FLOPs and HBM bytes; collective traffic is not in
there, so we parse the optimized HLO text and sum operand sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

from repro.analysis.costmodel import shape_bytes as _shape_bytes

PEAK_FLOPS = 197e12       # bf16 per chip
HBM_BW = 819e9            # bytes/s per chip
ICI_BW = 50e9             # bytes/s per link

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\][^ ]*))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")


@dataclass
class CollectiveStats:
    bytes_by_kind: dict = field(default_factory=dict)
    count_by_kind: dict = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def collective_stats(hlo_text: str) -> CollectiveStats:
    """Sum *operand* bytes of collective ops in optimized HLO text.

    Operand shapes appear inline in optimized dumps:
      %ag = bf16[16,128]{1,0} all-gather(bf16[2,128]{1,0} %p), ...
    For ops whose operands are not annotated we fall back to output size.
    """
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        kind = m.group(2)
        # operand section = inside the first (...) after the op name
        start = line.index(m.group(2) + "(") if m.group(2) + "(" in line else -1
        if start >= 0:
            rest = line[start + len(kind) + 1:]
            depth = 1
            out = []
            for ch in rest:
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        break
                out.append(ch)
            operand_text = "".join(out)
        else:
            operand_text = ""
        nbytes = _shape_bytes(operand_text)
        if nbytes == 0:
            nbytes = _shape_bytes(m.group(1))     # fall back to output shape
        stats.bytes_by_kind[kind] = stats.bytes_by_kind.get(kind, 0) + nbytes
        stats.count_by_kind[kind] = stats.count_by_kind.get(kind, 0) + 1
    return stats


def roofline_terms(flops: float, hbm_bytes: float, coll_bytes: float,
                   n_chips: int) -> dict:
    """The three per-step roofline terms, in seconds."""
    return {
        "compute_s": flops / (n_chips * PEAK_FLOPS),
        "memory_s": hbm_bytes / (n_chips * HBM_BW),
        "collective_s": coll_bytes / (n_chips * ICI_BW),
    }


def dominant_term(terms: dict) -> str:
    return max(("compute_s", "memory_s", "collective_s"),
               key=lambda k: terms[k]).replace("_s", "")
