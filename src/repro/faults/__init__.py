"""Deterministic fault injection + the degradation contracts (DESIGN.md §12).

``repro.faults`` is the chaos seam of the round engines and the serve
loop: a :class:`FaultPlan` declares *rates* for each fault class, a
:class:`FaultInjector` turns them into concrete per-round draws from its
own seeded rng streams, and the engines consult the injector at fixed
sites (client death after sampling, delta corruption before aggregation,
solver stalls, dispatch failures, checkpoint corruption, serve-side
upload/slot failures).  ``Experiment(faults=...)`` wires it in.

Wired-but-disabled injectors are contractually free: every hook
short-circuits before touching an rng, so a run with
``FaultPlan(enabled=False)`` is bit-identical to one with no injector at
all (tests/test_faults.py, BENCH_fault_overhead.json).
"""
from repro.faults.injector import (CORRUPT_CODES,  # noqa: F401
                                   FaultInjector, FaultPlan, TransientFault)

__all__ = ["CORRUPT_CODES", "FaultInjector", "FaultPlan", "TransientFault"]
