"""FaultPlan / FaultInjector: seeded, replayable fault schedules.

Determinism by construction: every hook draws from a RandomState derived
from ``(plan.seed, site, round)`` — never from one shared sequential
stream — so the schedule a seed produces is independent of call order,
engine, pipeline depth, and how many *other* fault classes are enabled.
Two runs with the same plan see byte-identical faults (acceptance (b) in
tests/test_faults.py), and the PR 8 determinism contract holds: the
injector is the module's only entropy source and it is fully seeded.

Fault sites and who consults them:

==================  =====================================================
site                consumer
==================  =====================================================
client death        ``FLServer._update_round_faulty`` — survivors mask
delta corruption    same round step — NaN/Inf/exploding delta rows
solver stall        ``FLServer.select_round`` — warm/greedy fallback
dispatch failure    ``FLServer._dispatch`` — bounded retry w/ backoff
ckpt corruption     ``FLServer.save_state`` — truncate/bitflip/manifest
delta upload        ``serve.DeltaOverlay`` — bounded per-entry retry
slot failure        ``launch.SlotServer`` — free + requeue, bounded
==================  =====================================================

The injector mutates nothing it observes: it returns masks/codes/bools
and raises :class:`TransientFault`; all degradation policy lives with the
consumers.
"""
from __future__ import annotations

import math
import os
from dataclasses import dataclass
from typing import Optional

import numpy as np

# int32 per-row corruption codes consumed by the guarded round program
# (runtime data — one compiled program serves every pattern)
CORRUPT_CODES = {"clean": 0, "nan": 1, "inf": 2, "explode": 3}

CKPT_CORRUPT_KINDS = ("truncate", "bitflip", "manifest")

# per-site stream ids (see _rng): distinct primes-multiplied lanes so no
# two sites ever alias onto the same derived seed for the same round
_SITE_DEATH = 1
_SITE_CORRUPT = 2
_SITE_STALL = 3
_SITE_DISPATCH = 4
_SITE_CKPT = 5
_SITE_UPLOAD = 6
_SITE_SLOT = 7


class TransientFault(RuntimeError):
    """An injected, retry-able failure (dispatch/upload).  The engines
    retry *only* this type — real bugs propagate unswallowed."""


@dataclass(frozen=True)
class FaultPlan:
    """Declarative fault schedule; all rates in [0, 1].

    ``enabled=False`` keeps the injector wired but contractually inert:
    every hook returns its no-fault answer without touching an rng, so
    the run is bit-identical to an injector-free one.
    """

    seed: int = 0
    enabled: bool = True
    # -- mid-round client death (after sampling, before reporting) -------
    death_rate: float = 0.0
    # -- reported-delta corruption ---------------------------------------
    corrupt_rate: float = 0.0
    corrupt_kinds: tuple = ("nan", "inf", "explode")
    explode_scale: float = 1e30
    # finite-guard norm threshold: rows whose masked Δ sq-norm exceeds it
    # are quarantined even when finite (inf = non-finite rows only)
    max_delta_sq: float = math.inf
    # -- host solver stalls ----------------------------------------------
    stall_rate: float = 0.0
    # -- round dispatch failures -----------------------------------------
    dispatch_fail_rate: float = 0.0
    dispatch_fail_count: int = 1          # consecutive failures per event
    max_dispatch_retries: int = 3
    retry_backoff_s: float = 0.0          # 0 = immediate retry (tests)
    # -- checkpoint corruption -------------------------------------------
    ckpt_corrupt_rate: float = 0.0
    ckpt_corrupt_kind: str = "truncate"   # truncate | bitflip | manifest
    # -- serve side ------------------------------------------------------
    upload_fail_rate: float = 0.0
    slot_fault_rate: float = 0.0

    def __post_init__(self):
        for name in ("death_rate", "corrupt_rate", "stall_rate",
                     "dispatch_fail_rate", "ckpt_corrupt_rate",
                     "upload_fail_rate", "slot_fault_rate"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {v!r}")
        unknown = set(self.corrupt_kinds) - (set(CORRUPT_CODES) - {"clean"})
        if unknown:
            raise ValueError(
                f"corrupt_kinds {sorted(unknown)} unknown; choose from "
                f"{sorted(set(CORRUPT_CODES) - {'clean'})}")
        if not self.corrupt_kinds and self.corrupt_rate > 0:
            raise ValueError("corrupt_rate > 0 needs at least one kind in "
                             "corrupt_kinds")
        if self.ckpt_corrupt_kind not in CKPT_CORRUPT_KINDS:
            raise ValueError(
                f"ckpt_corrupt_kind must be one of {CKPT_CORRUPT_KINDS}, "
                f"got {self.ckpt_corrupt_kind!r}")
        if self.max_dispatch_retries < 0:
            raise ValueError("max_dispatch_retries must be >= 0")
        if self.dispatch_fail_count < 1:
            raise ValueError("dispatch_fail_count must be >= 1")
        if self.explode_scale <= 0 or not math.isfinite(self.explode_scale):
            raise ValueError("explode_scale must be finite and > 0")


class FaultInjector:
    """Concrete fault draws for a :class:`FaultPlan`.

    Stateless between hooks except for the telemetry ``stats`` dict —
    every draw re-derives its stream from (seed, site, round), so the
    schedule replays identically regardless of execution interleaving.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.stats = {"dead_clients": 0, "corrupted_rows": 0, "stalls": 0,
                      "dispatch_faults": 0, "ckpt_corruptions": 0,
                      "upload_faults": 0, "slot_faults": 0}

    @property
    def enabled(self) -> bool:
        return self.plan.enabled

    def _rng(self, site: int, t: int) -> np.random.RandomState:
        # one independent lane per (site, round): draws never depend on
        # how many draws other sites/rounds made before this one
        return np.random.RandomState(
            (self.plan.seed * 1_000_003 + site * 7_919 + t) % (2**31 - 1))

    # -- round-step faults ------------------------------------------------
    def round_faults(self, t: int, n: int) -> tuple[np.ndarray, np.ndarray]:
        """Per-row ``(survivors f32 (n,), corruption codes int32 (n,))``
        for the round-``t`` cohort: 1/0 alive mask (client death strikes
        after sampling, before the update is reported) and a
        :data:`CORRUPT_CODES` entry per reported delta row."""
        p = self.plan
        survivors = np.ones(n, np.float32)
        codes = np.zeros(n, np.int32)
        if not p.enabled:
            return survivors, codes
        if p.death_rate > 0:
            dead = self._rng(_SITE_DEATH, t).random_sample(n) < p.death_rate
            survivors[dead] = 0.0
            self.stats["dead_clients"] += int(dead.sum())
        if p.corrupt_rate > 0:
            rng = self._rng(_SITE_CORRUPT, t)
            hit = rng.random_sample(n) < p.corrupt_rate
            kinds = rng.randint(0, len(p.corrupt_kinds), n)
            for i in np.flatnonzero(hit):
                codes[i] = CORRUPT_CODES[p.corrupt_kinds[kinds[i]]]
            self.stats["corrupted_rows"] += int(hit.sum())
        return survivors, codes

    def solver_stalls(self, t: int) -> bool:
        """Does the round-``t`` host solve stall past its deadline?"""
        p = self.plan
        if not p.enabled or p.stall_rate <= 0:
            return False
        stalled = bool(self._rng(_SITE_STALL, t).random_sample()
                       < p.stall_rate)
        if stalled:
            self.stats["stalls"] += 1
        return stalled

    # -- dispatch faults --------------------------------------------------
    def dispatch_failures(self, t: int) -> int:
        """How many consecutive dispatch attempts fail for round ``t``."""
        p = self.plan
        if not p.enabled or p.dispatch_fail_rate <= 0:
            return 0
        if self._rng(_SITE_DISPATCH, t).random_sample() \
                < p.dispatch_fail_rate:
            return p.dispatch_fail_count
        return 0

    def maybe_fail_dispatch(self, t: int, attempt: int) -> None:
        """Raise :class:`TransientFault` while ``attempt`` is still inside
        the round's injected failure run (attempts count from 0)."""
        if attempt < self.dispatch_failures(t):
            self.stats["dispatch_faults"] += 1
            raise TransientFault(
                f"injected dispatch failure (round {t}, attempt {attempt})")

    # -- checkpoint faults ------------------------------------------------
    def maybe_corrupt_checkpoint(self, path: str, t: int) -> bool:
        """Corrupt the just-written checkpoint at ``path`` (post-save, so
        the write itself succeeded — this models media/torn-write damage
        discovered only at restore time)."""
        p = self.plan
        if not p.enabled or p.ckpt_corrupt_rate <= 0:
            return False
        if self._rng(_SITE_CKPT, t).random_sample() >= p.ckpt_corrupt_rate:
            return False
        self.corrupt_checkpoint_dir(path, p.ckpt_corrupt_kind)
        self.stats["ckpt_corruptions"] += 1
        return True

    @staticmethod
    def corrupt_checkpoint_dir(path: str, kind: str) -> None:
        """Damage one checkpoint ``step_*/`` dir in a detectable-on-restore
        way.  ``truncate`` halves ``arrays.npz`` (torn write), ``bitflip``
        XORs a mid-archive byte (media decay — caught by the per-array
        checksums), ``manifest`` overwrites ``manifest.json`` with junk."""
        if kind not in CKPT_CORRUPT_KINDS:
            raise ValueError(f"unknown checkpoint corruption {kind!r}")
        arrays = os.path.join(path, "arrays.npz")
        if kind == "manifest":
            with open(os.path.join(path, "manifest.json"), "w") as f:
                f.write("{this is not json")
            return
        size = os.path.getsize(arrays)
        if kind == "truncate":
            with open(arrays, "r+b") as f:
                f.truncate(size // 2)
            return
        with open(arrays, "r+b") as f:      # bitflip
            f.seek(size // 2)
            b = f.read(1)
            f.seek(size // 2)
            f.write(bytes([b[0] ^ 0xFF]))

    # -- serve-side faults ------------------------------------------------
    def maybe_fail_upload(self, seq: int) -> None:
        """Raise :class:`TransientFault` for overlay entry-write ``seq``
        (a monotone per-overlay counter stands in for the round index)."""
        p = self.plan
        if not p.enabled or p.upload_fail_rate <= 0:
            return
        if self._rng(_SITE_UPLOAD, seq).random_sample() < p.upload_fail_rate:
            self.stats["upload_faults"] += 1
            raise TransientFault(f"injected delta-upload failure (#{seq})")

    def slot_faults(self, step: int, n_slots: int) -> np.ndarray:
        """(n_slots,) bool: decode slots struck at serve step ``step``."""
        p = self.plan
        if not p.enabled or p.slot_fault_rate <= 0:
            return np.zeros(n_slots, bool)
        hit = (self._rng(_SITE_SLOT, step).random_sample(n_slots)
               < p.slot_fault_rate)
        self.stats["slot_faults"] += int(hit.sum())  # repro: allow[host-sync] -- host np fault draw, no device value
        return hit


def coerce_injector(faults) -> Optional[FaultInjector]:
    """None | FaultPlan | FaultInjector → Optional[FaultInjector]."""
    if faults is None or isinstance(faults, FaultInjector):
        return faults
    if isinstance(faults, FaultPlan):
        return FaultInjector(faults)
    raise TypeError(
        f"faults must be a FaultPlan or FaultInjector, got {type(faults)}")


__all__ = ["CORRUPT_CODES", "CKPT_CORRUPT_KINDS", "FaultInjector",
           "FaultPlan", "TransientFault", "coerce_injector"]
