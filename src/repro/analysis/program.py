"""Program auditor: enumerate → lower → extract facts → gate (DESIGN.md §11).

The source linter (repro.analysis.engine) checks what the *code* says; this
module checks what the *compiled programs* do.  It enumerates every program
family the jit-suite cache can hold — the dense round step, all ≤L+1
masked-cut variants, the probe, the fused probe+update, the serve decode
programs (shared / delta / dense baseline) and the donated delta/bank
writes — lowers each on shape-only abstract inputs (nothing executes), and
extracts a :class:`repro.analysis.facts.ProgramFacts` row per program.

Two gates read the fact table:

* :mod:`repro.analysis.contracts` — version-robust invariants (FLOPs
  monotone in the cut, B-independent delta weight traffic, donation
  honored, dtype discipline, collective/transfer allowlist).
* the budget manifest ``experiments/bench/PROGRAM_BUDGETS.json`` — absolute
  per-program FLOPs/bytes/memory with per-metric tolerances, refreshed via
  ``python -m repro.analysis program --update-budgets`` and diffed in CI by
  the program-audit job and ``benchmarks/micro_ci.py``.

Audit configs are tiny ``reduced()`` variants (dense tinyllama + ssm
mamba2, plus a bf16 dense variant for the serve dtype contract) chosen so
block FLOPs dominate the loss head — the roofline crosscheck in
tests/test_hlo_cost.py depends on that.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Sequence

REPO_ROOT = os.path.join(os.path.dirname(__file__), "..", "..", "..")
DEFAULT_BUDGETS_PATH = os.path.normpath(os.path.join(
    REPO_ROOT, "experiments", "bench", "PROGRAM_BUDGETS.json"))

# Relative drift allowed per budget metric before the gate fails.  flops is
# the tight one (it is what the contracts reason about); the byte/memory
# models absorb more XLA-version noise (fusion decisions move fusion-
# boundary traffic and temp sizes without changing the program's math).
BUDGET_TOLERANCES = {
    "flops": 0.10,
    "hbm_bytes": 0.35,
    "weight_bytes": 0.10,
    "arg_bytes": 0.25,
    "temp_bytes": 0.60,
}
BUDGET_KEYS = tuple(BUDGET_TOLERANCES)


@dataclass
class ProgramSpec:
    """One auditable program: a jitted fn + its abstract inputs."""
    name: str
    fn: Callable
    args: tuple
    static_argnums: tuple = ()
    donate_argnums: tuple = ()
    weight_argnums: tuple = ()
    meta: dict = field(default_factory=dict)


def audit_models() -> list[tuple[str, Any, dict]]:
    """(label, Model, {train: bool, serve: bool}) triples for the audit.

    ``remat=False`` keeps the trained-layer cost at the paper's 3× forward
    (1 fwd + 2 bwd) — the ratio benchmarks/roofline.py's speedup model and
    the cut-monotonicity margins assume.
    """
    import dataclasses

    from repro.configs.base import RuntimeConfig, get_arch, reduced
    from repro.models.model import Model

    rt = RuntimeConfig(remat=False, seq_chunk=32, use_pallas=False)
    dense = reduced(get_arch("tinyllama_1_1b"), n_layers=4, d_model=64)
    ssm = reduced(get_arch("mamba2_370m"), n_layers=4, d_model=64)
    bf16 = dataclasses.replace(dense, dtype="bfloat16")
    return [
        ("dense", Model(dense, rt), {"train": True, "serve": True}),
        ("ssm", Model(ssm, rt), {"train": True, "serve": False}),
        ("dense_bf16", Model(bf16, rt), {"train": False, "serve": True}),
    ]


def enumerate_specs(models: Optional[list] = None) -> list[ProgramSpec]:
    """Every audited program across the audit configs, name-prefixed by
    config label (``dense/fl_step_masked/cut2``, ...)."""
    from repro.core.client import suite_program_specs
    from repro.serve.engine import serve_program_specs

    specs: list[ProgramSpec] = []
    for label, model, what in (models if models is not None
                               else audit_models()):
        rows: list[dict] = []
        if what.get("train"):
            rows += suite_program_specs(model)
        if what.get("serve"):
            rows += serve_program_specs(model)
        for r in rows:
            meta = dict(r["meta"], config=label)
            specs.append(ProgramSpec(
                name=f"{label}/{r['name']}", fn=r["fn"], args=tuple(r["args"]),
                static_argnums=tuple(r["static_argnums"]),
                donate_argnums=tuple(r["donate_argnums"]),
                weight_argnums=tuple(r["weight_argnums"]), meta=meta))
    return specs


def run_audit(specs: Optional[Sequence[ProgramSpec]] = None,
              progress: Optional[Callable[[str], None]] = None) -> dict:
    """Lower + extract facts for every spec.  Returns {name: ProgramFacts}."""
    from repro.analysis.facts import extract_facts

    if specs is None:
        specs = enumerate_specs()
    facts = {}
    for s in specs:
        if progress:
            progress(s.name)
        facts[s.name] = extract_facts(
            s.name, s.fn, s.args, static_argnums=s.static_argnums,
            donate_argnums=s.donate_argnums, weight_argnums=s.weight_argnums,
            meta=s.meta)
    return facts


# -- budget manifest ---------------------------------------------------------

def budgets_from_facts(facts: dict) -> dict:
    import jax
    return {
        "_meta": {
            "tolerances": BUDGET_TOLERANCES,
            "jax_version": jax.__version__,
            "refresh": "PYTHONPATH=src python -m repro.analysis program"
                       " --update-budgets",
        },
        "programs": {
            name: {k: getattr(f, k) for k in BUDGET_KEYS}
            for name, f in sorted(facts.items())},
    }


def load_budgets(path: str = DEFAULT_BUDGETS_PATH) -> Optional[dict]:
    if not os.path.exists(path):
        return None
    with open(path) as fh:
        return json.load(fh)


def save_budgets(facts: dict, path: str = DEFAULT_BUDGETS_PATH) -> dict:
    manifest = budgets_from_facts(facts)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as fh:
        json.dump(manifest, fh, indent=1, sort_keys=True)
        fh.write("\n")
    return manifest


def check_budgets(facts: dict, manifest: dict) -> list[str]:
    """Diff audited facts against the committed manifest.

    New/vanished programs are drift too: a program silently falling out of
    the audit is exactly the kind of regression the gate exists to catch.
    """
    failures: list[str] = []
    tols = dict(BUDGET_TOLERANCES,
                **manifest.get("_meta", {}).get("tolerances", {}))
    committed = manifest.get("programs", {})
    for name in sorted(set(facts) - set(committed)):
        failures.append(f"{name}: audited but missing from manifest "
                        f"(new program? run --update-budgets)")
    for name in sorted(set(committed) - set(facts)):
        failures.append(f"{name}: in manifest but no longer audited "
                        f"(vanished program? run --update-budgets)")
    for name in sorted(set(facts) & set(committed)):
        f = facts[name]
        for key, want in committed[name].items():
            have = getattr(f, key, None)
            if have is None:
                continue
            tol = tols.get(key, 0.25)
            base = max(abs(want), 1.0)
            drift = abs(have - want) / base
            if drift > tol:
                failures.append(
                    f"{name}: {key} drifted {drift:+.1%} beyond ±{tol:.0%} "
                    f"(budget {want:.3g}, audited {have:.3g})")
    return failures


def audit_report(facts: dict, violations, budget_failures) -> dict:
    """The machine-readable report ``python -m repro.analysis program
    --json`` emits (and the CI annotation step consumes)."""
    return {
        "programs": {n: f.to_dict() for n, f in sorted(facts.items())},
        "violations": [v.to_dict() for v in violations],
        "budget_failures": list(budget_failures),
        "ok": not violations and not budget_failures,
    }
