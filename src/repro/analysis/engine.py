"""Rule engine for the repo's static invariant linter (DESIGN.md §10).

The performance story of this repo rests on a handful of contracts that no
single test file owns — one jitted program per hot path, no host sync
inside the round/serve loops, deterministic rng sourcing, bit-identical
jnp fallbacks for every Pallas kernel.  ``repro.analysis`` encodes each
contract as an AST rule so violations surface at review time (``python -m
repro.analysis``) instead of as a regressed benchmark three PRs later.

This module is deliberately stdlib-only (``ast`` + ``re``): the lint CI
job and pre-commit use must not need jax installed.  The runtime
complement (transfer guard + retrace sentinel) lives in
:mod:`repro.analysis.strict` and imports jax lazily.

Suppression: a finding is silenced by a pragma on the offending line or
the line directly above it::

    x = float(loss)   # repro: allow[host-sync] -- round-boundary record

The ``-- reason`` tail is mandatory — a pragma without one does **not**
suppress and is itself reported (rule id ``pragma``), as is a pragma
naming an unknown rule.  Unused pragmas are currently tolerated (a fixed
site keeps its annotation until the next sweep removes it).
"""
from __future__ import annotations

import ast
import os
import re
from dataclasses import dataclass, field
from typing import Callable, Iterable, Optional

PRAGMA_RE = re.compile(
    r"#\s*repro:\s*allow\[([^\]]*)\]\s*(?:--\s*(\S.*))?\s*$")

# engine-level rule id for malformed pragmas (not one of the contract rules)
PRAGMA_RULE = "pragma"


@dataclass(frozen=True)
class Finding:
    """One rule violation at ``path:line`` (path repo-relative)."""
    path: str
    line: int
    rule: str
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line} {self.rule} {self.message}"


@dataclass(frozen=True)
class Pragma:
    line: int
    rules: tuple[str, ...]
    reason: Optional[str]


@dataclass
class SourceFile:
    """A parsed file plus its suppression pragmas."""
    path: str                      # absolute
    rel: str                       # repo-relative, '/'-separated
    text: str
    tree: ast.Module
    pragmas: dict[int, Pragma] = field(default_factory=dict)

    @classmethod
    def parse(cls, path: str, rel: str) -> "SourceFile":
        with open(path, encoding="utf-8") as fh:
            text = fh.read()
        sf = cls(path=path, rel=rel, text=text,
                 tree=ast.parse(text, filename=rel))
        for i, line in enumerate(text.splitlines(), start=1):
            m = PRAGMA_RE.search(line)
            if m:
                rules = tuple(r.strip() for r in m.group(1).split(",")
                              if r.strip())
                sf.pragmas[i] = Pragma(line=i, rules=rules,
                                       reason=m.group(2))
        return sf

    def allowed(self, rule: str, line: int) -> bool:
        """Is ``rule`` suppressed at ``line`` (same line or line above)?
        Only well-formed pragmas (with a reason) suppress."""
        for ln in (line, line - 1):
            p = self.pragmas.get(ln)
            if p is not None and p.reason and rule in p.rules:
                return True
        return False


@dataclass
class AnalysisConfig:
    """Knobs the rules read; tests override these to point at fixtures."""
    # jit-outside-cache: modules sanctioned to construct jitted callables
    # outside module scope (the shared jit-suite caches)
    jit_sanctioned: tuple[str, ...] = (
        "src/repro/core/client.py",
        "src/repro/serve/engine.py",
        "src/repro/sharding/",
    )
    # host-sync: hot-loop entry points, matched against "Class.method" /
    # bare function qualnames; reachability stops at the host-stage
    # boundary (the pipeline's plan/sample/checkpoint stages, which by
    # contract overlap the in-flight device program)
    hot_entry_points: tuple[str, ...] = (
        "RoundScheduler.run",
        "SlotServer.run",
    )
    host_stage_boundary: frozenset = frozenset({
        "plan_round", "sample_round", "save_state", "restore_state",
        "_next_barrier", "_print_round", "_is_ckpt_round",
        # the fault path materialises survivor/quarantine masks at the
        # round boundary by design (DESIGN.md §12)
        "_update_round_faulty",
    })
    # nondeterminism: round/selection/state code where PR 6's flat rng
    # streams are the only sanctioned entropy source
    nondet_scope: tuple[str, ...] = (
        "src/repro/core/", "src/repro/data/", "src/repro/api/",
        "src/repro/serve/", "src/repro/ckpt/", "src/repro/launch/",
        "src/repro/faults/",
    )
    # exception-swallow: failure-handling code where a silently swallowed
    # exception would defeat the degradation contracts (DESIGN.md §12) —
    # every except must re-raise, return a verdict, or do real recovery
    swallow_scope: tuple[str, ...] = (
        "src/repro/core/", "src/repro/ckpt/", "src/repro/serve/",
        "src/repro/faults/", "src/repro/launch/",
    )
    # kernel-parity: Pallas modules and where their contracts live
    kernel_dir: str = "src/repro/kernels/"
    kernel_exclude: tuple[str, ...] = ("ops.py", "ref.py", "__init__.py")
    kernel_tests: str = "tests/test_kernels.py"
    kernel_dispatch: str = "src/repro/kernels/ops.py"
    # donation-miss: where jit calls over params-sized trees must either
    # donate or carry a reasoned pragma, and the parameter names that mark
    # a params-sized tree argument
    donation_scope: tuple[str, ...] = (
        "src/repro/serve/", "src/repro/core/",
    )
    donation_tree_params: tuple[str, ...] = (
        "params", "stacked", "leaves", "cache", "bank", "state", "tree",
    )


class Context:
    """Shared analysis state: every scanned file + the project call graph."""

    def __init__(self, files: list[SourceFile], config: AnalysisConfig,
                 repo_root: str):
        self.files = files
        self.config = config
        self.repo_root = repo_root
        self.by_rel = {f.rel: f for f in files}
        self._callgraph = None

    @property
    def callgraph(self):
        if self._callgraph is None:
            from repro.analysis.callgraph import CallGraph
            self._callgraph = CallGraph.build(self.files)
        return self._callgraph

    def read_rel(self, rel: str) -> Optional[str]:
        """Source text of a repo-relative path — from the scanned set if
        present, else from disk (tests/ are not scanned but rules may need
        to look at them)."""
        sf = self.by_rel.get(rel)
        if sf is not None:
            return sf.text
        path = os.path.join(self.repo_root, rel)
        if os.path.exists(path):
            with open(path, encoding="utf-8") as fh:
                return fh.read()
        return None


# -- rule registry -----------------------------------------------------------

RULES: dict[str, "Rule"] = {}


@dataclass(frozen=True)
class Rule:
    name: str
    doc: str
    check: Callable[[SourceFile, Context], Iterable[Finding]]


def register_rule(name: str, doc: str):
    def deco(fn):
        RULES[name] = Rule(name=name, doc=doc, check=fn)
        return fn
    return deco


def _ensure_rules_loaded() -> None:
    if not RULES:
        from repro.analysis import rules as _rules  # noqa: F401


# -- runner ------------------------------------------------------------------

def collect_files(paths: list[str], repo_root: str) -> list[SourceFile]:
    out: list[SourceFile] = []
    for p in paths:
        ap = p if os.path.isabs(p) else os.path.join(repo_root, p)
        if os.path.isfile(ap):
            found = [ap]
        else:
            found = sorted(
                os.path.join(dp, fn)
                for dp, _, fns in os.walk(ap) for fn in fns
                if fn.endswith(".py"))
        for f in found:
            rel = os.path.relpath(f, repo_root).replace(os.sep, "/")
            out.append(SourceFile.parse(f, rel))
    return out


def pragma_findings(sf: SourceFile) -> list[Finding]:
    """Engine-level validation of the file's pragmas: a reason is
    mandatory, and every named rule must exist."""
    _ensure_rules_loaded()
    out = []
    for p in sf.pragmas.values():
        if not p.reason:
            out.append(Finding(
                sf.rel, p.line, PRAGMA_RULE,
                "allow[...] pragma is missing its ' -- reason' tail "
                "(reasonless suppressions are rejected)"))
        for r in p.rules:
            if r not in RULES:
                out.append(Finding(
                    sf.rel, p.line, PRAGMA_RULE,
                    f"pragma names unknown rule {r!r} "
                    f"(known: {', '.join(sorted(RULES))})"))
    return out


def run_files(files: list[SourceFile], repo_root: str,
              config: Optional[AnalysisConfig] = None,
              only: Optional[Iterable[str]] = None) -> list[Finding]:
    _ensure_rules_loaded()
    config = config or AnalysisConfig()
    ctx = Context(files, config, repo_root)
    names = list(only) if only else sorted(RULES)
    unknown = [n for n in names if n not in RULES]
    if unknown:
        raise ValueError(f"unknown rule(s) {unknown}; "
                         f"known: {sorted(RULES)}")
    findings: list[Finding] = []
    for sf in files:
        findings.extend(pragma_findings(sf))
        for name in names:
            for f in RULES[name].check(sf, ctx):
                if not sf.allowed(f.rule, f.line):
                    findings.append(f)
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings


def run_paths(paths: list[str], repo_root: Optional[str] = None,
              config: Optional[AnalysisConfig] = None,
              only: Optional[Iterable[str]] = None) -> list[Finding]:
    """Lint ``paths`` (files or directories); returns sorted findings."""
    root = repo_root or os.getcwd()
    return run_files(collect_files(paths, root), root, config, only)
