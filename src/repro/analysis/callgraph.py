"""Name-based project call graph for reachability rules.

The host-sync rule needs "functions reachable from the round/serve hot
loops".  Python's dynamism makes exact resolution impossible statically,
so this over-approximates the way review-time linters usually do: an edge
from function F to every function *named* like something F calls —
``self.probe_round(...)`` links to every ``def probe_round`` in the
scanned set, regardless of receiver type.  False edges make the rule
stricter (more sites need an explicit pragma), never looser, which is the
right failure mode for an invariant linter.

Reachability deliberately stops at the *host-stage boundary*
(``AnalysisConfig.host_stage_boundary``): plan/sample/checkpoint run on
the host by design, overlapped with the in-flight device program, so a
sync there costs nothing — the rule polices the dispatch segment only.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field


def _called_names(node: ast.AST) -> set[str]:
    """Last-segment names of everything ``node``'s body calls."""
    out: set[str] = set()
    for n in ast.walk(node):
        if isinstance(n, ast.Call):
            f = n.func
            if isinstance(f, ast.Name):
                out.add(f.id)
            elif isinstance(f, ast.Attribute):
                out.add(f.attr)
    return out


@dataclass
class FunctionInfo:
    rel: str                       # file (repo-relative)
    qualname: str                  # e.g. "RoundScheduler.run" or "main"
    name: str                      # last segment
    node: ast.AST
    calls: set[str] = field(default_factory=set)


class CallGraph:
    def __init__(self, functions: list[FunctionInfo]):
        self.functions = functions
        self.by_name: dict[str, list[FunctionInfo]] = {}
        for fn in functions:
            self.by_name.setdefault(fn.name, []).append(fn)

    @classmethod
    def build(cls, files) -> "CallGraph":
        funcs: list[FunctionInfo] = []

        def visit(node, stack, rel):
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.FunctionDef,
                                      ast.AsyncFunctionDef)):
                    qual = ".".join(stack + [child.name])
                    funcs.append(FunctionInfo(
                        rel=rel, qualname=qual, name=child.name,
                        node=child, calls=_called_names(child)))
                    visit(child, stack + [child.name], rel)
                elif isinstance(child, ast.ClassDef):
                    visit(child, stack + [child.name], rel)
                else:
                    visit(child, stack, rel)

        for sf in files:
            visit(sf.tree, [], sf.rel)
        return cls(funcs)

    def reachable(self, entry_points, boundary) -> list[FunctionInfo]:
        """Functions reachable from any entry point, not expanding through
        names in ``boundary``.  Entry points match on qualname suffix
        ("Class.method") or bare name."""
        seeds = [f for f in self.functions
                 if f.qualname in entry_points or f.name in entry_points]
        seen: set[int] = set()
        order: list[FunctionInfo] = []
        frontier = list(seeds)
        while frontier:
            fn = frontier.pop()
            if id(fn) in seen:
                continue
            seen.add(id(fn))
            order.append(fn)
            for cname in fn.calls:
                if cname in boundary:
                    continue
                for target in self.by_name.get(cname, ()):
                    if id(target) not in seen:
                        frontier.append(target)
        return order
