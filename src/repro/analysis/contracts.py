"""Program-level contracts over the audited fact table (DESIGN.md §11).

Each contract is a pure function ``facts -> [Violation]`` over the
``{name: ProgramFacts}`` table the auditor produced.  Unlike the budget
manifest (absolute numbers with tolerances, refreshed on intentional
change), contracts encode *relations* that hold across jax/XLA versions —
they are the load-bearing gate; the budgets catch the drift the relations
cannot see.

| contract                      | invariant (established by)                |
|-------------------------------|-------------------------------------------|
| cut-monotone                  | masked-cut FLOPs strictly decrease with   |
|                               | the cut; cut=L is forward-only (PR 5)     |
| delta-weight-traffic          | serve_decode_delta weight bytes are       |
|                               | B-independent and linear in capacity C;   |
|                               | the dense baseline scales with B (PR 7)   |
| donation-honored              | every declared-donated leaf is actually   |
|                               | aliased by XLA (PR 7)                     |
| dtype-discipline              | no f64 anywhere; bf16-configured decode   |
|                               | keeps its cache in bf16 (seed)            |
| collective-transfer-allowlist | single-host programs contain zero         |
|                               | collectives and zero host transfers;      |
|                               | sharded programs only mesh-declared       |
|                               | collective kinds (PR 4 / PR 8)            |
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List

# forward-only / full-training FLOPs ratio bound: theory says ≈(L+head)/(3L)
# ≈ 0.33 for block-dominated configs at remat=False; 0.6 leaves headroom
# for the loss head and XLA noise while still proving the backward is gone.
FORWARD_ONLY_MAX_FRAC = 0.6
# weight traffic equality across batch sizes is exact in the jaxpr model;
# the slack only covers float accounting.
B_INDEPENDENCE_RTOL = 0.005
C_LINEARITY_RTOL = 0.02
DENSE_SCALE_RTOL = 0.10


@dataclass
class Violation:
    contract: str
    program: str
    message: str

    def to_dict(self) -> dict:
        return {"contract": self.contract, "program": self.program,
                "message": self.message}


def _by_kind(facts: Dict, kind: str) -> list:
    return [f for f in facts.values() if f.meta.get("kind") == kind]


def _configs(rows) -> list:
    return sorted({f.meta.get("config", "?") for f in rows})


# -- 1. masked-cut FLOPs monotone, cut=L forward-only ------------------------

def check_cut_monotone(facts: Dict) -> List[Violation]:
    out: List[Violation] = []
    rows = _by_kind(facts, "fl_step_masked")
    for cfg in _configs(rows):
        series = sorted((f.meta["cut"], f) for f in rows
                        if f.meta.get("config") == cfg)
        if len(series) < 2:
            continue
        for (c0, f0), (c1, f1) in zip(series, series[1:]):
            if not f1.flops < f0.flops:
                out.append(Violation(
                    "cut-monotone", f1.name,
                    f"FLOPs not strictly decreasing in cut: cut={c1} has "
                    f"{f1.flops:.3g} >= cut={c0}'s {f0.flops:.3g}"))
        first_cut, first = series[0]
        last_cut, last = series[-1]
        L = last.meta.get("n_selectable")
        if first_cut == 0 and L is not None and last_cut == L:
            frac = last.flops / max(first.flops, 1.0)
            if frac > FORWARD_ONLY_MAX_FRAC:
                out.append(Violation(
                    "cut-monotone", last.name,
                    f"cut={last_cut} should be forward-only but costs "
                    f"{frac:.0%} of cut=0 (limit "
                    f"{FORWARD_ONLY_MAX_FRAC:.0%}) — backward not elided?"))
    return out


# -- 2. delta serve weight traffic: B-independent, C-linear ------------------

def _rel(a: float, b: float) -> float:
    return abs(a - b) / max(abs(a), abs(b), 1.0)


def check_delta_traffic(facts: Dict) -> List[Violation]:
    out: List[Violation] = []
    rows = _by_kind(facts, "serve_decode_delta")
    for cfg in _configs(rows):
        mine = [f for f in rows if f.meta.get("config") == cfg]
        # B-independence at every capacity
        caps = sorted({f.meta["capacity"] for f in mine})
        for C in caps:
            bs = sorted((f.meta["batch"], f) for f in mine
                        if f.meta["capacity"] == C)
            for (b0, f0), (b1, f1) in zip(bs, bs[1:]):
                if _rel(f0.weight_bytes, f1.weight_bytes) > B_INDEPENDENCE_RTOL:
                    out.append(Violation(
                        "delta-weight-traffic", f1.name,
                        f"weight bytes depend on batch: B={b0} reads "
                        f"{f0.weight_bytes:.3g}, B={b1} reads "
                        f"{f1.weight_bytes:.3g} (C={C})"))
        # C-linearity (equal increments, positive slope) at the first batch
        if len(caps) >= 3:
            b0 = min(f.meta["batch"] for f in mine)
            w = {f.meta["capacity"]: f.weight_bytes for f in mine
                 if f.meta["batch"] == b0}
            incs = [w[c1] - w[c0] for c0, c1 in zip(caps, caps[1:])]
            name = f"{cfg}/serve_decode_delta/B{b0}"
            if any(i <= 0 for i in incs):
                out.append(Violation(
                    "delta-weight-traffic", name,
                    f"weight bytes not increasing in capacity: {w}"))
            elif _rel(incs[0], incs[-1]) > C_LINEARITY_RTOL:
                out.append(Violation(
                    "delta-weight-traffic", name,
                    f"weight bytes not linear in capacity: increments "
                    f"{[f'{i:.3g}' for i in incs]}"))
    # contrast: the dense baseline MUST scale with B — if it stopped, the
    # provenance walk (and thus the B-independence above) proves nothing
    dense = _by_kind(facts, "serve_decode_dense")
    for cfg in _configs(dense):
        bs = sorted((f.meta["batch"], f) for f in dense
                    if f.meta.get("config") == cfg)
        for (b0, f0), (b1, f1) in zip(bs, bs[1:]):
            want = f0.weight_bytes * (b1 / b0)
            if _rel(f1.weight_bytes, want) > DENSE_SCALE_RTOL:
                out.append(Violation(
                    "delta-weight-traffic", f1.name,
                    f"dense baseline weight bytes should scale ~{b1}/{b0}x "
                    f"with batch, got {f0.weight_bytes:.3g} -> "
                    f"{f1.weight_bytes:.3g}"))
    return out


# -- 3. donation honored -----------------------------------------------------

def check_donation(facts: Dict) -> List[Violation]:
    out: List[Violation] = []
    for f in facts.values():
        if f.donated_declared == 0:
            continue
        if f.donation_applied < f.donated_declared:
            out.append(Violation(
                "donation-honored", f.name,
                f"{f.donated_declared} leaves declared donated but XLA "
                f"aliased only {f.donation_applied} — donated buffer is "
                f"silently copied"))
    return out


# -- 4. dtype discipline -----------------------------------------------------

def check_dtypes(facts: Dict) -> List[Violation]:
    out: List[Violation] = []
    for f in facts.values():
        if "float64" in f.jaxpr_dtypes or f.hlo_dtypes.get("f64"):
            out.append(Violation(
                "dtype-discipline", f.name,
                f"f64 present (jaxpr dtypes {f.jaxpr_dtypes}, hlo f64 "
                f"count {f.hlo_dtypes.get('f64', 0)}) — nothing in the "
                f"repo computes in double"))
        if (f.meta.get("dtype") == "bfloat16"
                and str(f.meta.get("kind", "")).startswith("serve_decode")):
            n_f32 = sum(1 for d in f.out_dtypes if d == "float32")
            if "bfloat16" not in f.out_dtypes or n_f32 > 1:
                out.append(Violation(
                    "dtype-discipline", f.name,
                    f"bf16-configured decode leaks f32: {n_f32} float32 "
                    f"outputs (cache must stay bfloat16; only the logits "
                    f"may widen)"))
    return out


# -- 5. collective / transfer allowlist --------------------------------------

def check_isolation(facts: Dict) -> List[Violation]:
    out: List[Violation] = []
    for f in facts.values():
        allowed = set(f.meta.get("allowed_collectives", ()))
        if f.meta.get("single_host"):
            if f.collective_counts:
                out.append(Violation(
                    "collective-transfer-allowlist", f.name,
                    f"single-host program contains collectives: "
                    f"{f.collective_counts}"))
            if f.transfer_ops:
                out.append(Violation(
                    "collective-transfer-allowlist", f.name,
                    f"host transfer ops inside compiled program: "
                    f"{f.transfer_ops}"))
        else:
            extra = set(f.collective_counts) - allowed
            if extra:
                out.append(Violation(
                    "collective-transfer-allowlist", f.name,
                    f"collective kinds {sorted(extra)} not in the "
                    f"mesh-declared allowlist {sorted(allowed)}"))
            if f.transfer_ops:
                out.append(Violation(
                    "collective-transfer-allowlist", f.name,
                    f"host transfer ops inside compiled program: "
                    f"{f.transfer_ops}"))
    return out


CONTRACTS = {
    "cut-monotone": check_cut_monotone,
    "delta-weight-traffic": check_delta_traffic,
    "donation-honored": check_donation,
    "dtype-discipline": check_dtypes,
    "collective-transfer-allowlist": check_isolation,
}


def check_all(facts: Dict) -> List[Violation]:
    out: List[Violation] = []
    for fn in CONTRACTS.values():
        out.extend(fn(facts))
    return out
