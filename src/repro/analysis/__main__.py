"""CLI: ``python -m repro.analysis [paths...] [--rule NAME ...]``.

Prints ``file:line rule message`` per finding and exits 1 if any exist.
Default paths are the repo's linted tree: ``src benchmarks examples``.
"""
from __future__ import annotations

import argparse
import os
import sys

from repro.analysis.engine import RULES, _ensure_rules_loaded, run_paths

DEFAULT_PATHS = ("src", "benchmarks", "examples")


def main(argv=None) -> int:
    _ensure_rules_loaded()
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static invariant linter (DESIGN.md §10).")
    ap.add_argument("paths", nargs="*", default=list(DEFAULT_PATHS),
                    help="files or directories (default: %(default)s)")
    ap.add_argument("--rule", action="append", dest="rules", metavar="NAME",
                    help="run only this rule (repeatable)")
    ap.add_argument("--root", default=os.getcwd(),
                    help="repo root for relative paths (default: cwd)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the registered rules and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for name in sorted(RULES):
            print(f"{name}: {RULES[name].doc}")
        return 0

    findings = run_paths(args.paths, repo_root=args.root, only=args.rules)
    for f in findings:
        print(f.format())
    if findings:
        print(f"{len(findings)} finding(s) across "
              f"{len({f.path for f in findings})} file(s)", file=sys.stderr)
    return 1 if findings else 0


if __name__ == "__main__":
    raise SystemExit(main())
