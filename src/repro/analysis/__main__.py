"""CLI: source linting and the program auditor.

    python -m repro.analysis [lint] [paths...] [--rule NAME ...] [--json]
    python -m repro.analysis program [--json] [--update-budgets]

``lint`` (the default, stdlib-only — the CI lint job runs it without jax)
prints ``file:line rule message`` per finding and exits 1 if any exist.
``program`` lowers every jit-suite program family on abstract inputs,
checks the DESIGN.md §11 contracts, and diffs the committed
``experiments/bench/PROGRAM_BUDGETS.json``; it needs jax (CPU is fine).
``--json`` emits a machine-readable report on stdout for either mode —
the CI jobs turn it into per-line GitHub annotations.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from repro.analysis.engine import RULES, _ensure_rules_loaded, run_paths

DEFAULT_PATHS = ("src", "benchmarks", "examples")


def lint_main(argv) -> int:
    _ensure_rules_loaded()
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis [lint]",
        description="Static invariant linter (DESIGN.md §10).")
    ap.add_argument("paths", nargs="*", default=list(DEFAULT_PATHS),
                    help="files or directories (default: %(default)s)")
    ap.add_argument("--rule", action="append", dest="rules", metavar="NAME",
                    help="run only this rule (repeatable)")
    ap.add_argument("--root", default=os.getcwd(),
                    help="repo root for relative paths (default: cwd)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the registered rules and exit")
    ap.add_argument("--json", action="store_true",
                    help="machine-readable findings on stdout")
    args = ap.parse_args(argv)

    if args.list_rules:
        for name in sorted(RULES):
            print(f"{name}: {RULES[name].doc}")
        return 0

    findings = run_paths(args.paths, repo_root=args.root, only=args.rules)
    if args.json:
        print(json.dumps({
            "findings": [{"path": f.path, "line": f.line, "rule": f.rule,
                          "message": f.message} for f in findings],
            "ok": not findings,
        }, indent=1))
        return 1 if findings else 0
    for f in findings:
        print(f.format())
    if findings:
        print(f"{len(findings)} finding(s) across "
              f"{len({f.path for f in findings})} file(s)", file=sys.stderr)
    return 1 if findings else 0


def program_main(argv) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis program",
        description="Program auditor: jaxpr/HLO contract checks + static "
                    "cost budgets (DESIGN.md §11).")
    ap.add_argument("--json", action="store_true",
                    help="machine-readable report on stdout")
    ap.add_argument("--update-budgets", action="store_true",
                    help="refresh the budget manifest from this audit "
                         "instead of diffing against it")
    ap.add_argument("--budgets", default=None, metavar="PATH",
                    help="budget manifest path (default: "
                         "experiments/bench/PROGRAM_BUDGETS.json)")
    args = ap.parse_args(argv)

    # jax only loads for the auditor — `lint` stays importable anywhere
    from repro.analysis import contracts as C
    from repro.analysis import program as P

    path = args.budgets or P.DEFAULT_BUDGETS_PATH
    progress = (None if args.json else
                (lambda n: print(f"  lowering {n}", file=sys.stderr)))
    facts = P.run_audit(progress=progress)
    violations = C.check_all(facts)

    budget_failures: list[str] = []
    if args.update_budgets:
        P.save_budgets(facts, path)
        print(f"wrote {len(facts)} program budgets to {path}",
              file=sys.stderr)
    else:
        manifest = P.load_budgets(path)
        if manifest is None:
            print(f"note: no budget manifest at {path} "
                  f"(run --update-budgets to create it); "
                  f"checking contracts only", file=sys.stderr)
        else:
            budget_failures = P.check_budgets(facts, manifest)

    if args.json:
        print(json.dumps(P.audit_report(facts, violations, budget_failures),
                         indent=1))
        return 1 if (violations or budget_failures) else 0

    for name, f in sorted(facts.items()):
        print(f"{name:44s} flops={f.flops:12.4g} hbm={f.hbm_bytes:12.4g} "
              f"weight={f.weight_bytes:10.4g} "
              f"donate={f.donation_applied}/{f.donated_declared}")
    for v in violations:
        print(f"CONTRACT {v.contract} :: {v.program}: {v.message}")
    for msg in budget_failures:
        print(f"BUDGET {msg}")
    n_bad = len(violations) + len(budget_failures)
    print(f"{len(facts)} programs audited, {len(violations)} contract "
          f"violation(s), {len(budget_failures)} budget failure(s)",
          file=sys.stderr)
    return 1 if n_bad else 0


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if argv and argv[0] == "program":
        return program_main(argv[1:])
    if argv and argv[0] == "lint":
        argv = argv[1:]
    return lint_main(argv)


if __name__ == "__main__":
    raise SystemExit(main())
