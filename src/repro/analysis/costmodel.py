"""Scan-aware cost analysis over optimized HLO text (the shared backend).

``compiled.cost_analysis()`` counts a ``while`` body **once**, but our whole
stack (layer scans, chunked attention, chunked CE, SSD) lowers to while
loops — undercounting FLOPs by ~L×.  This analyzer walks the computation
graph, multiplies loop bodies by their trip counts (recovered from the loop
condition's comparison constant), and produces:

* ``flops``           — dot/elementwise FLOPs, trip-count scaled
* ``hbm_bytes``       — fusion-boundary traffic model: operands+outputs of
  top-level ops (fusions count at their boundary — a reasonable proxy for
  materialised HBM traffic), trip-count scaled
* ``collective_bytes``— per-kind operand bytes of collectives, trip-count
  scaled (a collective inside the layer scan runs L times!)

Grown out of ``sharding/hlo_cost.py`` (which re-exports this module for
back-compat), it is now the ONE unrolled-cost backend shared by the
roofline dry-runs (launch/dryrun.py), the §Roofline/§Perf profiles, and
the program auditor (:mod:`repro.analysis.program`, DESIGN.md §11) — so
dry-run numbers and the CI-gated ``PROGRAM_BUDGETS.json`` agree by
construction.  The audit-facing extractors (:func:`transfer_op_counts`,
:func:`donation_aliases`, :func:`dtype_census`) live here too; the whole
module stays stdlib-only (``re`` + ``dataclasses``) so nothing below the
auditor needs jax.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                     "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\(?[^=]*?\)?)\s*"
                     r"([\w\-]+)\(")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?\s*->")
_ATTR_CALLS = re.compile(r"calls=%?([\w.\-]+)")
_ATTR_BODY = re.compile(r"body=%?([\w.\-]+)")
_ATTR_COND = re.compile(r"condition=%?([\w.\-]+)")
_ATTR_TO_APPLY = re.compile(r"to_apply=%?([\w.\-]+)")
_LHS_C = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CONST_INT = re.compile(r"constant\((\d+)\)")


def shape_bytes(type_text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def shape_elems(type_text: str) -> int:
    total = 0
    for _, dims in _SHAPE_RE.findall(type_text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n
    return total


@dataclass
class Op:
    name: str
    out_type: str
    opcode: str
    operands: tuple[str, ...]
    line: str


@dataclass
class Metrics:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: dict = field(default_factory=dict)
    coll_counts: dict = field(default_factory=dict)

    def add(self, other: "Metrics", times: float = 1.0):
        self.flops += times * other.flops
        self.hbm_bytes += times * other.hbm_bytes
        for k, v in other.coll_bytes.items():
            self.coll_bytes[k] = self.coll_bytes.get(k, 0) + times * v
        for k, v in other.coll_counts.items():
            self.coll_counts[k] = self.coll_counts.get(k, 0) + times * v

    @property
    def total_coll_bytes(self) -> float:
        return sum(self.coll_bytes.values())


_SKIP_BYTES = {"parameter", "constant", "get-tuple-element", "tuple",
               "bitcast", "after-all", "partition-id", "replica-id",
               "iota", "while", "call", "conditional", "custom-call"}


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.comps: dict[str, list[Op]] = {}
        self.shapes: dict[str, str] = {}
        self.entry: Optional[str] = None
        self._parse(hlo_text)
        self._memo: dict[str, Metrics] = {}

    # -- parsing -----------------------------------------------------------
    def _parse(self, text: str):
        cur = None
        comment = re.compile(r"/\*.*?\*/")
        for raw in text.splitlines():
            line = comment.sub("", raw.rstrip())
            if not line or line.startswith("HloModule"):
                continue
            if not line.startswith(" ") and ("->" in line) and line.endswith("{"):
                head = line.strip()
                is_entry = head.startswith("ENTRY")
                if is_entry:
                    head = head[len("ENTRY"):].strip()
                cur = head.split()[0].split("(")[0].lstrip("%")
                self.comps[cur] = []
                if is_entry:
                    self.entry = cur
                continue
            if line.strip() == "}":
                continue
            m = _DEF_RE.match(line)
            if m and cur is not None:
                name, out_type, opcode = m.group(1), m.group(2).strip(), m.group(3)
                operands = self._operand_names(line, opcode)
                self.comps[cur].append(Op(name, out_type, opcode, operands, line))
                self.shapes[name] = out_type

    @staticmethod
    def _operand_names(line: str, opcode: str) -> tuple[str, ...]:
        try:
            start = line.index(opcode + "(") + len(opcode) + 1
        except ValueError:
            return ()
        depth = 1
        buf = []
        for ch in line[start:]:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            buf.append(ch)
        inner = "".join(buf)
        return tuple(re.findall(r"%([\w.\-]+)", inner))

    # -- helpers -----------------------------------------------------------
    def _op_bytes(self, op: Op) -> int:
        total = shape_bytes(op.out_type)
        for o in op.operands:
            total += shape_bytes(self.shapes.get(o, ""))
        return total

    def _operand_bytes(self, op: Op) -> int:
        return sum(shape_bytes(self.shapes.get(o, "")) for o in op.operands)

    def _dot_flops(self, op: Op) -> float:
        out_elems = shape_elems(op.out_type)
        m = _LHS_C.search(op.line)
        contraction = 1
        if m and op.operands:
            lhs_shape = self.shapes.get(op.operands[0], "")
            sm = _SHAPE_RE.search(lhs_shape)
            if sm and sm.group(2):
                dims = [int(d) for d in sm.group(2).split(",")]
                for idx in (int(i) for i in m.group(1).split(",") if i):
                    if idx < len(dims):
                        contraction *= dims[idx]
        return 2.0 * out_elems * contraction

    def trip_count(self, cond_comp: str) -> int:
        """Max integer constant in the loop condition (jax scans compare
        the induction variable against the trip count)."""
        best = 1
        stack = [cond_comp]
        seen = set()
        while stack:
            c = stack.pop()
            if c in seen or c not in self.comps:
                continue
            seen.add(c)
            for op in self.comps[c]:
                for m in _CONST_INT.finditer(op.line):
                    best = max(best, int(m.group(1)))
                cm = _ATTR_CALLS.search(op.line)
                if cm:
                    stack.append(cm.group(1))
        return best

    # -- main recursion ------------------------------------------------------
    def metrics(self, comp: Optional[str] = None) -> Metrics:
        comp = comp or self.entry
        if comp in self._memo:
            return self._memo[comp]
        out = Metrics()
        self._memo[comp] = out            # guard (no recursion in valid HLO)
        for op in self.comps.get(comp, []):
            oc = op.opcode
            if oc == "while":
                body = _ATTR_BODY.search(op.line)
                cond = _ATTR_COND.search(op.line)
                trips = self.trip_count(cond.group(1)) if cond else 1
                if body:
                    out.add(self.metrics(body.group(1)), trips)
                if cond:
                    out.add(self.metrics(cond.group(1)), trips)
            elif oc == "fusion":
                called = _ATTR_CALLS.search(op.line)
                if called:
                    inner = self.metrics(called.group(1))
                    out.flops += inner.flops          # dots inside fusions
                # HBM model: fusion boundary traffic only
                out.hbm_bytes += self._op_bytes(op)
            elif oc in ("call", "conditional", "async-start"):
                for attr in (_ATTR_CALLS, _ATTR_BODY, _ATTR_TO_APPLY):
                    m = attr.search(op.line)
                    if m:
                        out.add(self.metrics(m.group(1)))
            elif oc == "dot":
                out.flops += self._dot_flops(op)
                out.hbm_bytes += self._op_bytes(op)
            elif oc == "convolution":
                out.flops += 2.0 * shape_elems(op.out_type) * 16  # coarse
                out.hbm_bytes += self._op_bytes(op)
            elif any(oc.startswith(k) for k in _COLLECTIVE_KINDS):
                kind = next(k for k in _COLLECTIVE_KINDS if oc.startswith(k))
                b = self._operand_bytes(op) or shape_bytes(op.out_type)
                out.coll_bytes[kind] = out.coll_bytes.get(kind, 0) + b
                out.coll_counts[kind] = out.coll_counts.get(kind, 0) + 1
                out.hbm_bytes += self._op_bytes(op)
            elif oc in _SKIP_BYTES:
                continue
            elif oc in ("reduce", "reduce-window"):
                out.flops += shape_elems(" ".join(
                    self.shapes.get(o, "") for o in op.operands))
                out.hbm_bytes += self._op_bytes(op)
            else:
                # standalone elementwise / data movement op
                out.flops += shape_elems(op.out_type)
                out.hbm_bytes += self._op_bytes(op)
        return out


def analyze(hlo_text: str) -> Metrics:
    return HloCostModel(hlo_text).metrics()


def top_collectives(hlo_text: str, n: int = 12) -> list[dict]:
    """Largest individual collectives with their executed-times multiplier —
    the §Perf profile: tells you *which* op inside *which* loop to attack."""
    model = HloCostModel(hlo_text)

    # executed-times per computation (entry=1, while bodies × trips)
    times: dict[str, float] = {model.entry: 1.0}
    order = [model.entry]
    i = 0
    while i < len(order):
        comp = order[i]
        i += 1
        for op in model.comps.get(comp, []):
            mult = times[comp]
            for attr, extra in ((_ATTR_BODY, None), (_ATTR_CALLS, None)):
                m = attr.search(op.line)
                if not m:
                    continue
                child = m.group(1)
                t = mult
                if op.opcode == "while":
                    cond = _ATTR_COND.search(op.line)
                    t = mult * (model.trip_count(cond.group(1)) if cond else 1)
                times[child] = times.get(child, 0) + t
                if child not in order:
                    order.append(child)

    rows = []
    for comp, ops in model.comps.items():
        t = times.get(comp, 0.0)
        if t == 0:
            continue
        for op in ops:
            if not any(op.opcode.startswith(k) for k in _COLLECTIVE_KINDS):
                continue
            b = model._operand_bytes(op) or shape_bytes(op.out_type)
            rows.append({"op": op.name, "kind": op.opcode, "comp": comp,
                         "bytes": b, "times": t, "total": b * t,
                         "shape": op.out_type[:60],
                         "meta": op.line[op.line.find("metadata="):][:120]})
    rows.sort(key=lambda r: -r["total"])
    return rows[:n]


def top_hbm_ops(hlo_text: str, n: int = 12) -> list[dict]:
    """Largest HBM-traffic ops (fusion boundaries), executed-times scaled."""
    model = HloCostModel(hlo_text)
    times: dict[str, float] = {model.entry: 1.0}
    order = [model.entry]
    i = 0
    while i < len(order):
        comp = order[i]
        i += 1
        for op in model.comps.get(comp, []):
            m = _ATTR_BODY.search(op.line) or (
                _ATTR_CALLS.search(op.line) if op.opcode != "fusion" else None)
            if m:
                child = m.group(1)
                t = times[comp]
                if op.opcode == "while":
                    cond = _ATTR_COND.search(op.line)
                    t *= model.trip_count(cond.group(1)) if cond else 1
                times[child] = times.get(child, 0) + t
                if child not in order:
                    order.append(child)
    rows = []
    for comp, ops in model.comps.items():
        t = times.get(comp, 0.0)
        if t == 0:
            continue
        for op in ops:
            if op.opcode in _SKIP_BYTES or op.opcode == "fusion" and False:
                continue
            if op.opcode in _SKIP_BYTES:
                continue
            b = model._op_bytes(op)
            if b:
                rows.append({"op": op.name, "kind": op.opcode, "comp": comp,
                             "bytes": b, "times": t, "total": b * t,
                             "meta": op.line[op.line.find("metadata="):][:140]})
    rows.sort(key=lambda r: -r["total"])
    return rows[:n]


# ---------------------------------------------------------------------------
# Audit-facing extractors (repro.analysis.program, DESIGN.md §11)
# ---------------------------------------------------------------------------

# Opcodes that move data across the device boundary (or between devices)
# inside a compiled program.  Single-host suite programs must contain NONE
# of these: an infeed/outfeed/send/recv (or a host-callback custom-call)
# in a round/serve program means a per-step host round-trip snuck past the
# source-level host-sync rule.
TRANSFER_OPCODES = ("infeed", "outfeed", "send", "recv",
                    "send-done", "recv-done")
_CALLBACK_TARGETS = ("callback", "host")

_OPCODE_RE = re.compile(r"=\s*(?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\]\S*)\s+"
                        r"([a-z][\w\-]*)\(")
_CUSTOM_TARGET_RE = re.compile(r'custom_call_target="([^"]+)"')


def transfer_op_counts(hlo_text: str) -> dict:
    """Count device-boundary transfer ops per opcode in optimized HLO.

    Host-callback custom-calls (``custom_call_target`` naming a callback)
    count under ``"host-callback"``; other custom-calls (kernels, cublas,
    topk) are device-local and ignored.
    """
    out: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _OPCODE_RE.search(line)
        if not m:
            continue
        oc = m.group(1)
        if oc in TRANSFER_OPCODES:
            out[oc] = out.get(oc, 0) + 1
        elif oc == "custom-call":
            tm = _CUSTOM_TARGET_RE.search(line)
            if tm and any(k in tm.group(1).lower()
                          for k in _CALLBACK_TARGETS):
                out["host-callback"] = out.get("host-callback", 0) + 1
    return out


# ``input_output_alias={ {1}: (0, {0}, may-alias), ... }`` in the HloModule
# header: one entry per (output index, donated parameter) pair XLA actually
# aliased.  A donated argument that XLA silently copied instead does NOT
# appear — which is exactly the regression the donation contract catches.
# Entries nest braces (the output tuple index is itself brace-wrapped), so
# the block is extracted by brace matching, not regex.
_ALIAS_ENTRY_RE = re.compile(r"\{[0-9,\s]*\}:\s*\((\d+)")


def donation_aliases(hlo_text: str) -> list[int]:
    """Parameter numbers (flat argument indices) XLA aliased to outputs.

    One list entry per applied alias; a parameter aliased for several
    outputs appears once per alias.  Empty list == no donation applied.
    """
    header = hlo_text.split("\n", 1)[0]
    key = "input_output_alias={"
    start = header.find(key)
    if start < 0:
        return []
    depth = 1
    buf = []
    for ch in header[start + len(key):]:
        if ch == "{":
            depth += 1
        elif ch == "}":
            depth -= 1
            if depth == 0:
                break
        buf.append(ch)
    return [int(p) for p in _ALIAS_ENTRY_RE.findall("".join(buf))]


def dtype_census(hlo_text: str) -> dict:
    """Occurrence count per dtype across every shape in the HLO text.

    The dtype-discipline contract reads this: ``f64`` anywhere is a bug
    (nothing in the repo computes in double), and for bf16-configured
    programs an unexpected flood of ``f32`` shapes marks an upcast leak.
    """
    out: dict[str, int] = {}
    for dtype, _dims in _SHAPE_RE.findall(hlo_text):
        if dtype in _DTYPE_BYTES:
            out[dtype] = out.get(dtype, 0) + 1
    return out


def unrolled_summary(hlo_text: str) -> dict:
    """One-call scan-unrolled cost summary of an optimized HLO dump.

    The shared report shape consumed by launch/dryrun.py and the program
    auditor — both sides of the ``PROGRAM_BUDGETS.json`` gate read these
    exact keys, so a dry-run and an audit of the same program agree.
    """
    m = analyze(hlo_text)
    return {
        "flops": m.flops,
        "hbm_bytes": m.hbm_bytes,
        "collective_bytes": m.total_coll_bytes,
        "collective_by_kind": dict(m.coll_bytes),
        "collective_counts": dict(m.coll_counts),
        "transfer_ops": transfer_op_counts(hlo_text),
        "dtypes": dtype_census(hlo_text),
        "donation_aliases": donation_aliases(hlo_text),
    }
