"""Static invariant linter + strict-mode runtime tripwires.

Static side (stdlib-only, no jax needed):

    python -m repro.analysis src benchmarks examples

Runtime side (``REPRO_STRICT=1``): :mod:`repro.analysis.strict`.
"""
from repro.analysis.engine import (AnalysisConfig, Finding, RULES,
                                   run_files, run_paths)
from repro.analysis import rules as _rules  # noqa: F401  (populates RULES)
from repro.analysis.strict import (RetraceSentinel, no_implicit_transfers,
                                   strict_enabled, strict_region)

__all__ = [
    "AnalysisConfig", "Finding", "RULES", "run_files", "run_paths",
    "RetraceSentinel", "no_implicit_transfers", "strict_enabled",
    "strict_region",
]
