"""Per-program fact extraction for the program auditor (DESIGN.md §11).

Given a jitted callable and shape-only abstract inputs, this module lowers
the program ONCE and reads two complementary views:

* the **jaxpr** (``fn.trace(...)``) — semantic structure before XLA gets
  creative: a weight-provenance walk tags every input leaf declared a
  *weight* and follows the tags through layout/cast primitives into
  ``dot_general`` operands, yielding ``weight_bytes`` — the bytes of
  weight operands streamed into matmuls, with ``scan`` bodies multiplied
  by their trip count.  This is the quantity the delta-serving contract
  pins: ``serve_decode_delta`` reads (1+C)·d·f per layer regardless of
  batch B, while the dense baseline reads B·d·f.  The walk also records a
  dtype census of every aval it sees (the f64 tripwire fires here even
  when XLA would fold the offending cast away).

* the **compiled HLO** (``.lower().compile().as_text()``) — what actually
  runs: scan-unrolled FLOPs/HBM bytes and collective traffic via
  :mod:`repro.analysis.costmodel`, transfer/outfeed ops, the donation
  aliases XLA *applied* (vs. merely requested), an HLO-side dtype census,
  and ``memory_analysis()`` sizes.

Provenance semantics: a value is weight-tagged iff it is reachable from a
weight input leaf through pure layout/cast primitives
(transpose/reshape/slice/convert/...).  Outputs of ``dot_general`` and of
arithmetic are *activations* — mixing ends the tag.  ``scan`` maps tags
through consts/carry/xs onto the body (an xs slice of a tagged stack stays
tagged) and multiplies body traffic by ``length``; ``pjit``/``remat2``/
custom-derivative calls and ``cond`` branches are descended with the
multiplier unchanged (``cond`` contributes the max across branches).
``while`` bodies are counted once — the repo's loops are scans, which keep
their trip count at jaxpr level.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Iterable, Sequence

import jax

from repro.analysis import costmodel as CM

# -- jaxpr weight-provenance walk -------------------------------------------

# Primitives that preserve "this value IS (a view/cast of) weights".
_LAYOUT_PRIMS = frozenset({
    "convert_element_type", "transpose", "reshape", "broadcast_in_dim",
    "slice", "dynamic_slice", "dynamic_update_slice", "squeeze",
    "expand_dims", "rev", "concatenate", "pad", "gather", "copy",
    "device_put", "select_n", "stop_gradient",
})

# Call-like primitives whose inner jaxpr's invars map 1:1 onto eqn.invars.
_CALL_PRIM_JAXPR_KEYS = {
    "pjit": ("jaxpr",),
    "closed_call": ("call_jaxpr", "jaxpr"),
    "core_call": ("call_jaxpr",),
    "remat2": ("jaxpr",),
    "remat": ("jaxpr",),
    "checkpoint": ("jaxpr",),
    "custom_jvp_call": ("call_jaxpr", "fun_jaxpr"),
    "custom_vjp_call": ("call_jaxpr", "fun_jaxpr"),
    "custom_jvp_call_jaxpr": ("fun_jaxpr",),
    "custom_vjp_call_jaxpr": ("fun_jaxpr",),
}

# Matmul-class primitives whose weight-tagged operands count as streamed
# weight traffic.
_MATMUL_PRIMS = frozenset({"dot_general", "conv_general_dilated"})


def _is_literal(atom) -> bool:
    return hasattr(atom, "val")          # core.Literal ducks; Var does not


def _aval_bytes(aval) -> int:
    try:
        return int(aval.size) * int(aval.dtype.itemsize)
    except (AttributeError, TypeError):
        return 0                          # tokens / dtype-less avals


def _aval_dtype(atom) -> str | None:
    aval = getattr(atom, "aval", atom)
    dt = getattr(aval, "dtype", None)
    return None if dt is None else str(dt)


def _unwrap(maybe_closed):
    """ClosedJaxpr → Jaxpr; Jaxpr passes through."""
    return getattr(maybe_closed, "jaxpr", maybe_closed)


class JaxprWalk:
    """Accumulates weight traffic + a dtype census over one closed jaxpr."""

    def __init__(self):
        self.weight_bytes = 0.0
        self.dtypes: set[str] = set()

    def _note(self, atoms: Iterable[Any]):
        for a in atoms:
            dt = _aval_dtype(a)
            if dt is not None:
                self.dtypes.add(dt)

    def walk(self, jaxpr, tags: dict, mult: float) -> list[bool]:
        """Walk one (open) jaxpr; returns the tag per outvar."""
        self._note(jaxpr.invars)
        self._note(jaxpr.constvars)
        for eqn in jaxpr.eqns:
            prim = eqn.primitive.name
            in_tags = [(not _is_literal(a)) and tags.get(a, False)
                       for a in eqn.invars]
            self._note(eqn.invars)
            self._note(eqn.outvars)
            if prim in _MATMUL_PRIMS:
                for a, t in zip(eqn.invars, in_tags):
                    if t:
                        self.weight_bytes += mult * _aval_bytes(a.aval)
                out_tags = [False] * len(eqn.outvars)
            elif prim == "scan":
                inner = _unwrap(eqn.params["jaxpr"])
                length = int(eqn.params.get("length", 1))
                sub = dict(zip(inner.invars, in_tags))
                out_tags = self.walk(inner, sub, mult * length)
            elif prim in _CALL_PRIM_JAXPR_KEYS:
                inner = None
                for k in _CALL_PRIM_JAXPR_KEYS[prim]:
                    if eqn.params.get(k) is not None:
                        inner = _unwrap(eqn.params[k])
                        break
                if inner is None:
                    out_tags = [False] * len(eqn.outvars)
                else:
                    sub = dict(zip(inner.invars, in_tags))
                    out_tags = self.walk(inner, sub, mult)
            elif prim == "cond":
                # invars = (index, *operands); contribute the costliest branch
                best, best_tags = -1.0, [False] * len(eqn.outvars)
                for br in eqn.params["branches"]:
                    inner = _unwrap(br)
                    probe = JaxprWalk()
                    sub = dict(zip(inner.invars, in_tags[1:]))
                    btags = probe.walk(inner, sub, mult)
                    self.dtypes |= probe.dtypes
                    if probe.weight_bytes > best:
                        best, best_tags = probe.weight_bytes, btags
                self.weight_bytes += max(best, 0.0)
                out_tags = best_tags
            elif prim == "while":
                # trip count is dynamic at jaxpr level: count the body once
                body = _unwrap(eqn.params["body_jaxpr"])
                cn = int(eqn.params.get("cond_nconsts", 0))
                sub = dict(zip(body.invars, in_tags[cn:]))
                out_tags = self.walk(body, sub, mult)
            elif prim in _LAYOUT_PRIMS:
                out_tags = [any(in_tags)] * len(eqn.outvars)
            else:
                out_tags = [False] * len(eqn.outvars)
            for v, t in zip(eqn.outvars, out_tags):
                tags[v] = t
        return [(not _is_literal(v)) and tags.get(v, False)
                for v in jaxpr.outvars]


def weight_traffic(closed_jaxpr, invar_tags: Sequence[bool]
                   ) -> tuple[float, set[str]]:
    """(weight bytes streamed into matmuls, dtype census) of a jaxpr."""
    jaxpr = _unwrap(closed_jaxpr)
    if len(invar_tags) != len(jaxpr.invars):
        raise ValueError(
            f"invar tag count {len(invar_tags)} != jaxpr invars "
            f"{len(jaxpr.invars)} — static_argnums/weight_argnums mismatch")
    w = JaxprWalk()
    w.walk(jaxpr, dict(zip(jaxpr.invars, invar_tags)), 1.0)
    return w.weight_bytes, w.dtypes


# -- the fact table row ------------------------------------------------------

@dataclass
class ProgramFacts:
    """Everything the contract layer and the budget gate read, one program."""
    name: str
    meta: dict = field(default_factory=dict)
    # compiled-HLO side (scan-unrolled, repro.analysis.costmodel)
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_by_kind: dict = field(default_factory=dict)
    collective_counts: dict = field(default_factory=dict)
    transfer_ops: dict = field(default_factory=dict)
    hlo_dtypes: dict = field(default_factory=dict)
    donation_applied: int = 0
    # jaxpr side
    weight_bytes: float = 0.0
    jaxpr_dtypes: list = field(default_factory=list)
    out_dtypes: list = field(default_factory=list)
    donated_declared: int = 0
    # memory
    arg_bytes: int = 0
    out_bytes: int = 0
    temp_bytes: int = 0
    code_bytes: int = 0
    param_bytes: int = 0        # bytes of the weight-tagged abstract inputs

    def to_dict(self) -> dict:
        return {
            "name": self.name, "meta": dict(self.meta),
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
            "collective_by_kind": dict(self.collective_by_kind),
            "collective_counts": dict(self.collective_counts),
            "transfer_ops": dict(self.transfer_ops),
            "hlo_dtypes": dict(self.hlo_dtypes),
            "donation_applied": self.donation_applied,
            "weight_bytes": self.weight_bytes,
            "jaxpr_dtypes": sorted(self.jaxpr_dtypes),
            "out_dtypes": list(self.out_dtypes),
            "donated_declared": self.donated_declared,
            "arg_bytes": self.arg_bytes, "out_bytes": self.out_bytes,
            "temp_bytes": self.temp_bytes, "code_bytes": self.code_bytes,
            "param_bytes": self.param_bytes,
        }


def _n_leaves(tree) -> int:
    return len(jax.tree_util.tree_leaves(tree))


def extract_facts(name: str, fn: Callable, args: Sequence[Any], *,
                  static_argnums: Sequence[int] = (),
                  donate_argnums: Sequence[int] = (),
                  weight_argnums: Sequence[int] = (),
                  meta: dict | None = None) -> ProgramFacts:
    """Lower ``fn(*args)`` once and extract the full fact row.

    ``fn`` is a jitted callable (its own static/donate setup governs the
    lowering); the ``*_argnums`` here describe the *positional* ``args``
    for bookkeeping: which are compile-time static (excluded from the
    jaxpr's invars), which the suite declares donated (expected-alias
    count), and which hold weights (provenance roots).  Abstract
    (``ShapeDtypeStruct``) args are fine — nothing executes.
    """
    static = set(static_argnums)
    donate = set(donate_argnums)
    weights = set(weight_argnums)

    traced = fn.trace(*args)
    closed = traced.jaxpr

    invar_tags: list[bool] = []
    donated_declared = 0
    param_bytes = 0
    for i, a in enumerate(args):
        if i in static:
            continue
        n = _n_leaves(a)
        tag = i in weights
        invar_tags.extend([tag] * n)
        if tag:
            param_bytes += sum(
                _aval_bytes(l) for l in jax.tree_util.tree_leaves(a))
        if i in donate:
            donated_declared += n
    wbytes, jdtypes = weight_traffic(closed, invar_tags)

    compiled = traced.lower().compile()
    hlo = compiled.as_text()
    summary = CM.unrolled_summary(hlo)

    mem = {"arg": 0, "out": 0, "temp": 0, "code": 0}
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            mem = {"arg": int(ma.argument_size_in_bytes),
                   "out": int(ma.output_size_in_bytes),
                   "temp": int(ma.temp_size_in_bytes),
                   "code": int(ma.generated_code_size_in_bytes)}
    except Exception:       # backend without memory stats: facts stay zero
        pass

    return ProgramFacts(
        name=name, meta=dict(meta or {}),
        flops=summary["flops"], hbm_bytes=summary["hbm_bytes"],
        collective_bytes=summary["collective_bytes"],
        collective_by_kind=summary["collective_by_kind"],
        collective_counts=summary["collective_counts"],
        transfer_ops=summary["transfer_ops"],
        hlo_dtypes=summary["dtypes"],
        donation_applied=len(summary["donation_aliases"]),
        weight_bytes=wbytes,
        jaxpr_dtypes=sorted(jdtypes),
        out_dtypes=[str(getattr(a, "dtype", a)) for a in closed.out_avals],
        donated_declared=donated_declared,
        arg_bytes=mem["arg"], out_bytes=mem["out"],
        temp_bytes=mem["temp"], code_bytes=mem["code"],
        param_bytes=param_bytes,
    )
