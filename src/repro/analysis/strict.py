"""Runtime complement to the static rules: opt-in strict mode.

``REPRO_STRICT=1`` arms two runtime tripwires that prove the properties
the linter can only approximate statically:

* :func:`no_implicit_transfers` — ``jax.transfer_guard("disallow")``
  around a region, so any *implicit* host↔device transfer (an np array
  leaking into a jitted program, a device array silently pulled to host)
  raises instead of costing a hidden sync.  Explicit movement
  (``jax.device_put``, ``np.asarray(device_arr)`` at a round boundary)
  stays legal.
* :class:`RetraceSentinel` — snapshots ``jit_cache_stats()["programs"]``
  on entry and asserts on exit that no jit-suite entry point compiled a
  new trace, i.e. steady-state rounds replay cached programs.

jax is imported lazily so ``repro.analysis`` stays importable (and the
lint CI job runnable) without jax installed.
"""
from __future__ import annotations

import contextlib
import os

STRICT_ENV = "REPRO_STRICT"


def strict_enabled() -> bool:
    return os.environ.get(STRICT_ENV, "").strip() not in ("", "0", "false")


@contextlib.contextmanager
def no_implicit_transfers(enabled: bool = True):
    """Disallow implicit transfers inside the block (no-op if disabled)."""
    if not enabled:
        yield
        return
    import jax
    with jax.transfer_guard("disallow"):
        yield


class RetraceSentinel:
    """Assert the jit-suite compiled no new programs across a region.

    >>> with RetraceSentinel("steady-state rounds"):
    ...     scheduler.run(rounds=4)
    """

    def __init__(self, label: str = "region", enabled: bool = True):
        self.label = label
        self.enabled = enabled
        self.before: dict[str, int] = {}
        self.after: dict[str, int] = {}

    @staticmethod
    def _programs() -> dict[str, int]:
        from repro.core.client import jit_cache_stats
        return dict(jit_cache_stats()["programs"])

    def __enter__(self) -> "RetraceSentinel":
        if self.enabled:
            self.before = self._programs()
        return self

    def grown(self) -> dict[str, tuple[int, int]]:
        """entry_point -> (before, after) for every grown counter."""
        return {k: (self.before.get(k, 0), v)
                for k, v in self.after.items()
                if v > self.before.get(k, 0)}

    def __exit__(self, exc_type, exc, tb) -> None:
        if not self.enabled or exc_type is not None:
            return
        self.after = self._programs()
        grown = self.grown()
        if grown:
            detail = ", ".join(f"{k}: {b}->{a}"
                               for k, (b, a) in sorted(grown.items()))
            raise AssertionError(
                f"retrace inside {self.label}: jit-suite compiled new "
                f"programs ({detail}) — a steady-state hot loop must "
                f"replay cached traces")


@contextlib.contextmanager
def strict_region(label: str = "region", enabled: bool | None = None):
    """Both tripwires at once; ``enabled=None`` reads REPRO_STRICT."""
    on = strict_enabled() if enabled is None else enabled
    with no_implicit_transfers(on), RetraceSentinel(label, enabled=on):
        yield
