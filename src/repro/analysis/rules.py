"""The contract rules (DESIGN.md §10 maps each to the PR that set it).

Every rule is a pure function of the parsed file plus shared context, and
every finding names the violated contract so the fix (or the pragma
reason) can be reviewed against it.
"""
from __future__ import annotations

import ast
from typing import Iterable, Optional

from repro.analysis.engine import (Context, Finding, SourceFile,
                                   register_rule)

# ---------------------------------------------------------------------------
# shared AST helpers
# ---------------------------------------------------------------------------


def dotted(node: ast.AST) -> Optional[str]:
    """'jax.numpy.asarray'-style dotted name of a Name/Attribute chain."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def import_aliases(tree: ast.Module) -> dict[str, str]:
    """Local name → canonical dotted module for every import in the file."""
    out: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                out[a.asname or a.name.split(".")[0]] = (
                    a.name if a.asname else a.name.split(".")[0])
        elif isinstance(node, ast.ImportFrom) and node.module:
            for a in node.names:
                out[a.asname or a.name] = f"{node.module}.{a.name}"
    return out


def canonical(node: ast.AST, aliases: dict[str, str]) -> Optional[str]:
    """Dotted chain with its head import-alias expanded:
    ``jnp.asarray`` → ``jax.numpy.asarray`` under ``import jax.numpy as
    jnp``."""
    d = dotted(node)
    if d is None:
        return None
    head, _, rest = d.partition(".")
    root = aliases.get(head, head)
    return f"{root}.{rest}" if rest else root


def walk_with_function(tree: ast.Module):
    """Yield ``(node, enclosing_function_node_or_None)`` for every node."""
    def rec(node, fn):
        for child in ast.iter_child_nodes(node):
            nfn = (child if isinstance(child, (ast.FunctionDef,
                                               ast.AsyncFunctionDef,
                                               ast.Lambda)) else fn)
            yield child, fn
            yield from rec(child, nfn)
    yield from rec(tree, None)


def _in_file(rel: str, prefixes: Iterable[str]) -> bool:
    return any(rel == p or rel.startswith(p) for p in prefixes)


JIT_WRAPPERS = ("jax.jit", "jax.pmap", "jax.experimental.pjit.pjit")


def _jit_calls(sf: SourceFile, aliases):
    """(call_node, enclosing_fn, canonical_name) for jit/pmap wrappers."""
    for node, fn in walk_with_function(sf.tree):
        if isinstance(node, ast.Call):
            name = canonical(node.func, aliases)
            if name in JIT_WRAPPERS:
                yield node, fn, name


# ---------------------------------------------------------------------------
# Rule: jit-outside-cache  (contract from PR 2/PR 7's shared jit suite)
# ---------------------------------------------------------------------------

@register_rule(
    "jit-outside-cache",
    "jax.jit/jax.pmap construction belongs in the sanctioned jit-suite "
    "modules (core/client.py, serve/engine.py, sharding/) or at module "
    "scope; per-call construction elsewhere makes a fresh trace cache "
    "and defeats jit_cache_stats()'s program pins.")
def jit_outside_cache(sf: SourceFile, ctx: Context):
    if _in_file(sf.rel, ctx.config.jit_sanctioned):
        return
    aliases = import_aliases(sf.tree)
    for node, fn, name in _jit_calls(sf, aliases):
        if fn is None:
            # module scope: compiled once per import / static signature —
            # the hazard is a fresh jitted callable per call or instance
            continue
        yield Finding(
            sf.rel, node.lineno, "jit-outside-cache",
            f"{name} constructed inside {getattr(fn, 'name', '<lambda>')}() "
            f"outside the sanctioned jit-suite modules: each call builds a "
            f"fresh trace cache (recompiles every invocation; invisible to "
            f"jit_cache_stats)")


# ---------------------------------------------------------------------------
# Rule: host-sync  (contract from PR 2/PR 4's streaming pipeline)
# ---------------------------------------------------------------------------

SYNC_ATTR_CALLS = ("item", "block_until_ready")
SYNC_FUNCS = ("jax.device_get", "numpy.asarray", "numpy.array",
              "jax.block_until_ready")


@register_rule(
    "host-sync",
    "No device→host synchronisation inside functions reachable from the "
    "round/serve hot loops: .item(), float()/int() on arrays, "
    "np.asarray, jax.device_get, block_until_ready stall the async "
    "dispatch stream that the 2.8–8.6× pipeline wins depend on.")
def host_sync(sf: SourceFile, ctx: Context):
    cfg = ctx.config
    reach = ctx.callgraph.reachable(set(cfg.hot_entry_points),
                                    cfg.host_stage_boundary)
    here = [f for f in reach if f.rel == sf.rel]
    if not here:
        return
    aliases = import_aliases(sf.tree)
    for info in here:
        for node in ast.walk(info.node):
            if not isinstance(node, ast.Call):
                continue
            f = node.func
            if isinstance(f, ast.Attribute) and f.attr in SYNC_ATTR_CALLS:
                yield Finding(
                    sf.rel, node.lineno, "host-sync",
                    f".{f.attr}() in {info.qualname} (reachable from "
                    f"{'/'.join(cfg.hot_entry_points)}) forces a device "
                    f"sync in the hot path")
                continue
            name = canonical(f, aliases)
            if name in SYNC_FUNCS:
                yield Finding(
                    sf.rel, node.lineno, "host-sync",
                    f"{name}(...) in {info.qualname} materialises to host "
                    f"inside the hot path — move it to a round boundary "
                    f"or annotate the sanctioned sync point")
            elif (isinstance(f, ast.Name) and f.id in ("float", "int")
                  and node.args
                  and not isinstance(node.args[0], ast.Constant)):
                yield Finding(
                    sf.rel, node.lineno, "host-sync",
                    f"{f.id}(...) on a non-literal in {info.qualname} "
                    f"blocks on the device value if it is a jax array")


# ---------------------------------------------------------------------------
# Rule: nondeterminism  (contract from PR 6's flat rng streams)
# ---------------------------------------------------------------------------

SEEDED_CTORS = ("RandomState", "default_rng", "Generator", "SeedSequence",
                "PRNGKey", "key")
TIME_FUNCS = ("time.time", "time.time_ns", "time.perf_counter",
              "time.monotonic")


@register_rule(
    "nondeterminism",
    "Round/selection/state code draws entropy only from seeded, "
    "checkpointable streams (PR 6's ClientStreamState / explicit "
    "RandomState): the global random module, wall clocks, and numpy's "
    "global generator break bit-exact resume and the engine-parity "
    "oracles.")
def nondeterminism(sf: SourceFile, ctx: Context):
    if not _in_file(sf.rel, ctx.config.nondet_scope):
        return
    aliases = import_aliases(sf.tree)
    for node in ast.walk(sf.tree):
        if not isinstance(node, ast.Call):
            continue
        name = canonical(node.func, aliases)
        if name is None:
            continue
        if name.startswith("random."):
            yield Finding(
                sf.rel, node.lineno, "nondeterminism",
                f"stdlib {name}(...) uses the unseeded global generator — "
                f"draw from the server/task RandomState streams instead")
        elif name in TIME_FUNCS:
            yield Finding(
                sf.rel, node.lineno, "nondeterminism",
                f"{name}(...) is wall-clock state: fine for telemetry "
                f"(annotate it), never as an input to round math")
        elif name.startswith("numpy.random."):
            tail = name.rsplit(".", 1)[1]
            if tail not in SEEDED_CTORS:
                yield Finding(
                    sf.rel, node.lineno, "nondeterminism",
                    f"{name}(...) draws from numpy's global generator — "
                    f"use an explicitly seeded RandomState/stream")
            elif not node.args and not node.keywords:
                yield Finding(
                    sf.rel, node.lineno, "nondeterminism",
                    f"{name}() without a seed is entropy from the OS — "
                    f"pass an explicit seed")


# ---------------------------------------------------------------------------
# Rule: tracer-hazard  (contract from PR 1/PR 5's jitted round programs)
# ---------------------------------------------------------------------------

TRACED_MODULES = ("jax.numpy.", "jax.lax.", "jax.nn.")
TRACED_ATTR_TESTS = ("any", "all", "item")


def _jit_registered_functions(sf: SourceFile, aliases):
    """Function defs that become jitted programs: decorated with jax.jit
    (directly or via functools.partial), or referenced by name as the
    first argument of a jax.jit(...) call anywhere in the file — the
    jit-suite registration pattern — plus every def nested inside one."""
    jitted_names: set[str] = set()
    for node, _fn, _name in _jit_calls(sf, aliases):
        if node.args:
            target = node.args[0]
            if isinstance(target, ast.Attribute):
                jitted_names.add(target.attr)
            elif isinstance(target, ast.Name):
                jitted_names.add(target.id)

    def is_jit_decorator(dec) -> bool:
        if canonical(dec, aliases) in JIT_WRAPPERS:
            return True
        if isinstance(dec, ast.Call):
            if canonical(dec.func, aliases) in JIT_WRAPPERS:
                return True
            head = canonical(dec.func, aliases)
            if head in ("functools.partial", "partial") and dec.args:
                return canonical(dec.args[0], aliases) in JIT_WRAPPERS
        return False

    out = []
    for node in ast.walk(sf.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if (node.name in jitted_names
                    or any(is_jit_decorator(d) for d in node.decorator_list)):
                out.append(node)
    return out


def _has_traced_call(expr: ast.AST, aliases) -> Optional[str]:
    for n in ast.walk(expr):
        if isinstance(n, ast.Call):
            name = canonical(n.func, aliases)
            if name and any(name.startswith(m) for m in TRACED_MODULES):
                return name
            f = n.func
            if isinstance(f, ast.Attribute) and f.attr in TRACED_ATTR_TESTS:
                return f".{f.attr}()"
    return None


@register_rule(
    "tracer-hazard",
    "Inside jit-registered functions, Python `if`/`while`/`bool()` on a "
    "traced value either crashes (ConcretizationTypeError) or — worse — "
    "silently bakes one branch into the compiled program and retraces "
    "per value, breaking the one-program-per-hot-path pin.")
def tracer_hazard(sf: SourceFile, ctx: Context):
    aliases = import_aliases(sf.tree)
    for fdef in _jit_registered_functions(sf, aliases):
        for node in ast.walk(fdef):
            if isinstance(node, (ast.If, ast.While)):
                hit = _has_traced_call(node.test, aliases)
                if hit:
                    kw = "if" if isinstance(node, ast.If) else "while"
                    yield Finding(
                        sf.rel, node.lineno, "tracer-hazard",
                        f"Python `{kw}` on traced expression ({hit}) "
                        f"inside jitted {fdef.name}(): use jnp.where / "
                        f"lax.cond, or hoist to a static argument")
            elif (isinstance(node, ast.Call)
                  and isinstance(node.func, ast.Name)
                  and node.func.id == "bool"):
                yield Finding(
                    sf.rel, node.lineno, "tracer-hazard",
                    f"bool(...) inside jitted {fdef.name}() concretises "
                    f"a tracer (host round-trip or trace error)")


# ---------------------------------------------------------------------------
# Rule: unhashable-static  (contract from PR 2's (ArchConfig, RuntimeConfig)
# cache keys and the suites' static tail arguments)
# ---------------------------------------------------------------------------

MUTABLE_LITERALS = (ast.List, ast.Dict, ast.Set, ast.ListComp, ast.DictComp,
                    ast.SetComp)
MUTABLE_CTORS = ("list", "dict", "set", "bytearray")
# keywords whose values end up as static jit args / cache-key components
STATIC_KEYWORDS = ("reqs",)


@register_rule(
    "unhashable-static",
    "Everything used as a jit static argument or a jit-suite cache-key "
    "component must be hashable: no mutable default arguments, tuple (not "
    "list) static_argnums/static_argnames, and tuple-valued `reqs` "
    "probe-requirement sets.")
def unhashable_static(sf: SourceFile, ctx: Context):
    for node in ast.walk(sf.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            args = node.args
            named = list(args.args) + list(args.posonlyargs) \
                + list(args.kwonlyargs)
            defaults = list(args.defaults) + [d for d in args.kw_defaults
                                              if d is not None]
            for d in defaults:
                bad = isinstance(d, MUTABLE_LITERALS) or (
                    isinstance(d, ast.Call)
                    and isinstance(d.func, ast.Name)
                    and d.func.id in MUTABLE_CTORS)
                if bad:
                    fname = getattr(node, "name", "<lambda>")
                    yield Finding(
                        sf.rel, d.lineno, "unhashable-static",
                        f"mutable default argument in {fname}() — shared "
                        f"across calls and unhashable as a static/"
                        f"cache-key value; use None or a tuple")
            del named
        elif isinstance(node, ast.Call):
            for kw in node.keywords:
                if kw.arg in ("static_argnums", "static_argnames") \
                        and isinstance(kw.value, ast.List):
                    yield Finding(
                        sf.rel, kw.value.lineno, "unhashable-static",
                        f"{kw.arg} given a list literal — the repo "
                        f"convention is a tuple (hashable, and matches "
                        f"the suite cache keys)")
                elif kw.arg in STATIC_KEYWORDS \
                        and isinstance(kw.value, MUTABLE_LITERALS):
                    yield Finding(
                        sf.rel, kw.value.lineno, "unhashable-static",
                        f"{kw.arg}= given a mutable literal — the probe "
                        f"suites take it as a static jit argument; pass "
                        f"a tuple")


# ---------------------------------------------------------------------------
# Rule: kernel-parity  (contract from PR 5/PR 7's kernel fallbacks)
# ---------------------------------------------------------------------------

@register_rule(
    "kernel-parity",
    "Every Pallas kernel module ships a pure-jnp fallback (`*_jnp`) "
    "selected off-TPU via the RuntimeConfig.use_pallas / ops mode "
    "dispatch, and a parity test in tests/test_kernels.py pins the two "
    "against each other — TPU-only code paths must never be the only "
    "implementation of round math.")
def kernel_parity(sf: SourceFile, ctx: Context):
    cfg = ctx.config
    if not sf.rel.startswith(cfg.kernel_dir):
        return
    base = sf.rel.rsplit("/", 1)[1]
    if base in cfg.kernel_exclude:
        return
    aliases = import_aliases(sf.tree)
    pallas_lines = [
        node.lineno for node in ast.walk(sf.tree)
        if isinstance(node, ast.Call)
        and (canonical(node.func, aliases) or "").endswith("pallas_call")]
    if not pallas_lines:
        return
    line = min(pallas_lines)
    stem = base[:-3]
    fallbacks = [
        n.name for n in ast.walk(sf.tree)
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
        and n.name.endswith("_jnp") and not n.name.startswith("_")]
    if not fallbacks:
        yield Finding(
            sf.rel, line, "kernel-parity",
            f"{base} calls pallas_call but defines no public *_jnp "
            f"fallback — off-TPU runs have no bit-traceable reference "
            f"for this kernel")
    dispatch_src = ctx.read_rel(cfg.kernel_dispatch)
    if dispatch_src is not None and sf.rel != cfg.kernel_dispatch \
            and stem not in dispatch_src:
        yield Finding(
            sf.rel, line, "kernel-parity",
            f"{base} is not referenced by {cfg.kernel_dispatch} — the "
            f"kernel is unreachable from the use_pallas mode dispatch")
    tests_src = ctx.read_rel(cfg.kernel_tests)
    if tests_src is None or stem not in tests_src:
        yield Finding(
            sf.rel, line, "kernel-parity",
            f"{base} has no matching parity coverage in "
            f"{cfg.kernel_tests} (module name never mentioned)")
    else:
        for fb in fallbacks:
            if fb not in tests_src:
                yield Finding(
                    sf.rel, line, "kernel-parity",
                    f"fallback {fb}() is never exercised by "
                    f"{cfg.kernel_tests} — kernel/fallback parity is "
                    f"unpinned")


# ---------------------------------------------------------------------------
# Rule: donation-miss  (contract from PR 7's donated serve writes, audited
# program-side by repro.analysis.program's donation-honored contract)
# ---------------------------------------------------------------------------


def _resolve_jit_target(call: ast.Call, sf: SourceFile):
    """File-local FunctionDef/Lambda the jit call wraps, or None.

    Handles ``jax.jit(fn)``, ``jax.jit(self._impl)`` and
    ``jax.jit(lambda ...)``; targets defined in other modules resolve to
    None and are skipped (the rule only reasons about signatures it can
    see)."""
    if not call.args:
        return None
    target = call.args[0]
    if isinstance(target, ast.Lambda):
        return target
    name = None
    if isinstance(target, ast.Name):
        name = target.id
    elif isinstance(target, ast.Attribute):
        name = target.attr
    if name is None:
        return None
    for node in ast.walk(sf.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and node.name == name:
            return node
    return None


@register_rule(
    "donation-miss",
    "jax.jit calls in serve/ and core/ whose wrapped function takes a "
    "params-sized tree (params/stacked/leaves/cache/bank/...) must declare "
    "donate_argnums (buffer reuse is the point of the in-place write "
    "programs) or carry a reasoned pragma naming why the buffer must "
    "survive the call — the program auditor then verifies declared "
    "donations are actually applied by XLA.")
def donation_miss(sf: SourceFile, ctx: Context):
    cfg = ctx.config
    if not _in_file(sf.rel, cfg.donation_scope):
        return
    aliases = import_aliases(sf.tree)
    tree_names = set(cfg.donation_tree_params)
    for node, _fn, name in _jit_calls(sf, aliases):
        if any(kw.arg in ("donate_argnums", "donate_argnames")
               for kw in node.keywords):
            continue
        target = _resolve_jit_target(node, sf)
        if target is None:
            continue
        args = target.args
        named = [a.arg for a in
                 list(args.posonlyargs) + list(args.args)
                 + list(args.kwonlyargs)]
        hit = [a for a in named if a in tree_names]
        if hit:
            tname = getattr(target, "name", "<lambda>")
            yield Finding(
                sf.rel, node.lineno, "donation-miss",
                f"{name}({tname}) takes params-sized tree argument(s) "
                f"{hit} but declares no donate_argnums — the caller's "
                f"buffer is copied, not reused; donate it or pragma the "
                f"reason the old buffer must stay alive")


# ---------------------------------------------------------------------------
# Rule: exception-swallow  (contract from the fault harness, DESIGN.md §12)
# ---------------------------------------------------------------------------

@register_rule(
    "exception-swallow",
    "failure-handling code in core/, ckpt/, serve/, faults/ and launch/ "
    "must not silently swallow exceptions: a bare 'except:' that never "
    "re-raises, or an 'except Exception/BaseException:' whose body is "
    "only pass/continue/..., hides exactly the faults the degradation "
    "contracts are supposed to surface (count, warn, fall back — never "
    "ignore).  Narrow the handler to the expected types, or pragma the "
    "reason swallowing is genuinely safe.")
def exception_swallow(sf: SourceFile, ctx: Context):
    if not _in_file(sf.rel, ctx.config.swallow_scope):
        return
    for node in ast.walk(sf.tree):
        if not isinstance(node, ast.ExceptHandler):
            continue
        if node.type is None:
            if not any(isinstance(n, ast.Raise) for n in ast.walk(node)):
                yield Finding(
                    sf.rel, node.lineno, "exception-swallow",
                    "bare 'except:' with no re-raise swallows every "
                    "failure (including KeyboardInterrupt) — name the "
                    "expected exception types or re-raise")
            continue
        name = dotted(node.type)
        if name not in ("Exception", "BaseException"):
            continue                      # narrow/tuple handlers are fine
        body_is_noop = all(
            isinstance(stmt, (ast.Pass, ast.Continue))
            or (isinstance(stmt, ast.Expr)
                and isinstance(stmt.value, ast.Constant))
            for stmt in node.body)
        if body_is_noop:
            yield Finding(
                sf.rel, node.lineno, "exception-swallow",
                f"'except {name}: pass' silently discards the failure — "
                f"handle it (count/warn/fall back), narrow the type, or "
                f"pragma why ignoring it is safe")
