"""Mamba2-370M — SSD (state-space duality), attention-free [arXiv:2405.21060]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=0,            # attention-free
    n_kv_heads=0,
    d_ff=0,               # no MLP: mamba2 block is the whole layer
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_heads=32,         # d_inner(2048) / headdim(64)
    ssm_chunk=128,
    tie_embeddings=True,
    source="arXiv:2405.21060",
)
