"""CLIP ViT-B/32 vision backbone — the paper's CIFAR-10 / DomainNet model.

Image tower only, used as an encoder-classifier for the FL experiments (the
paper fine-tunes CLIP's transformer layers with a fixed classifier). The patch
embedding is a stub per the frontend carve-out: ``input_specs`` provides
(B, 50, 768) patch embeddings (49 patches + CLS at 224px/32).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="clip-vit-b32",
    family="vlm",          # prefix-only encoder over stubbed patch embeds
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=10,         # classification head (CIFAR-10)
    n_prefix_tokens=50,
    task="classification",
    n_classes=10,
    mlp_act="gelu_plain",
    rope_theta=0.0,        # learned positions in ViT; stubbed into embeds
    tie_embeddings=False,
    source="paper §5.1 (Radford et al., 2021)",
)
