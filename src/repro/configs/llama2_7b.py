"""LLaMA-2-7B — the paper's QA-datasets model."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama2-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=11008,
    vocab_size=32000,
    mlp_act="silu",
    tie_embeddings=False,
    source="paper §5.1 (Touvron et al., 2023)",
)
