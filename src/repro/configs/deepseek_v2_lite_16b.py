"""DeepSeek-V2-Lite 16B — MLA + fine-grained MoE [arXiv:2405.04434].

MLA with kv_lora_rank=512; first block dense; 2 shared + 64 routed experts,
top-6, per-expert FFN width 1408.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,        # MLA: kv heads == heads after up-projection
    d_ff=1408,            # per routed expert
    vocab_size=102400,
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    first_dense=1,
    use_mla=True,
    kv_lora_rank=512,
    qk_rope_dim=64,
    qk_nope_dim=128,
    v_head_dim=128,
    mlp_act="silu",
    tie_embeddings=False,
    source="arXiv:2405.04434",
)
