"""CodeQwen1.5-7B — qwen1.5 arch (attention QKV bias) [hf:Qwen/CodeQwen1.5-7B]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="codeqwen1.5-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,       # per the assignment (qwen1.5 MHA-style kv)
    d_ff=13440,
    vocab_size=92416,
    qkv_bias=True,       # qwen1.5 signature
    mlp_act="silu",
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    source="hf:Qwen/CodeQwen1.5-7B",
)
