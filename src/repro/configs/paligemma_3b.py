"""PaliGemma-3B — SigLIP vision encoder (stubbed) + gemma decoder [arXiv:2407.07726].

Per the assignment carve-out the SigLIP tower + projector are a stub:
``input_specs`` provides (B, n_prefix_tokens, d_model) patch embeddings; we
implement the gemma-2b language backbone that consumes them (prefix-LM).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,         # MQA
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    n_prefix_tokens=256,  # 224px/14 patches -> 256 SigLIP tokens
    mlp_act="gelu",       # GeGLU
    tie_embeddings=True,
    source="arXiv:2407.07726",
)
