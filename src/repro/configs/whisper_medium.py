"""Whisper-medium — encoder-decoder, conv/mel frontend stubbed [arXiv:2212.04356].

Per the assignment carve-out the mel-spectrogram + conv feature extractor are a
stub: ``input_specs`` provides (B, enc_seq, d_model) frame embeddings; we
implement the encoder/decoder transformer backbone with cross-attention.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium",
    family="audio",
    n_layers=24,          # decoder blocks
    n_enc_layers=24,
    enc_seq=1500,         # 30s of audio at 50 frames/s
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    mlp_act="gelu_plain", # whisper uses plain GELU MLP (not gated)
    rope_theta=0.0,       # whisper uses learned/sinusoidal positions, not RoPE
    tie_embeddings=True,
    source="arXiv:2212.04356",
)
