"""XLM-RoBERTa-Base — the paper's XGLUE-NC model (text classification)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlm-roberta-base",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=250002,
    task="classification",
    n_classes=10,          # XGLUE-NC: 10 news classes
    mlp_act="gelu_plain",
    rope_theta=0.0,        # learned absolute positions
    tie_embeddings=False,
    source="paper §5.1 (Conneau et al., 2019)",
)
