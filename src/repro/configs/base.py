"""Configuration system for the `repro` framework.

Three layers of config:

* :class:`ArchConfig` — a model architecture (one per assigned architecture,
  ``src/repro/configs/<id>.py`` exports ``CONFIG`` with the exact assignment
  values plus ``reduced()`` for CPU smoke tests).
* :class:`FLConfig` — the paper's federated fine-tuning setup (Algorithm 1):
  cohort size, local steps ``tau``, per-client budgets ``R_i``, selection
  strategy and its ``lambda`` regulariser (Problem P1).
* :class:`ShapeConfig` — the assigned input shapes (train_4k / prefill_32k /
  decode_32k / long_500k), each mapping to the step kind it lowers
  (``train`` / ``prefill`` / ``decode``).

Configs are plain frozen dataclasses — hashable, usable as jit static args.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, replace
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# Architecture
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ArchConfig:
    """A transformer/SSM architecture, selectable via ``--arch <name>``."""

    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int                    # decoder blocks (for enc-dec: decoder)
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None   # default d_model // n_heads
    source: str = ""                 # citation for the assignment

    # --- MLP ---
    mlp_act: str = "silu"            # silu => SwiGLU, gelu => GeGLU
    qkv_bias: bool = False           # qwen-style attention bias

    # --- MoE ---
    n_experts: int = 0               # routed experts (0 = dense MLP)
    n_shared_experts: int = 0        # deepseek shared experts
    top_k: int = 0
    first_dense: int = 0             # leading dense blocks (deepseek-v2: 1)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01  # load-balance aux loss

    # --- MLA (deepseek multi-head latent attention) ---
    use_mla: bool = False
    kv_lora_rank: int = 0
    qk_rope_dim: int = 64
    qk_nope_dim: int = 128
    v_head_dim: int = 128

    # --- SSM (mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_heads: int = 0               # SSD heads; default d_inner // 64
    ssm_expand: int = 2
    ssm_chunk: int = 128
    ssm_conv: int = 4
    ssm_groups: int = 1
    attn_every: int = 0              # hybrid: 1 shared attn block per k ssm blocks

    # --- encoder-decoder (whisper) ---
    n_enc_layers: int = 0
    enc_seq: int = 0                 # fixed encoder frames (whisper: 1500)

    # --- VLM prefix (paligemma) ---
    n_prefix_tokens: int = 0         # stub patch embeddings

    # --- attention variant ---
    sliding_window: int = 0          # 0 = full causal; >0 = window size
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = True
    logit_softcap: float = 0.0

    # task head: "lm" (causal next-token) or "classification" (pooled head).
    # The paper's own models (CLIP / XLM-R) are classification fine-tunes.
    task: str = "lm"
    n_classes: int = 0

    # numerics
    dtype: str = "bfloat16"          # compute / param dtype on target HW

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm_expand * self.d_model

    @property
    def resolved_ssm_heads(self) -> int:
        return self.ssm_heads if self.ssm_heads else max(1, self.d_inner // 64)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def has_encoder(self) -> bool:
        return self.n_enc_layers > 0

    def n_selectable_layers(self) -> int:
        """Length of the paper's masking vector m_i ∈ {0,1}^L for this arch.

        One entry per decoder block, plus encoder blocks (whisper), plus the
        shared attention block for hybrids (zamba2's shared block counts once:
        it is a single set of weights).
        """
        n = self.n_layers
        if self.has_encoder:
            n += self.n_enc_layers
        if self.family == "hybrid" and self.attn_every > 0:
            n += 1  # the shared attention block
        return n

    def with_sliding_window(self, window: int) -> "ArchConfig":
        return replace(self, sliding_window=window)

    def validate(self) -> None:
        assert self.n_heads % max(self.n_kv_heads, 1) == 0 or self.family == "ssm", (
            f"{self.name}: n_heads={self.n_heads} not a multiple of kv={self.n_kv_heads}")
        if self.n_experts:
            assert self.top_k > 0, f"{self.name}: MoE needs top_k"
        if self.family == "hybrid":
            assert self.ssm_state > 0 and self.attn_every > 0
        if self.family == "vlm":
            assert self.n_prefix_tokens > 0
        if self.family == "audio":
            assert self.n_enc_layers > 0 and self.enc_seq > 0


def reduced(cfg: ArchConfig, *, n_layers: int = 2, d_model: int = 256,
            max_experts: int = 4) -> ArchConfig:
    """A smoke-test variant of the same family: ≤2 layers, d_model≤512, ≤4 experts."""
    d = min(d_model, cfg.d_model)
    n_heads = max(2, min(4, cfg.n_heads))
    n_kv = 1 if cfg.n_kv_heads == 1 else max(1, min(2, cfg.n_kv_heads))
    while n_heads % n_kv:
        n_kv -= 1
    changes = dict(
        n_layers=n_layers,
        d_model=d,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        d_ff=min(cfg.d_ff, 4 * d) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
        head_dim=(64 if cfg.head_dim else None),
        dtype="float32",
    )
    if cfg.n_experts:
        changes.update(n_experts=min(cfg.n_experts, max_experts),
                       top_k=min(cfg.top_k, 2),
                       n_shared_experts=min(cfg.n_shared_experts, 1),
                       first_dense=min(cfg.first_dense, 1))
    if cfg.use_mla:
        changes.update(kv_lora_rank=64, qk_rope_dim=16, qk_nope_dim=32, v_head_dim=32)
    if cfg.ssm_state:
        changes.update(ssm_state=min(cfg.ssm_state, 16), ssm_heads=4, ssm_chunk=32)
    if cfg.family == "hybrid":
        changes.update(attn_every=2, n_layers=max(3, n_layers + 1))
    if cfg.has_encoder:
        changes.update(n_enc_layers=2, enc_seq=16)
    if cfg.n_prefix_tokens:
        changes.update(n_prefix_tokens=8)
    if cfg.sliding_window:
        changes.update(sliding_window=16)
    if cfg.task == "classification":
        changes.update(n_classes=cfg.n_classes)
    return replace(cfg, **changes)


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode

    @property
    def lowers(self) -> str:
        return {"train": "train_step", "prefill": "prefill_step",
                "decode": "serve_step"}[self.kind]


INPUT_SHAPES: dict[str, ShapeConfig] = {
    "train_4k":    ShapeConfig("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  ShapeConfig("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeConfig("long_500k",   524_288, 1,   "decode"),
}


# ---------------------------------------------------------------------------
# Federated learning setup (the paper)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FLConfig:
    """Algorithm 1 + Problem (P1) hyper-parameters."""

    n_clients: int = 100            # N
    cohort_size: int = 20           # |S_t|
    rounds: int = 50                # T
    local_steps: int = 1            # tau
    lr: float = 0.01                # eta
    batch_size: int = 64

    # Layer selection
    strategy: str = "ours"          # ours | top | bottom | both | snr | rgn | full
    budget: int = 1                 # R (identical-resource scenario)
    budgets: Optional[Tuple[int, ...]] = None   # heterogeneous per-client R_i
    lam: float = 10.0               # lambda in (P1)
    selection_period: int = 1       # re-select every k rounds ("Sel. Period")
    selection_batches: int = 1      # batches used for the probe gradient ("Sel. Batch")
    seed: int = 0

    # Layer freezing (paper §B.2: embeddings and classifier frozen)
    freeze_embed: bool = True
    freeze_head: bool = True

    def budget_of(self, i: int) -> int:
        if self.budgets is not None:
            return self.budgets[i % len(self.budgets)]
        return self.budget


# ---------------------------------------------------------------------------
# Distributed runtime
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class RuntimeConfig:
    """How a step is laid out on the mesh."""

    multi_pod: bool = False
    zero3: bool = True               # shard frozen base over the client axes
    remat: bool = True               # activation checkpointing per block
    use_pallas: bool = False         # use Pallas kernels (TPU) vs jnp reference
    seq_chunk: int = 1024            # query-chunk for lax attention (prefill)
    unified_selection: bool = True   # static union layer set per round (server-regulated)

    # ---- §Perf levers (default OFF = paper-faithful naive baseline) ----
    tp_constraints: bool = False     # Megatron-style sharding constraints on
                                     # the model axis inside the FL step
    remat_scores: bool = False       # checkpoint each attention query-chunk
                                     # (never materialise all chunks' scores)
    sel_upload: bool = False         # structural R/L upload: backward
                                     # collective over the selected sub-stack
                                     # only (requires static selected set)
    moe_local_dispatch: bool = False # per-sample MoE routing (vmap over
                                     # batch): sort/scatter stay local to the
                                     # data shard instead of a global sort


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ASSIGNED_ARCHS: tuple[str, ...] = (
    "tinyllama_1_1b",
    "grok_1_314b",
    "smollm_360m",
    "zamba2_7b",
    "codeqwen1_5_7b",
    "paligemma_3b",
    "deepseek_v2_lite_16b",
    "mamba2_370m",
    "gemma_7b",
    "whisper_medium",
)

PAPER_ARCHS: tuple[str, ...] = (
    "clip_vit_b32",       # paper: CLIP ViT on CIFAR-10 / DomainNet
    "xlm_roberta_base",   # paper: XGLUE-NC
    "llama2_7b",          # paper: QA datasets
)

_ALIASES = {
    "tinyllama-1.1b": "tinyllama_1_1b",
    "grok-1-314b": "grok_1_314b",
    "smollm-360m": "smollm_360m",
    "zamba2-7b": "zamba2_7b",
    "codeqwen1.5-7b": "codeqwen1_5_7b",
    "paligemma-3b": "paligemma_3b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "mamba2-370m": "mamba2_370m",
    "gemma-7b": "gemma_7b",
    "whisper-medium": "whisper_medium",
    "clip-vit-b32": "clip_vit_b32",
    "xlm-roberta-base": "xlm_roberta_base",
    "llama2-7b": "llama2_7b",
}


def get_arch(name: str) -> ArchConfig:
    """Load ``CONFIG`` from ``repro.configs.<name>`` (accepts dashed ids)."""
    mod_name = _ALIASES.get(name, name.replace("-", "_").replace(".", "_"))
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    cfg: ArchConfig = mod.CONFIG
    cfg.validate()
    return cfg


def all_arch_names(include_paper: bool = False) -> tuple[str, ...]:
    return ASSIGNED_ARCHS + (PAPER_ARCHS if include_paper else ())


def describe(cfg: ArchConfig) -> str:
    bits = [f"{cfg.name} [{cfg.family}] {cfg.n_layers}L d={cfg.d_model}"]
    if cfg.family != "ssm":
        bits.append(f"{cfg.n_heads}H/kv{cfg.n_kv_heads} ff={cfg.d_ff}")
    if cfg.n_experts:
        bits.append(f"MoE {cfg.n_experts}e top-{cfg.top_k}")
    if cfg.ssm_state:
        bits.append(f"ssd state={cfg.ssm_state}")
    bits.append(f"V={cfg.vocab_size}")
    return " ".join(bits)
