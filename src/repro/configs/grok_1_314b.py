"""Grok-1 314B — 8 experts top-2 MoE [hf:xai-org/grok-1]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,        # GQA
    head_dim=128,
    d_ff=32768,          # per-expert FFN width
    vocab_size=131072,
    n_experts=8,
    top_k=2,
    mlp_act="gelu",
    tie_embeddings=True,
    logit_softcap=30.0,
    source="hf:xai-org/grok-1",
)
