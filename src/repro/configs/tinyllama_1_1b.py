"""TinyLlama-1.1B — llama2-arch small [arXiv:2401.02385]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="tinyllama-1.1b",
    family="dense",
    n_layers=22,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,        # GQA
    d_ff=5632,
    vocab_size=32000,
    mlp_act="silu",
    rope_theta=10000.0,
    tie_embeddings=False,
    source="arXiv:2401.02385",
)
