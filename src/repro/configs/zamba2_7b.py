"""Zamba2-7B — Mamba2 backbone + shared attention blocks [arXiv:2411.15242].

81 total selectable SSM blocks with one *shared* attention block applied every
``attn_every`` SSM blocks (zamba2's parameter-shared transformer block).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,          # mamba2 blocks
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,        # the shared attention block is MHA
    d_ff=14336,           # FFN of the shared block
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_chunk=128,
    attn_every=6,         # shared attn applied after every 6th mamba block
    sliding_window=4096,  # long_500k: windowed KV for the shared block
    mlp_act="gelu",
    tie_embeddings=True,
    source="arXiv:2411.15242",
)
