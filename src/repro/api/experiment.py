"""Experiment: the composable front door of the federation API.

    from repro.api import Experiment
    from repro.api.task import DirichletTaskConfig, DirichletTokenMixtureTask

    exp = Experiment(model_cfg, task, strategy="ours",
                     cohort_size=8, rounds=20, budget=2)
    params, history = exp.run(verbose=True)

``Experiment`` wires the three protocols together — a model (ArchConfig or
an already-built Model), a :class:`repro.api.task.Task`, and a strategy
(registered name or Strategy instance, including per-client
:class:`~repro.api.strategy.MixtureStrategy` objects) — and builds the
round engine (``engine="vectorized" | "sequential"``; ``pipeline_depth=k``
sets how many rounds ahead the streaming scheduler plans/samples, see
``repro.core.scheduler``).  FL hyper-parameters
come from an explicit ``fl=FLConfig(...)`` or keyword overrides
(``rounds=...``, ``budget=...``, ...); ``n_clients`` always follows the
task.  ``FLServer(model, fl, data)`` with a string strategy remains the
thin back-compat construction path and produces bit-identical rounds
(pinned in tests/test_api.py).
"""
from __future__ import annotations

from dataclasses import replace
from typing import Any, Optional, Union

import jax
import numpy as np

from repro.api.strategy import Strategy, get_strategy
from repro.api.task import Task
from repro.configs.base import ArchConfig, FLConfig, RuntimeConfig
from repro.core.server import FLServer, History
from repro.models.model import Model

PyTree = Any


class Experiment:
    """Builder for a federated fine-tuning run over the pluggable API."""

    def __init__(self, model: Union[ArchConfig, Model], task: Task,
                 strategy: Union[str, Strategy] = "ours", *,
                 fl: Optional[FLConfig] = None,
                 runtime: Optional[RuntimeConfig] = None,
                 engine: str = "vectorized",
                 pipeline: Optional[bool] = None,
                 pipeline_depth: int = 1,
                 mask_aware: Optional[bool] = None,
                 checkpoint_dir: Optional[str] = None,
                 checkpoint_every: int = 10,
                 faults: Optional[object] = None,
                 solver_deadline_s: Optional[float] = None,
                 pretrain_steps: int = 0, pretrain_lr: float = 3e-3,
                 seed: Optional[int] = None,
                 **fl_overrides):
        if isinstance(model, Model):
            self.model = model
        else:
            self.model = Model(model, runtime
                               or RuntimeConfig(remat=False, seq_chunk=32))
        self.task = task
        self.strategy = get_strategy(strategy)
        n_clients = len(np.asarray(task.sizes))
        fl = fl if fl is not None else FLConfig()
        changes = dict(fl_overrides, n_clients=n_clients)
        if seed is not None:
            changes["seed"] = seed
        # keep the record/back-compat string in sync with the resolved
        # strategy object (mixtures report their synthetic 'mixture' name)
        changes["strategy"] = self.strategy.name
        self.fl = replace(fl, **changes)
        if self.fl.cohort_size > n_clients:
            self.fl = replace(self.fl, cohort_size=n_clients)
        self.engine = engine
        self.pipeline = pipeline
        self.pipeline_depth = pipeline_depth
        # None = auto: the mask-aware (frozen-prefix-skipping) update
        # program wherever the family supports it (DESIGN.md §7)
        self.mask_aware = mask_aware
        # round-boundary checkpoint/resume (None = off): run() saves every
        # checkpoint_every rounds + at the end, and auto-resumes from the
        # latest checkpoint under checkpoint_dir
        self.checkpoint_dir = checkpoint_dir
        self.checkpoint_every = checkpoint_every
        # chaos seam (DESIGN.md §12): a FaultPlan/FaultInjector, or None.
        # Wired-but-disabled is contractually bit-identical to None.
        self.faults = faults
        self.solver_deadline_s = solver_deadline_s
        self.pretrain_steps = pretrain_steps
        self.pretrain_lr = pretrain_lr
        self._server: Optional[FLServer] = None

    # ------------------------------------------------------------------
    def build(self) -> FLServer:
        """Construct (once) and return the round engine."""
        if self._server is None:
            self._server = FLServer(self.model, self.fl, self.task,
                                    engine=self.engine,
                                    pipeline=self.pipeline,
                                    pipeline_depth=self.pipeline_depth,
                                    strategy=self.strategy,
                                    mask_aware=self.mask_aware,
                                    checkpoint_dir=self.checkpoint_dir,
                                    checkpoint_every=self.checkpoint_every,
                                    faults=self.faults,
                                    solver_deadline_s=self.solver_deadline_s)
        return self._server

    @property
    def server(self) -> FLServer:
        return self.build()

    def init_params(self) -> PyTree:
        """Fresh params; pretrains the foundation-model stand-in when
        ``pretrain_steps > 0`` (requires the task's ``pretrain_batch``)."""
        params = self.model.init(jax.random.PRNGKey(self.fl.seed))
        if self.pretrain_steps > 0:
            from repro.data.pretrain import pretrain
            params = pretrain(self.model, params, self.task,
                              steps=self.pretrain_steps, lr=self.pretrain_lr)
        return params

    def run(self, params: Optional[PyTree] = None,
            rounds: Optional[int] = None,
            verbose: bool = False, resume: bool = True
            ) -> tuple[PyTree, History]:
        """Run Algorithm 1 for ``rounds`` (default ``fl.rounds``).

        With ``checkpoint_dir`` set, state is saved at round boundaries and
        — unless ``resume=False`` — the latest checkpoint under that dir is
        restored first: params, client-state store, rng streams, and
        History, so the continued run is bit-identical on masks to one that
        never stopped.  A checkpoint at or past ``rounds`` returns
        immediately with the restored result."""
        server = self.build()
        start, history = 0, None
        if resume and self.checkpoint_dir is not None:
            restored = server.restore_state(
                params if params is not None
                else self.model.init(jax.random.PRNGKey(self.fl.seed)))
            if restored is not None:
                params, start, history = restored
        if params is None:
            params = self.init_params()
        return server.run(params, rounds=rounds, verbose=verbose,
                          start=start, history=history)
