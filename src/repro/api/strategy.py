"""Strategy protocol + registry: layer selection as a pluggable primitive.

The paper's central lever is the *layer selection strategy*; here it is a
first-class component instead of a string ``if/elif``.  A strategy is an
object with three declarations the round engines consume:

* ``probe_requirements`` — which probe statistics it needs
  (subset of :data:`PROBE_KEYS`).  ``Client.probe_cohort`` computes *only*
  the requested stats, so e.g. ``ours`` pays for gradient square norms only
  while ``snr`` pays for mean/var — not every strategy pays for everything.
* ``host`` — True for strategies whose selection is a host-side solve
  (``ours``/``unified`` run the (P1) solver on L floats per client); False
  for score-based strategies, which additionally expose a device-side
  :meth:`ScoreStrategy.score_device` (pure ``jnp``) so the per-layer score
  can fuse into the vectorized probe program (the mask top-k itself stays
  on the host — it is O(n·L) on tiny arrays).
* ``select(probe, budgets, ctx)`` — the (cohort, L) mask matrix.

Strategies register by name::

    @register_strategy("my_strategy")
    class MyStrategy(Strategy):
        probe_requirements = frozenset({"grad_sq_norms"})
        def select(self, probe, budgets, ctx): ...

and are resolved with :func:`get_strategy`, which accepts either a name or
a ``Strategy`` instance and raises :class:`UnknownStrategyError` (with the
registered names and a nearest-match suggestion) for unknown names.

:class:`MixtureStrategy` is the per-client heterogeneous meta-strategy:
it maps client ids to registered strategies, requests the union of their
probe requirements, and routes each cohort row to its owner's ``select``.
"""
from __future__ import annotations

import difflib
from dataclasses import dataclass, replace
from typing import Callable, Iterable, Optional, Union

import jax.numpy as jnp
import numpy as np

from repro.core.solver import get_solver
from repro.core.strategies import (PROBE_KEYS, ProbeReport, _positional,
                                   _score_topk)

StrategyLike = Union[str, "Strategy"]


class UnknownStrategyError(KeyError, ValueError):
    """Unknown strategy name.  Subclasses both KeyError and ValueError so
    pre-registry callers catching either keep working."""

    def __init__(self, name: str, registered: tuple[str, ...]):
        self.name = name
        self.registered = registered
        close = difflib.get_close_matches(str(name), registered, n=1,
                                          cutoff=0.4)
        hint = f" — did you mean {close[0]!r}?" if close else ""
        super().__init__(
            f"unknown strategy {name!r}{hint} "
            f"(registered: {', '.join(registered)})")

    def __str__(self) -> str:      # KeyError would repr() the message
        return self.args[0]


@dataclass(frozen=True)
class SelectionContext:
    """Host-side context the engines hand to ``Strategy.select``."""

    client_ids: np.ndarray                 # (n,) cohort client ids
    round: int = 0
    lam: float = 10.0                      # λ in (P1)
    costs: Optional[np.ndarray] = None     # (L,) per-layer cost vector
    n_layers: int = 0
    eps: float = 1e-12
    # warm-start hint for iterative host solvers: the cohort's previous
    # converged mask rows (aligned with client_ids), or None for a cold
    # start.  FLServer fills this from its per-client-id mask cache; a
    # strategy is free to ignore it.
    init: Optional[np.ndarray] = None      # (n, L) previous masks


class Strategy:
    """Base class for layer-selection strategies."""

    name: str = "?"
    probe_requirements: frozenset = frozenset()
    host: bool = False
    # True => select() is a pure function of (probe, budgets, client_ids,
    # lam, costs) — notably independent of ctx.round — so the round engines
    # may skip the solve when those inputs are byte-identical to the
    # previous round ("unchanged utilities" early exit).  Leave False for
    # strategies with round-dependent schedules (exploration, annealing).
    memoizable_select: bool = False

    def select(self, probe: ProbeReport, budgets,
               ctx: SelectionContext) -> np.ndarray:
        """Return the (cohort, L) float32 mask matrix."""
        raise NotImplementedError

    def device_score_fn(self) -> Optional[Callable]:
        """A jnp stats-dict → (n, L) scores callable to fuse into the
        vectorized probe program, or None (host/positional strategies)."""
        return None

    def __repr__(self) -> str:
        return f"<Strategy {self.name}>"


class ScoreStrategy(Strategy):
    """Strategies that rank layers by a per-layer score.

    Subclasses implement :meth:`score_device` with pure ``jnp`` ops over the
    requested stats; the same formula serves both paths: fused on device
    inside the vectorized probe (``probe.scores``), or on the host from the
    uploaded stats (the sequential oracle and the ``select()`` shim).
    """

    def score_device(self, stats: dict, eps: float = 1e-12):
        raise NotImplementedError

    def device_score_fn(self) -> Callable:
        return self.score_device

    def select(self, probe: ProbeReport, budgets,
               ctx: SelectionContext) -> np.ndarray:
        scores = probe.scores
        if scores is None:
            stats = {k: getattr(probe, k) for k in PROBE_KEYS
                     if getattr(probe, k) is not None}
            scores = self.score_device(stats, eps=ctx.eps)
        return _score_topk(np.asarray(scores), budgets)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, Strategy] = {}


def register_strategy(name: str, *, aliases: Iterable[str] = ()):
    """Class/instance decorator: register under ``name`` (+ aliases)."""

    def deco(obj):
        inst = obj() if isinstance(obj, type) else obj
        inst.name = name
        _REGISTRY[name] = inst
        for a in aliases:
            _REGISTRY[a] = inst
        return obj

    return deco


def strategy_names() -> tuple[str, ...]:
    """All registered names (canonical names and aliases), sorted."""
    return tuple(sorted(_REGISTRY))


def get_strategy(strategy: StrategyLike) -> Strategy:
    """Resolve a name (or pass through an instance) to a Strategy."""
    if isinstance(strategy, Strategy):
        return strategy
    try:
        return _REGISTRY[strategy]
    except KeyError:
        raise UnknownStrategyError(strategy, strategy_names()) from None


# ---------------------------------------------------------------------------
# Built-in strategies (the paper's §5.1 baselines + ours)
# ---------------------------------------------------------------------------

class _Positional(Strategy):
    """No probe needed: masks depend only on position and budget."""

    def __init__(self, mode: str):
        self._mode = mode

    def select(self, probe, budgets, ctx):
        return _positional(probe.n, probe.L, budgets, self._mode)


register_strategy("top")(_Positional("top"))
register_strategy("bottom")(_Positional("bottom"))
register_strategy("both")(_Positional("both"))


@register_strategy("full")
class _Full(Strategy):
    def select(self, probe, budgets, ctx):
        return np.ones((probe.n, probe.L), np.float32)


@register_strategy("snr")
class _SNR(ScoreStrategy):
    """Highest |mean(g)| / var(g) per layer [Mahsereci+17]."""

    probe_requirements = frozenset({"grad_means", "grad_vars"})

    def score_device(self, stats, eps: float = 1e-12):
        return jnp.abs(stats["grad_means"]) / (stats["grad_vars"] + eps)


@register_strategy("rgn")
class _RGN(ScoreStrategy):
    """Highest ‖g_l‖ / ‖θ_l‖ (relative gradient norm) [Lee+22]."""

    probe_requirements = frozenset({"grad_sq_norms", "param_sq_norms"})

    def score_device(self, stats, eps: float = 1e-12):
        return (jnp.sqrt(stats["grad_sq_norms"])
                / (jnp.sqrt(stats["param_sq_norms"]) + eps))


@register_strategy("gradnorm")
class _GradNorm(ScoreStrategy):
    """Highest raw ‖g_l‖² — the λ=0 limit of (P1), useful as a mixture
    member and as the cheapest probe-based baseline."""

    probe_requirements = frozenset({"grad_sq_norms"})

    def score_device(self, stats, eps: float = 1e-12):
        return stats["grad_sq_norms"]


class _OursSolver(Strategy):
    """(P1) host solver — λ consistency-regularised selection (§4.2)."""

    host = True
    probe_requirements = frozenset({"grad_sq_norms"})
    memoizable_select = True          # (P1) is round-independent

    def __init__(self, solver: str):
        self._solver = solver

    def select(self, probe, budgets, ctx):
        solve = get_solver(self._solver)
        if self._solver == "icm":
            # ctx.init warm-starts the block-coordinate ascent from the
            # cohort's previous converged masks — fewer sweeps once layer
            # utilities stabilise, still budget-exact (core/solver.py)
            masks, _, _ = solve(probe.grad_sq_norms, budgets, ctx.lam,
                                costs=ctx.costs, init=ctx.init)
            return masks
        return solve(probe.grad_sq_norms, budgets, costs=ctx.costs)


register_strategy("ours")(_OursSolver("icm"))
register_strategy("ours_unified", aliases=("unified",))(
    _OursSolver("unified"))


# ---------------------------------------------------------------------------
# Per-client heterogeneous mixtures
# ---------------------------------------------------------------------------

class MixtureStrategy(Strategy):
    """Meta-strategy: client ids → registered strategies.

    ``assignment`` is a ``{client_id: strategy}`` dict or a
    ``client_id -> strategy`` callable (values are names or instances);
    unmapped clients fall back to ``default``.  With a callable assignment,
    pass ``members`` so the probe requirements (the union over all member
    strategies) are known up front.  Device score fusion is disabled —
    each member scores its own rows from the uploaded stats.

    Selection runs each member strategy on *its own client rows*: joint
    solvers like ``ours`` couple clients within their group via λ (their
    consistency regulariser sees only same-strategy cohort members), while
    score/positional members are row-independent anyway.
    """

    name = "mixture"

    def __init__(self, assignment, default: StrategyLike = "ours", *,
                 members: Iterable[StrategyLike] = ()):
        self._default = get_strategy(default)
        if callable(assignment):
            self._fn = assignment
            declared = list(members)
            if not declared:
                raise ValueError(
                    "MixtureStrategy with a callable assignment needs "
                    "members=[...] to declare its probe requirements")
        else:
            mapping = {int(k): get_strategy(v) for k, v in assignment.items()}
            self._fn = mapping.get
            declared = list(mapping.values())
        self._members = tuple(dict.fromkeys(            # order-stable unique
            [get_strategy(m) for m in declared] + [self._default]))
        self.probe_requirements = frozenset().union(
            *(m.probe_requirements for m in self._members))
        self.host = any(m.host for m in self._members)
        # routing is by client id (in the memo key), so the mixture is
        # memoizable iff every member is
        self.memoizable_select = all(
            getattr(m, "memoizable_select", False) for m in self._members)

    def strategy_of(self, client_id: int) -> Strategy:
        s = self._fn(int(client_id))
        return self._default if s is None else get_strategy(s)

    def select(self, probe, budgets, ctx):
        ids = np.asarray(ctx.client_ids)
        n, L = probe.n, probe.L
        budgets = np.broadcast_to(np.asarray(budgets, int), (n,))
        owners = [self.strategy_of(i) for i in ids]
        masks = np.zeros((n, L), np.float32)
        for strat in dict.fromkeys(owners):
            rows = np.array([r for r, o in enumerate(owners) if o is strat])
            sub = replace(ctx, client_ids=ids[rows],
                          init=None if ctx.init is None else ctx.init[rows])
            masks[rows] = strat.select(probe.take(rows), budgets[rows], sub)
        return masks


__all__ = [
    "PROBE_KEYS", "ProbeReport", "SelectionContext", "Strategy",
    "ScoreStrategy", "MixtureStrategy", "UnknownStrategyError",
    "register_strategy", "get_strategy", "strategy_names",
]
