"""Pluggable federation API: Strategy registry, Task protocol, Experiment.

Three composable protocols (DESIGN.md §6):

* **Strategy** (``repro.api.strategy``) — layer selection as a registered,
  swappable component with declared probe requirements.
* **Task** (``repro.api.task``) — the datasource seam: cohort batch
  sampling, held-out eval, per-client sizes, plus plan-stage
  availability/straggler hooks.
* **Experiment** (``repro.api.experiment``) — the front door that wires a
  model, a task and a strategy into a round engine.

``Experiment`` is imported lazily (PEP 562): ``repro.core.server`` imports
the strategy registry at module level, and ``experiment`` imports the
server back — resolving it on first attribute access breaks the cycle.
"""
from repro.api.strategy import (PROBE_KEYS, MixtureStrategy,  # noqa: F401
                                ProbeReport, ScoreStrategy, SelectionContext,
                                Strategy, UnknownStrategyError, get_strategy,
                                register_strategy, strategy_names)
from repro.api.task import (ChaosTask, DirichletTaskConfig,  # noqa: F401
                            DirichletTokenMixtureTask, Task)

__all__ = [
    "PROBE_KEYS", "ProbeReport", "SelectionContext", "Strategy",
    "ScoreStrategy", "MixtureStrategy", "UnknownStrategyError",
    "register_strategy", "get_strategy", "strategy_names",
    "Task", "ChaosTask", "DirichletTaskConfig", "DirichletTokenMixtureTask",
    "Experiment",
]


def __getattr__(name):
    if name == "Experiment":
        from repro.api.experiment import Experiment
        return Experiment
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
