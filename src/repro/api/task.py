"""Task protocol: the datasource seam of the federation API.

A *Task* is anything the round engines can federate over.  The required
surface (structural — no inheritance needed) is:

* ``sizes`` — (n_clients,) int array of per-client dataset sizes d_i
  (Eq. 1's α_i = d_i / Σ d_j are derived from these);
* ``cohort_batches(cohort, batch_size, n)`` — stacked host batches with
  leading ``(len(cohort), n)`` axes, drawn from each member's stream;
* ``test_batch(batch_size=None)`` — the held-out eval batch.  Must be
  deterministic across calls (draw the set once, return a fixed slice):
  the streaming pipeline fetches it once per run, while the synchronous
  loop calls it every round — a per-call-random implementation would make
  the two documented-identical paths diverge.

Optional plan-stage hooks (consumed by ``FLServer.plan_round``):

* ``available_clients(t, rng) -> ids`` — the pool the round-t cohort is
  drawn from (cross-device FL: only a fraction of clients is reachable in
  any round).  Return None/omit for full availability.
* ``drop_stragglers(t, cohort, rng) -> keep_mask`` — boolean mask over the
  drawn cohort; members marked False fail to report this round and are
  dropped before probing/budgeting (the engine never drops everyone).

Fault model note (DESIGN.md §12): these hooks model *pre-round* attrition
— the engine plans around them before any compute is spent.  *Mid-round*
failure (a sampled client dying after local training started, or reporting
a poisoned delta) is the injector's domain (``repro.faults``), handled by
survivor-reweighted aggregation inside the round step.  :class:`ChaosTask`
below drives the hooks to their edge cases (empty pools, all-straggler
rounds) for the degradation tests.

Optional extras some drivers use: ``client_batch(i, batch_size)`` and
``pretrain_batch(batch_size)`` (the foundation-model stand-in,
``data/pretrain.py``), and ``alpha`` (population data ratios).

Optional checkpoint hooks (consumed by ``FLServer.save_state`` /
``restore_state``): ``state_dict() -> {name: np.ndarray}`` and
``load_state_dict(d)`` — the task's resumable stream state as flat arrays
("/"-namespaced keys).  Tasks without them simply aren't checkpointed
(resume then replays their streams from construction, which is only exact
for stateless tasks).

``SyntheticFederatedData`` implements the protocol as-is;
:class:`DirichletTokenMixtureTask` below is a second, independent
implementation proving the seam — a Dirichlet-partitioned topic-mixture
text task with built-in availability windows and stragglers.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Protocol, runtime_checkable

import numpy as np

from repro.core.state import (ClientStreamState, rng_state_from_arrays,
                              rng_state_to_arrays, sub_state)


@runtime_checkable
class Task(Protocol):
    """Structural datasource protocol for the round engines."""

    sizes: np.ndarray

    def cohort_batches(self, cohort, batch_size: int, n: int) -> dict: ...

    def test_batch(self, batch_size: Optional[int] = None) -> dict: ...


@dataclass
class DirichletTaskConfig:
    """A Dirichlet-partitioned token-mixture task (non-IID text analogue).

    Each of ``n_topics`` topics owns a token distribution; client i's topic
    weights are drawn from Dirichlet(α) — the standard partition protocol
    the paper's CIFAR-10 split uses, here over topics instead of labels.
    A sample draws its topic from the client's weights, its label *is* the
    topic, and ``signal`` of the positions carry topic-conditional tokens.
    """

    n_clients: int = 32
    n_topics: int = 8
    vocab_size: int = 512
    seq_len: int = 32
    samples_per_client: int = 64
    dirichlet_alpha: float = 0.5
    objective: str = "classification"     # classification | lm
    test_samples: int = 256
    seed: int = 0
    signal: float = 0.7
    # --- plan-stage heterogeneity hooks -------------------------------
    # fraction of clients reachable per round (1.0 = everyone, no hook
    # effect); the available pool is a deterministic rotating window, so
    # tests can recompute it
    availability: float = 1.0
    # probability a drawn cohort member fails to report (straggler drop)
    straggler_rate: float = 0.0


class DirichletTokenMixtureTask:
    """Second Task implementation (independent of SyntheticFederatedData)."""

    def __init__(self, cfg: DirichletTaskConfig):
        self.cfg = cfg
        rng = np.random.RandomState(cfg.seed)
        K, V = cfg.n_topics, cfg.vocab_size

        # topic-conditional token distributions: each topic prefers a band
        logits = rng.randn(K, V) * 0.5
        for k in range(K):
            logits[k, np.arange(V) % K == k] += 3.0
        probs = np.exp(logits) / np.exp(logits).sum(-1, keepdims=True)
        cdf = np.cumsum(probs, axis=1)
        self._topic_cdf = cdf / cdf[:, -1:]

        # Dirichlet partition: per-client topic weights
        self.client_topic_p = rng.dirichlet(
            np.full(K, cfg.dirichlet_alpha), size=cfg.n_clients)
        tcdf = np.cumsum(self.client_topic_p, axis=1)
        self._client_cdf = tcdf / tcdf[:, -1:]

        self.sizes = np.maximum(
            (cfg.samples_per_client *
             np.exp(rng.randn(cfg.n_clients) * 0.3)).astype(int), 8)
        # lazy per-client streams (flat positions + on-first-touch rngs):
        # same per-(seed, i) stream seeds as the old eager list, O(touched)
        # memory at population scale, checkpointable via state_dict
        self._streams = ClientStreamState(
            cfg.n_clients, lambda i, s=cfg.seed: s * 977 + 13 * i + 5)
        self._heldout_rng = np.random.RandomState(cfg.seed + 131071)
        self._pretrain_rng = np.random.RandomState(cfg.seed + 524287)
        self._test_set: Optional[dict] = None

    @property
    def _rngs(self) -> ClientStreamState:
        """Back-compat: ``task._rngs[i]`` still yields client i's stream."""
        return self._streams

    def stream_positions(self) -> np.ndarray:
        """(n_clients,) samples drawn per client stream so far."""
        return self._streams.positions.copy()

    def state_dict(self) -> dict[str, np.ndarray]:
        """Flat-array resumable state (see the Task protocol docstring).
        The held-out rng is not saved: the fixed test set is its first and
        only consumer, so a fresh task redraws it identically."""
        d = {f"streams/{k}": v for k, v in self._streams.state_dict().items()}
        d.update({f"pretrain_rng/{k}": v for k, v in
                  rng_state_to_arrays(self._pretrain_rng).items()})
        return d

    def load_state_dict(self, d: dict[str, np.ndarray]) -> None:
        self._streams.load_state_dict(sub_state(d, "streams/"))
        rng_state_from_arrays(sub_state(d, "pretrain_rng/"),
                              self._pretrain_rng)

    # ------------------------------------------------------------------
    @property
    def n_clients(self) -> int:
        return self.cfg.n_clients

    @property
    def alpha(self) -> np.ndarray:
        return self.sizes / self.sizes.sum()

    # -- sampling --------------------------------------------------------
    def _draw(self, rng: np.random.RandomState, topic_cdf_row: np.ndarray,
              n: int) -> dict:
        cfg = self.cfg
        y = np.searchsorted(topic_cdf_row, rng.random_sample(n),
                            side="right").astype(np.int64)
        sig = rng.random_sample((n, cfg.seq_len))
        u = rng.random_sample((n, cfg.seq_len))
        noise = rng.randint(0, cfg.vocab_size, (n, cfg.seq_len))
        topical = np.empty((n, cfg.seq_len), np.int64)
        for k in np.unique(y):
            m = y == k
            topical[m] = np.searchsorted(self._topic_cdf[k], u[m],
                                         side="right")
        toks = np.where(sig < cfg.signal, topical, noise).astype(np.int32)
        batch = {"tokens": toks}
        if cfg.objective == "classification":
            batch["label"] = y.astype(np.int32)
        return batch

    def client_batch(self, i: int, batch_size: int) -> dict:
        self._streams.advance(i, batch_size)
        return self._draw(self._streams.rng(i), self._client_cdf[i],
                          batch_size)

    def client_batches(self, i: int, batch_size: int, n: int) -> dict:
        self._streams.advance(i, n * batch_size)
        flat = self._draw(self._streams.rng(i), self._client_cdf[i],
                          n * batch_size)
        return {k: v.reshape((n, batch_size) + v.shape[1:])
                for k, v in flat.items()}

    def cohort_batches(self, cohort, batch_size: int, n: int) -> dict:
        per = [self.client_batches(int(i), batch_size, n) for i in cohort]
        return {k: np.stack([b[k] for b in per]) for k in per[0]}

    def pretrain_batch(self, batch_size: int) -> dict:
        """Balanced topic mixture — the 'pretraining corpus' stand-in."""
        uniform = np.linspace(1 / self.cfg.n_topics, 1.0, self.cfg.n_topics)
        return self._draw(self._pretrain_rng, uniform, batch_size)

    def test_batch(self, batch_size: Optional[int] = None) -> dict:
        cfg = self.cfg
        n = batch_size or cfg.test_samples
        if n > cfg.test_samples:
            raise ValueError(f"test_batch({n}) exceeds the fixed held-out "
                             f"set (test_samples={cfg.test_samples})")
        if self._test_set is None:
            rng = self._heldout_rng
            owners = rng.choice(cfg.n_clients, size=cfg.test_samples,
                                p=self.alpha)
            outs = {}
            for i in np.unique(owners):
                m = owners == i
                # repro: allow[host-sync] -- one-time test-set assembly on host np arrays, not a round loop
                outs[int(i)] = (m, self._draw(rng, self._client_cdf[i],
                                              int(m.sum())))  # repro: allow[host-sync] -- host np owner counts
            sample = next(iter(outs.values()))[1]
            merged = {k: np.empty((cfg.test_samples,) + v.shape[1:], v.dtype)
                      for k, v in sample.items()}
            for m, b in outs.values():
                for k in merged:
                    merged[k][m] = b[k]
            self._test_set = merged
        return {k: v[:n] for k, v in self._test_set.items()}

    # -- plan-stage hooks ------------------------------------------------
    def available_pool(self, t: int) -> np.ndarray:
        """The deterministic rotating availability window for round t."""
        cfg = self.cfg
        n = cfg.n_clients
        k = max(1, int(round(n * cfg.availability)))
        start = (t * max(1, n // 4)) % n
        return (start + np.arange(k)) % n

    def available_clients(self, t: int, rng: np.random.RandomState):
        if self.cfg.availability >= 1.0:
            return None                     # full availability: no hook effect
        return self.available_pool(t)

    def drop_stragglers(self, t: int, cohort: np.ndarray,
                        rng: np.random.RandomState) -> np.ndarray:
        if self.cfg.straggler_rate <= 0.0:
            return np.ones(len(cohort), bool)
        return rng.random_sample(len(cohort)) >= self.cfg.straggler_rate


class ChaosTask:
    """Wrap any Task and force its plan-stage hooks to the worst case on
    chosen rounds — the adversarial fixture of the degradation tests
    (DESIGN.md §12).

    ``empty_pool_rounds``: rounds whose availability pool is empty (no
    client reachable); ``all_straggler_rounds``: rounds where every drawn
    cohort member fails to report.  All other behaviour — data streams,
    sizes, checkpoint hooks — delegates verbatim to ``inner``, so a
    ChaosTask run is bit-identical to the inner task outside the listed
    rounds.
    """

    def __init__(self, inner, *, empty_pool_rounds=(),
                 all_straggler_rounds=()):
        self.inner = inner
        self.empty_pool_rounds = frozenset(int(t) for t in empty_pool_rounds)
        self.all_straggler_rounds = frozenset(
            int(t) for t in all_straggler_rounds)

    def __getattr__(self, name):
        return getattr(self.inner, name)

    @property
    def sizes(self) -> np.ndarray:
        return self.inner.sizes

    def cohort_batches(self, cohort, batch_size: int, n: int) -> dict:
        return self.inner.cohort_batches(cohort, batch_size, n)

    def test_batch(self, batch_size: Optional[int] = None) -> dict:
        return self.inner.test_batch(batch_size)

    def available_clients(self, t: int, rng: np.random.RandomState):
        if t in self.empty_pool_rounds:
            return np.zeros(0, np.int64)
        hook = getattr(self.inner, "available_clients", None)
        return hook(t, rng) if callable(hook) else None

    def drop_stragglers(self, t: int, cohort: np.ndarray,
                        rng: np.random.RandomState) -> np.ndarray:
        if t in self.all_straggler_rounds:
            return np.zeros(len(cohort), bool)
        hook = getattr(self.inner, "drop_stragglers", None)
        if callable(hook):
            return hook(t, cohort, rng)
        return np.ones(len(cohort), bool)
