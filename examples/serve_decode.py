"""Serve a (fine-tuned) model: batched greedy decoding with a KV cache.

Demonstrates the serve path the decode_32k / long_500k dry-run shapes
lower — including the sliding-window variant for long contexts.

    PYTHONPATH=src python examples/serve_decode.py --arch tinyllama-1.1b \
        --batch 4 --prompt-len 12 --gen 20 [--window 8]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs.base import RuntimeConfig, get_arch, reduced
from repro.models.model import Model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--gen", type=int, default=20)
    ap.add_argument("--window", type=int, default=0,
                    help="sliding-window size (long-context serving mode)")
    args = ap.parse_args()

    cfg = reduced(get_arch(args.arch))
    model = Model(cfg, RuntimeConfig(remat=False, seq_chunk=32))
    params = model.init(jax.random.PRNGKey(0))

    max_seq = args.prompt_len + args.gen
    cache = model.init_cache(args.batch, max_seq, window=args.window)
    # repro: allow[jit-outside-cache] -- one-shot demo script; jitted once per process, no suite cache to share
    step = jax.jit(lambda p, tok, pos, c: model.decode_step(
        p, tok, pos, c, window=args.window))

    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)
    seqs = [prompt[:, t] for t in range(args.prompt_len)]

    # prefill via decode steps (teacher-forced), then greedy generation
    tok = prompt[:, 0]
    for t in range(max_seq - 1):
        logits, cache = step(params, tok, jnp.int32(t), cache)
        if t + 1 < args.prompt_len:
            tok = prompt[:, t + 1]
        else:
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            seqs.append(tok)

    out = jnp.stack(seqs, axis=1)
    print(f"arch={cfg.name} window={args.window or 'full'} "
          f"cache entries={args.window or max_seq}")
    for b in range(args.batch):
        toks = out[b].tolist()
        print(f"  seq[{b}]: prompt={toks[:args.prompt_len]} "
              f"gen={toks[args.prompt_len:]}")


if __name__ == "__main__":
    main()
