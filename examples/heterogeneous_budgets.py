"""Heterogeneous-resource FL (Table 2 scenario) with theory diagnostics.

Clients get budgets R_i ~ truncated half-normal on [1,4]; we run the
paper's strategy vs. the positional baselines and report, per round, the
theory quantities E_t1 / E_t2 from §4.1 — showing the error floor the
selection strategy is implicitly minimising.  Runs through the
``repro.api.Experiment`` front door; each Experiment shares the
module-level jit suite, so only the first compiles.

    PYTHONPATH=src python examples/heterogeneous_budgets.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.api import Experiment
from repro.configs.base import RuntimeConfig, get_arch, reduced
from repro.core import theory
from repro.core.masks import union_mask
from repro.data.pretrain import pretrain
from repro.data.synthetic import FederatedTaskConfig, SyntheticFederatedData
from repro.models.model import Model

N = 16
SMOKE = os.environ.get("REPRO_SMOKE") == "1"


def half_normal_budgets(n, lo=1, hi=4, seed=0):
    rng = np.random.RandomState(seed)
    v = np.abs(rng.randn(n)) * (hi - lo) / 2 + lo
    return tuple(int(x) for x in np.clip(np.round(v), lo, hi))


def main():
    cfg = reduced(get_arch("xlm-roberta-base"), n_layers=6, d_model=64)
    model = Model(cfg, RuntimeConfig(remat=False, seq_chunk=32))
    data = SyntheticFederatedData(FederatedTaskConfig(
        n_clients=N, vocab_size=cfg.vocab_size, seq_len=16, skew="feature",
        objective="classification", signal=0.8, domain_strength=0.4))
    params = pretrain(model, model.init(jax.random.PRNGKey(0)), data,
                      steps=30 if SMOKE else 200, lr=3e-3)
    budgets = half_normal_budgets(N)
    print("client budgets R_i:", budgets)

    # full-batch per-client grads for theory terms (small model => feasible)
    batches = [data.client_batch(i, 32) for i in range(N)]
    gg = theory.global_gradient(model, params, batches, data.alpha)
    cg = theory.per_client_gradients(model, params, batches)
    kappa = theory.kappa_per_layer(model, gg, cg)
    print("kappa_l (gradient diversity):", np.round(kappa, 3))

    strategies = ("ours", "top") if SMOKE else ("ours", "top", "bottom", "rgn")
    for strategy in strategies:
        exp = Experiment(model, data, strategy,
                         cohort_size=4, rounds=3 if SMOKE else 12,
                         local_steps=2, lr=0.01, batch_size=16,
                         budgets=budgets, lam=1.0)
        new_params, hist = exp.run(params)
        # theory terms for this strategy's LAST-round selection
        rec = hist.records[-1]
        e1 = theory.e_t1(model, gg, union_mask(rec.mask_matrix))
        e2 = theory.e_t2(rec.mask_matrix, data.sizes[rec.cohort], kappa,
                         population_alpha=data.alpha, cohort_idx=rec.cohort)
        s = hist.summary()
        print(f"{strategy:7s}: best_acc={s['best_acc']:.3f} "
              f"final={s['final_acc']:.3f}  E_t1={e1:.4f} E_t2={e2:.4f} "
              f"(error floor ∝ E_t1+E_t2 = {e1 + e2:.4f})")

    from repro.core.client import jit_cache_stats
    print("jit suite cache:", jit_cache_stats())


if __name__ == "__main__":
    main()
