"""End-to-end FL fine-tuning driver: pretrain -> Algorithm 1 -> checkpoint.

Default is a ~15M-parameter llama-family model trained for 200 rounds on
CPU (a few minutes); scale up with --layers/--d-model/--rounds (the model
definition is the same one the 1.1B config uses).

    PYTHONPATH=src python examples/fl_finetune_e2e.py \
        --arch tinyllama-1.1b --layers 8 --d-model 256 --rounds 200 \
        --strategy ours --budget 2 --ckpt /tmp/fl_ckpt
"""
import argparse
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.api import Experiment
from repro.ckpt import latest_step, restore_checkpoint, save_checkpoint
from repro.configs.base import RuntimeConfig, get_arch, reduced
from repro.data.pretrain import pretrain
from repro.data.synthetic import FederatedTaskConfig, SyntheticFederatedData
from repro.models.model import Model, count_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--strategy", default="ours")
    ap.add_argument("--budget", type=int, default=2)
    ap.add_argument("--lam", type=float, default=1.0)
    ap.add_argument("--clients", type=int, default=50)
    ap.add_argument("--cohort", type=int, default=10)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--lr", type=float, default=0.01)
    ap.add_argument("--pretrain-steps", type=int, default=300)
    ap.add_argument("--ckpt", default="/tmp/repro_fl_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = reduced(get_arch(args.arch), n_layers=args.layers,
                  d_model=args.d_model)
    cfg = dataclasses.replace(cfg, task="classification", n_classes=10)
    model = Model(cfg, RuntimeConfig(remat=False, seq_chunk=32))
    print(f"model: {cfg.name} reduced to {args.layers}L d={args.d_model} "
          f"({count_params(model.init(jax.random.PRNGKey(0))):,} params)")

    data = SyntheticFederatedData(FederatedTaskConfig(
        n_clients=args.clients, vocab_size=cfg.vocab_size, seq_len=32,
        skew="feature", objective="classification", signal=0.8,
        domain_strength=0.4))

    params = model.init(jax.random.PRNGKey(0))
    if latest_step(args.ckpt) is not None:
        params, manifest = restore_checkpoint(args.ckpt, params)
        start = manifest["extra"].get("round", 0)
        print(f"resumed from {args.ckpt} at round {start}")
    else:
        print(f"pretraining foundation stand-in ({args.pretrain_steps} steps)…")
        params = pretrain(model, params, data, steps=args.pretrain_steps,
                          lr=3e-3, verbose=True)
        start = 0

    # the Experiment front door resolves the strategy from the registry
    # (unknown names fail fast with a did-you-mean) and builds the engine;
    # the explicit run_round loop below owns checkpointing
    exp = Experiment(model, data, args.strategy,
                     cohort_size=args.cohort, rounds=args.rounds,
                     local_steps=args.local_steps, lr=args.lr,
                     batch_size=16, budget=args.budget, lam=args.lam)
    server = exp.build()

    from repro.core.server import History
    hist = History()
    for t in range(start, args.rounds):
        params, rec = server.run_round(params, t)
        hist.records.append(rec)
        if t % 10 == 0 or t == args.rounds - 1:
            print(f"[{t:4d}] loss={rec.test_loss:.4f} acc={rec.test_acc:.4f} "
                  f"union={rec.union_frac:.2f} upload={rec.uploaded_params:,}")
        if (t + 1) % args.ckpt_every == 0:
            path = save_checkpoint(args.ckpt, t + 1, params,
                                   extra={"round": t + 1,
                                          "acc": rec.test_acc})
            print(f"  checkpoint -> {path}")

    print("\nfinal:", hist.summary())


if __name__ == "__main__":
    main()
