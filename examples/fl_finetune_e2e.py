"""End-to-end FL fine-tuning driver: pretrain -> Algorithm 1 -> checkpoint.

Default is a ~15M-parameter llama-family model trained for 200 rounds on
CPU (a few minutes); scale up with --layers/--d-model/--rounds (the model
definition is the same one the 1.1B config uses).

Checkpointing is full-state via ``Experiment(checkpoint_dir=...)``: every
--ckpt-every rounds the server saves params, the population-state store
(warm masks, probe-stat cache, stream positions), and the rng states, and
a re-run of this script auto-resumes from the latest checkpoint —
bit-identical on cohorts/masks to a run that never stopped (pretraining
is skipped because the checkpoint already carries post-pretrain params).

    PYTHONPATH=src python examples/fl_finetune_e2e.py \
        --arch tinyllama-1.1b --layers 8 --d-model 256 --rounds 200 \
        --strategy ours --budget 2 --ckpt /tmp/fl_ckpt
"""
import argparse
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.api import Experiment
from repro.ckpt import latest_step
from repro.configs.base import RuntimeConfig, get_arch, reduced
from repro.data.synthetic import FederatedTaskConfig, SyntheticFederatedData
from repro.models.model import Model, count_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--strategy", default="ours")
    ap.add_argument("--budget", type=int, default=2)
    ap.add_argument("--lam", type=float, default=1.0)
    ap.add_argument("--clients", type=int, default=50)
    ap.add_argument("--cohort", type=int, default=10)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--lr", type=float, default=0.01)
    ap.add_argument("--pretrain-steps", type=int, default=300)
    ap.add_argument("--ckpt", default="/tmp/repro_fl_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = reduced(get_arch(args.arch), n_layers=args.layers,
                  d_model=args.d_model)
    cfg = dataclasses.replace(cfg, task="classification", n_classes=10)
    model = Model(cfg, RuntimeConfig(remat=False, seq_chunk=32))
    print(f"model: {cfg.name} reduced to {args.layers}L d={args.d_model} "
          f"({count_params(model.init(jax.random.PRNGKey(0))):,} params)")

    data = SyntheticFederatedData(FederatedTaskConfig(
        n_clients=args.clients, vocab_size=cfg.vocab_size, seq_len=32,
        skew="feature", objective="classification", signal=0.8,
        domain_strength=0.4))

    # the Experiment front door resolves the strategy from the registry
    # (unknown names fail fast with a did-you-mean), builds the engine, and
    # owns checkpoint/resume: run() restores the latest checkpoint under
    # --ckpt (params + client-state store + rng streams + History) and
    # pretrains the foundation stand-in only on a cold start
    step = latest_step(args.ckpt)
    if step is not None:
        print(f"resuming from {args.ckpt} at round {step}")
    else:
        print(f"cold start: pretraining foundation stand-in "
              f"({args.pretrain_steps} steps)…")
    exp = Experiment(model, data, args.strategy,
                     cohort_size=args.cohort, rounds=args.rounds,
                     local_steps=args.local_steps, lr=args.lr,
                     batch_size=16, budget=args.budget, lam=args.lam,
                     checkpoint_dir=args.ckpt,
                     checkpoint_every=args.ckpt_every,
                     pretrain_steps=args.pretrain_steps)
    params, hist = exp.run(verbose=True)

    print("\nfinal:", hist.summary())


if __name__ == "__main__":
    main()
