"""Personalized-delta serving end-to-end (DESIGN.md §9).

The full export → store → serve path: a "client" fine-tunes its selected
layers (stand-in for an FL round), the round checkpoint is diffed against
the base parameters into a sparse per-user delta
(``ckpt.extract_delta``), and a :class:`SlotServer` in ``delta`` mode
batch-decodes requests from *different* users — each against base + its
own delta — inside one jitted program.  The script verifies every
generation against decoding that user's materialised private params
alone.

    PYTHONPATH=src python examples/serve_personalized.py --slots 2 \
        --requests 6 --users 3 --delta-layers 2
"""
import argparse
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import extract_delta, save_checkpoint
from repro.configs.base import RuntimeConfig, get_arch, reduced
from repro.launch.serve import Request, SlotServer
from repro.models.model import Model
from repro.serve import DeltaStore


def finetune_stub(params, layers, seed):
    """Stand-in for a client's selected-layer fine-tuning: perturb exactly
    the selected rows of the blocks stack."""
    rng = np.random.RandomState(seed)
    sel = np.isin(np.arange(next(iter(params["blocks"].values())).shape[0]),
                  layers)
    tuned = dict(params)
    tuned["blocks"] = {
        name: np.asarray(leaf, np.float32)
        + 0.02 * sel.reshape((-1,) + (1,) * (leaf.ndim - 1))
        * rng.standard_normal(leaf.shape).astype(np.float32)
        for name, leaf in params["blocks"].items()}
    return tuned


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--users", type=int, default=3)
    ap.add_argument("--delta-layers", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()

    cfg = reduced(get_arch(args.arch), n_layers=4, d_model=64)
    model = Model(cfg, RuntimeConfig(remat=False, seq_chunk=16))
    params = model.init(jax.random.PRNGKey(0))

    # --- export: round checkpoint -> sparse per-user deltas ---------------
    store = DeltaStore(cfg)
    rng = np.random.RandomState(0)
    with tempfile.TemporaryDirectory() as ckpt_root:
        for uid in range(args.users):
            layers = np.sort(rng.choice(cfg.n_layers,
                                        size=min(args.delta_layers,
                                                 cfg.n_layers),
                                        replace=False)).astype(np.int32)
            tuned = finetune_stub(params, layers, seed=uid)
            ckpt_dir = os.path.join(ckpt_root, f"user{uid}")
            save_checkpoint(ckpt_dir, 1, {"params": tuned, "round": 1})
            rec = extract_delta(ckpt_dir, params, cfg)   # auto-detect rows
            assert rec.layers.tolist() == layers.tolist()
            store.put(uid, rec)
            print(f"user {uid}: delta layers={rec.layers.tolist()} "
                  f"({rec.nbytes / 1e3:.0f} kB vs "
                  f"{sum(np.asarray(l).nbytes for l in jax.tree.leaves(params)) / 1e3:.0f} kB dense)")

    # --- serve: mixed users through the batched delta overlay -------------
    max_seq = args.prompt_len + args.max_new + 1
    reqs = [Request(i, rng.randint(0, cfg.vocab_size,
                                   args.prompt_len).tolist(),
                    args.max_new, user_id=i % args.users)
            for i in range(args.requests)]
    prompts = {r.rid: (list(r.prompt), r.user_id) for r in reqs}
    server = SlotServer(model, params, args.slots, max_seq, mode="delta",
                        store=store)
    done, stats = server.run(reqs)
    print(f"served {len(done)} requests, {stats['gen_tokens']} tokens in "
          f"{stats['steps']} steps ({stats['tok_per_s']:.1f} tok/s)")

    # --- verify: batched delta decode == private params alone -------------
    for r in done:
        prompt, uid = prompts[r.rid]
        private = store.materialize(params, uid)
        cache = model.init_cache(1, max_seq)
        out = []
        for t in range(len(prompt) + r.max_new - 1):
            cur = prompt[t] if t < len(prompt) else out[-1]
            logits, cache = model.decode_step(private, jnp.asarray([cur]),
                                              jnp.int32(t), cache)
            if t >= len(prompt) - 1:
                out.append(int(jnp.argmax(logits[0])))
        assert r.generated == out, (r.rid, r.generated, out)
        print(f"  req {r.rid} (user {uid}): gen={r.generated}  "
              f"== private-params-alone decode")
    print("parity OK: one shared program, per-user outputs")


if __name__ == "__main__":
    main()
