"""Quickstart: selective layer fine-tuning in FL in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs.base import FLConfig, RuntimeConfig, get_arch, reduced
from repro.core.server import FLServer
from repro.data.pretrain import pretrain
from repro.data.synthetic import FederatedTaskConfig, SyntheticFederatedData
from repro.models.model import Model


def main():
    # 1. A reduced assigned architecture (CPU-sized smoke variant).
    cfg = reduced(get_arch("xlm-roberta-base"), n_layers=4, d_model=64)
    model = Model(cfg, RuntimeConfig(remat=False, seq_chunk=32))

    # 2. A synthetic federated task with feature skew (DomainNet-style).
    data = SyntheticFederatedData(FederatedTaskConfig(
        n_clients=20, vocab_size=cfg.vocab_size, seq_len=16,
        skew="feature", objective="classification", signal=0.8,
        domain_strength=0.4))

    # 3. "Pretrained foundation model" stand-in (DESIGN.md §2).
    params = pretrain(model, model.init(jax.random.PRNGKey(0)), data,
                      steps=150, lr=3e-3, verbose=True)

    # 4. Algorithm 1 with the paper's strategy: each client fine-tunes its
    #    best R=1 layer, selections regulated by λ.  The vectorized engine
    #    runs the whole cohort as one fused XLA program per round;
    #    engine="sequential" is the paper-literal per-client oracle (both
    #    produce identical masks and params — tests/test_round_engine.py).
    fl = FLConfig(n_clients=20, cohort_size=5, rounds=10, local_steps=2,
                  lr=0.01, batch_size=16, strategy="ours", budget=1, lam=1.0)
    server = FLServer(model, fl, data, engine="vectorized")
    params, hist = server.run(params, verbose=True)

    print("\nsummary:", hist.summary())
    print("per-layer selection counts by round:\n", hist.selection_heatmap())


if __name__ == "__main__":
    main()
