"""Quickstart: selective layer fine-tuning in FL through the federation API.

    PYTHONPATH=src python examples/quickstart.py

The three pluggable pieces (DESIGN.md §6): a model config, a Task
(datasource), and a registered Strategy name — composed by
``repro.api.Experiment``, the front door for every example and benchmark.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.api import Experiment
from repro.configs.base import get_arch, reduced
from repro.data.synthetic import FederatedTaskConfig, SyntheticFederatedData

SMOKE = os.environ.get("REPRO_SMOKE") == "1"     # CI smoke: tiny run


def main():
    # 1. A reduced assigned architecture (CPU-sized smoke variant).
    cfg = reduced(get_arch("xlm-roberta-base"), n_layers=4, d_model=64)

    # 2. A synthetic federated task with feature skew (DomainNet-style).
    #    Any object implementing repro.api.Task plugs in here — see
    #    repro.api.task.DirichletTokenMixtureTask for a second datasource.
    task = SyntheticFederatedData(FederatedTaskConfig(
        n_clients=20, vocab_size=cfg.vocab_size, seq_len=16,
        skew="feature", objective="classification", signal=0.8,
        domain_strength=0.4))

    # 3. Algorithm 1 with the paper's strategy ("ours" = the (P1) solver):
    #    each client fine-tunes its best R=1 layer, selections regulated by
    #    λ.  Any registered strategy name works — see
    #    repro.api.strategy_names() and examples/custom_strategy.py.
    #    pretrain_steps builds the "pretrained foundation model" stand-in
    #    (DESIGN.md §2) before the federated rounds.  pipeline_depth makes
    #    the round scheduler plan/sample 2 rounds ahead of the in-flight
    #    device program (results identical at any depth, DESIGN.md §5).
    exp = Experiment(cfg, task, strategy="ours",
                     cohort_size=5, rounds=3 if SMOKE else 10,
                     local_steps=2, lr=0.01, batch_size=16, budget=1,
                     lam=1.0, pretrain_steps=30 if SMOKE else 150,
                     pipeline_depth=2)
    params, hist = exp.run(verbose=True)

    print("\nsummary:", hist.summary())
    print("per-layer selection counts by round:\n", hist.selection_heatmap())


if __name__ == "__main__":
    main()
