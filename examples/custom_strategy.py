"""Register a user-defined layer-selection strategy and run it end-to-end.

    PYTHONPATH=src python examples/custom_strategy.py

The registry (repro.api.strategy) makes selection strategies pluggable:
declare which probe statistics you need, implement ``select`` (or just a
``score_device`` for rank-by-score strategies), register under a name, and
every entry point — Experiment, FLServer(strategy="..."), benchmarks —
can use it.  The probe computes *only* the stats you declared.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp
import numpy as np

from repro.api import (Experiment, MixtureStrategy, ScoreStrategy,
                       UnknownStrategyError, get_strategy, register_strategy,
                       strategy_names)
from repro.configs.base import get_arch, reduced
from repro.data.synthetic import FederatedTaskConfig, SyntheticFederatedData

SMOKE = os.environ.get("REPRO_SMOKE") == "1"


# A rank-by-score strategy in ~10 lines: normalised gradient energy, i.e.
# ‖g_l‖² scaled by the layer's parameter norm *product* — favours layers
# where much gradient lives in few parameters.  Declaring
# probe_requirements means clients compute exactly these two stats.
@register_strategy("energy_density")
class EnergyDensity(ScoreStrategy):
    probe_requirements = frozenset({"grad_sq_norms", "param_sq_norms"})

    def score_device(self, stats, eps: float = 1e-12):
        return stats["grad_sq_norms"] / (stats["param_sq_norms"] + eps)


def main():
    cfg = reduced(get_arch("xlm-roberta-base"), n_layers=4, d_model=64)
    task = SyntheticFederatedData(FederatedTaskConfig(
        n_clients=16, vocab_size=cfg.vocab_size, seq_len=16,
        skew="label", objective="classification", signal=0.8))

    print("registered strategies:", ", ".join(strategy_names()))
    print("probe requirements of energy_density:",
          sorted(get_strategy("energy_density").probe_requirements))

    # the registry rejects typos with a suggestion instead of a bare error
    try:
        get_strategy("energy_densty")
    except UnknownStrategyError as e:
        print("typo handling:", e)

    rounds = 3 if SMOKE else 8
    pre = 30 if SMOKE else 120
    exp = Experiment(cfg, task, strategy="energy_density",
                     cohort_size=4, rounds=rounds, local_steps=2,
                     batch_size=16, budget=1, lam=1.0, pretrain_steps=pre)
    params, hist = exp.run(verbose=True)
    print("energy_density:", hist.summary())

    # the same registered name composes into per-client mixtures: half the
    # clients run the custom strategy, the rest the paper's solver
    mix = MixtureStrategy({i: "energy_density" for i in range(8)},
                          default="ours")
    exp2 = Experiment(cfg, task, strategy=mix,
                      cohort_size=4, rounds=rounds, local_steps=2,
                      batch_size=16, budget=1, lam=1.0)
    _, hist2 = exp2.run(params)
    print("mixture(energy_density | ours):", hist2.summary())


if __name__ == "__main__":
    main()
