"""Multi-seed repeat of the headline Table-1 comparison (xglue, R=1).

Single-seed orderings at reduced-model scale are noisy; this repeats the
ours / rgn / top / full comparison over 3 seeds and reports mean ± std.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import SCENARIOS, run_fl, save_result

STRATS = ("ours", "rgn", "top", "bottom", "full")


def main(rounds=None, seeds=(0, 1, 2)):
    scn = SCENARIOS["xglue"]
    table = {s: [] for s in STRATS}
    for seed in seeds:
        for s in STRATS:
            h = run_fl(scn, s, budget=1, seed=seed,
                       **({} if rounds is None else {"rounds": rounds}))
            table[s].append(h.summary()["best_acc"])
    print(f"=== Table 1 (xglue, R=1) over seeds {list(seeds)} ===")
    for s in STRATS:
        v = np.array(table[s])
        print(f"  {s:8s}: {v.mean():.3f} ± {v.std():.3f}   {np.round(v, 3)}")
    save_result("table1_seeds", {k: list(map(float, v))
                                 for k, v in table.items()})
    return table


if __name__ == "__main__":
    main()
