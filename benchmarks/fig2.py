"""Figure 2 analogue — visualization of selected layers over training.

ASCII heatmap: rows = rounds, columns = layers, cell = #cohort clients that
selected the layer.  The paper's qualitative claim: selection patterns
differ between label-skew and feature-skew datasets and drift over rounds.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import SCENARIOS, half_normal_budgets, N_CLIENTS, run_fl, save_result

GLYPHS = " .:-=+*#%@"


def heat_to_ascii(h: np.ndarray, max_val: int) -> list[str]:
    out = []
    for row in h:
        out.append("".join(GLYPHS[min(int(v / max(max_val, 1) * (len(GLYPHS) - 1)),
                                      len(GLYPHS) - 1)] for v in row))
    return out


def main(rounds=None):
    results = {}
    for sname in ("cifar", "domainnet", "xglue"):
        scn = SCENARIOS[sname]
        kw = {} if rounds is None else {"rounds": rounds}
        h = run_fl(scn, "ours", budgets=half_normal_budgets(N_CLIENTS), **kw)
        heat = h.selection_heatmap()
        results[sname] = heat.tolist()
        print(f"--- Fig.2 analogue [{sname}]: cohort selections per layer "
              f"(rows=rounds, cols=layer 0..L-1) ---")
        for line in heat_to_ascii(heat, heat.max()):
            print(f"  |{line}|")
        print(f"  column sums: {heat.sum(0).astype(int).tolist()}")
    save_result("fig2", results)
    return results


if __name__ == "__main__":
    main()
