"""Table 1 analogue — identical resources: every client fine-tunes R layers.

Columns: strategies (Top/Bottom/Both/SNR/RGN/Ours + Full benchmark);
rows: scenario × R ∈ {1, 2}.  Reports best test accuracy over the run.
"""
from __future__ import annotations

from benchmarks.common import SCENARIOS, run_fl, save_result

STRATS = ("top", "bottom", "both", "snr", "rgn", "ours")


def run(scenarios=("cifar", "domainnet", "xglue"), budgets=(1, 2),
        rounds=None) -> dict:
    out = {}
    for sname in scenarios:
        scn = SCENARIOS[sname]
        kw = {} if rounds is None else {"rounds": rounds}
        full = run_fl(scn, "full", **kw).summary()
        out[(sname, "full")] = full["best_acc"]
        for R in budgets:
            for s in STRATS:
                if s == "both" and R < 2:
                    out[(sname, s, R)] = float("nan")
                    continue
                h = run_fl(scn, s, budget=R, **kw)
                out[(sname, s, R)] = h.summary()["best_acc"]
    return out


def fmt(results: dict, budgets=(1, 2)) -> str:
    lines = ["=== Table 1: identical resources (best test acc) ==="]
    scenarios = sorted({k[0] for k in results})
    hdr = f"{'strategy':9s}" + "".join(
        f" | {s}:R={r}" for s in scenarios for r in budgets)
    lines.append(hdr)
    lines.append(f"{'full':9s}" + "".join(
        f" | {results[(s, 'full')]:.3f}  " for s in scenarios for _ in budgets))
    for strat in STRATS:
        row = f"{strat:9s}"
        for s in scenarios:
            for r in budgets:
                v = results.get((s, strat, r), float("nan"))
                row += f" | {v:.3f}  " if v == v else " |   -    "
        lines.append(row)
    return "\n".join(lines)


def main(rounds=None):
    res = run(rounds=rounds)
    print(fmt(res))
    save_result("table1", {str(k): v for k, v in res.items()})
    return res


if __name__ == "__main__":
    main()
