"""Shared harness for the paper-table benchmarks.

Scenario = (model family, non-IID pattern) analogue of the paper's four
dataset blocks (§5.1), sized for CPU:

* ``cifar``     — label skew Dir(0.1), CLIP-ViT-like encoder classifier
* ``domainnet`` — feature skew (domains), CLIP-ViT-like encoder classifier
* ``xglue``     — feature skew, XLM-R-like text classifier

Each scenario pretrains a reduced model on the balanced identity-domain
corpus (the offline stand-in for the pretrained checkpoint, DESIGN.md §2)
and then runs the paper's Algorithm 1 under the requested strategy.
"""
from __future__ import annotations

import json
import os
import sys
import time
from dataclasses import dataclass

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.api import Experiment
from repro.configs.base import FLConfig, RuntimeConfig, get_arch, reduced
from repro.core.server import History
from repro.data.pretrain import pretrain
from repro.data.synthetic import FederatedTaskConfig, SyntheticFederatedData
from repro.models.model import Model

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                           "bench")
ROUNDS = int(os.environ.get("BENCH_ROUNDS", "25"))
N_CLIENTS = int(os.environ.get("BENCH_CLIENTS", "20"))
COHORT = int(os.environ.get("BENCH_COHORT", "5"))


@dataclass
class Scenario:
    name: str
    arch: str
    skew: str
    n_layers: int = 4
    d_model: int = 64
    lr: float = 0.01
    lam: float = 1.0
    local_steps: int = 2
    batch_size: int = 16
    pretrain_steps: int = 200


SCENARIOS = {
    "cifar": Scenario("cifar", "clip_vit_b32", "label"),
    "domainnet": Scenario("domainnet", "clip_vit_b32", "feature"),
    "xglue": Scenario("xglue", "xlm_roberta_base", "feature"),
}


_cache: dict = {}


def build_world(scn: Scenario, seed: int = 0):
    """(model, pretrained params, data) — cached per (scenario, seed)."""
    key = (scn.name, seed)
    if key in _cache:
        return _cache[key]
    cfg = reduced(get_arch(scn.arch), n_layers=scn.n_layers,
                  d_model=scn.d_model)
    model = Model(cfg, RuntimeConfig(remat=False, seq_chunk=32))
    vlm = cfg.family == "vlm"
    data = SyntheticFederatedData(FederatedTaskConfig(
        n_clients=N_CLIENTS, n_classes=cfg.n_classes or 10,
        vocab_size=cfg.vocab_size, seq_len=16, samples_per_client=32,
        skew=scn.skew, objective="classification", signal=0.8,
        domain_strength=0.4, dirichlet_alpha=0.1, seed=seed,
        modality="patches" if vlm else "tokens",
        patch_tokens=cfg.n_prefix_tokens if vlm else 8,
        patch_dim=cfg.d_model if vlm else 64))
    params = model.init(jax.random.PRNGKey(seed))
    params = pretrain(model, params, data, steps=scn.pretrain_steps, lr=3e-3)
    _cache[key] = (model, params, data)
    return _cache[key]


ENGINE = os.environ.get("BENCH_ENGINE", "vectorized")
# BENCH_PIPELINE=0 disables the streaming round pipeline (same results,
# synchronous stage execution) — for A/B timing.  BENCH_PIPELINE_DEPTH=k
# sets the scheduler lookahead (same results at any depth, DESIGN.md §5).
PIPELINE = os.environ.get("BENCH_PIPELINE", "1") != "0"
PIPELINE_DEPTH = int(os.environ.get("BENCH_PIPELINE_DEPTH", "1"))


def run_fl(scn: Scenario, strategy, *, budget=1, budgets=None,
           rounds: int = ROUNDS, seed: int = 0,
           engine: str = ENGINE, pipeline: bool = PIPELINE,
           pipeline_depth: int = PIPELINE_DEPTH) -> History:
    """Run one scenario through the Experiment front door.

    ``strategy`` is a registered name or any Strategy instance (e.g. a
    per-client MixtureStrategy) — repro.api.Experiment resolves it.
    """
    model, params, data = build_world(scn, seed)
    fl = FLConfig(cohort_size=COHORT, rounds=rounds,
                  local_steps=scn.local_steps, lr=scn.lr,
                  batch_size=scn.batch_size,
                  budget=budget, budgets=budgets, lam=scn.lam, seed=seed)
    exp = Experiment(model, data, strategy, fl=fl, engine=engine,
                     pipeline=pipeline, pipeline_depth=pipeline_depth)
    _, hist = exp.run(params)
    return hist


def save_history(name: str, hist: History, **extra):
    """Persist a run as JSON (no pickling) — benchmarks/report.py renders
    any experiments/bench/*.json with a 'records' key as an FL-run row."""
    save_result(name, dict(hist.to_json(), **extra))


def half_normal_budgets(n: int, lo: int = 1, hi: int = 4,
                        seed: int = 0) -> tuple[int, ...]:
    """R_i ~ truncated half-normal on [lo, hi] (§5.2 heterogeneous)."""
    rng = np.random.RandomState(seed)
    vals = np.abs(rng.randn(n)) * (hi - lo) / 2 + lo
    return tuple(int(v) for v in np.clip(np.round(vals), lo, hi))


def save_result(name: str, payload: dict):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as f:
        json.dump(payload, f, indent=1, default=float)


def timer(fn, *args, reps: int = 3, **kw):
    fn(*args, **kw)                      # warmup / compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    jax.block_until_ready(out) if hasattr(out, "block_until_ready") else None
    return (time.perf_counter() - t0) / reps * 1e6   # µs
