"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from the dry-run JSONs.

    PYTHONPATH=src python -m benchmarks.report > experiments/roofline_tables.md
"""
from __future__ import annotations

import glob
import json
import os
from collections import defaultdict

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")
BENCH_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                         "bench")
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def norm(a: str) -> str:
    return a.replace("-", "_").replace(".", "_")


def load():
    rows = {}
    for path in glob.glob(os.path.join(DRYRUN_DIR, "*.json")):
        with open(path) as f:
            r = json.load(f)
        key = (norm(r["arch"]), r["shape"], r["mesh"],
               "opt" if r.get("opts") else "base")
        prev = rows.get(key)
        if prev is None or os.path.getmtime(path) > prev[1]:
            rows[key] = (r, os.path.getmtime(path))
    return {k: v[0] for k, v in rows.items()}


def fmt_table(rows: dict, mesh: str, variant: str) -> str:
    out = [f"### {'Optimized' if variant == 'opt' else 'Baseline'} — mesh {mesh}",
           "",
           "| arch | shape | compute_s | memory_s | collective_s | dominant | useful | temp GB/chip |",
           "|---|---|---|---|---|---|---|---|"]
    archs = sorted({k[0] for k in rows})
    for a in archs:
        for s in SHAPE_ORDER:
            r = rows.get((a, s, mesh, variant))
            if not r:
                continue
            t = r["roofline"]
            out.append(
                f"| {a} | {s} | {t['compute_s']:.3e} | {t['memory_s']:.3e} "
                f"| {t['collective_s']:.3e} | {r['dominant']} "
                f"| {r.get('useful_flops_frac') or 0:.3f} "
                f"| {r['memory']['temp_bytes'] / 1e9:.1f} |")
    return "\n".join(out)


def fmt_dryrun_summary(rows: dict) -> str:
    counts = defaultdict(int)
    for (a, s, mesh, v), r in rows.items():
        if v == "base":
            counts[mesh] += 1
    out = ["### Compile status",
           ""]
    for mesh, n in sorted(counts.items()):
        out.append(f"* mesh {mesh}: {n} (arch × shape) pairs lowered + compiled")
    out.append("")
    out.append("| arch | shape | mesh | compile_s | args GB/chip | flops (step) | coll GB (step) |")
    out.append("|---|---|---|---|---|---|---|")
    for (a, s, mesh, v) in sorted(rows):
        if v != "base":
            continue
        r = rows[(a, s, mesh, v)]
        out.append(f"| {a} | {s} | {mesh} | {r['compile_s']:.1f} "
                   f"| {r['memory']['argument_bytes'] / 1e9:.2f} "
                   f"| {r['flops']:.2e} | {r['collective_bytes'] / 1e9:.1f} |")
    return "\n".join(out)


def fmt_fl_runs() -> str:
    """FL-run table from History.to_json() files (no pickling needed)."""
    out = ["### FL runs",
           "",
           "| run | rounds | final_acc | best_acc | uploaded params |",
           "|---|---|---|---|---|"]
    found = False
    for path in sorted(glob.glob(os.path.join(BENCH_DIR, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if "records" not in r:
            continue
        found = True
        s = r["summary"]
        name = os.path.splitext(os.path.basename(path))[0]
        final = s["final_acc"]
        best = s["best_acc"]
        out.append(f"| {name} | {s['rounds']} "
                   f"| {final if final is None else f'{final:.3f}'} "
                   f"| {best if best is None else f'{best:.3f}'} "
                   f"| {s['uploaded_params_total']} |")
    if not found:
        out.append("| (no saved runs) | - | - | - | - |")
    return "\n".join(out)


def main():
    rows = load()
    print("## §Dry-run\n")
    print(fmt_dryrun_summary(rows))
    print("\n## §Roofline\n")
    for mesh in ("16x16", "2x16x16"):
        print(fmt_table(rows, mesh, "base"))
        print()
    print(fmt_table(rows, "16x16", "opt"))
    print("\n## §FL runs\n")
    print(fmt_fl_runs())


if __name__ == "__main__":
    main()
