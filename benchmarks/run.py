"""Benchmark entrypoint: ``PYTHONPATH=src python -m benchmarks.run``.

One section per paper table/figure plus micro-benchmarks and the roofline
report.  Prints ``name,us_per_call,derived`` CSV lines for the micro
section, then the formatted tables.

Env knobs: BENCH_ROUNDS (default 25), BENCH_FAST=1 (8 rounds, micro only
reps=1).
"""
from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

FAST = os.environ.get("BENCH_FAST") == "1"
ROUNDS = 8 if FAST else None


def micro_benchmarks():
    """name,us_per_call,derived CSV: kernels + FL primitives."""
    from benchmarks.common import timer
    from repro.kernels import ops
    from repro.core.solver import solve_icm

    print("name,us_per_call,derived")

    # flash attention kernel (interpret) vs jnp reference
    B, H, K, S, D = 1, 4, 2, 256, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, K, D))
    v = jax.random.normal(ks[2], (B, S, K, D))
    us = timer(lambda: jax.block_until_ready(
        ops.flash_attention(q, k, v, interpret=True)), reps=1 if FAST else 3)
    flops = 4 * B * H * S * S * D
    print(f"flash_attention_interp_{S}x{D},{us:.1f},{flops/us*1e-3:.2f}GFLOPs")

    # ssd kernel
    x = jax.random.normal(ks[0], (4, 256, 64))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (4, 256)))
    A = -jnp.exp(jax.random.uniform(ks[2], (4,)))
    Bm = jax.random.normal(ks[0], (4, 256, 32))
    Cm = jax.random.normal(ks[1], (4, 256, 32))
    Dp = jnp.ones((4,))
    from repro.kernels.ssd_scan import ssd_scan
    us = timer(lambda: jax.block_until_ready(
        ssd_scan(x, dt, A, Bm, Cm, Dp, chunk=64, interpret=True)),
        reps=1 if FAST else 3)
    print(f"ssd_scan_interp_bh4_s256,{us:.1f},-")

    # layer grad norms (fused) vs per-leaf jnp
    g = {"w": jax.random.normal(ks[0], (16, 64, 256)),
         "b": jax.random.normal(ks[1], (16, 256))}
    us = timer(lambda: jax.block_until_ready(
        ops.layer_grad_norms(g, interpret=True)), reps=1 if FAST else 3)
    print(f"layer_grad_norms_L16,{us:.1f},-")

    # (P1) solver
    G = np.abs(np.random.RandomState(0).randn(20, 24))
    t0 = time.perf_counter()
    for _ in range(10):
        solve_icm(G, 2, lam=1.0)
    us = (time.perf_counter() - t0) / 10 * 1e6
    print(f"p1_solver_icm_n20_L24,{us:.1f},-")

    # one FL round (simulator, reduced model)
    from benchmarks.common import SCENARIOS, build_world, run_fl
    t0 = time.perf_counter()
    run_fl(SCENARIOS["cifar"], "ours", budget=1, rounds=1)
    us = (time.perf_counter() - t0) * 1e6
    print(f"fl_round_sim_cifar,{us:.1f},includes_jit")
    t0 = time.perf_counter()
    run_fl(SCENARIOS["cifar"], "ours", budget=1, rounds=2)
    us2 = (time.perf_counter() - t0) / 2 * 1e6
    print(f"fl_round_sim_cifar_warm,{us2:.1f},-")

    # round engine: sequential per-client loop vs the fused vmap round step
    round_engine_benchmarks()
    # mask-aware engine: frozen-prefix backward skipping vs the dense path
    masked_backward_benchmarks()
    # full round including host-side sampling: pre-PR scalar path vs the
    # vectorized sampler + streaming pipeline
    full_round_benchmarks()
    # requirements-trimmed selection probe vs the all-stats probe
    probe_trim_benchmarks()
    # depth-k lookahead scheduler vs the classic depth-1 double buffer
    pipeline_depth_benchmarks()
    # population-state store: per-round host cost flat in population size
    population_state_benchmarks()
    # personalized-delta serving: fused overlay decode vs per-user params
    delta_serving_benchmarks()


def round_engine_benchmarks() -> list[dict]:
    """Warm µs per cohort *engine step* at cohort_size ∈ {4, 8}.

    Times exactly what the engine switch changes — the probe + τ-step local
    updates + Eq.(5)-(7) aggregation + apply — on pre-drawn batches, in the
    FL-realistic small-microbatch regime (synthetic data generation and test
    evaluation are identical across engines and excluded).  The vectorized
    row's derived column reports the speedup over the sequential oracle at
    the same cohort size.  Returns the rows for BENCH_*.json recording.
    """
    from repro.configs.base import (FLConfig, RuntimeConfig, get_arch,
                                    reduced)
    from repro.core import aggregation as agg
    from repro.core.client import Client
    from repro.data.synthetic import (FederatedTaskConfig,
                                      SyntheticFederatedData)
    from repro.models.model import Model

    cfg = reduced(get_arch("xlm_roberta_base"), n_layers=4, d_model=32)
    model = Model(cfg, RuntimeConfig(remat=False, seq_chunk=16))
    params = model.init(jax.random.PRNGKey(0))
    data = SyntheticFederatedData(FederatedTaskConfig(
        n_clients=20, n_classes=10, vocab_size=cfg.vocab_size, seq_len=8,
        samples_per_client=16, skew="label", objective="classification"))
    fl = FLConfig(n_clients=20, local_steps=2, lr=0.01, batch_size=4,
                  strategy="ours", budget=1)
    reps = 1 if FAST else 5
    rows: list[dict] = []
    for cohort_n in (4, 8):
        client = Client(model)       # shared jit suite (module-level cache);
                                     # per-shape compiles handled by warmup
        cohort = np.arange(cohort_n)
        masks = np.zeros((cohort_n, model.n_selectable), np.float32)
        masks[:, 1] = 1.0
        sizes = data.sizes[cohort]
        batches = data.cohort_batches(cohort, fl.batch_size, fl.local_steps)
        probe_b = data.cohort_batches(cohort, fl.batch_size,
                                      fl.selection_batches)

        def vec_step():
            client.probe_cohort(params, probe_b)
            _, losses = client.cohort_update(params, batches, masks, sizes,
                                             fl.lr)
            return losses

        def seq_step():
            for i in range(cohort_n):
                client.probe(params, jax.tree.map(lambda x: x[i, 0], probe_b))
            outs = [client.local_update(params,
                                        jax.tree.map(lambda x, i=i: x[i],
                                                     batches),
                                        masks[i], fl.lr)
                    for i in range(cohort_n)]
            update = agg.aggregate([o[0] for o in outs], masks, sizes, cfg)
            return agg.apply_update(params, update, fl.lr)

        seq_us = None
        for engine, step in (("sequential", seq_step),
                             ("vectorized", vec_step)):
            step()                               # warmup: jit compile
            t0 = time.perf_counter()
            for _ in range(reps):
                out = step()
            jax.block_until_ready(out)
            us = (time.perf_counter() - t0) / reps * 1e6
            derived = "-"
            if engine == "sequential":
                seq_us = us
            else:
                derived = f"{seq_us / us:.2f}x_vs_seq"
            print(f"round_engine_{engine}_c{cohort_n},{us:.1f},{derived}")
            rows.append({"name": f"round_engine_{engine}_c{cohort_n}",
                         "engine": engine, "cohort": cohort_n,
                         "us_per_call": us, "derived": derived})
    return rows


def masked_backward_benchmarks(cohort_n: int = 8) -> dict:
    """Warm µs per cohort update: mask-aware engine vs the dense program,
    sweeping the frozen-prefix depth (cut ∈ {0, L/2, L−1}).

    Both rows run ``Client.cohort_update_raw`` on identical pre-drawn
    batches and identical masks (every layer ≥ cut selected); the only
    change is ``cut`` — dense (None) differentiates all L layers and zeroes
    frozen gradients afterwards, mask-aware skips the frozen prefix's
    backward, activations and scan-carry entirely (plus the always-frozen
    embed/head/norm backward, which is why even cut=0 wins).  ``micro_ci``
    gates mask-aware ≤ dense at every cut and ≥1.5× at cut = L−1 via the
    median of *paired* per-rep ratios.  Returns a dict for
    BENCH_masked_backward.json.
    """
    from repro.configs.base import (FLConfig, RuntimeConfig, get_arch,
                                    reduced)
    from repro.core.client import Client
    from repro.data.synthetic import (FederatedTaskConfig,
                                      SyntheticFederatedData)
    from repro.models.model import Model

    cfg = reduced(get_arch("xlm_roberta_base"), n_layers=8, d_model=32)
    model = Model(cfg, RuntimeConfig(remat=False, seq_chunk=16))
    params = model.init(jax.random.PRNGKey(0))
    L = model.n_selectable
    data = SyntheticFederatedData(FederatedTaskConfig(
        n_clients=20, n_classes=10, vocab_size=cfg.vocab_size, seq_len=8,
        samples_per_client=16, skew="label", objective="classification"))
    fl = FLConfig(n_clients=20, local_steps=2, lr=0.01, batch_size=4)
    client = Client(model)
    cohort = np.arange(cohort_n)
    sizes = data.sizes[cohort]
    batches = data.cohort_batches(cohort, fl.batch_size, fl.local_steps)
    reps = 2 if FAST else 10
    cuts = (0, L // 2, L - 1)
    out: dict = {"cohort": cohort_n, "L": L, "reps": reps, "cuts": list(cuts)}

    def masks_for(cut):
        m = np.zeros((cohort_n, L), np.float32)
        m[:, cut:] = 1.0
        return m

    for cut in cuts:                         # warmup: compile both variants
        m = masks_for(cut)
        jax.block_until_ready(client.cohort_update_raw(
            params, batches, m, sizes, fl.lr)[0])
        jax.block_until_ready(client.cohort_update_raw(
            params, batches, m, sizes, fl.lr, cut=cut)[0])

    for cut in cuts:
        m = masks_for(cut)
        dense_t, masked_t = [], []
        for _ in range(reps):                # interleave: paired reps
            for which, times in (("dense", dense_t), ("masked", masked_t)):
                t0 = time.perf_counter()
                p, _ = client.cohort_update_raw(
                    params, batches, m, sizes, fl.lr,
                    cut=None if which == "dense" else cut)
                jax.block_until_ready(p)
                times.append(time.perf_counter() - t0)
        dense_t, masked_t = np.asarray(dense_t), np.asarray(masked_t)
        ratio = float(np.median(masked_t / dense_t))   # paired per-rep
        out[f"cut{cut}_dense_us"] = float(np.min(dense_t) * 1e6)
        out[f"cut{cut}_masked_us"] = float(np.min(masked_t) * 1e6)
        out[f"cut{cut}_ratio"] = ratio
        print(f"masked_backward_cut{cut}_c{cohort_n},"
              f"{out[f'cut{cut}_masked_us']:.1f},"
              f"{1.0 / ratio:.2f}x_vs_dense")
    return out


def probe_trim_benchmarks(cohort_n: int = 8) -> dict:
    """Warm µs per cohort probe: requirements-trimmed vs all-stats.

    Strategies declare ``probe_requirements`` (repro.api.strategy), so the
    probe computes only the stats the strategy consumes — ``ours`` pays for
    gradient square norms only, while the pre-API probe always paid for the
    full SNR+RGN stat set.  Times ``Client.probe_cohort`` on pre-drawn
    batches for each requirement set; ``micro_ci`` gates trimmed <= all.
    Returns a dict suitable for BENCH_probe_trim.json.
    """
    from repro.api import get_strategy
    from repro.configs.base import (FLConfig, RuntimeConfig, get_arch,
                                    reduced)
    from repro.core.client import Client
    from repro.core.strategies import PROBE_KEYS
    from repro.data.synthetic import (FederatedTaskConfig,
                                      SyntheticFederatedData)
    from repro.models.model import Model

    cfg = reduced(get_arch("xlm_roberta_base"), n_layers=4, d_model=32)
    model = Model(cfg, RuntimeConfig(remat=False, seq_chunk=16))
    params = model.init(jax.random.PRNGKey(0))
    data = SyntheticFederatedData(FederatedTaskConfig(
        n_clients=20, n_classes=10, vocab_size=cfg.vocab_size, seq_len=8,
        samples_per_client=16, skew="label", objective="classification"))
    fl = FLConfig(n_clients=20, batch_size=4, selection_batches=2)
    client = Client(model)
    probe_b = data.cohort_batches(np.arange(cohort_n), fl.batch_size,
                                  fl.selection_batches)
    reps = 3 if FAST else 25
    variants = [
        ("all_stats", PROBE_KEYS, None),
        ("ours_trimmed", ("grad_sq_norms",), None),
        ("snr_trimmed", ("grad_means", "grad_vars"),
         get_strategy("snr").device_score_fn()),
    ]
    for _, reqs, score_fn in variants:       # warmup: jit compile
        jax.block_until_ready(
            client.probe_cohort_raw(params, probe_b, reqs, score_fn))
    # interleave variants across reps (decorrelates host noise) and take
    # min-of-N: the probe is grad-dominated on these tiny CPU models, so
    # the trim delta is small relative to scheduler jitter
    times: dict = {name: [] for name, _, _ in variants}
    for _ in range(reps):
        for name, reqs, score_fn in variants:
            t0 = time.perf_counter()
            jax.block_until_ready(
                client.probe_cohort_raw(params, probe_b, reqs, score_fn))
            times[name].append(time.perf_counter() - t0)
    out: dict = {"cohort": cohort_n, "reps": reps}
    base = np.asarray(times["all_stats"])
    for name, _, _ in variants:
        t = np.asarray(times[name])
        us = float(np.min(t) * 1e6)
        derived = "-"
        if name != "all_stats":
            # paired per-rep ratio vs the all-stats call of the same
            # interleave round — load spikes hit both sides and cancel
            ratio = float(np.median(t / base))
            out[f"{name}_ratio"] = ratio
            derived = f"{1.0 / ratio:.2f}x_vs_all"
        print(f"probe_{name}_c{cohort_n},{us:.1f},{derived}")
        out[f"{name}_us"] = us
    return out


def pipeline_depth_benchmarks(depth: int = 4, cohort_n: int = 8,
                              rounds: int = 4) -> dict:
    """Warm µs per full round: depth-k lookahead scheduler vs depth-1.

    Both rows run the streaming pipeline (RoundScheduler) on the
    sampling-bound config of :func:`full_round_benchmarks`; the only change
    is ``pipeline_depth`` — how many rounds ahead the host plans/samples
    while the (P1) solve runs on its background thread.  Results are
    bit-identical across depths (tests/test_scheduler.py); the delta is
    pure host scheduling.  ``micro_ci`` gates depth-k ≥ depth-1 throughput
    via the median of *paired* per-rep ratios (each rep times both depths
    back to back, so load spikes hit both sides and cancel).  Returns a
    dict suitable for BENCH_pipeline_depth.json.
    """
    from dataclasses import replace

    if depth < 2:
        raise ValueError(f"depth must be >= 2 to compare against depth-1, "
                         f"got {depth}")

    from repro.configs.base import (FLConfig, RuntimeConfig, get_arch,
                                    reduced)
    from repro.core.server import FLServer
    from repro.data.synthetic import (FederatedTaskConfig,
                                      SyntheticFederatedData)
    from repro.models.model import Model

    cfg = replace(reduced(get_arch("xlm_roberta_base"), n_layers=2,
                          d_model=16), vocab_size=4096)
    model = Model(cfg, RuntimeConfig(remat=False, seq_chunk=4))
    params = model.init(jax.random.PRNGKey(0))
    task = FederatedTaskConfig(
        n_clients=20, n_classes=10, vocab_size=cfg.vocab_size, seq_len=4,
        samples_per_client=16, skew="label", objective="classification",
        test_samples=4096)
    fl = FLConfig(n_clients=20, cohort_size=cohort_n, local_steps=2,
                  lr=0.01, batch_size=16, strategy="ours", budget=1)
    rounds = 1 if FAST else rounds
    reps = 2 if FAST else 5

    def fresh(d):
        # fresh data + server per timed run: the per-client streams and
        # solver warm caches start identical for both depths
        return FLServer(model, fl, SyntheticFederatedData(task),
                        pipeline=True, pipeline_depth=d)

    for d in (1, depth):                     # warmup: compile both shapes
        fresh(d).run(params, rounds=2)
    times: dict = {1: [], depth: []}
    for _ in range(reps):
        for d in (1, depth):                 # interleave: paired reps
            server = fresh(d)
            t0 = time.perf_counter()
            server.run(params, rounds=rounds)    # run() syncs on finalize
            times[d].append((time.perf_counter() - t0) / rounds)
    t1, tk = np.asarray(times[1]), np.asarray(times[depth])
    ratio = float(np.median(tk / t1))
    out = {"cohort": cohort_n, "rounds_timed": rounds, "reps": reps,
           "depth": depth, "paired_ratio": ratio,
           "depth1_us_per_round": float(np.min(t1) * 1e6),
           f"depth{depth}_us_per_round": float(np.min(tk) * 1e6)}
    print(f"pipeline_depth1_c{cohort_n},{out['depth1_us_per_round']:.1f},-")
    print(f"pipeline_depth{depth}_c{cohort_n},"
          f"{out[f'depth{depth}_us_per_round']:.1f},"
          f"{1.0 / ratio:.2f}x_vs_depth1")
    return out


def fault_overhead_benchmarks(cohort_n: int = 8, rounds: int = 4) -> dict:
    """Warm µs per round: wired-but-disabled FaultInjector vs no injector.

    The chaos seam's standing cost when nothing is injected must be noise:
    a disabled injector short-circuits before any rng draw and the guarded
    program is never dispatched, so the only admissible delta is the
    ``_faults_active`` property check per stage.  ``micro_ci`` gates the
    median of *paired* per-rep ratios at ≤ 1.05x (each rep times both
    sides back to back on a fresh server, so host load spikes cancel).
    Returns a dict suitable for BENCH_fault_overhead.json.
    """
    from dataclasses import replace

    from repro.configs.base import (FLConfig, RuntimeConfig, get_arch,
                                    reduced)
    from repro.core.server import FLServer
    from repro.data.synthetic import (FederatedTaskConfig,
                                      SyntheticFederatedData)
    from repro.faults import FaultPlan
    from repro.models.model import Model

    cfg = replace(reduced(get_arch("xlm_roberta_base"), n_layers=2,
                          d_model=16), vocab_size=4096)
    model = Model(cfg, RuntimeConfig(remat=False, seq_chunk=4))
    params = model.init(jax.random.PRNGKey(0))
    task = FederatedTaskConfig(
        n_clients=20, n_classes=10, vocab_size=cfg.vocab_size, seq_len=4,
        samples_per_client=16, skew="label", objective="classification",
        test_samples=4096)
    fl = FLConfig(n_clients=20, cohort_size=cohort_n, local_steps=2,
                  lr=0.01, batch_size=16, strategy="ours", budget=1)
    # the 1.05x gate is tight, so keep enough samples even in FAST mode —
    # the config is tiny and the median of paired ratios converges fast
    rounds = 2 if FAST else rounds
    reps = 3 if FAST else 5
    # every fault class armed, master switch off: the contractually-free
    # configuration (bit-identical results, tests/test_faults.py)
    disabled = FaultPlan(seed=7, enabled=False, death_rate=0.5,
                         corrupt_rate=0.5, stall_rate=0.5,
                         dispatch_fail_rate=0.5, ckpt_corrupt_rate=0.5)

    def fresh(faults):
        return FLServer(model, fl, SyntheticFederatedData(task),
                        faults=faults)

    for f in (None, disabled):               # warmup: compile both sides
        fresh(f).run(params, rounds=2)
    times: dict = {"none": [], "disabled": []}
    for _ in range(reps):
        for key, f in (("none", None), ("disabled", disabled)):
            server = fresh(f)
            t0 = time.perf_counter()
            server.run(params, rounds=rounds)    # run() syncs on finalize
            times[key].append((time.perf_counter() - t0) / rounds)
    t_none = np.asarray(times["none"])
    t_off = np.asarray(times["disabled"])
    out = {"cohort": cohort_n, "rounds_timed": rounds, "reps": reps,
           "paired_ratio": float(np.median(t_off / t_none)),
           "none_us_per_round": float(np.min(t_none) * 1e6),
           "disabled_us_per_round": float(np.min(t_off) * 1e6)}
    print(f"fault_none_c{cohort_n},{out['none_us_per_round']:.1f},-")
    print(f"fault_disabled_c{cohort_n},{out['disabled_us_per_round']:.1f},"
          f"{out['paired_ratio']:.3f}x_vs_none")
    return out


def full_round_benchmarks(cohort_n: int = 8, rounds: int = 4) -> dict:
    """End-to-end warm µs per *full round* — sampling included.

    Compares the pre-PR host path (legacy per-sample token loops + per-round
    test-set resampling, no prefetch) against the streaming pipeline
    (vectorized sampler, construction-time test set, double-buffered
    prefetch + fused probe/update).  The device math is identical in both
    rows; the delta is pure host-side sampling + scheduling.  The config is
    sampling-bound (short sequences, wide vocab, large held-out set — the
    regime where ROADMAP observed the per-sample loops dominating): XLA:CPU
    per-program overhead otherwise hides the host path entirely.  Returns a
    dict suitable for BENCH_full_round.json.
    """
    from dataclasses import replace

    from repro.configs.base import (FLConfig, RuntimeConfig, get_arch,
                                    reduced)
    from repro.core.server import FLServer
    from repro.data.synthetic import (FederatedTaskConfig,
                                      SyntheticFederatedData)
    from repro.models.model import Model

    cfg = replace(reduced(get_arch("xlm_roberta_base"), n_layers=2,
                          d_model=16), vocab_size=4096)
    model = Model(cfg, RuntimeConfig(remat=False, seq_chunk=4))
    params = model.init(jax.random.PRNGKey(0))
    task = FederatedTaskConfig(
        n_clients=20, n_classes=10, vocab_size=cfg.vocab_size, seq_len=4,
        samples_per_client=16, skew="label", objective="classification",
        test_samples=4096)
    fl = FLConfig(n_clients=20, cohort_size=cohort_n, local_steps=2,
                  lr=0.01, batch_size=16, strategy="ours", budget=1)
    rounds = 1 if FAST else rounds
    out = {"cohort": cohort_n, "rounds_timed": rounds}
    for mode in ("legacy", "vectorized"):
        data = SyntheticFederatedData(task)
        data.legacy_sampling = mode == "legacy"
        server = FLServer(model, fl, data, pipeline=mode != "legacy")
        # warmup: 2 rounds so the fused probe+update program (used when a
        # next round exists) compiles outside the timed region
        server.run(params, rounds=2)
        t0 = time.perf_counter()
        server.run(params, rounds=rounds)        # run() syncs on finalize
        us = (time.perf_counter() - t0) / rounds * 1e6
        out[f"{mode}_us_per_round"] = us
        print(f"full_round_{mode}_c{cohort_n},{us:.1f},"
              + ("-" if mode == "legacy" else
                 f"{out['legacy_us_per_round'] / us:.2f}x_vs_legacy"))
    out["speedup"] = out["legacy_us_per_round"] / out["vectorized_us_per_round"]
    return out


def population_state_benchmarks(cohort_n: int = 8,
                                populations: tuple = (10_000, 100_000),
                                n_layers: int = 24) -> dict:
    """Host µs per round of ClientStateStore/ClientStreamState traffic.

    Times one round's worth of population-state ops — warm-mask gather +
    scatter, stats validity check + scatter + gather, per-client stream
    draw + advance, and a periodic O(1) generation clear — against stores
    sized at 10⁴ and 10⁵ clients with the same cohort.  Every op is an
    O(cohort) fancy-index into flat arrays, so the per-round cost must be
    independent of the population size: ``micro_ci`` gates the median of
    *paired* per-rep ratios (each rep times both populations back to back,
    so load spikes hit both sides and cancel) flat at ≤ 2.0.  Returns a
    dict suitable for BENCH_population_state.json.
    """
    from repro.core.state import ClientStateStore, ClientStreamState

    reps = 3 if FAST else 7
    rounds = 20 if FAST else 100
    rng = np.random.RandomState(0)
    stat_keys = ("grad_sq_norms", "param_sq_norms", "scores")

    def one_round(store, streams, cohort, t):
        # plan: which cohort members need a fresh probe?
        probe_ids = store.missing_stats(cohort)
        if len(probe_ids):
            store.set_stat_rows(probe_ids, {
                k: np.ones((len(probe_ids), n_layers), np.float32)
                for k in stat_keys})
        stats = store.stat_rows(cohort)
        # warm-start gather, (P1)-solve stand-in, scatter back
        rows, valid = store.warm_rows(cohort)
        rows[~valid] = 1.0
        store.set_warm_rows(cohort, rows, t=t)
        # per-client data streams
        for i in cohort:
            streams.rng(int(i)).randint(0, 1 << 16, 4)
            streams.advance(int(i), 4)
        if t % 10 == 9:                      # selection refresh: O(1) bump
            store.clear_stats()
        return stats

    def fresh(n):
        store = ClientStateStore(n, n_layers)
        streams = ClientStreamState(n, lambda i: 7 * i + 1)
        cohorts = rng.randint(0, n, size=(rounds, cohort_n))
        return store, streams, cohorts

    for n in populations:                    # warmup: allocator + caches
        store, streams, cohorts = fresh(n)
        for t in range(5):
            one_round(store, streams, cohorts[t], t)
    times: dict = {n: [] for n in populations}
    for _ in range(reps):
        for n in populations:                # interleave: paired reps
            store, streams, cohorts = fresh(n)
            t0 = time.perf_counter()
            for t in range(rounds):
                one_round(store, streams, cohorts[t], t)
            times[n].append((time.perf_counter() - t0) / rounds)
    lo, hi = populations[0], populations[-1]
    t_lo, t_hi = np.asarray(times[lo]), np.asarray(times[hi])
    ratio = float(np.median(t_hi / t_lo))
    out = {"cohort": cohort_n, "rounds_timed": rounds, "reps": reps,
           "populations": list(populations), "paired_ratio": ratio}
    for n in populations:
        us = float(np.min(np.asarray(times[n])) * 1e6)
        out[f"pop{n}_us_per_round"] = us
        print(f"population_state_n{n}_c{cohort_n},{us:.1f},"
              + ("-" if n == lo else f"{ratio:.2f}x_vs_n{lo}"))
    return out


def delta_serving_benchmarks(slot_counts: tuple = (4, 6),
                             densities: tuple = (1, 2, 4)) -> dict:
    """Steady-state decode tok/s: batched delta overlay vs the dense
    per-user-params baseline, sweeping delta density and slot count.

    Each config serves B slots whose users tuned ``k`` selected layers
    (k ∈ {1, L/4, L/2} at L=8) of an 8-layer dense model.  The delta row
    decodes the whole batch against ONE shared parameter set plus a
    capacity-C per-layer delta entry table (kernels/delta_matmul.py
    linearity split: per-step weight traffic (1+C)·d·f); the dense row is
    the honest baseline — a vmapped decode over B private full-parameter
    copies (B·d·f traffic).  Capacity is the exact per-layer load of a
    round-robin layer assignment, so C+1 < B at every density and the
    traffic model predicts the win.  ``micro_ci`` gates delta ≤ dense at
    every (slots, density) via the median of *paired* per-rep ratios.
    Returns a dict suitable for BENCH_delta_serving.json.
    """
    from repro.configs.base import RuntimeConfig, get_arch, reduced
    from repro.models.model import Model, _block_shapes
    from repro.serve import (DeltaOverlay, DeltaRecord, serve_suite,
                             stack_tree)

    cfg = reduced(get_arch("tinyllama_1_1b"), n_layers=8, d_model=128)
    model = Model(cfg, RuntimeConfig(remat=False, seq_chunk=16))
    params = model.init(jax.random.PRNGKey(0))
    suite = serve_suite(model)
    shapes = _block_shapes(cfg, "dense")
    L, W = cfg.n_layers, 64
    steps = 8 if FAST else 20
    reps = 2 if FAST else 5
    rng = np.random.RandomState(0)
    out: dict = {"L": L, "d_model": cfg.d_model, "steps": steps,
                 "reps": reps, "configs": []}

    def record_for(layers):
        idx = np.sort(np.asarray(layers, np.int32))
        leaves = {
            name: (0.01 * rng.standard_normal((len(idx),) + tuple(shp)))
            .astype(np.float32) for name, shp in shapes.items()}
        return DeltaRecord(layers=idx, segments={"blocks": (idx, leaves)})

    for B in slot_counts:
        toks = jnp.arange(B, dtype=jnp.int32)
        pos = jnp.zeros(B, jnp.int32)
        bank = stack_tree(params, B)
        dense_cache0 = stack_tree(
            model.init_cache(1, W, per_slot=True), B)
        for k in densities:
            # round-robin layer assignment: per-layer load == capacity
            C = -(-B * k // L)                       # ceil(B·k/L)
            overlay = DeltaOverlay(model, C)
            for u in range(B):
                rec = record_for([(u * k + j) % L for j in range(k)])
                assert overlay.try_admit(u, rec)
            cache = model.init_cache(B, W, per_slot=True)
            dcache = dense_cache0

            def delta_step(c):
                return suite["serve_decode_delta"](params, toks, pos, c,
                                                   overlay.device(), 0)

            def dense_step(c):
                return suite["serve_decode_dense"](bank, toks, pos, c, 0)

            # warmup: compile both programs for this (B, C)
            _, cache = delta_step(cache)
            _, dcache = dense_step(dcache)
            delta_t, dense_t = [], []
            for _ in range(reps):                    # interleave: paired reps
                for which, times in (("delta", delta_t), ("dense", dense_t)):
                    step = delta_step if which == "delta" else dense_step
                    c = cache if which == "delta" else dcache
                    t0 = time.perf_counter()
                    for _ in range(steps):
                        lg, c = step(c)
                    jax.block_until_ready(lg)
                    times.append((time.perf_counter() - t0) / steps)
                    if which == "delta":
                        cache = c
                    else:
                        dcache = c
            delta_t, dense_t = np.asarray(delta_t), np.asarray(dense_t)
            ratio = float(np.median(delta_t / dense_t))   # paired per-rep
            row = {"slots": B, "density": k, "capacity": C,
                   "paired_ratio": ratio,
                   "delta_tok_s": float(B / np.min(delta_t)),
                   "dense_tok_s": float(B / np.min(dense_t))}
            out["configs"].append(row)
            print(f"delta_serving_b{B}_k{k}_cap{C},"
                  f"{np.min(delta_t) * 1e6:.1f},"
                  f"{1.0 / ratio:.2f}x_vs_dense")
    return out


def main() -> None:
    micro_benchmarks()
    print()
    from benchmarks import (ablation_lambda, fig2, roofline, seeds, table1,
                            table2, table3)
    table1.main(rounds=ROUNDS)
    print()
    seeds.main(rounds=ROUNDS, seeds=(0,) if FAST else (0, 1, 2))
    print()
    table2.main(rounds=ROUNDS)
    print()
    table3.main()
    print()
    ablation_lambda.main(rounds=ROUNDS)
    print()
    fig2.main(rounds=ROUNDS)
    print()
    roofline.main(None)


if __name__ == '__main__':
    main()
