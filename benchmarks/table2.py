"""Table 2 analogue — heterogeneous resources: R_i ~ half-normal on [1,4]."""
from __future__ import annotations

from benchmarks.common import (N_CLIENTS, SCENARIOS, half_normal_budgets,
                               run_fl, save_result)

STRATS = ("top", "bottom", "both", "snr", "rgn", "ours")


def run(scenarios=("cifar", "domainnet", "xglue"), rounds=None) -> dict:
    budgets = half_normal_budgets(N_CLIENTS)
    out = {"budgets": budgets}
    kw = {} if rounds is None else {"rounds": rounds}
    for sname in scenarios:
        scn = SCENARIOS[sname]
        out[(sname, "full")] = run_fl(scn, "full", **kw).summary()["best_acc"]
        for s in STRATS:
            h = run_fl(scn, s, budgets=budgets, **kw)
            out[(sname, s)] = h.summary()["best_acc"]
    return out


def fmt(results: dict) -> str:
    lines = ["=== Table 2: heterogeneous resources R_i∈[1,4] (best acc) ===",
             f"budgets: {results['budgets']}"]
    scenarios = sorted({k[0] for k in results if isinstance(k, tuple)})
    lines.append(f"{'strategy':9s}" + "".join(f" | {s:9s}" for s in scenarios))
    lines.append(f"{'full':9s}" + "".join(
        f" | {results[(s, 'full')]:9.3f}" for s in scenarios))
    for strat in STRATS:
        lines.append(f"{strat:9s}" + "".join(
            f" | {results[(s, strat)]:9.3f}" for s in scenarios))
    return "\n".join(lines)


def main(rounds=None):
    res = run(rounds=rounds)
    print(fmt(res))
    save_result("table2", {str(k): (list(v) if isinstance(v, tuple) else v)
                           for k, v in res.items()})
    return res


if __name__ == "__main__":
    main()
