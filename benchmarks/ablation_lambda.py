"""λ ablation (§4.2): the consistency regulariser of Problem (P1).

The paper tunes λ ∈ {1,...,1000} per dataset.  Mechanism check: larger λ
must increase cohort mask agreement (lower pairwise ℓ1 disagreement, lower
χ/E_t2), while λ=0 gives independent per-client top-R choices.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import SCENARIOS, build_world, run_fl, save_result
from repro.configs.base import FLConfig
from repro.core.server import FLServer


def disagreement(mask_matrix: np.ndarray) -> float:
    n = mask_matrix.shape[0]
    d = np.abs(mask_matrix[:, None, :] - mask_matrix[None, :, :]).sum(-1)
    return float((d.sum() - np.trace(d)) / max(n * (n - 1), 1))


def main(rounds=None):
    scn = SCENARIOS["xglue"]
    model, params, data = build_world(scn, seed=0)
    out = {}
    print("=== λ ablation (P1 consistency regulariser, xglue scenario) ===")
    print(f"{'lambda':>8s} {'best_acc':>9s} {'mean pairwise |m_i - m_j|_1':>28s} "
          f"{'union frac':>11s}")
    for lam in (0.0, 1.0, 10.0, 1000.0):
        fl = FLConfig(n_clients=20, cohort_size=5,
                      rounds=rounds or 15, local_steps=scn.local_steps,
                      lr=scn.lr, batch_size=scn.batch_size, strategy="ours",
                      budget=2, lam=lam, seed=0)
        server = FLServer(model, fl, data)
        _, hist = server.run(params)
        dis = float(np.mean([disagreement(r.mask_matrix)
                             for r in hist.records]))
        uni = float(np.mean([r.union_frac for r in hist.records]))
        out[lam] = {"best_acc": hist.summary()["best_acc"],
                    "disagreement": dis, "union_frac": uni}
        print(f"{lam:>8.1f} {out[lam]['best_acc']:>9.3f} {dis:>28.3f} "
              f"{uni:>11.3f}")
    # mechanism assertions (soft — printed, tested in test_solver)
    assert out[1000.0]["disagreement"] <= out[0.0]["disagreement"] + 1e-9
    save_result("ablation_lambda", {str(k): v for k, v in out.items()})
    return out


if __name__ == "__main__":
    main()
