"""Roofline report — reads the dry-run JSONs (launch/dryrun.py) and renders
the §Roofline table: three terms per (arch × shape × mesh), dominant
bottleneck, MODEL_FLOPS/HLO_FLOPS ratio, and a one-line lever."""
from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")

LEVERS = {
    "compute": "shard the replicated compute (heads/experts) or cut waste "
               "flops (remat policy, dense-expert fallback)",
    "memory": "fuse/reshard to cut materialised activations (scores, "
              "logits); shrink cache reads per step",
    "collective": "reshard to remove all-gather/all-reduce from the layer "
                  "loop; overlap or quantize Eq.(5) upload",
}


def load_reports(mesh: str | None = None) -> list[dict]:
    from benchmarks.report import load
    rows = load()          # deduped: newest per (arch, shape, mesh, variant)
    out = [r for (a, s, m, v), r in sorted(rows.items())
           if (mesh is None or m == mesh)]
    return out


def fmt_row(r: dict) -> str:
    t = r["roofline"]
    frac = r.get("useful_flops_frac") or 0.0
    variant = "+".join(r.get("opts", [])) or "base"
    return (f"{r['arch']:<22s} {r['shape']:<12s} {r['mesh']:<8s} "
            f"{variant:<18s} "
            f"{t['compute_s']:>10.3e} {t['memory_s']:>10.3e} "
            f"{t['collective_s']:>10.3e}  {r['dominant']:<10s} "
            f"{frac:>7.3f}")


def masked_backward_expectations(L: int = 8, cuts=None) -> list[dict]:
    """Backward-FLOPs-vs-cut expectations for the mask-aware engine
    (DESIGN.md §7).

    With a frozen prefix of depth ``cut``, block backward FLOPs scale as
    (L − cut)/L and the train step (fwd:bwd ≈ 1:2 per block) is expected
    to speed up by 3L / (L + 2(L − cut)) over the dense program — before
    counting the embed/head/norm backward the mask-aware path also drops
    (measured sweep: BENCH_masked_backward.json, CI-gated ≥ these
    shapes' trend: monotone in cut, ≥1.5x at cut = L−1).
    """
    cuts = list(range(L + 1)) if cuts is None else list(cuts)
    rows = []
    print(f"\n=== Mask-aware engine: expected backward FLOPs vs prefix cut "
          f"(L={L}) ===")
    print(f"{'cut':>4s} {'bwd_frac':>9s} {'step_speedup':>13s}")
    for cut in cuts:
        frac = (L - cut) / L
        speed = 3 * L / (L + 2 * (L - cut)) if cut < L else 3.0
        rows.append({"cut": cut, "bwd_frac": frac, "step_speedup": speed})
        print(f"{cut:>4d} {frac:>9.3f} {speed:>12.2f}x")
    print("(forward always runs all L layers; probes stay dense — "
          "selection needs utilities for frozen layers too)")
    return rows


def main(mesh: str | None = "16x16"):
    masked_backward_expectations()
    reports = load_reports(mesh)
    if not reports:
        print(f"(roofline: no dry-run reports found under {DRYRUN_DIR} — "
              f"run `python -m repro.launch.dryrun --all` first)")
        return []
    print("=== Roofline (per step; seconds; TPU v5e constants) ===")
    print(f"{'arch':<22s} {'shape':<12s} {'mesh':<8s} {'variant':<18s} "
          f"{'compute_s':>10s} {'memory_s':>10s} {'collect_s':>10s}  "
          f"{'dominant':<10s} {'useful':>7s}")
    for r in reports:
        print(fmt_row(r))
    # levers summary
    doms = {}
    for r in reports:
        doms.setdefault(r["dominant"], []).append(f"{r['arch']}×{r['shape']}")
    print("\nDominant-term levers:")
    for d, pairs in doms.items():
        print(f"  {d} ({len(pairs)} pairs): {LEVERS[d]}")
    return reports


if __name__ == "__main__":
    main(None)
