"""CI micro-benchmark gate: round_engine + masked_backward + full_round +
probe_trim + pipeline_depth + population_state + delta_serving +
fault_overhead.

    PYTHONPATH=src python -m benchmarks.micro_ci

Runs the engine micro-benchmarks, records them to
``experiments/bench/BENCH_round_engine.json``,
``experiments/bench/BENCH_masked_backward.json``,
``experiments/bench/BENCH_full_round.json``,
``experiments/bench/BENCH_probe_trim.json``,
``experiments/bench/BENCH_pipeline_depth.json``,
``experiments/bench/BENCH_population_state.json``,
``experiments/bench/BENCH_delta_serving.json`` and
``experiments/bench/BENCH_fault_overhead.json`` (uploaded as CI
artifacts), and enforces the wall-clock budgets: the vectorized engine
step must not be slower than the sequential oracle at any cohort size, the
mask-aware engine must not be slower than the dense program at any
frozen-prefix cut AND must beat it ≥1.5x at the deepest cut (the paper's
partial-layer efficiency claim, DESIGN.md §7), the streaming pipeline's
full round (sampling included) must not be slower than the pre-pipeline
legacy path (no dispatch regression from the pluggable-API probe path),
the requirements-trimmed probes must not be slower than the all-stats
probe, the depth-k lookahead scheduler must not be slower than the
depth-1 double buffer (paired per-rep ratios), and the population-state
store's per-round host cost must stay flat when the population grows
10x (O(cohort) gather/scatter, DESIGN.md §8), and the personalized-delta
serving decode must not be slower than the dense per-user-params baseline
at any swept (slots, density) (DESIGN.md §9), and a wired-but-disabled
fault injector must cost at most 1.05x the injector-free round loop
(DESIGN.md §12).  The static program audit
(DESIGN.md §11) gates here too: every jit-suite program family is lowered
on abstract inputs, the compiled-program contracts checked, and the
committed ``experiments/bench/PROGRAM_BUDGETS.json`` diffed — a cost
regression fails deterministically with zero timing noise.  Exits
non-zero on a budget violation.
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    from benchmarks.common import save_result
    from benchmarks.run import (delta_serving_benchmarks,
                                fault_overhead_benchmarks,
                                full_round_benchmarks,
                                masked_backward_benchmarks,
                                pipeline_depth_benchmarks,
                                population_state_benchmarks,
                                probe_trim_benchmarks,
                                round_engine_benchmarks)

    print("name,us_per_call,derived")
    engine_rows = round_engine_benchmarks()
    save_result("BENCH_round_engine", {"rows": engine_rows})
    masked = masked_backward_benchmarks()
    save_result("BENCH_masked_backward", masked)
    full = full_round_benchmarks()
    save_result("BENCH_full_round", full)
    probe = probe_trim_benchmarks()
    save_result("BENCH_probe_trim", probe)
    pdepth = pipeline_depth_benchmarks()
    save_result("BENCH_pipeline_depth", pdepth)
    popstate = population_state_benchmarks()
    save_result("BENCH_population_state", popstate)
    serving = delta_serving_benchmarks()
    save_result("BENCH_delta_serving", serving)
    fault = fault_overhead_benchmarks()
    save_result("BENCH_fault_overhead", fault)

    failures = []
    by_cohort: dict = {}
    for row in engine_rows:
        by_cohort.setdefault(row["cohort"], {})[row["engine"]] = row
    for cohort, pair in sorted(by_cohort.items()):
        seq, vec = pair["sequential"], pair["vectorized"]
        if vec["us_per_call"] > seq["us_per_call"]:
            failures.append(
                f"round_engine c{cohort}: vectorized {vec['us_per_call']:.0f}us"
                f" > sequential {seq['us_per_call']:.0f}us")
    # the mask-aware engine strictly skips work the dense program does
    # (frozen-prefix backward + embed/head/norm backward): it must not be
    # slower at ANY cut (paired per-rep ratios; 10% CI-jitter headroom),
    # and the deepest cut — backward reduced to one layer of L — must hold
    # the paper's efficiency claim at ≥1.5x over dense
    deepest = masked["cuts"][-1]
    for cut in masked["cuts"]:
        if masked[f"cut{cut}_ratio"] > 1.10:
            failures.append(
                f"masked_backward: cut={cut} paired ratio "
                f"{masked[f'cut{cut}_ratio']:.2f} > 1.10 vs dense")
    if 1.0 / masked[f"cut{deepest}_ratio"] < 1.5:
        failures.append(
            f"masked_backward: cut={deepest} speedup "
            f"{1.0 / masked[f'cut{deepest}_ratio']:.2f}x < 1.5x vs dense")
    # the gap must grow monotonically in frozen-prefix depth (a deeper cut
    # skips strictly more backward); 5% slack absorbs paired-ratio jitter
    ratios = [masked[f"cut{c}_ratio"] for c in masked["cuts"]]
    for (c0, r0), (c1, r1) in zip(zip(masked["cuts"], ratios),
                                  zip(masked["cuts"][1:], ratios[1:])):
        if r1 > r0 + 0.05:
            failures.append(
                f"masked_backward: ratio not monotone in cut depth "
                f"(cut={c1}: {r1:.2f} > cut={c0}: {r0:.2f})")
    if full["vectorized_us_per_round"] > full["legacy_us_per_round"]:
        failures.append(
            f"full_round: vectorized {full['vectorized_us_per_round']:.0f}us"
            f" > legacy {full['legacy_us_per_round']:.0f}us")
    # requirements-trimmed probes do strictly less work than the all-stats
    # probe; gate the median of *paired* per-rep ratios (load spikes hit
    # both sides of a pair and cancel), with 10% headroom for CI jitter
    for name in ("ours_trimmed", "snr_trimmed"):
        if probe[f"{name}_ratio"] > 1.10:
            failures.append(
                f"probe_trim: {name} paired ratio "
                f"{probe[f'{name}_ratio']:.2f} > 1.10 vs all_stats")
    # depth-k lookahead does strictly more overlap than the depth-1 double
    # buffer with identical results; gate the median of paired per-rep
    # ratios with the same 10% CI-jitter headroom
    if pdepth["paired_ratio"] > 1.10:
        failures.append(
            f"pipeline_depth: depth-{pdepth['depth']} paired ratio "
            f"{pdepth['paired_ratio']:.2f} > 1.10 vs depth-1")
    # every store op is an O(cohort) fancy-index into flat arrays: growing
    # the population 10x must leave the per-round host cost flat (2.0 is
    # generous headroom for allocator/cache noise at the 10^5 row arrays —
    # a dict- or O(n)-scan regression shows up as ~10x)
    pops = popstate["populations"]
    if popstate["paired_ratio"] > 2.0:
        failures.append(
            f"population_state: {pops[-1]}-client paired ratio "
            f"{popstate['paired_ratio']:.2f} > 2.0 vs {pops[0]} clients "
            f"(per-round host cost must be independent of population size)")

    # the delta overlay streams (1+C)·d·f weight bytes per step where the
    # dense per-user baseline streams B·d·f, and C+1 < B at every swept
    # density — delta decode must not be slower at ANY (slots, density)
    # (paired per-rep ratios; 10% CI-jitter headroom, DESIGN.md §9)
    for row in serving["configs"]:
        if row["paired_ratio"] > 1.10:
            failures.append(
                f"delta_serving: slots={row['slots']} density={row['density']}"
                f" paired ratio {row['paired_ratio']:.2f} > 1.10 vs dense "
                f"per-user params")

    # the chaos seam (DESIGN.md §12) must be free when nothing is injected:
    # a wired-but-disabled FaultPlan may cost at most the per-stage
    # _faults_active property check (paired per-rep ratios, 5% ceiling —
    # tighter than the other gates because the admissible delta is a few
    # attribute reads, not a different program)
    if fault["paired_ratio"] > 1.05:
        failures.append(
            f"fault_overhead: disabled-injector paired ratio "
            f"{fault['paired_ratio']:.3f} > 1.05 vs no injector")

    # static program budgets (DESIGN.md §11): zero timing noise — the
    # auditor lowers every jit-suite program family on abstract inputs,
    # checks the program-level contracts (cut-monotone FLOPs,
    # B-independent delta weight traffic, donation honored, dtype
    # discipline, collective/transfer allowlist) and diffs the committed
    # PROGRAM_BUDGETS.json with per-metric tolerances
    from repro.analysis import contracts as program_contracts
    from repro.analysis import program as program_audit
    facts = program_audit.run_audit()
    violations = program_contracts.check_all(facts)
    budget_failures = []
    manifest = program_audit.load_budgets()
    if manifest is None:
        failures.append(
            "program_audit: experiments/bench/PROGRAM_BUDGETS.json missing "
            "— run `python -m repro.analysis program --update-budgets` and "
            "commit it")
    else:
        budget_failures = program_audit.check_budgets(facts, manifest)
    save_result("BENCH_program_audit",
                program_audit.audit_report(facts, violations,
                                           budget_failures))
    failures += [f"program_audit[{v.contract}] {v.program}: {v.message}"
                 for v in violations]
    failures += [f"program_audit[budget] {m}" for m in budget_failures]

    print(f"full_round speedup over pre-pipeline path: "
          f"{full['speedup']:.2f}x")
    print("masked_backward speedups vs dense: "
          + ", ".join(f"cut={c}: {1.0 / masked[f'cut{c}_ratio']:.2f}x"
                      for c in masked["cuts"]))
    print(f"probe trim (ours): paired ratio "
          f"{probe['ours_trimmed_ratio']:.2f} vs all-stats probe")
    print(f"pipeline depth-{pdepth['depth']}: paired ratio "
          f"{pdepth['paired_ratio']:.2f} vs depth-1")
    print(f"population_state {pops[-1]} vs {pops[0]} clients: paired ratio "
          f"{popstate['paired_ratio']:.2f}")
    print("delta_serving speedups vs dense per-user params: "
          + ", ".join(f"b{r['slots']}/k{r['density']}: "
                      f"{1.0 / r['paired_ratio']:.2f}x"
                      for r in serving["configs"]))
    print(f"fault_overhead: disabled-injector paired ratio "
          f"{fault['paired_ratio']:.3f} vs no injector")
    if failures:
        for f in failures:
            print(f"BUDGET VIOLATION: {f}", file=sys.stderr)
        sys.exit(1)
    print("micro-benchmark budget: OK "
          "(vectorized <= sequential, masked <= dense at every cut and "
          ">=1.5x at the deepest, trimmed probe <= all-stats, "
          "depth-k <= depth-1, population-state cost flat in n, "
          "delta serving <= dense per-user params at every density, "
          "disabled fault injector <= 1.05x no-injector, "
          f"{len(facts)} programs statically audited: contracts + budgets)")


if __name__ == "__main__":
    main()
