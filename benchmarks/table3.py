"""Table 3 analogue — computational & communication costs.

Reproduces the paper's cost table structure: full fine-tuning vs the
proposed method (R=1), with the selection-period and selection-batch
variants.  Costs come from the §4.3 model (exact per-layer accounting,
core/costs.py) evaluated on a *real* assigned architecture (tinyllama);
the measured uploaded-parameter counter from the simulator cross-checks
the transmission ratio.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import SCENARIOS, run_fl, save_result
from repro.configs.base import FLConfig, get_arch
from repro.core.costs import backward_cost_exact, backward_cost_uniform
from repro.core.masks import count_layer_params
from repro.models.model import init_params

import jax.numpy as jnp


def run() -> dict:
    cfg = get_arch("tinyllama-1.1b")
    shapes = jax.eval_shape(lambda k: init_params(cfg, k),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    layer_params = count_layer_params(
        jax.tree.map(lambda s: np.zeros(s.shape, np.int8), shapes), cfg)
    L = cfg.n_layers
    tau, tokens = 5, 64 * 2048          # batch 64, seq 2048 (paper-ish)
    mask = np.zeros(L, np.float32)
    mask[-1] = 1                        # R=1

    full = backward_cost_exact(layer_params, np.ones(L, np.float32), tau,
                               tokens_per_batch=tokens)
    rows = {"full": {"tflops": full.compute_flops / 1e12, "ratio": 1.0,
                     "mbits": float(layer_params.sum()) * 32 / 1e6,
                     "tx_ratio": 1.0}}

    variants = {
        "ours": dict(sel_period=1, sel_batches=1),
        "ours_period2": dict(sel_period=2, sel_batches=1),
        # "Sel. Batch=1" in the paper = probing on fewer samples; we model it
        # as a probe over 1/5 of the local batch budget:
        "ours_selbatch": dict(sel_period=5, sel_batches=1),
    }
    for name, kw in variants.items():
        rep = backward_cost_exact(layer_params, mask, tau,
                                  tokens_per_batch=tokens, **kw)
        rows[name] = {
            "tflops": rep.compute_flops / 1e12,
            "sel_tflops": rep.select_flops / 1e12,
            "ratio": rep.compute_flops / full.compute_flops,
            "mbits": rep.transmit_bits / 1e6,
            "tx_ratio": rep.ratio_transmit,
        }

    # unit crosscheck: uniform and exact must agree in *bits* on uniform
    # layer sizes (the uniform model has one abstract param per layer)
    uni = backward_cost_uniform(L, 1, tau)
    uni_exact = backward_cost_exact(np.ones(L, np.int64), mask, tau)
    assert uni.transmit_bits == uni_exact.transmit_bits, \
        (uni.transmit_bits, uni_exact.transmit_bits)
    assert uni.ratio_transmit == uni_exact.ratio_transmit
    rows["uniform_bits_crosscheck"] = uni.transmit_bits

    # cross-check the transmission ratio against the simulator's counter
    # (the bench scenario model has L=4 selectable layers, so R=1 -> 1/4)
    h_sel = run_fl(SCENARIOS["cifar"], "top", budget=1, rounds=2)
    h_full = run_fl(SCENARIOS["cifar"], "full", rounds=2)
    rows["measured_tx_ratio"] = (
        h_sel.summary()["uploaded_params_total"]
        / h_full.summary()["uploaded_params_total"])
    rows["measured_tx_L"] = 4
    return rows


def fmt(rows: dict) -> str:
    lines = ["=== Table 3: computational & communication costs "
             "(tinyllama-1.1b, R=1, tau=5) ==="]
    lines.append(f"{'variant':<16s} {'TFLOPs':>10s} {'ratio':>8s}"
                 f" {'MBits':>12s} {'tx_ratio':>9s}")
    for name in ("full", "ours", "ours_period2", "ours_selbatch"):
        r = rows[name]
        lines.append(f"{name:<16s} {r['tflops']:>10.2f} {r['ratio']:>8.2%}"
                     f" {r['mbits']:>12.1f} {r['tx_ratio']:>9.4f}")
    L = rows.get("measured_tx_L", 4)
    lines.append(f"measured upload ratio (simulator scenario, R=1, L={L}):"
                 f" {rows['measured_tx_ratio']:.4f} (expect {1/L:.4f})")
    return "\n".join(lines)


def main():
    rows = run()
    print(fmt(rows))
    save_result("table3", {k: v for k, v in rows.items()})
    return rows


if __name__ == "__main__":
    main()
