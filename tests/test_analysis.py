"""Rule-engine coverage: a bad/good fixture pair per rule (each bad
fixture is the test that would fail if its rule were dropped), pragma
suppression semantics, and the self-lint pin that keeps the repo clean."""
import os
import textwrap

import pytest

from repro.analysis import AnalysisConfig, RULES, run_paths

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def lint(tmp_path, sources, config=None, only=None):
    for rel, text in sources.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(text))
    return run_paths([str(tmp_path)], repo_root=str(tmp_path),
                     config=config, only=only)


def rules_hit(findings):
    return {f.rule for f in findings}


def test_rules_registered():
    assert len(RULES) >= 8
    assert set(RULES) >= {"jit-outside-cache", "host-sync", "nondeterminism",
                          "tracer-hazard", "unhashable-static",
                          "kernel-parity", "donation-miss",
                          "exception-swallow"}


# -- jit-outside-cache -------------------------------------------------------

def test_jit_outside_cache_bad_and_good(tmp_path):
    bad = lint(tmp_path, {"a.py": """
        import jax
        def make(model):
            return jax.jit(model.loss)
        """}, only=["jit-outside-cache"])
    assert [f.rule for f in bad] == ["jit-outside-cache"]
    assert bad[0].line == 4

    good = lint(tmp_path, {"b.py": """
        import jax
        def loss(p, b):
            return p
        loss_jit = jax.jit(loss)          # module scope: compiled once
        """}, only=["jit-outside-cache"])
    assert not [f for f in good if f.path == "b.py"]


def test_jit_sanctioned_module_allowed(tmp_path):
    cfg = AnalysisConfig(jit_sanctioned=("engine/",))
    out = lint(tmp_path, {"engine/suite.py": """
        import jax
        def build(fn):
            return jax.jit(fn)
        """}, config=cfg, only=["jit-outside-cache"])
    assert not out


# -- host-sync ---------------------------------------------------------------

HOT_CFG = AnalysisConfig(hot_entry_points=("main",),
                         host_stage_boundary=frozenset({"sample_round"}))


def test_host_sync_reachable_bad(tmp_path):
    bad = lint(tmp_path, {"hot.py": """
        import numpy as np
        def main(xs):
            for x in xs:
                record(x)
        def record(x):
            return float(x.mean()), np.asarray(x)
        """}, config=HOT_CFG, only=["host-sync"])
    assert rules_hit(bad) == {"host-sync"}
    assert len(bad) >= 2          # float(...) and np.asarray(...)


def test_host_sync_stops_at_stage_boundary(tmp_path):
    out = lint(tmp_path, {"hot.py": """
        import numpy as np
        def main(xs):
            sample_round(xs)
        def sample_round(xs):
            return np.asarray(xs)      # host stage: sanctioned by design
        def unrelated(x):
            return float(x)            # not reachable from main
        """}, config=HOT_CFG, only=["host-sync"])
    assert not out


# -- nondeterminism ----------------------------------------------------------

NONDET_CFG = AnalysisConfig(nondet_scope=("",))


def test_nondeterminism_bad_sources(tmp_path):
    bad = lint(tmp_path, {"sel.py": """
        import random, time
        import numpy as np
        def pick(xs):
            t = time.time()
            i = random.randrange(len(xs))
            return xs[i] + np.random.rand(), t
        """}, config=NONDET_CFG, only=["nondeterminism"])
    assert rules_hit(bad) == {"nondeterminism"}
    assert len(bad) == 3          # time.time, random.randrange, np.random.rand


def test_nondeterminism_seeded_streams_allowed(tmp_path):
    out = lint(tmp_path, {"sel.py": """
        import numpy as np
        def pick(xs, seed):
            rng = np.random.RandomState(seed)
            return xs[rng.randint(len(xs))]
        """}, config=NONDET_CFG, only=["nondeterminism"])
    assert not out
    bad = lint(tmp_path, {"sel2.py": """
        import numpy as np
        def pick(xs):
            return xs[np.random.default_rng().integers(len(xs))]
        """}, config=NONDET_CFG, only=["nondeterminism"])
    assert [f.rule for f in bad] == ["nondeterminism"]   # ctor unseeded


# -- tracer-hazard -----------------------------------------------------------

def test_tracer_hazard_bad_and_good(tmp_path):
    bad = lint(tmp_path, {"t.py": """
        import jax
        import jax.numpy as jnp
        @jax.jit
        def step(x):
            if jnp.sum(x) > 0:
                return x
            return -x
        """}, only=["tracer-hazard"])
    assert [f.rule for f in bad] == ["tracer-hazard"]

    good = lint(tmp_path, {"g.py": """
        import jax
        import jax.numpy as jnp
        @jax.jit
        def step(x):
            return jnp.where(jnp.sum(x) > 0, x, -x)
        def host_side(x):
            if jnp.sum(x) > 0:        # not a jitted function: fine
                return x
        """}, only=["tracer-hazard"])
    assert not [f for f in good if f.path == "g.py"]


def test_tracer_hazard_catches_suite_registration(tmp_path):
    """Functions registered via jax.jit(self._impl, ...) — the jit-suite
    pattern — are treated as jitted even without a decorator."""
    bad = lint(tmp_path, {"s.py": """
        import jax
        import jax.numpy as jnp
        class C:
            def _impl(self, x):
                while jnp.any(x > 0):
                    x = x - 1
                return x
            def __init__(self):
                self._f = jax.jit(self._impl)
        """}, only=["tracer-hazard"])
    assert [f.rule for f in bad] == ["tracer-hazard"]


# -- unhashable-static -------------------------------------------------------

def test_unhashable_static_bad_and_good(tmp_path):
    bad = lint(tmp_path, {"u.py": """
        import jax
        def f(x, history=[]):
            return x
        g = jax.jit(f, static_argnums=[1])
        """}, only=["unhashable-static"])
    assert [f.rule for f in bad] == ["unhashable-static"] * 2

    good = lint(tmp_path, {"v.py": """
        import jax
        def f(x, history=None):
            return x
        g = jax.jit(f, static_argnums=(1,))
        """}, only=["unhashable-static"])
    assert not [f for f in good if f.path == "v.py"]


# -- kernel-parity -----------------------------------------------------------

KERNEL_GOOD = {
    "kernels/foo.py": """
        from jax.experimental import pallas as pl
        def foo(x):
            return pl.pallas_call(None)(x)
        def foo_jnp(x):
            return x
        """,
    "kernels/ops.py": "# dispatches foo via use_pallas\n",
    "tests/test_kernels.py": "# exercises foo and foo_jnp parity\n",
}


def kernel_cfg():
    return AnalysisConfig(kernel_dir="kernels/",
                          kernel_exclude=("ops.py",),
                          kernel_tests="tests/test_kernels.py",
                          kernel_dispatch="kernels/ops.py")


def test_kernel_parity_good(tmp_path):
    out = lint(tmp_path, KERNEL_GOOD, config=kernel_cfg(),
               only=["kernel-parity"])
    assert not out


def test_kernel_parity_flags_missing_fallback_dispatch_and_test(tmp_path):
    srcs = dict(KERNEL_GOOD)
    srcs["kernels/foo.py"] = """
        from jax.experimental import pallas as pl
        def foo(x):
            return pl.pallas_call(None)(x)
        """
    srcs["kernels/ops.py"] = "# nothing here\n"
    srcs["tests/test_kernels.py"] = "# nothing here\n"
    bad = lint(tmp_path, srcs, config=kernel_cfg(), only=["kernel-parity"])
    msgs = " ".join(f.message for f in bad)
    assert rules_hit(bad) == {"kernel-parity"} and len(bad) == 3
    assert "fallback" in msgs and "dispatch" in msgs and "parity" in msgs


def test_kernel_parity_flags_untested_fallback(tmp_path):
    srcs = dict(KERNEL_GOOD)
    srcs["tests/test_kernels.py"] = "# mentions foo but not the fallback\n"
    bad = lint(tmp_path, srcs, config=kernel_cfg(), only=["kernel-parity"])
    assert [f.rule for f in bad] == ["kernel-parity"]
    assert "foo_jnp" in bad[0].message


# -- donation-miss -----------------------------------------------------------

DON_CFG = AnalysisConfig(donation_scope=("serve/",),
                         donation_tree_params=("params", "stacked"))


def test_donation_miss_bad_and_good(tmp_path):
    bad = lint(tmp_path, {"serve/e.py": """
        import jax
        def step(params, x):
            return params
        f = jax.jit(step)
        """}, config=DON_CFG, only=["donation-miss"])
    assert [f.rule for f in bad] == ["donation-miss"]
    assert "params" in bad[0].message and "donate_argnums" in bad[0].message

    good = lint(tmp_path, {"serve/g.py": """
        import jax
        def write(stacked, p, b):
            return stacked
        f = jax.jit(write, donate_argnums=0)       # donates: fine
        def sample(tokens, key):
            return tokens
        g = jax.jit(sample)                        # no params-sized tree
        """}, config=DON_CFG, only=["donation-miss"])
    assert not [f for f in good if f.path == "serve/g.py"]


def test_donation_miss_lambda_target(tmp_path):
    bad = lint(tmp_path, {"serve/l.py": """
        import jax
        f = jax.jit(lambda stacked, b: stacked)
        """}, config=DON_CFG, only=["donation-miss"])
    assert [f.rule for f in bad] == ["donation-miss"]


def test_donation_miss_outside_scope_ignored(tmp_path):
    out = lint(tmp_path, {"probe/e.py": """
        import jax
        def step(params, x):
            return params
        f = jax.jit(step)
        """}, config=DON_CFG, only=["donation-miss"])
    assert not out


def test_donation_miss_pragma_escape(tmp_path):
    out = lint(tmp_path, {"serve/p.py": """
        import jax
        def step(params, x):
            return params
        f = jax.jit(step)  # repro: allow[donation-miss] -- params shared across slots
        """}, config=DON_CFG, only=["donation-miss"])
    assert not out


# -- exception-swallow -------------------------------------------------------

SWALLOW_CFG = AnalysisConfig(swallow_scope=("core/",))


def test_exception_swallow_bad(tmp_path):
    out = lint(tmp_path, {"core/a.py": """
        def load(path):
            try:
                return open(path).read()
            except:
                return None

        def tick(items):
            for x in items:
                try:
                    x.step()
                except Exception:
                    pass
        """}, config=SWALLOW_CFG, only=["exception-swallow"])
    assert [f.rule for f in out] == ["exception-swallow"] * 2
    assert {f.line for f in out} == {5, 12}


def test_exception_swallow_good(tmp_path):
    out = lint(tmp_path, {"core/b.py": """
        import shutil

        def save(tmp):
            try:
                return write(tmp)
            except Exception:
                shutil.rmtree(tmp, ignore_errors=True)
                raise

        def verify(path):
            try:
                return parse(path), "ok"
            except (OSError, ValueError) as e:
                return None, str(e)

        def load(z):
            try:
                return z.read()
            except Exception as e:
                return None  # repro: allow[exception-swallow] -- verdict returned to caller
        """}, config=SWALLOW_CFG, only=["exception-swallow"])
    assert not out


def test_exception_swallow_outside_scope_ignored(tmp_path):
    out = lint(tmp_path, {"tools/c.py": """
        def f():
            try:
                g()
            except Exception:
                pass
        """}, config=SWALLOW_CFG, only=["exception-swallow"])
    assert not out


# -- pragmas -----------------------------------------------------------------

def test_pragma_suppresses_with_reason(tmp_path):
    out = lint(tmp_path, {"p.py": """
        import jax
        def make(fn):
            return jax.jit(fn)  # repro: allow[jit-outside-cache] -- test fixture
        """}, only=["jit-outside-cache"])
    assert not out


def test_pragma_line_above(tmp_path):
    out = lint(tmp_path, {"p.py": """
        import jax
        def make(fn):
            # repro: allow[jit-outside-cache] -- test fixture
            return jax.jit(fn)
        """}, only=["jit-outside-cache"])
    assert not out


def test_pragma_without_reason_rejected(tmp_path):
    out = lint(tmp_path, {"p.py": """
        import jax
        def make(fn):
            return jax.jit(fn)  # repro: allow[jit-outside-cache]
        """}, only=["jit-outside-cache"])
    # reasonless pragma does NOT suppress, and is itself a finding
    assert rules_hit(out) == {"jit-outside-cache", "pragma"}


def test_pragma_unknown_rule_rejected(tmp_path):
    out = lint(tmp_path, {"p.py": """
        x = 1  # repro: allow[no-such-rule] -- because
        """})
    assert rules_hit(out) == {"pragma"}
    assert "no-such-rule" in out[0].message


def test_pragma_only_suppresses_named_rule(tmp_path):
    out = lint(tmp_path, {"p.py": """
        import jax
        def make(fn, xs=[]):
            return jax.jit(fn)  # repro: allow[unhashable-static] -- wrong rule named
        """}, only=["jit-outside-cache", "unhashable-static"])
    assert rules_hit(out) == {"jit-outside-cache", "unhashable-static"}


# -- CLI + self-lint ---------------------------------------------------------

def test_cli_exit_codes(tmp_path):
    from repro.analysis.__main__ import main
    (tmp_path / "bad.py").write_text(
        "import jax\ndef f(g):\n    return jax.jit(g)\n")
    assert main([str(tmp_path / "bad.py"), "--root", str(tmp_path)]) == 1
    assert main(["--list-rules"]) == 0


def test_cli_json_findings(tmp_path, capsys):
    """--json: the machine-readable findings the CI lint job turns into
    per-line GitHub annotations."""
    import json

    from repro.analysis.__main__ import main
    (tmp_path / "bad.py").write_text(
        "import jax\ndef f(g):\n    return jax.jit(g)\n")

    rc = main(["--json", str(tmp_path / "bad.py"), "--root", str(tmp_path)])
    report = json.loads(capsys.readouterr().out)
    assert rc == 1 and not report["ok"]
    assert [(f["rule"], f["line"]) for f in report["findings"]] == [
        ("jit-outside-cache", 3)]
    assert report["findings"][0]["path"] == "bad.py"

    (tmp_path / "ok.py").write_text("x = 1\n")
    rc = main(["--json", str(tmp_path / "ok.py"), "--root", str(tmp_path)])
    report = json.loads(capsys.readouterr().out)
    assert rc == 0 and report["ok"] and report["findings"] == []


def test_self_lint_repo_clean():
    """The acceptance pin: the linted tree (src benchmarks examples) is
    clean under every rule — new violations need a fix or a reasoned
    pragma to land."""
    findings = run_paths(["src", "benchmarks", "examples"],
                         repo_root=REPO_ROOT)
    assert not findings, "\n".join(f.format() for f in findings)
