"""Vectorized vs. sequential round-engine parity + seed determinism.

The vectorized engine (Client.cohort_update / Client.probe_cohort) must be
an exact drop-in for the paper-literal sequential loop: identical cohorts,
identical masks, params equal within fp tolerance — across strategies and
heterogeneous per-client budgets.
"""
import jax
import numpy as np
import pytest

from repro.api.task import DirichletTaskConfig, DirichletTokenMixtureTask
from repro.configs.base import FLConfig, RuntimeConfig, get_arch, reduced
from repro.core.server import FLServer
from repro.data.synthetic import FederatedTaskConfig, SyntheticFederatedData
from repro.models.model import Model


@pytest.fixture(scope="module")
def world():
    cfg = reduced(get_arch("xlm_roberta_base"), n_layers=4, d_model=32)
    model = Model(cfg, RuntimeConfig(remat=False, seq_chunk=16))
    params = model.init(jax.random.PRNGKey(0))
    task = FederatedTaskConfig(
        n_clients=12, n_classes=10, vocab_size=cfg.vocab_size, seq_len=8,
        samples_per_client=16, skew="label", objective="classification")
    return model, params, task


def _run(model, params, task, fl, engine):
    # fresh data per run: both engines must consume identical RNG streams
    data = SyntheticFederatedData(task)
    server = FLServer(model, fl, data, engine=engine)
    return server.run(params)


def _assert_parity(model, params, task, fl, atol=1e-5):
    p_seq, h_seq = _run(model, params, task, fl, "sequential")
    p_vec, h_vec = _run(model, params, task, fl, "vectorized")
    for rs, rv in zip(h_seq.records, h_vec.records):
        np.testing.assert_array_equal(rs.cohort, rv.cohort)
        np.testing.assert_array_equal(rs.mask_matrix, rv.mask_matrix)
        assert rs.uploaded_params == rv.uploaded_params
        assert rs.train_loss == pytest.approx(rv.train_loss, abs=1e-4)
        assert rs.test_loss == pytest.approx(rv.test_loss, abs=1e-4)
    err = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(np.abs(np.asarray(a, np.float32)
                                  - np.asarray(b, np.float32)).max()),
        p_seq, p_vec)))
    assert err < atol, f"param divergence {err}"


@pytest.mark.parametrize("strategy", ["ours", "top", "rgn", "full"])
def test_engine_parity_across_strategies(world, strategy):
    model, params, task = world
    fl = FLConfig(n_clients=12, cohort_size=4, rounds=2, local_steps=2,
                  lr=0.01, batch_size=4, strategy=strategy, budget=2,
                  lam=1.0, seed=3)
    _assert_parity(model, params, task, fl)


@pytest.mark.parametrize("strategy", ["ours", "top"])
def test_engine_parity_heterogeneous_budgets(world, strategy):
    model, params, task = world
    fl = FLConfig(n_clients=12, cohort_size=4, rounds=2, local_steps=2,
                  lr=0.01, batch_size=4, strategy=strategy,
                  budgets=(1, 2, 3, 4), lam=1.0, seed=7)
    _assert_parity(model, params, task, fl)


def test_engine_parity_hybrid_shared_attn():
    """The hybrid family's unstacked shared block exercises the (n,)-weight
    einsum branch of aggregate_stacked."""
    cfg = reduced(get_arch("zamba2_7b"), n_layers=2, d_model=32)
    model = Model(cfg, RuntimeConfig(remat=False, seq_chunk=16))
    params = model.init(jax.random.PRNGKey(1))
    task = FederatedTaskConfig(n_clients=8, vocab_size=cfg.vocab_size,
                               seq_len=8, samples_per_client=16, skew="label",
                               objective="lm")
    fl = FLConfig(n_clients=8, cohort_size=3, rounds=1, local_steps=2,
                  lr=0.01, batch_size=2, strategy="ours", budget=2,
                  lam=1.0, seed=0)
    _assert_parity(model, params, task, fl)


@pytest.mark.parametrize("engine", ["sequential", "vectorized"])
def test_seed_determinism(world, engine):
    """Fixed FLConfig.seed => identical cohort sequence and summary twice."""
    model, params, task = world
    fl = FLConfig(n_clients=12, cohort_size=4, rounds=3, local_steps=1,
                  lr=0.01, batch_size=4, strategy="ours", budget=2,
                  lam=1.0, seed=11)
    _, h1 = _run(model, params, task, fl, engine)
    _, h2 = _run(model, params, task, fl, engine)
    for r1, r2 in zip(h1.records, h2.records):
        np.testing.assert_array_equal(r1.cohort, r2.cohort)
        np.testing.assert_array_equal(r1.mask_matrix, r2.mask_matrix)
    assert h1.summary() == h2.summary()


@pytest.mark.parametrize("period", [2, 3])
def test_engine_parity_selection_period(world, period):
    """Both engines share the per-client stat cache + on-demand probes."""
    model, params, task = world
    fl = FLConfig(n_clients=12, cohort_size=4, rounds=4, local_steps=1,
                  lr=0.01, batch_size=4, strategy="ours",
                  budgets=(1, 2, 3, 4), selection_period=period, lam=1.0,
                  seed=5)
    _assert_parity(model, params, task, fl)


@pytest.mark.parametrize("period", [1, 2])
def test_pipelined_run_matches_synchronous(world, period):
    """The streaming pipeline (prefetch + async/fused probe) is a pure
    scheduling change: cohorts and masks bit-identical, params within fp."""
    model, params, task = world
    fl = FLConfig(n_clients=12, cohort_size=4, rounds=3, local_steps=2,
                  lr=0.01, batch_size=4, strategy="ours", budget=2,
                  selection_period=period, lam=1.0, seed=13)
    data_p = SyntheticFederatedData(task)
    data_s = SyntheticFederatedData(task)
    p_pipe, h_pipe = FLServer(model, fl, data_p, pipeline=True).run(params)
    p_sync, h_sync = FLServer(model, fl, data_s, pipeline=False).run(params)
    for rp, rs in zip(h_pipe.records, h_sync.records):
        np.testing.assert_array_equal(rp.cohort, rs.cohort)
        np.testing.assert_array_equal(rp.mask_matrix, rs.mask_matrix)
        assert rp.train_loss == pytest.approx(rs.train_loss, abs=1e-5)
        assert rp.test_loss == pytest.approx(rs.test_loss, abs=1e-5)
    err = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(np.abs(np.asarray(a, np.float32)
                                  - np.asarray(b, np.float32)).max()),
        p_pipe, p_sync)))
    assert err < 1e-5, f"pipelined param divergence {err}"


@pytest.mark.parametrize("depth,period", [(1, 1), (3, 1), (3, 2)])
def test_pipelined_hooks_match_synchronous(world, depth, period):
    """Availability + straggler hooks consume the server rng at the plan
    stage, which pins when the scheduler may fire plan_round(t+1): the
    depth-k pipeline must still draw bit-identical cohorts and masks versus
    the synchronous loop."""
    model, params, _ = world
    dcfg = DirichletTaskConfig(n_clients=12, vocab_size=model.cfg.vocab_size,
                               seq_len=8, test_samples=32, availability=0.5,
                               straggler_rate=0.3, seed=4)
    fl = FLConfig(n_clients=12, cohort_size=4, rounds=5, local_steps=1,
                  lr=0.01, batch_size=4, strategy="ours", budget=2,
                  selection_period=period, lam=1.0, seed=19)
    p_pipe, h_pipe = FLServer(model, fl, DirichletTokenMixtureTask(dcfg),
                              pipeline=True,
                              pipeline_depth=depth).run(params)
    p_sync, h_sync = FLServer(model, fl, DirichletTokenMixtureTask(dcfg),
                              pipeline=False).run(params)
    assert len(h_pipe.records) == len(h_sync.records) == 5
    shrunk = False
    for rp, rs in zip(h_pipe.records, h_sync.records):
        np.testing.assert_array_equal(rp.cohort, rs.cohort)
        np.testing.assert_array_equal(rp.mask_matrix, rs.mask_matrix)
        assert rp.train_loss == pytest.approx(rs.train_loss, abs=1e-5)
        assert rp.test_loss == pytest.approx(rs.test_loss, abs=1e-5)
        shrunk = shrunk or len(rp.cohort) < 4
    assert shrunk, "straggler hook never fired — test lost its teeth"
    err = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(np.abs(np.asarray(a, np.float32)
                                  - np.asarray(b, np.float32)).max()),
        p_pipe, p_sync)))
    assert err < 1e-5, f"hooked pipelined param divergence {err}"
