"""Checkpointing, data pipeline, optimizers, cost model."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import latest_step, restore_checkpoint, save_checkpoint
from repro.configs.base import RuntimeConfig, get_arch, reduced
from repro.core.costs import backward_cost_exact, backward_cost_uniform
from repro.data.synthetic import FederatedTaskConfig, SyntheticFederatedData
from repro.models.model import Model
from repro.optim import adamw, apply_updates, cosine_schedule, sgd


# --- checkpointing ---------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    cfg = reduced(get_arch("smollm_360m"), n_layers=2, d_model=64)
    model = Model(cfg, RuntimeConfig(remat=False))
    params = model.init(jax.random.PRNGKey(0))
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 3, params, extra={"round": 3})
    save_checkpoint(d, 7, params, extra={"round": 7})
    assert latest_step(d) == 7
    template = jax.tree.map(jnp.zeros_like, params)
    restored, manifest = restore_checkpoint(d, template)
    assert manifest["extra"]["round"] == 7
    flat_a = jax.tree.leaves(params)
    flat_b = jax.tree.leaves(restored)
    for a, b in zip(flat_a, flat_b):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    d = str(tmp_path / "c")
    save_checkpoint(d, 0, {"w": jnp.ones((3, 3))})
    with pytest.raises(AssertionError):
        restore_checkpoint(d, {"w": jnp.ones((2, 2))})


# --- data pipeline -----------------------------------------------------------

def test_label_skew_concentration():
    data = SyntheticFederatedData(FederatedTaskConfig(
        n_clients=50, skew="label", dirichlet_alpha=0.1, seed=1))
    # Dirichlet(0.1): client label distributions are strongly concentrated
    maxes = data.client_label_p.max(axis=1)
    assert np.median(maxes) > 0.5
    # and the aggregate stays roughly balanced
    agg = (data.client_label_p * data.alpha[:, None]).sum(0)
    assert agg.max() < 0.5


def test_feature_skew_domains_differ():
    data = SyntheticFederatedData(FederatedTaskConfig(
        n_clients=10, skew="feature", n_domains=3, seed=2,
        domain_strength=0.5))
    perms = data.domain_perm
    assert len(perms) == 4                      # 3 domains + identity
    assert np.array_equal(perms[-1], np.arange(len(perms[-1])))
    assert not np.array_equal(perms[0], perms[1])


def test_batches_deterministic_shapes():
    data = SyntheticFederatedData(FederatedTaskConfig(n_clients=5, seed=3))
    b = data.client_batch(2, 16)
    assert b["tokens"].shape == (16, data.cfg.seq_len)
    assert b["label"].shape == (16,)
    assert b["tokens"].max() < data.cfg.vocab_size
    stacked = data.client_batches(1, 8, 3)
    assert stacked["tokens"].shape == (3, 8, data.cfg.seq_len)


def test_alpha_sums_to_one():
    data = SyntheticFederatedData(FederatedTaskConfig(n_clients=7, seed=4))
    np.testing.assert_allclose(data.alpha.sum(), 1.0)


# --- optimizers ----------------------------------------------------------------

def _quad_min(opt, steps=200):
    params = {"w": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(steps):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        u, state = opt.update(g, state, params)
        params = apply_updates(params, u)
    return float(jnp.max(jnp.abs(params["w"])))


def test_sgd_converges_quadratic():
    assert _quad_min(sgd(0.1)) < 1e-3


def test_sgd_momentum_converges():
    assert _quad_min(sgd(0.05, momentum=0.9)) < 1e-3


def test_adamw_converges_quadratic():
    assert _quad_min(adamw(0.1), steps=400) < 1e-2


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, 100, warmup=10)
    assert float(lr(0)) == 0.0
    assert abs(float(lr(10)) - 1.0) < 1e-6
    assert float(lr(100)) < 1e-6
    assert float(lr(55)) < float(lr(20))


# --- §4.3 cost model -------------------------------------------------------------

def test_eq16_eq17_ratios():
    L, R, tau = 24, 2, 5
    rep = backward_cost_uniform(L, R, tau)
    assert rep.compute_flops == pytest.approx(R * tau + L - 1)
    assert rep.ratio_compute == pytest.approx((R * tau + L - 1) / (L * tau))
    assert rep.ratio_transmit == pytest.approx(R / L)


def test_selection_period_reduces_probe_cost():
    a = backward_cost_uniform(24, 1, 5, sel_period=1)
    b = backward_cost_uniform(24, 1, 5, sel_period=2)
    assert b.select_flops == pytest.approx(a.select_flops / 2)
    assert b.compute_flops < a.compute_flops


def test_exact_cost_uses_layer_sizes():
    layer_params = np.array([100, 200, 300])
    mask = np.array([0, 1, 0], np.float32)
    rep = backward_cost_exact(layer_params, mask, tau=2, bits_per_param=32)
    assert rep.transmit_bits == 200 * 32
    assert rep.ratio_transmit == pytest.approx(200 / 600)
