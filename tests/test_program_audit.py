"""Program auditor (DESIGN.md §11): the repo's programs satisfy every
contract, the committed budget manifest matches a fresh audit, and —
the other half of the acceptance bar — every contract FAILS when its
invariant is deliberately broken (tripwire injections: an f64 cast, a
dropped donation, batch-dependent delta traffic, non-monotone cuts,
smuggled collectives/transfers, a budget drift)."""
import dataclasses
import json

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import program as P
from repro.analysis.contracts import (check_all, check_cut_monotone,
                                      check_delta_traffic, check_donation,
                                      check_dtypes, check_isolation)
from repro.analysis.facts import ProgramFacts, extract_facts, weight_traffic

SDS = jax.ShapeDtypeStruct


def by_kind(facts, kind, config=None):
    return {f.meta["cut"] if kind == "fl_step_masked" else f.name: f
            for f in facts.values()
            if f.meta.get("kind") == kind
            and (config is None or f.meta.get("config") == config)}


# -- the repo's programs pass every contract ---------------------------------

def test_subset_contracts_clean(program_audit_facts):
    violations = check_all(program_audit_facts)
    assert not violations, "\n".join(
        f"{v.contract} {v.program}: {v.message}" for v in violations)


def test_masked_cut_flops_strictly_decreasing(program_audit_facts):
    for cfg in ("dense", "ssm"):
        cuts = by_kind(program_audit_facts, "fl_step_masked", cfg)
        assert len(cuts) >= 3
        series = [cuts[c].flops for c in sorted(cuts)]
        assert all(b < a for a, b in zip(series, series[1:])), (cfg, series)
        # deepest cut is forward-only: well under half the full train step
        assert series[-1] < 0.5 * series[0]


def test_delta_weight_traffic_b_independent(program_audit_facts):
    rows = [f for f in program_audit_facts.values()
            if f.meta.get("kind") == "serve_decode_delta"]
    caps = sorted({f.meta["capacity"] for f in rows})
    for C in caps:
        w = {f.meta["batch"]: f.weight_bytes for f in rows
             if f.meta["capacity"] == C}
        assert len(w) == 2
        b_lo, b_hi = sorted(w)
        # exact in the jaxpr model: the overlay streams (1+C) rows per
        # layer no matter how many slots decode in the batch
        assert w[b_lo] == pytest.approx(w[b_hi], rel=1e-6), (C, w)
    # the contrast that makes it meaningful: dense per-user params scale
    dense = {f.meta["batch"]: f.weight_bytes
             for f in program_audit_facts.values()
             if f.meta.get("kind") == "serve_decode_dense"}
    b_lo, b_hi = sorted(dense)
    assert dense[b_hi] == pytest.approx(dense[b_lo] * b_hi / b_lo, rel=1e-6)


# -- tripwires: each contract must fail when its invariant is broken ---------

def masked_fact(name, cut, flops, L=4, config="t"):
    return ProgramFacts(name=name, flops=flops,
                        meta={"kind": "fl_step_masked", "cut": cut,
                              "n_selectable": L, "config": config,
                              "single_host": True})


def test_cut_monotone_tripwire_non_decreasing():
    facts = {f.name: f for f in [
        masked_fact("t/cut0", 0, 100.0), masked_fact("t/cut1", 1, 80.0),
        masked_fact("t/cut2", 2, 85.0)]}
    out = check_cut_monotone(facts)
    assert [v.contract for v in out] == ["cut-monotone"]
    assert "not strictly decreasing" in out[0].message


def test_cut_monotone_tripwire_backward_not_elided():
    # monotone, but cut=L still costs 65% of cut=0: backward survived
    flops = [100.0, 90.0, 80.0, 70.0, 65.0]
    facts = {f.name: f for f in [
        masked_fact(f"t/cut{c}", c, fl) for c, fl in enumerate(flops)]}
    out = check_cut_monotone(facts)
    assert len(out) == 1 and "forward-only" in out[0].message


def test_delta_traffic_tripwire_b_dependence(program_audit_facts):
    facts = {n: f for n, f in program_audit_facts.items()
             if f.meta.get("kind") in ("serve_decode_delta",
                                       "serve_decode_dense")}
    name = "dense/serve_decode_delta/B6/C1"
    facts[name] = dataclasses.replace(
        facts[name], weight_bytes=facts[name].weight_bytes * 2)
    out = check_delta_traffic(facts)
    assert any(v.program == name and "depend on batch" in v.message
               for v in out)


def test_delta_traffic_tripwire_dense_stops_scaling(program_audit_facts):
    facts = {n: f for n, f in program_audit_facts.items()
             if f.meta.get("kind") in ("serve_decode_delta",
                                       "serve_decode_dense")}
    lo, hi = "dense/serve_decode_dense/B3", "dense/serve_decode_dense/B6"
    facts[hi] = dataclasses.replace(
        facts[hi], weight_bytes=facts[lo].weight_bytes)
    out = check_delta_traffic(facts)
    assert any(v.program == hi and "should scale" in v.message for v in out)


def test_donation_tripwire():
    """Declared-donated but not jit-donated: XLA applies no alias and the
    donation-honored contract must fire; the genuinely donated twin must
    pass."""
    tree = {k: SDS((16, 16), jnp.float32) for k in ("a", "b")}

    def bump(t):
        return {k: v + 1.0 for k, v in t.items()}

    bad = extract_facts("t/bad", jax.jit(bump), (tree,), donate_argnums=(0,))
    assert bad.donated_declared == 2 and bad.donation_applied == 0
    out = check_donation({"t/bad": bad})
    assert [v.contract for v in out] == ["donation-honored"]

    good = extract_facts("t/good", jax.jit(bump, donate_argnums=0), (tree,),
                         donate_argnums=(0,))
    assert good.donation_applied >= good.donated_declared == 2
    assert not check_donation({"t/good": good})


def test_f64_tripwire():
    """An injected double-precision cast must trip dtype-discipline even
    though the program's outputs are f32 again."""
    from jax.experimental import enable_x64

    def leak(x):
        return (x.astype(jnp.float64) * 2.0).sum().astype(jnp.float32)

    with enable_x64():
        f = extract_facts("t/f64", jax.jit(leak), (SDS((8,), jnp.float32),))
    assert "float64" in f.jaxpr_dtypes
    out = check_dtypes({"t/f64": f})
    assert [v.contract for v in out] == ["dtype-discipline"]


def test_bf16_leak_tripwire(program_audit_facts):
    real = program_audit_facts["dense_bf16/serve_decode/B3"]
    assert "bfloat16" in real.out_dtypes          # the passing repo check
    leaky = ProgramFacts(
        name="t/bf16", meta=dict(real.meta),
        out_dtypes=["float32"] * len(real.out_dtypes))
    out = check_dtypes({"t/bf16": leaky})
    assert [v.contract for v in out] == ["dtype-discipline"]
    assert "leaks f32" in out[0].message


def test_isolation_tripwires(program_audit_facts):
    base = program_audit_facts["dense/serve_decode_dense/B3"]
    assert base.meta["single_host"] and not base.collective_counts

    smuggled = dataclasses.replace(
        base, collective_counts={"all-reduce": 2})
    leaking = dataclasses.replace(base, transfer_ops={"outfeed": 1})
    out = check_isolation({"t/coll": smuggled, "t/xfer": leaking})
    assert {v.contract for v in out} == {"collective-transfer-allowlist"}
    assert len(out) == 2

    # sharded programs: only mesh-declared collective kinds pass
    sharded_meta = dict(base.meta, single_host=False,
                        allowed_collectives=("all-reduce",))
    ok = dataclasses.replace(base, meta=sharded_meta,
                             collective_counts={"all-reduce": 4})
    rogue = dataclasses.replace(base, meta=sharded_meta,
                                collective_counts={"all-gather": 1})
    out = check_isolation({"t/ok": ok, "t/rogue": rogue})
    assert len(out) == 1 and "all-gather" in out[0].message


# -- budget manifest ----------------------------------------------------------

def test_committed_budgets_match_audit(program_audit_facts):
    """The committed PROGRAM_BUDGETS.json is fresh: a re-audit of the
    subset lands inside every per-metric tolerance."""
    manifest = P.load_budgets()
    assert manifest is not None, "PROGRAM_BUDGETS.json missing — run " \
        "`python -m repro.analysis program --update-budgets`"
    sub = {"_meta": manifest["_meta"],
           "programs": {n: manifest["programs"][n]
                        for n in program_audit_facts}}
    assert len(sub["programs"]) == len(program_audit_facts)
    failures = P.check_budgets(program_audit_facts, sub)
    assert not failures, "\n".join(failures)


def test_budget_drift_detected(program_audit_facts):
    manifest = P.budgets_from_facts(program_audit_facts)
    name = "dense/fl_step_masked/cut0"
    manifest["programs"][name]["flops"] *= 1.5     # way past the 10% band
    failures = P.check_budgets(program_audit_facts, manifest)
    assert any(name in m and "flops drifted" in m for m in failures)


def test_budget_membership_is_drift(program_audit_facts):
    manifest = P.budgets_from_facts(program_audit_facts)
    del manifest["programs"]["dense/serve_write_params"]
    manifest["programs"]["dense/ghost_program"] = {"flops": 1.0}
    failures = P.check_budgets(program_audit_facts, manifest)
    assert any("missing from manifest" in m for m in failures)
    assert any("no longer audited" in m for m in failures)


def test_budget_roundtrip_clean(program_audit_facts, tmp_path):
    path = str(tmp_path / "budgets.json")
    P.save_budgets(program_audit_facts, path)
    assert P.check_budgets(program_audit_facts, P.load_budgets(path)) == []


# -- jaxpr weight-provenance unit pin ----------------------------------------

def test_weight_traffic_scan_multiplier():
    """A scanned matmul over a tagged (L, N, N) weight stack streams
    exactly L·N·N·4 weight bytes; the activation carry contributes 0."""
    L, N = 5, 16

    def g(x, ws):
        def step(c, w):
            return c @ w, None
        out, _ = jax.lax.scan(step, x, ws)
        return out

    traced = jax.jit(g).trace(SDS((N, N), jnp.float32),
                              SDS((L, N, N), jnp.float32))
    wbytes, dtypes = weight_traffic(traced.jaxpr, [False, True])
    assert wbytes == L * N * N * 4
    assert "float32" in dtypes
    # tag the activation instead: its operand bytes count, the stack's don't
    wbytes_x, _ = weight_traffic(traced.jaxpr, [True, False])
    assert wbytes_x == L * N * N * 4   # carry slice is (N,N) per iter too


# -- CLI ---------------------------------------------------------------------

def test_program_cli_json(tmp_path, monkeypatch, capsys):
    """`python -m repro.analysis program` end-to-end on a one-program
    enumeration: --update-budgets writes the manifest, a re-run diffs
    clean, and --json emits the machine-readable report CI annotates."""
    from repro.analysis.__main__ import main

    spec = P.ProgramSpec(
        name="unit/mm", fn=jax.jit(lambda a, b: a @ b),
        args=(SDS((8, 8), jnp.float32), SDS((8, 8), jnp.float32)),
        weight_argnums=(1,), meta={"single_host": True, "kind": "unit"})
    monkeypatch.setattr(P, "enumerate_specs", lambda models=None: [spec])
    path = str(tmp_path / "budgets.json")

    assert main(["program", "--update-budgets", "--budgets", path]) == 0
    capsys.readouterr()
    assert main(["program", "--json", "--budgets", path]) == 0
    report = json.loads(capsys.readouterr().out)
    assert report["ok"] and "unit/mm" in report["programs"]
    assert report["programs"]["unit/mm"]["flops"] > 0

    # drift the manifest: the CLI must exit non-zero and report it
    manifest = json.load(open(path))
    manifest["programs"]["unit/mm"]["flops"] *= 10
    json.dump(manifest, open(path, "w"))
    assert main(["program", "--json", "--budgets", path]) == 1
    report = json.loads(capsys.readouterr().out)
    assert not report["ok"] and report["budget_failures"]
