"""Distributed FL step vs simulator — exact Eq.(5)-(7) equivalence.

Runs in a subprocess with xla_force_host_platform_device_count=8 (the
repo-wide rule: only the dry-run and these subprocesses fake device counts;
everything else sees 1 device).
"""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


EQUIV = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs.base import get_arch, reduced, RuntimeConfig
from repro.models.model import Model, apply_layer_mask
from repro.core import aggregation as agg
from repro.sharding.fl_step import make_fl_train_step
from repro.launch.mesh import make_host_mesh

cfg = reduced(get_arch("{arch}"), n_layers=4, d_model=64)
model = Model(cfg, RuntimeConfig(remat=False, seq_chunk=16))
params = model.init(jax.random.PRNGKey(0))
mesh = make_host_mesh(4, 2)
clients, pcb, S = 4, 2, 16
key = jax.random.PRNGKey(7)
batch = {{"tokens": jax.random.randint(key, (clients, pcb, S), 0, cfg.vocab_size)}}
masks = jnp.array([[1,0,0,1],[0,1,0,1],[1,1,0,0],[0,0,0,1]], jnp.float32)
sizes = jnp.array([10., 20., 30., 40.])
lr = jnp.float32(0.1)
build = make_fl_train_step(model, mesh, zero3={zero3})
step_fn, specs = build(jax.eval_shape(lambda: params))
pshard = jax.device_put(params, jax.tree.map(
    lambda s: NamedSharding(mesh, s), specs, is_leaf=lambda x: isinstance(x, P)))
new_params, metrics = step_fn(pshard, batch, masks, sizes, lr)
deltas = []
for i in range(clients):
    g = jax.grad(model.loss)(params, {{"tokens": batch["tokens"][i]}})
    deltas.append(apply_layer_mask(g, masks[i], cfg))
update = agg.aggregate(deltas, masks, sizes, cfg)
ref = agg.apply_update(params, update, float(lr))
err = max(jax.tree.leaves(jax.tree.map(
    lambda a, b: float(jnp.max(jnp.abs(a - b))), new_params, ref)))
print("ERR", err)
assert err < 3e-5, err
"""


@pytest.mark.parametrize("zero3", [True, False])
def test_fl_step_matches_simulator_dense(zero3):
    out = _run(EQUIV.format(arch="tinyllama_1_1b", zero3=zero3))
    assert "ERR" in out


def test_fl_step_matches_simulator_ssm():
    out = _run(EQUIV.format(arch="mamba2_370m", zero3="True"))
    assert "ERR" in out


TAU_EQUIV = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs.base import get_arch, reduced, RuntimeConfig
from repro.models.model import Model
from repro.core.client import Client
from repro.core import aggregation as agg
from repro.sharding.fl_step import make_fl_train_step_tau
from repro.launch.mesh import make_host_mesh

cfg = reduced(get_arch("tinyllama_1_1b"), n_layers=4, d_model=64)
model = Model(cfg, RuntimeConfig(remat=False, seq_chunk=16))
params = model.init(jax.random.PRNGKey(0))
mesh = make_host_mesh(4, 2)
clients, tau, pcb, S = 4, 3, 2, 16
key = jax.random.PRNGKey(7)
batch = {"tokens": jax.random.randint(key, (clients, tau, pcb, S), 0, cfg.vocab_size)}
# heterogeneous masks within the static union {1, 3}
masks = jnp.array([[0,1,0,1],[0,0,0,1],[0,1,0,0],[0,1,0,1]], jnp.float32)
sizes = jnp.array([10., 20., 30., 40.])
lr = jnp.float32(0.05)

build = make_fl_train_step_tau(model, mesh, sel_idx=(1, 3), tau=tau, zero3=True)
step_fn, specs = build(jax.eval_shape(lambda: params))
pshard = jax.device_put(params, jax.tree.map(
    lambda s: NamedSharding(mesh, s), specs, is_leaf=lambda x: isinstance(x, P)))
new_params, metrics = step_fn(pshard, batch, masks, sizes, lr)

# simulator reference: Client.local_update per client (full Eq.3-4), Eq.5-7 agg
client = Client(model)
deltas = []
for i in range(clients):
    b_i = {"tokens": batch["tokens"][i]}
    delta, _ = client._local_update(params, b_i, masks[i], lr)
    deltas.append(delta)
update = agg.aggregate(deltas, masks, sizes, cfg)
ref = agg.apply_update(params, update, float(lr))
err = max(jax.tree.leaves(jax.tree.map(
    lambda a, b: float(jnp.max(jnp.abs(a - b))), new_params, ref)))
print("TAU_ERR", err)
assert err < 5e-5, err
"""


def test_fl_step_tau_matches_simulator():
    out = _run(TAU_EQUIV)
    assert "TAU_ERR" in out


STORE_SHARDED = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs.base import get_arch, reduced, RuntimeConfig
from repro.models.model import Model
from repro.core.state import ClientStateStore
from repro.sharding.fl_step import make_fl_train_step, shard_cohort_rows
from repro.launch.mesh import make_host_mesh

cfg = reduced(get_arch("tinyllama_1_1b"), n_layers=4, d_model=64)
model = Model(cfg, RuntimeConfig(remat=False, seq_chunk=16))
params = model.init(jax.random.PRNGKey(0))
mesh = make_host_mesh(4, 2)

# population-scale store; the round only ever touches the cohort's rows
store = ClientStateStore(100_000, 4)
cohort = np.array([17, 4_242, 73_291, 99_999])
masks_np = np.array([[1,0,0,1],[0,1,0,1],[1,1,0,0],[0,0,0,1]], np.float32)
store.set_warm_rows(cohort, masks_np, t=0)

rows, valid = store.warm_rows(cohort)
assert valid.all()
sharded = shard_cohort_rows(mesh, rows)
# one cohort member per client-axis coordinate, values bit-identical
assert "data" in sharded.sharding.spec[0]
np.testing.assert_array_equal(np.asarray(sharded), masks_np)

clients, pcb, S = 4, 2, 16
key = jax.random.PRNGKey(7)
batch = {"tokens": jax.random.randint(key, (clients, pcb, S), 0, cfg.vocab_size)}
sizes = jnp.array([10., 20., 30., 40.])
lr = jnp.float32(0.1)
build = make_fl_train_step(model, mesh, zero3=True)
step_fn, specs = build(jax.eval_shape(lambda: params))
pshard = jax.device_put(params, jax.tree.map(
    lambda s: NamedSharding(mesh, s), specs, is_leaf=lambda x: isinstance(x, P)))
# the sharded store rows drive the step exactly like plain host masks
new_a, _ = step_fn(pshard, batch, sharded, sizes, lr)
new_b, _ = step_fn(pshard, batch, jnp.asarray(masks_np), sizes, lr)
err = max(jax.tree.leaves(jax.tree.map(
    lambda a, b: float(jnp.max(jnp.abs(a - b))), new_a, new_b)))
print("STORE_ERR", err)
assert err == 0.0, err
"""


def test_store_rows_shard_and_drive_fl_step():
    out = _run(STORE_SHARDED)
    assert "STORE_ERR" in out


DRYRUN_SMALL = """
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import get_arch, reduced, RuntimeConfig, ShapeConfig
from repro.models.model import Model, init_params
from repro.sharding.fl_step import make_fl_train_step
from repro.sharding.serve import make_serve_step
from repro.launch.mesh import make_host_mesh
from repro.launch import specs as S

cfg = reduced(get_arch("tinyllama_1_1b"), n_layers=2, d_model=128)
model = Model(cfg, RuntimeConfig(remat=False, seq_chunk=16))
mesh = make_host_mesh(4, 2)
shapes = jax.eval_shape(lambda k: init_params(cfg, k),
                        jax.ShapeDtypeStruct((2,), jnp.uint32))
# train lowering
build = make_fl_train_step(model, mesh, zero3=True)
fn, _ = build(shapes)
shape = ShapeConfig("t", 64, 8, "train")
batch, masks, sizes, lr = S.fl_round_specs(cfg, shape, mesh, model.n_selectable)
c = fn.lower(shapes, batch, masks, sizes, lr).compile()
assert c.memory_analysis().temp_size_in_bytes > 0
# serve lowering
buildd = make_serve_step(model, mesh, zero3=False)
cache = jax.eval_shape(lambda: model.init_cache(8, 64))
fn2, _ = buildd(shapes, cache, 8)
c2 = fn2.lower(shapes, jax.ShapeDtypeStruct((8,), jnp.int32),
               jax.ShapeDtypeStruct((), jnp.int32), cache).compile()
print("LOWER_OK")
"""


def test_small_mesh_lowering():
    out = _run(DRYRUN_SMALL)
    assert "LOWER_OK" in out
