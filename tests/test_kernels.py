"""Pallas kernel sweeps: shapes × dtypes vs the pure-jnp oracles in ref.py.

All kernels run in interpret mode on CPU (the kernel bodies execute exactly
as they would on TPU, minus the hardware tiling)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention as fa_raw
from repro.kernels.layer_grad_norm import layer_sq_norms_2d
from repro.kernels.masked_update import masked_sgd_update_2d
from repro.kernels.ssd_scan import ssd_scan

ATTN_CASES = [
    # (B, H, K, S, D, causal, window, dtype)
    (2, 4, 2, 128, 64, True, 0, jnp.float32),
    (1, 4, 4, 256, 64, False, 0, jnp.float32),
    (2, 8, 2, 128, 128, True, 64, jnp.float32),
    (1, 2, 1, 256, 64, True, 96, jnp.float32),
    (1, 4, 2, 128, 64, True, 0, jnp.bfloat16),
]


@pytest.mark.parametrize("B,H,K,S,D,causal,window,dtype", ATTN_CASES)
def test_flash_attention_sweep(B, H, K, S, D, causal, window, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, S, D), dtype)
    k = jax.random.normal(ks[1], (B, K, S, D), dtype)
    v = jax.random.normal(ks[2], (B, K, S, D), dtype)
    out = fa_raw(q, k, v, causal=causal, window=window, block_q=64,
                 block_k=64, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


SSD_CASES = [
    # (BH, S, P, N, chunk, dtype)
    (4, 128, 64, 32, 32, jnp.float32),
    (2, 256, 32, 64, 64, jnp.float32),
    (6, 64, 64, 16, 16, jnp.float32),
    (2, 128, 64, 32, 128, jnp.float32),   # single chunk
    (2, 128, 32, 32, 32, jnp.bfloat16),
]


@pytest.mark.parametrize("BH,S,P,N,chunk,dtype", SSD_CASES)
def test_ssd_scan_sweep(BH, S, P, N, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    x = jax.random.normal(ks[0], (BH, S, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (BH, S))).astype(dtype)
    A = -jnp.exp(jax.random.uniform(ks[2], (BH,), minval=-1.0, maxval=0.5))
    Bm = (jax.random.normal(ks[3], (BH, S, N)) * 0.5).astype(dtype)
    Cm = (jax.random.normal(ks[4], (BH, S, N)) * 0.5).astype(dtype)
    D = jnp.ones((BH,))
    y = ssd_scan(x, dt, A, Bm, Cm, D, chunk=chunk, interpret=True)
    want = ref.ssd_scan_ref(x, dt, A, Bm, Cm, D)
    tol = 1e-1 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


NORM_CASES = [(1, 7), (3, 4096), (8, 5000), (2, 17)]


@pytest.mark.parametrize("L,F", NORM_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_layer_sq_norms_sweep(L, F, dtype):
    g = jax.random.normal(jax.random.PRNGKey(2), (L, F), dtype)
    out = layer_sq_norms_2d(g, block=1024, interpret=True)
    want = ref.layer_sq_norms_ref(g)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-2 if dtype == jnp.bfloat16 else 1e-5)


@pytest.mark.parametrize("L,F", [(4, 64), (6, 1000), (1, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_masked_update_sweep(L, F, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    p = jax.random.normal(ks[0], (L, F), dtype)
    g = jax.random.normal(ks[1], (L, F), dtype)
    mask = (jax.random.uniform(ks[2], (L,)) > 0.5).astype(jnp.float32)
    out = masked_sgd_update_2d(p, g, mask, 0.1, block=256, interpret=True)
    want = ref.masked_sgd_update_ref(p, g, mask, 0.1)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=2e-2)
    # masked rows unchanged exactly
    for l in range(L):
        if mask[l] == 0:
            np.testing.assert_array_equal(np.asarray(out[l]), np.asarray(p[l]))


# ---------------------------------------------------------------------------
# Kernel ⇄ jnp-fallback pins for the mask-aware hot path (DESIGN.md §7).
# The FL hot paths call the kernels through dispatching wrappers (mode
# "pallas" on TPU, the pure-jnp fallback elsewhere); these tests pin the two
# implementations bit-identical under like-for-like jit compilation, so any
# kernel/core drift fails CI (the examples smoke job runs this file's
# masked_update/grad_norm oracles).
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("L,F", NORM_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_layer_sq_norms_jnp_fallback_bit_identical(L, F, dtype):
    """The fallback replays the kernel's per-block accumulation order, so
    the results agree bit-for-bit (not just allclose)."""
    from repro.kernels.layer_grad_norm import layer_sq_norms_2d_jnp
    g = jax.random.normal(jax.random.PRNGKey(2), (L, F), dtype)
    kernel = layer_sq_norms_2d(g, block=1024, interpret=True)
    fallback = jax.jit(lambda g: layer_sq_norms_2d_jnp(g, block=1024))(g)
    np.testing.assert_array_equal(np.asarray(kernel), np.asarray(fallback))


@pytest.mark.parametrize("L,F", [(4, 64), (6, 1000), (3, 5000), (1, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_masked_update_jnp_fallback_bit_identical(L, F, dtype):
    """Same elementwise expression, same fusion: kernel (interpret) and the
    jitted fallback produce bit-identical updates."""
    from repro.kernels.masked_update import masked_sgd_update_2d_jnp
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    p = jax.random.normal(ks[0], (L, F), dtype)
    g = jax.random.normal(ks[1], (L, F), dtype)
    mask = (jax.random.uniform(ks[2], (L,)) > 0.5).astype(jnp.float32)
    kernel = masked_sgd_update_2d(p, g, mask, 0.1, block=256, interpret=True)
    fallback = jax.jit(masked_sgd_update_2d_jnp)(p, g, mask, 0.1)
    np.testing.assert_array_equal(np.asarray(kernel, np.float32),
                                  np.asarray(fallback, np.float32))


@pytest.mark.parametrize("B,H,K,S,D,causal,window,dtype", ATTN_CASES)
def test_flash_attention_jnp_fallback_bit_identical(B, H, K, S, D, causal,
                                                    window, dtype):
    """The fallback replays the kernel's blocked streaming softmax (same
    block shapes, same f32 running max/normaliser), so kernel (interpret)
    and fallback agree bit-for-bit — not just allclose like the dense
    ref.py oracle."""
    from repro.kernels.flash_attention import flash_attention_jnp
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, S, D), dtype)
    k = jax.random.normal(ks[1], (B, K, S, D), dtype)
    v = jax.random.normal(ks[2], (B, K, S, D), dtype)
    kernel = fa_raw(q, k, v, causal=causal, window=window, block_q=64,
                    block_k=64, interpret=True)
    fallback = flash_attention_jnp(q, k, v, causal=causal, window=window,
                                   block_q=64, block_k=64)
    np.testing.assert_array_equal(np.asarray(kernel, np.float32),
                                  np.asarray(fallback, np.float32))


@pytest.mark.parametrize("BH,S,P,N,chunk,dtype", SSD_CASES)
def test_ssd_scan_jnp_fallback_bit_identical(BH, S, P, N, chunk, dtype):
    """The fallback replays the kernel's chunked semiseparable scan (same
    chunking, same carried (P,N) f32 state), so kernel (interpret) and
    fallback agree bit-for-bit."""
    from repro.kernels.ssd_scan import ssd_scan_jnp
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    x = jax.random.normal(ks[0], (BH, S, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (BH, S))).astype(dtype)
    A = -jnp.exp(jax.random.uniform(ks[2], (BH,), minval=-1.0, maxval=0.5))
    Bm = (jax.random.normal(ks[3], (BH, S, N)) * 0.5).astype(dtype)
    Cm = (jax.random.normal(ks[4], (BH, S, N)) * 0.5).astype(dtype)
    D = jnp.ones((BH,))
    kernel = ssd_scan(x, dt, A, Bm, Cm, D, chunk=chunk, interpret=True)
    fallback = ssd_scan_jnp(x, dt, A, Bm, Cm, D, chunk=chunk)
    np.testing.assert_array_equal(np.asarray(kernel, np.float32),
                                  np.asarray(fallback, np.float32))


def test_ops_attention_ssd_mode_dispatch():
    """The ops-layer wrappers route mode='jnp' to the fallbacks and
    mode='pallas' to the kernels; both paths agree on model-layout
    inputs (GQA attention + grouped SSD)."""
    ks = jax.random.split(jax.random.PRNGKey(6), 8)
    q = jax.random.normal(ks[0], (2, 128, 4, 64))
    k = jax.random.normal(ks[1], (2, 128, 2, 64))
    v = jax.random.normal(ks[2], (2, 128, 2, 64))
    a_p = ops.flash_attention(q, k, v, window=32, interpret=True,
                              mode="pallas")
    a_j = ops.flash_attention(q, k, v, window=32, mode="jnp")
    np.testing.assert_array_equal(np.asarray(a_p), np.asarray(a_j))

    x = jax.random.normal(ks[3], (2, 64, 4, 32))
    dt = jax.nn.softplus(jax.random.normal(ks[4], (2, 64, 4)))
    A_log = jax.random.uniform(ks[5], (4,), minval=-1.0, maxval=1.0)
    Bm = jax.random.normal(ks[6], (2, 64, 2, 16)) * 0.5
    Cm = jax.random.normal(ks[7], (2, 64, 2, 16)) * 0.5
    D = jnp.ones((4,))
    s_p = ops.ssd(x, dt, A_log, Bm, Cm, D, chunk=32, interpret=True,
                  mode="pallas")
    s_j = ops.ssd(x, dt, A_log, Bm, Cm, D, chunk=32, mode="jnp")
    np.testing.assert_array_equal(np.asarray(s_p), np.asarray(s_j))


DELTA_MM_CASES = [
    # (B, d, f, C, block_f, dtype)
    (4, 64, 128, 2, None, jnp.float32),
    (6, 128, 512, 4, 128, jnp.float32),
    (3, 32, 100, 1, 64, jnp.float32),      # f padded to the block
    (4, 64, 256, 3, None, jnp.bfloat16),
    (2, 16, 48, 2, 32, jnp.bfloat16),
]


def _delta_mm_inputs(B, d, f, C, dtype, seed=5):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(ks[0], (B, d), dtype)
    w = jax.random.normal(ks[1], (d, f), dtype)
    dw = (jax.random.normal(ks[2], (C, d, f)) * 0.1).astype(dtype)
    # serving invariant: ≤1 entry per slot per layer — distinct owners,
    # with one entry left empty (-1) when capacity allows
    slots = np.random.RandomState(seed).permutation(B)[:C].astype(np.int32)
    if C > 1:
        slots[-1] = -1
    return x, w, dw, jnp.asarray(slots)


@pytest.mark.parametrize("B,d,f,C,block_f,dtype", DELTA_MM_CASES)
def test_base_delta_matmul_sweep(B, d, f, C, block_f, dtype):
    """Fused base+delta GEMM vs the unfused oracle: y[b] = x[b]@W, plus
    x[b]@dw[e] for the entry e owned by slot b (DESIGN.md §9)."""
    from repro.kernels.delta_matmul import base_delta_matmul_2d
    x, w, dw, slots = _delta_mm_inputs(B, d, f, C, dtype)
    out = base_delta_matmul_2d(x, w, dw, slots, block_f=block_f,
                               interpret=True)
    x32 = np.asarray(x, np.float32)
    want = x32 @ np.asarray(w, np.float32)
    for e, s in enumerate(np.asarray(slots)):
        if s >= 0:
            want[s] += x32[s] @ np.asarray(dw[e], np.float32)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(out, np.float32), want,
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("B,d,f,C,block_f,dtype", DELTA_MM_CASES)
def test_base_delta_matmul_jnp_fallback_bit_identical(B, d, f, C, block_f,
                                                      dtype):
    """Kernel (interpret) and the jitted jnp fallback share the per-entry
    accumulation (_entry_accumulate) and the f-blocking, so the serving
    decode is bit-identical on and off TPU."""
    from repro.kernels.delta_matmul import (base_delta_matmul_2d,
                                            base_delta_matmul_2d_jnp)
    x, w, dw, slots = _delta_mm_inputs(B, d, f, C, dtype)
    kernel = base_delta_matmul_2d(x, w, dw, slots, block_f=block_f,
                                  interpret=True)
    fallback = jax.jit(lambda *a: base_delta_matmul_2d_jnp(
        *a, block_f=block_f))(x, w, dw, slots)
    np.testing.assert_array_equal(np.asarray(kernel, np.float32),
                                  np.asarray(fallback, np.float32))


def test_ops_base_delta_matmul_dispatch():
    """The ops-layer wrapper: (B,1,d) decode activations route through the
    2-D path; empty slot table degenerates to the plain GEMM exactly."""
    x, w, dw, slots = _delta_mm_inputs(3, 16, 32, 2, jnp.float32)
    out3 = ops.base_delta_matmul(x[:, None], w, dw, slots, mode="jnp")
    out2 = ops.base_delta_matmul(x, w, dw, slots, mode="jnp")
    np.testing.assert_array_equal(np.asarray(out3[:, 0]), np.asarray(out2))
    empty = ops.base_delta_matmul(x, w, dw, jnp.full((2,), -1, jnp.int32),
                                  mode="jnp")
    np.testing.assert_allclose(
        np.asarray(empty),
        np.asarray(x, np.float32) @ np.asarray(w, np.float32), atol=1e-5)


def _small_world():
    from repro.configs.base import RuntimeConfig, get_arch, reduced
    from repro.models.model import Model
    cfg = reduced(get_arch("tinyllama_1_1b"), n_layers=3, d_model=64)
    model = Model(cfg, RuntimeConfig(remat=False, seq_chunk=16))
    params = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                          cfg.vocab_size)}
    return model, params, batch


def test_hot_path_masked_sgd_site_kernel_parity():
    """The masked engine's apply-step call site (client.masked_suffix_sgd):
    Pallas kernel (interpret) vs the jnp fallback it runs off-TPU."""
    from repro.core.client import masked_suffix_sgd
    from repro.models.model import trainable_slice
    model, params, batch = _small_world()
    cfg = model.cfg
    cut = 1
    tr = trainable_slice(params, cut, cfg)
    g = jax.grad(lambda t: model.loss(params, batch, trainable=t,
                                      cut=cut))(tr)
    mask = jnp.asarray([0.0, 1.0, 0.0], jnp.float32)
    out_k = masked_suffix_sgd(tr, g, mask, 0.1, cut, cfg, mode="pallas")
    out_j = jax.jit(lambda tr, g: masked_suffix_sgd(tr, g, mask, 0.1, cut,
                                                    cfg, mode="jnp"))(tr, g)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), out_k, out_j)
    # masked rows (mask 0 above the cut) unchanged exactly
    jax.tree.map(lambda t, o: np.testing.assert_array_equal(
        np.asarray(t[-1]), np.asarray(o[-1])), tr, out_j)


def test_hot_path_probe_reduction_kernel_parity():
    """The probe's grad-norm reduction call site (masks.per_layer_sq_norms
    routed through ops.layer_grad_norms): kernel vs jnp fallback, pinned
    bit-identical on a real gradient tree."""
    from repro.core.masks import per_layer_sq_norms
    model, params, batch = _small_world()
    g = jax.grad(model.loss)(params, batch)
    out_k = per_layer_sq_norms(g, model.cfg, mode="pallas", interpret=True)
    out_j = jax.jit(lambda g: per_layer_sq_norms(g, model.cfg,
                                                 mode="jnp"))(g)
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_j))


def test_ops_layer_grad_norms_matches_core():
    """The fused kernel equals core.masks.per_layer_sq_norms on a real tree."""
    from repro.configs.base import RuntimeConfig, get_arch, reduced
    from repro.core.masks import per_layer_sq_norms
    from repro.models.model import Model
    cfg = reduced(get_arch("tinyllama_1_1b"), n_layers=3, d_model=64)
    model = Model(cfg, RuntimeConfig(remat=False, seq_chunk=16))
    params = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                          cfg.vocab_size)}
    g = jax.grad(model.loss)(params, batch)
    want = np.asarray(per_layer_sq_norms(g, cfg))
    got = np.asarray(ops.layer_grad_norms(g["blocks"], interpret=True))
    np.testing.assert_allclose(got, want, rtol=1e-4)


def test_ops_ssd_matches_model_path():
    from repro.models.ssd import ssd_chunked
    b, s, h, p, g, n = 2, 64, 4, 32, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A_log = jax.random.uniform(ks[2], (h,), minval=-1.0, maxval=1.0)
    Bm = jax.random.normal(ks[3], (b, s, g, n)) * 0.5
    Cm = jax.random.normal(ks[4], (b, s, g, n)) * 0.5
    D = jnp.ones((h,))
    y_k = ops.ssd(x, dt, A_log, Bm, Cm, D, chunk=32, interpret=True)
    y_j, _ = ssd_chunked(x, dt, A_log, Bm, Cm, D, 32)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_j), atol=1e-4)
