"""Pallas kernel sweeps: shapes × dtypes vs the pure-jnp oracles in ref.py.

All kernels run in interpret mode on CPU (the kernel bodies execute exactly
as they would on TPU, minus the hardware tiling)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention as fa_raw
from repro.kernels.layer_grad_norm import layer_sq_norms_2d
from repro.kernels.masked_update import masked_sgd_update_2d
from repro.kernels.ssd_scan import ssd_scan

ATTN_CASES = [
    # (B, H, K, S, D, causal, window, dtype)
    (2, 4, 2, 128, 64, True, 0, jnp.float32),
    (1, 4, 4, 256, 64, False, 0, jnp.float32),
    (2, 8, 2, 128, 128, True, 64, jnp.float32),
    (1, 2, 1, 256, 64, True, 96, jnp.float32),
    (1, 4, 2, 128, 64, True, 0, jnp.bfloat16),
]


@pytest.mark.parametrize("B,H,K,S,D,causal,window,dtype", ATTN_CASES)
def test_flash_attention_sweep(B, H, K, S, D, causal, window, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, S, D), dtype)
    k = jax.random.normal(ks[1], (B, K, S, D), dtype)
    v = jax.random.normal(ks[2], (B, K, S, D), dtype)
    out = fa_raw(q, k, v, causal=causal, window=window, block_q=64,
                 block_k=64, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


SSD_CASES = [
    # (BH, S, P, N, chunk, dtype)
    (4, 128, 64, 32, 32, jnp.float32),
    (2, 256, 32, 64, 64, jnp.float32),
    (6, 64, 64, 16, 16, jnp.float32),
    (2, 128, 64, 32, 128, jnp.float32),   # single chunk
    (2, 128, 32, 32, 32, jnp.bfloat16),
]


@pytest.mark.parametrize("BH,S,P,N,chunk,dtype", SSD_CASES)
def test_ssd_scan_sweep(BH, S, P, N, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    x = jax.random.normal(ks[0], (BH, S, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (BH, S))).astype(dtype)
    A = -jnp.exp(jax.random.uniform(ks[2], (BH,), minval=-1.0, maxval=0.5))
    Bm = (jax.random.normal(ks[3], (BH, S, N)) * 0.5).astype(dtype)
    Cm = (jax.random.normal(ks[4], (BH, S, N)) * 0.5).astype(dtype)
    D = jnp.ones((BH,))
    y = ssd_scan(x, dt, A, Bm, Cm, D, chunk=chunk, interpret=True)
    want = ref.ssd_scan_ref(x, dt, A, Bm, Cm, D)
    tol = 1e-1 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


NORM_CASES = [(1, 7), (3, 4096), (8, 5000), (2, 17)]


@pytest.mark.parametrize("L,F", NORM_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_layer_sq_norms_sweep(L, F, dtype):
    g = jax.random.normal(jax.random.PRNGKey(2), (L, F), dtype)
    out = layer_sq_norms_2d(g, block=1024, interpret=True)
    want = ref.layer_sq_norms_ref(g)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-2 if dtype == jnp.bfloat16 else 1e-5)


@pytest.mark.parametrize("L,F", [(4, 64), (6, 1000), (1, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_masked_update_sweep(L, F, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    p = jax.random.normal(ks[0], (L, F), dtype)
    g = jax.random.normal(ks[1], (L, F), dtype)
    mask = (jax.random.uniform(ks[2], (L,)) > 0.5).astype(jnp.float32)
    out = masked_sgd_update_2d(p, g, mask, 0.1, block=256, interpret=True)
    want = ref.masked_sgd_update_ref(p, g, mask, 0.1)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=2e-2)
    # masked rows unchanged exactly
    for l in range(L):
        if mask[l] == 0:
            np.testing.assert_array_equal(np.asarray(out[l]), np.asarray(p[l]))


def test_ops_layer_grad_norms_matches_core():
    """The fused kernel equals core.masks.per_layer_sq_norms on a real tree."""
    from repro.configs.base import RuntimeConfig, get_arch, reduced
    from repro.core.masks import per_layer_sq_norms
    from repro.models.model import Model
    cfg = reduced(get_arch("tinyllama_1_1b"), n_layers=3, d_model=64)
    model = Model(cfg, RuntimeConfig(remat=False, seq_chunk=16))
    params = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                          cfg.vocab_size)}
    g = jax.grad(model.loss)(params, batch)
    want = np.asarray(per_layer_sq_norms(g, cfg))
    got = np.asarray(ops.layer_grad_norms(g["blocks"], interpret=True))
    np.testing.assert_allclose(got, want, rtol=1e-4)


def test_ops_ssd_matches_model_path():
    from repro.models.ssd import ssd_chunked
    b, s, h, p, g, n = 2, 64, 4, 32, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A_log = jax.random.uniform(ks[2], (h,), minval=-1.0, maxval=1.0)
    Bm = jax.random.normal(ks[3], (b, s, g, n)) * 0.5
    Cm = jax.random.normal(ks[4], (b, s, g, n)) * 0.5
    D = jnp.ones((h,))
    y_k = ops.ssd(x, dt, A_log, Bm, Cm, D, chunk=32, interpret=True)
    y_j, _ = ssd_chunked(x, dt, A_log, Bm, Cm, D, 32)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_j), atol=1e-4)
