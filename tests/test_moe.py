"""MoE dispatch correctness: sort-based vs dense reference, local dispatch,
capacity behaviour."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch, reduced
from repro.models import moe as MOE


@pytest.fixture(scope="module")
def cfg():
    # 4 experts, top-2, dropless capacity
    c = reduced(get_arch("grok_1_314b"))
    return dataclasses.replace(c, capacity_factor=8.0)


@pytest.fixture(scope="module")
def setup(cfg):
    key = jax.random.PRNGKey(0)
    shapes = MOE.moe_param_shapes(cfg)
    from repro.models.blocks import init_stacked
    p = init_stacked(key, shapes, 0, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    return p, x


def test_sparse_matches_dense_when_dropless(cfg, setup):
    p, x = setup
    out_s, st_s = MOE.moe_fwd(p, x, cfg)
    out_d, _ = MOE.moe_fwd_dense(p, x, cfg)
    assert float(st_s.dropped_frac) == 0.0
    np.testing.assert_allclose(np.asarray(out_s), np.asarray(out_d),
                               atol=2e-5, rtol=2e-5)


def test_local_dispatch_matches_global_when_dropless(cfg, setup):
    p, x = setup
    out_g, _ = MOE.moe_fwd(p, x, cfg)
    out_l, _ = MOE.moe_fwd(p, x, cfg, local_dispatch=True)
    np.testing.assert_allclose(np.asarray(out_g), np.asarray(out_l),
                               atol=2e-5, rtol=2e-5)


def test_capacity_drops_tokens(cfg, setup):
    p, x = setup
    tight = dataclasses.replace(cfg, capacity_factor=0.25)
    out, st = MOE.moe_fwd(p, x, tight)
    assert float(st.dropped_frac) > 0.0
    assert bool(jnp.all(jnp.isfinite(out)))


def test_aux_loss_penalises_imbalance(cfg, setup):
    p, x = setup
    # identical tokens -> every token routes to the same top-k experts
    # -> maximally imbalanced f_e -> higher load-balance loss
    x_same = jnp.broadcast_to(x[:1, :1], x.shape)
    _, st_bal = MOE.moe_fwd(p, x, cfg)
    _, st_imb = MOE.moe_fwd(p, x_same, cfg)
    assert float(st_imb.aux_loss) > float(st_bal.aux_loss)


def test_gradients_flow_to_experts(cfg, setup):
    p, x = setup

    def loss(pp):
        out, st = MOE.moe_fwd(pp, x, cfg)
        return jnp.sum(out ** 2) + st.aux_loss

    g = jax.grad(loss)(p)
    assert float(jnp.abs(g["wi_e"]).max()) > 0
    assert float(jnp.abs(g["router"]).max()) > 0
