"""Mask-aware compute engine (DESIGN.md §7): frozen-prefix backward skipping.

The vectorized engine's update program is keyed on a static prefix cut —
the smallest layer any cohort member trains — and must be a pure *compute*
change: identical masks and fp-tolerant params versus both the dense
vectorized program (cut=None) and the sequential paper-literal oracle, at
every cut (including cut = L, the all-empty-mask forward-only variant) and
at every pipeline depth.  Also covers the single-forward eval fix and the
partial warm starts for cohorts with unseen members.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FLConfig, RuntimeConfig, get_arch, reduced
from repro.core import masks as M
from repro.core.client import Client
from repro.core.server import FLServer
from repro.core.solver import greedy_rows
from repro.core.strategies import ProbeReport
from repro.data.synthetic import FederatedTaskConfig, SyntheticFederatedData
from repro.models.model import (Model, segment_cuts, supports_prefix_cut,
                                trainable_slice)


def _max_err(a, b):
    return max(jax.tree.leaves(jax.tree.map(
        lambda x, y: float(np.abs(np.asarray(x, np.float32)
                                  - np.asarray(y, np.float32)).max()), a, b)))


@pytest.fixture(scope="module")
def world():
    cfg = reduced(get_arch("xlm_roberta_base"), n_layers=4, d_model=32)
    model = Model(cfg, RuntimeConfig(remat=False, seq_chunk=16))
    params = model.init(jax.random.PRNGKey(0))
    data = SyntheticFederatedData(FederatedTaskConfig(
        n_clients=12, n_classes=10, vocab_size=cfg.vocab_size, seq_len=8,
        samples_per_client=16, skew="label", objective="classification"))
    return model, params, data


# ---------------------------------------------------------------------------
# Client-level: masked program ≡ dense program at every cut
# ---------------------------------------------------------------------------

def test_cohort_update_matches_dense_at_every_cut(world):
    """Sweep every prefix cut 0..L: the masked program must match the dense
    program on params (fp) and per-client losses, with masks that actually
    leave the prefix frozen (mask[:, :cut] == 0)."""
    model, params, data = world
    client = Client(model)
    L = model.n_selectable
    cohort = np.arange(4)
    batches = data.cohort_batches(cohort, 4, 2)
    sizes = data.sizes[cohort]
    for cut in range(L + 1):
        masks = np.zeros((4, L), np.float32)
        masks[:, cut:] = 1.0
        p_d, l_d = client.cohort_update(params, batches, masks, sizes, 0.01)
        p_m, l_m = client.cohort_update(params, batches, masks, sizes, 0.01,
                                        cut=cut)
        assert _max_err(p_d, p_m) < 1e-5, f"cut={cut}"
        np.testing.assert_allclose(l_m, l_d, atol=1e-5)


def test_cohort_update_heterogeneous_masks_above_cut(world):
    """The cut is the cohort *minimum*: members may train different subsets
    above it (per-row masks still apply inside the suffix)."""
    model, params, data = world
    client = Client(model)
    L = model.n_selectable
    cohort = np.arange(3)
    batches = data.cohort_batches(cohort, 4, 2)
    sizes = data.sizes[cohort]
    masks = np.array([[0, 1, 0, 1], [0, 0, 1, 1], [0, 1, 1, 0]], np.float32)
    cut = M.first_trainable_layer(masks)
    assert cut == 1
    p_d, _ = client.cohort_update(params, batches, masks, sizes, 0.01)
    p_m, _ = client.cohort_update(params, batches, masks, sizes, 0.01, cut=cut)
    assert _max_err(p_d, p_m) < 1e-5


def test_cohort_update_empty_masks_forward_only(world):
    """cut = L (no member trains anything): the forward-only variant leaves
    params untouched and still reports the same per-client losses."""
    model, params, data = world
    client = Client(model)
    L = model.n_selectable
    cohort = np.arange(3)
    batches = data.cohort_batches(cohort, 4, 2)
    sizes = data.sizes[cohort]
    masks = np.zeros((3, L), np.float32)
    p_d, l_d = client.cohort_update(params, batches, masks, sizes, 0.01)
    p_m, l_m = client.cohort_update(params, batches, masks, sizes, 0.01, cut=L)
    assert _max_err(params, p_m) == 0.0          # bit-identical pass-through
    assert _max_err(p_d, p_m) == 0.0             # dense zero-mask = identity
    np.testing.assert_allclose(l_m, l_d, atol=1e-5)


def test_masked_matches_dense_ssm_family():
    """The prefix split also covers non-attention scans (mamba2)."""
    cfg = reduced(get_arch("mamba2_370m"), n_layers=3, d_model=32)
    model = Model(cfg, RuntimeConfig(remat=False, seq_chunk=16))
    params = model.init(jax.random.PRNGKey(1))
    data = SyntheticFederatedData(FederatedTaskConfig(
        n_clients=6, vocab_size=cfg.vocab_size, seq_len=8,
        samples_per_client=8, skew="label", objective="lm"))
    client = Client(model)
    cohort = np.arange(3)
    batches = data.cohort_batches(cohort, 2, 2)
    sizes = data.sizes[cohort]
    L = model.n_selectable
    masks = np.zeros((3, L), np.float32)
    masks[:, L - 1:] = 1.0
    p_d, _ = client.cohort_update(params, batches, masks, sizes, 0.01)
    p_m, _ = client.cohort_update(params, batches, masks, sizes, 0.01,
                                  cut=L - 1)
    assert _max_err(p_d, p_m) < 1e-5


def test_masked_matches_dense_audio_family():
    """Whisper: the cut can split the *encoder* stack (mask order = compute
    order: enc_blocks before decoder blocks)."""
    cfg = reduced(get_arch("whisper_medium"), n_layers=2, d_model=32)
    model = Model(cfg, RuntimeConfig(remat=False, seq_chunk=16))
    params = model.init(jax.random.PRNGKey(2))
    L = model.n_selectable
    B, tau, n = 2, 2, 3
    ks = jax.random.split(jax.random.PRNGKey(3), 2)
    batches = {
        "frames": jax.random.normal(ks[0], (n, tau, B, cfg.enc_seq,
                                            cfg.d_model)),
        "tokens": jax.random.randint(ks[1], (n, tau, B, 8), 0,
                                     cfg.vocab_size),
    }
    sizes = np.full(n, 8.0)
    client = Client(model)
    for cut in (1, cfg.n_enc_layers, L - 1):    # mid-encoder / boundary / deep
        masks = np.zeros((n, L), np.float32)
        masks[:, cut:] = 1.0
        p_d, _ = client.cohort_update(params, batches, masks, sizes, 0.01)
        p_m, _ = client.cohort_update(params, batches, masks, sizes, 0.01,
                                      cut=cut)
        assert _max_err(p_d, p_m) < 1e-5, f"cut={cut}"


# ---------------------------------------------------------------------------
# Slicing primitives
# ---------------------------------------------------------------------------

def test_first_trainable_layer_edges():
    m = np.zeros((3, 5), np.float32)
    assert M.first_trainable_layer(m) == 5
    m[1, 3] = 1.0
    assert M.first_trainable_layer(m) == 3
    m[2, 0] = 1.0
    assert M.first_trainable_layer(m) == 0


def test_segment_cuts_and_trainable_slice_moe_dense0():
    """deepseek's dense0 segment precedes blocks in mask order: a cut inside
    blocks freezes all of dense0, a cut inside dense0 splits it."""
    cfg = reduced(get_arch("deepseek_v2_lite_16b"), n_layers=3, d_model=32)
    assert cfg.first_dense == 1
    model = Model(cfg, RuntimeConfig(remat=False, seq_chunk=16))
    params = model.init(jax.random.PRNGKey(0))
    assert segment_cuts(0, cfg) == {"dense0": 0, "blocks": 0}
    assert segment_cuts(1, cfg) == {"dense0": 1, "blocks": 0}
    assert segment_cuts(2, cfg) == {"dense0": 1, "blocks": 1}
    tr = trainable_slice(params, 1, cfg)
    assert "dense0" not in tr                    # fully frozen → omitted
    nb = cfg.n_layers - cfg.first_dense
    assert all(x.shape[0] == nb for x in jax.tree.leaves(tr["blocks"]))


def test_hybrid_family_has_no_prefix_cut():
    cfg = reduced(get_arch("zamba2_7b"), n_layers=2, d_model=32)
    assert not supports_prefix_cut(cfg)
    model = Model(cfg, RuntimeConfig(remat=False, seq_chunk=16))
    data = SyntheticFederatedData(FederatedTaskConfig(
        n_clients=4, vocab_size=cfg.vocab_size, seq_len=8,
        samples_per_client=8, skew="label", objective="lm"))
    fl = FLConfig(n_clients=4, cohort_size=2, rounds=1, local_steps=1,
                  batch_size=2, strategy="ours", budget=1, lam=1.0)
    server = FLServer(model, fl, data)
    assert server.mask_aware is False            # auto fallback to dense
    assert server._cut_for(np.ones((2, model.n_selectable))) is None
    with pytest.raises(ValueError, match="prefix-cut"):
        FLServer(model, fl, data, mask_aware=True)


def test_sequential_oracle_stays_dense(world):
    model, _, data = world
    fl = FLConfig(n_clients=12, cohort_size=3, rounds=1, local_steps=1,
                  batch_size=4, strategy="ours", budget=1, lam=1.0)
    seq = FLServer(model, fl, data, engine="sequential")
    assert seq.mask_aware is False
    with pytest.raises(ValueError, match="sequential"):
        FLServer(model, fl, data, engine="sequential", mask_aware=True)


# ---------------------------------------------------------------------------
# Server-level: mask-aware default ≡ dense engine, at every pipeline depth
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("depth", [1, 3])
def test_server_masked_matches_dense_engine(world, depth):
    model, params, _ = world
    task = FederatedTaskConfig(
        n_clients=12, n_classes=10, vocab_size=model.cfg.vocab_size,
        seq_len=8, samples_per_client=16, skew="label",
        objective="classification")
    fl = FLConfig(n_clients=12, cohort_size=4, rounds=3, local_steps=2,
                  lr=0.01, batch_size=4, strategy="ours", budget=1, lam=1.0,
                  seed=23)
    s_m = FLServer(model, fl, SyntheticFederatedData(task),
                   pipeline_depth=depth)
    s_d = FLServer(model, fl, SyntheticFederatedData(task),
                   pipeline_depth=depth, mask_aware=False)
    assert s_m.mask_aware and not s_d.mask_aware
    p_m, h_m = s_m.run(params)
    p_d, h_d = s_d.run(params)
    for rm, rd in zip(h_m.records, h_d.records):
        np.testing.assert_array_equal(rm.cohort, rd.cohort)
        np.testing.assert_array_equal(rm.mask_matrix, rd.mask_matrix)
        assert rm.train_loss == pytest.approx(rd.train_loss, abs=1e-5)
        assert rm.test_loss == pytest.approx(rd.test_loss, abs=1e-5)
    assert _max_err(p_m, p_d) < 1e-5


def test_server_empty_budget_round_runs_masked(world):
    """Layer costs no budget affords: every mask is empty (cut = L), the
    forward-only program variant runs, params stay put — same as the dense
    engine's zero-masked round."""
    model, params, _ = world
    task = FederatedTaskConfig(
        n_clients=12, n_classes=10, vocab_size=model.cfg.vocab_size,
        seq_len=8, samples_per_client=16, skew="label",
        objective="classification")
    fl = FLConfig(n_clients=12, cohort_size=3, rounds=1, local_steps=1,
                  lr=0.01, batch_size=4, strategy="ours", budget=1, lam=1.0,
                  seed=5)
    outs = {}
    for aware in (True, False):
        server = FLServer(model, fl, SyntheticFederatedData(task),
                          mask_aware=aware)
        server.layer_costs = np.full(server.L, 10.0)   # nothing fits R=1
        outs[aware] = server.run(params)
    p_m, h_m = outs[True]
    p_d, h_d = outs[False]
    assert h_m.records[0].union_frac == 0.0
    np.testing.assert_array_equal(h_m.records[0].mask_matrix,
                                  h_d.records[0].mask_matrix)
    assert _max_err(p_m, params) == 0.0          # untouched
    assert _max_err(p_m, p_d) == 0.0


# ---------------------------------------------------------------------------
# Satellite: single-forward eval
# ---------------------------------------------------------------------------

def test_eval_single_forward_unchanged(world):
    """Eval computes loss and accuracy from ONE forward; the values must
    equal the old double-forward composition (model.loss + a second
    forward_seq for the logits) exactly."""
    model, params, data = world
    client = Client(model)
    batch = data.test_batch()
    loss, acc = client.evaluate(params, batch)

    @jax.jit
    def old_eval(params, batch):                 # the pre-fix composition
        loss = model.loss(params, batch)
        h, _, _ = model.forward_seq(params, batch)
        logits = model._head(params, jnp.mean(h, axis=1)[:, None])[:, 0]
        acc = jnp.mean((jnp.argmax(logits, -1)
                        == batch["label"]).astype(jnp.float32))
        return loss, acc

    want_loss, want_acc = old_eval(params, batch)
    assert loss == pytest.approx(float(want_loss), abs=1e-6)
    assert acc == pytest.approx(float(want_acc), abs=1e-6)
    # and the new program actually dropped the second forward: the traced
    # jaxpr carries fewer equations than the old double-forward composition
    new_eqns = len(jax.make_jaxpr(client._eval_impl)(params, batch).eqns)
    old_eqns = len(jax.make_jaxpr(
        lambda p, b: old_eval.__wrapped__(p, b))(params, batch).eqns)
    assert new_eqns < old_eqns


# ---------------------------------------------------------------------------
# Satellite: partial warm starts for cohorts with unseen members
# ---------------------------------------------------------------------------

def test_partial_warm_start_fills_unseen_rows(world):
    model, params, _ = world
    task = FederatedTaskConfig(
        n_clients=12, n_classes=10, vocab_size=model.cfg.vocab_size,
        seq_len=8, samples_per_client=16, skew="label",
        objective="classification")
    fl = FLConfig(n_clients=12, cohort_size=3, rounds=1, local_steps=1,
                  batch_size=4, strategy="ours", budget=2, lam=1.0, seed=0)
    server = FLServer(model, fl, SyntheticFederatedData(task))
    rng = np.random.RandomState(0)

    # round 0: cohort {1, 4, 7} — populates the warm-mask cache
    plan0 = server._plan_for(np.array([1, 4, 7]), t=0)
    stats0 = {"grad_sq_norms":
              np.abs(rng.randn(3, server.L)).astype(np.float32)}
    server.select_round(plan0, stats0)
    assert server.select_stats["partial_warm_starts"] == 0

    # cohort {1, 4, 9}: 9 is unseen — known rows keep their warm masks,
    # the unseen row gets the solver's greedy cold-start fill
    cohort = np.array([1, 4, 9])
    G = np.abs(rng.randn(3, server.L)).astype(np.float32)
    probe = ProbeReport(grad_sq_norms=G)
    budgets = server._budgets(cohort)
    init = server._warm_init(cohort, probe, budgets)
    assert init is not None and init.shape == (3, server.L)
    assert server.select_stats["partial_warm_starts"] == 1
    np.testing.assert_array_equal(init[0], server._warm_masks[1])
    np.testing.assert_array_equal(init[1], server._warm_masks[4])
    np.testing.assert_array_equal(
        init[2], greedy_rows(G, budgets, costs=server.layer_costs)[2])

    # the full select path counts it too and stays budget-exact
    plan1 = server._plan_for(cohort, t=1)
    masks = server.select_round(plan1, {"grad_sq_norms": G})
    assert server.select_stats["partial_warm_starts"] == 2
    assert np.all(masks.sum(1) <= 2)
    assert set(server._warm_masks) == {1, 4, 7, 9}


def test_partial_warm_start_runs_deterministic(world):
    """Two identical runs with rotating cohorts (so unseen members appear
    mid-run) stay bit-identical — the greedy fill is a pure function of the
    round's utilities."""
    model, params, _ = world
    task = FederatedTaskConfig(
        n_clients=12, n_classes=10, vocab_size=model.cfg.vocab_size,
        seq_len=8, samples_per_client=16, skew="label",
        objective="classification")
    fl = FLConfig(n_clients=12, cohort_size=4, rounds=4, local_steps=1,
                  lr=0.01, batch_size=4, strategy="ours", budget=2, lam=1.0,
                  seed=29)
    hists = []
    for _ in range(2):
        server = FLServer(model, fl, SyntheticFederatedData(task))
        _, h = server.run(params)
        hists.append(h)
        # rotating cohorts must actually have triggered a partial fill
        assert server.select_stats["partial_warm_starts"] >= 1
    for r1, r2 in zip(hists[0].records, hists[1].records):
        np.testing.assert_array_equal(r1.cohort, r2.cohort)
        np.testing.assert_array_equal(r1.mask_matrix, r2.mask_matrix)
