"""Scan-aware HLO cost model: known-workload validation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.sharding.hlo_cost import HloCostModel, analyze, shape_bytes


def test_shape_bytes():
    assert shape_bytes("f32[2,3]{1,0}") == 24
    assert shape_bytes("bf16[4,4]") == 32
    assert shape_bytes("(s32[], f32[8,8]{1,0})") == 4 + 256
    assert shape_bytes("pred[]") == 1


def test_plain_matmul_flops():
    M, K, N = 32, 64, 128
    c = jax.jit(lambda a, b: a @ b).lower(
        jax.ShapeDtypeStruct((M, K), jnp.float32),
        jax.ShapeDtypeStruct((K, N), jnp.float32)).compile()
    m = analyze(c.as_text())
    assert m.flops == pytest.approx(2 * M * K * N, rel=0.05)


def test_scan_trip_count_scaling():
    L, M, N = 9, 32, 64
    def g(x, ws):
        def step(c, w):
            return c @ w, None
        out, _ = jax.lax.scan(step, x, ws)
        return out.sum()
    c = jax.jit(g).lower(jax.ShapeDtypeStruct((M, N), jnp.float32),
                         jax.ShapeDtypeStruct((L, N, N), jnp.float32)).compile()
    m = analyze(c.as_text())
    expect = 2 * M * N * N * L
    assert m.flops == pytest.approx(expect, rel=0.1)


def test_nested_scan_scaling():
    Lo, Li, N = 4, 3, 32
    def g(x, ws):
        def outer(c, wrow):
            def inner(ci, w):
                return ci @ w, None
            c2, _ = jax.lax.scan(inner, c, wrow)
            return c2, None
        out, _ = jax.lax.scan(outer, x, ws)
        return out.sum()
    c = jax.jit(g).lower(
        jax.ShapeDtypeStruct((N, N), jnp.float32),
        jax.ShapeDtypeStruct((Lo, Li, N, N), jnp.float32)).compile()
    m = analyze(c.as_text())
    expect = 2 * N ** 3 * Lo * Li
    assert m.flops == pytest.approx(expect, rel=0.15)


def test_entry_detected():
    c = jax.jit(lambda x: x + 1).lower(
        jax.ShapeDtypeStruct((4,), jnp.float32)).compile()
    model = HloCostModel(c.as_text())
    assert model.entry is not None
    assert model.metrics().flops >= 0
