"""Scan-aware HLO cost model: known-workload validation, plus the
roofline crosscheck — the auditor's measured masked-cut FLOPs must match
benchmarks/roofline.py's static 3L/(L+2(L−cut)) speedup model on both the
dense and ssm audit configs."""
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.sharding.hlo_cost import HloCostModel, analyze, shape_bytes

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def test_shape_bytes():
    assert shape_bytes("f32[2,3]{1,0}") == 24
    assert shape_bytes("bf16[4,4]") == 32
    assert shape_bytes("(s32[], f32[8,8]{1,0})") == 4 + 256
    assert shape_bytes("pred[]") == 1


def test_plain_matmul_flops():
    M, K, N = 32, 64, 128
    c = jax.jit(lambda a, b: a @ b).lower(
        jax.ShapeDtypeStruct((M, K), jnp.float32),
        jax.ShapeDtypeStruct((K, N), jnp.float32)).compile()
    m = analyze(c.as_text())
    assert m.flops == pytest.approx(2 * M * K * N, rel=0.05)


def test_scan_trip_count_scaling():
    L, M, N = 9, 32, 64
    def g(x, ws):
        def step(c, w):
            return c @ w, None
        out, _ = jax.lax.scan(step, x, ws)
        return out.sum()
    c = jax.jit(g).lower(jax.ShapeDtypeStruct((M, N), jnp.float32),
                         jax.ShapeDtypeStruct((L, N, N), jnp.float32)).compile()
    m = analyze(c.as_text())
    expect = 2 * M * N * N * L
    assert m.flops == pytest.approx(expect, rel=0.1)


def test_nested_scan_scaling():
    Lo, Li, N = 4, 3, 32
    def g(x, ws):
        def outer(c, wrow):
            def inner(ci, w):
                return ci @ w, None
            c2, _ = jax.lax.scan(inner, c, wrow)
            return c2, None
        out, _ = jax.lax.scan(outer, x, ws)
        return out.sum()
    c = jax.jit(g).lower(
        jax.ShapeDtypeStruct((N, N), jnp.float32),
        jax.ShapeDtypeStruct((Lo, Li, N, N), jnp.float32)).compile()
    m = analyze(c.as_text())
    expect = 2 * N ** 3 * Lo * Li
    assert m.flops == pytest.approx(expect, rel=0.15)


def test_entry_detected():
    c = jax.jit(lambda x: x + 1).lower(
        jax.ShapeDtypeStruct((4,), jnp.float32)).compile()
    model = HloCostModel(c.as_text())
    assert model.entry is not None
    assert model.metrics().flops >= 0


def test_donation_aliases_nested_entries():
    """input_output_alias entries nest braces (`{0}: (0, {}, may-alias)`);
    the parser must read the whole balanced header block, not stop at the
    first `}` — a multi-leaf donated tree yields one alias per leaf."""
    from repro.analysis.costmodel import donation_aliases

    tree = {k: jax.ShapeDtypeStruct((8, 8), jnp.float32) for k in "ab"}
    c = jax.jit(lambda t: {k: v + 1.0 for k, v in t.items()},
                donate_argnums=0).lower(tree).compile()
    assert len(donation_aliases(c.as_text())) == 2

    no_donate = jax.jit(lambda t: {k: v + 1.0 for k, v in t.items()}).lower(
        tree).compile()
    assert donation_aliases(no_donate.as_text()) == []


def test_unrolled_summary_report_shape():
    """The shared report dict dryrun and the auditor both consume."""
    from repro.analysis.costmodel import unrolled_summary

    M, K, N = 16, 32, 8
    c = jax.jit(lambda a, b: a @ b).lower(
        jax.ShapeDtypeStruct((M, K), jnp.float32),
        jax.ShapeDtypeStruct((K, N), jnp.float32)).compile()
    s = unrolled_summary(c.as_text())
    assert set(s) >= {"flops", "hbm_bytes", "collective_bytes",
                      "collective_by_kind", "collective_counts",
                      "transfer_ops", "dtypes", "donation_aliases"}
    assert s["flops"] == pytest.approx(2 * M * K * N, rel=0.05)
    assert not s["collective_counts"] and not s["transfer_ops"]
    assert s["dtypes"].get("f32", 0) > 0


# -- roofline crosscheck ------------------------------------------------------

def test_masked_cut_flops_match_roofline(program_audit_facts):
    """The auditor's compiled-HLO FLOPs reproduce the paper's static
    speedup model: a frozen prefix of depth `cut` speeds the train step by
    3L/(L+2(L−cut)) when blocks dominate (the audit configs cap the vocab
    so they do).  Crosschecked on the dense AND ssm configs."""
    from benchmarks.roofline import masked_backward_expectations

    for cfg in ("dense", "ssm"):
        rows = {f.meta["cut"]: f for f in program_audit_facts.values()
                if f.meta.get("kind") == "fl_step_masked"
                and f.meta.get("config") == cfg}
        assert len(rows) >= 3, f"masked-cut series missing for {cfg}"
        L = rows[max(rows)].meta["n_selectable"]
        expect = {r["cut"]: r["step_speedup"]
                  for r in masked_backward_expectations(L, sorted(rows))}
        base = rows[0].flops
        for cut in sorted(rows):
            if cut == 0:
                continue
            measured = base / rows[cut].flops
            assert measured == pytest.approx(expect[cut], rel=0.2), (
                f"{cfg} cut={cut}: audited speedup {measured:.2f}x vs "
                f"roofline {expect[cut]:.2f}x")
