"""Shared test fixtures.

NOTE: xla_force_host_platform_device_count is deliberately NOT set here —
smoke tests and benches must see 1 device.  Multi-device tests
(test_fl_distributed.py) spawn subprocesses with their own XLA_FLAGS.
"""
import os
import sys

import jax
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def program_audit_facts():
    """Audited ProgramFacts for the contract-bearing subset of the program
    auditor's enumeration (DESIGN.md §11): the masked-cut series on both
    the dense and ssm configs, the delta-serving decode family with its
    dense baseline, the donated writes, and one bf16 decode row.  Session
    scoped — test_program_audit.py and test_hlo_cost.py share the ~20
    lowerings instead of paying for them twice."""
    from repro.analysis import program as P

    def want(s):
        cfgl = s.meta.get("config")
        if "fl_step_masked" in s.name:
            return cfgl in ("dense", "ssm")
        if cfgl == "dense":
            return any(k in s.name for k in (
                "serve_decode_delta", "serve_decode_dense",
                "serve_write_delta_entry", "serve_write_params"))
        if cfgl == "dense_bf16":
            return s.name.endswith("serve_decode/B3")
        return False

    specs = [s for s in P.enumerate_specs() if want(s)]
    return P.run_audit(specs)


@pytest.fixture
def strict_mode():
    """Opt-in strict-mode context factory (REPRO_STRICT=1 in CI smoke).

    Yields a callable: ``with strict_mode("label"): ...`` arms
    ``jax.transfer_guard("disallow")`` plus the jit-suite retrace sentinel
    for the block — implicit host↔device transfers and new compiled
    programs both raise.  When REPRO_STRICT is unset the context is a
    no-op, so tests using it stay cheap by default and become tripwires
    under the strict CI job.
    """
    from repro.analysis.strict import strict_enabled, strict_region

    def region(label="strict-region", force: bool = False):
        return strict_region(label, enabled=force or strict_enabled())

    return region


def make_batch(cfg, B, S, key=None):
    """Synthetic batch matching an arch's input contract."""
    import jax.numpy as jnp
    key = key if key is not None else jax.random.PRNGKey(1)
    ks = jax.random.split(key, 3)
    batch = {}
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(ks[0], (B, cfg.n_prefix_tokens,
                                                     cfg.d_model))
        if cfg.task == "lm":
            batch["tokens"] = jax.random.randint(
                ks[1], (B, max(S - cfg.n_prefix_tokens, 4)), 0, cfg.vocab_size)
        else:
            batch["label"] = jax.random.randint(ks[1], (B,), 0, cfg.n_classes)
    elif cfg.family == "audio":
        batch["frames"] = jax.random.normal(ks[0], (B, cfg.enc_seq, cfg.d_model))
        batch["tokens"] = jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size)
    else:
        batch["tokens"] = jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size)
        if cfg.task == "classification":
            batch["label"] = jax.random.randint(ks[2], (B,), 0, cfg.n_classes)
    return batch
