"""Fallback mini property-test shim used when `hypothesis` is absent.

Tier-1 must collect and run without optional dependencies, so the property
tests import hypothesis through this module::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_compat import given, settings, st

The shim covers exactly the strategy surface this suite uses (``integers``,
``floats``) and runs each ``@given`` test on a deterministic sample: the
bound corners first, then fixed pseudo-random draws.  It does no shrinking
and no coverage-guided search — install the real `hypothesis`
(requirements-dev.txt) for that.
"""
from __future__ import annotations

import functools
import inspect

import numpy as np

# Cap fallback example counts: smoke-level determinism, not exploration.
_MAX_FALLBACK_EXAMPLES = 16


class _Strategy:
    def __init__(self, lo, hi, is_float: bool):
        self.lo, self.hi, self.is_float = lo, hi, is_float

    def example(self, i: int, rng: np.random.RandomState):
        if i == 0:
            return self.lo
        if i == 1:
            return self.hi
        if self.is_float:
            return float(rng.uniform(self.lo, self.hi))
        # randint's exclusive hi overflows int64 for bounds like 2**63-1;
        # sample in float space and round into range instead
        return int(self.lo + rng.rand() * (self.hi - self.lo))


class st:
    """Namespace mirroring ``hypothesis.strategies`` for the used subset."""

    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(min_value, max_value, is_float=False)

    @staticmethod
    def floats(min_value: float, max_value: float, **_) -> _Strategy:
        return _Strategy(float(min_value), float(max_value), is_float=True)


def settings(*, max_examples: int = 12, deadline=None, **_):
    def deco(fn):
        fn._compat_max_examples = max_examples
        return fn
    return deco


def given(*strategies: _Strategy):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_compat_max_examples",
                        getattr(fn, "_compat_max_examples", 12))
            n = min(n, _MAX_FALLBACK_EXAMPLES)
            rng = np.random.RandomState(0)
            for i in range(n):
                drawn = [s.example(i, rng) for s in strategies]
                fn(*args, *drawn, **kwargs)

        # hide the original signature: the drawn parameters must not look
        # like pytest fixtures (only non-strategy leading params remain)
        params = list(inspect.signature(fn).parameters.values())
        keep = params[:len(params) - len(strategies)]
        wrapper.__signature__ = inspect.Signature(keep)
        del wrapper.__wrapped__
        return wrapper
    return deco
