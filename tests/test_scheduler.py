"""RoundScheduler: depth-k parity, async solve state, verbose overlap,
plan-stage guards, and cross-round stream bookkeeping.

The engine-parity suite (tests/test_round_engine.py) pins the pipelined
path against the synchronous loop; this file covers the scheduler's own
contracts: lookahead depth as a pure scheduling knob, the host-solver
warm-start/memo counters, ``wall_s`` host-time semantics, verbose printing
decoupled from materialisation, and the empty/undersized-pool guards.
"""
import time

import jax
import numpy as np
import pytest

from repro.api import Experiment
from repro.configs.base import FLConfig, RuntimeConfig, get_arch, reduced
from repro.core.scheduler import RoundScheduler
from repro.core.server import FLServer
from repro.data.synthetic import FederatedTaskConfig, SyntheticFederatedData
from repro.models.model import Model


def _stats(**kw):
    """Expected select_stats: the full zeroed counter set with overrides
    (new fault/degradation counters default to 0 in fault-free tests)."""
    base = {"solves": 0, "memo_hits": 0, "partial_warm_starts": 0,
            "all_straggler_rounds": 0, "quarantined_rows": 0,
            "dead_clients": 0, "solver_timeouts": 0, "dispatch_retries": 0,
            "ckpt_fallbacks": 0}
    base.update(kw)
    return base


@pytest.fixture(scope="module")
def world():
    cfg = reduced(get_arch("xlm_roberta_base"), n_layers=4, d_model=32)
    model = Model(cfg, RuntimeConfig(remat=False, seq_chunk=16))
    params = model.init(jax.random.PRNGKey(0))
    task = FederatedTaskConfig(
        n_clients=12, n_classes=10, vocab_size=cfg.vocab_size, seq_len=8,
        samples_per_client=16, skew="label", objective="classification")
    return model, params, task


def _records_equal(h_a, h_b, atol=1e-5):
    assert len(h_a.records) == len(h_b.records)
    for ra, rb in zip(h_a.records, h_b.records):
        np.testing.assert_array_equal(ra.cohort, rb.cohort)
        np.testing.assert_array_equal(ra.mask_matrix, rb.mask_matrix)
        assert ra.train_loss == pytest.approx(rb.train_loss, abs=atol)
        assert ra.test_loss == pytest.approx(rb.test_loss, abs=atol)


def _params_close(p_a, p_b, atol=1e-5):
    err = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(np.abs(np.asarray(a, np.float32)
                                  - np.asarray(b, np.float32)).max()),
        p_a, p_b)))
    assert err < atol, f"param divergence {err}"


# ---------------------------------------------------------------------------
# Depth-k is a pure scheduling change
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("depth,period", [(1, 1), (2, 1), (4, 1), (3, 2)])
def test_depth_k_matches_synchronous(world, depth, period):
    """Any lookahead depth: cohorts/masks bit-identical to the synchronous
    loop, params within fp, per-client data streams consumed identically."""
    model, params, task = world
    fl = FLConfig(n_clients=12, cohort_size=4, rounds=5, local_steps=2,
                  lr=0.01, batch_size=4, strategy="ours", budget=2,
                  selection_period=period, lam=1.0, seed=17)
    data_p = SyntheticFederatedData(task)
    data_s = SyntheticFederatedData(task)
    p_pipe, h_pipe = FLServer(model, fl, data_p, pipeline=True,
                              pipeline_depth=depth).run(params)
    p_sync, h_sync = FLServer(model, fl, data_s, pipeline=False).run(params)
    _records_equal(h_pipe, h_sync)
    _params_close(p_pipe, p_sync)
    # cross-round stream bookkeeping: the scheduler drew exactly the same
    # number of samples from every client stream as the synchronous loop
    np.testing.assert_array_equal(data_p.stream_positions(),
                                  data_s.stream_positions())
    assert data_p.stream_positions().sum() > 0


@pytest.mark.parametrize("strategy", ["top", "rgn"])
def test_depth_k_probe_free_and_score_strategies(world, strategy):
    """Lookahead with no host solve (positional / device-scored): still a
    pure scheduling change."""
    model, params, task = world
    fl = FLConfig(n_clients=12, cohort_size=4, rounds=4, local_steps=1,
                  lr=0.01, batch_size=4, strategy=strategy, budget=2,
                  lam=1.0, seed=23)
    p_pipe, h_pipe = FLServer(model, fl, SyntheticFederatedData(task),
                              pipeline=True, pipeline_depth=3).run(params)
    p_sync, h_sync = FLServer(model, fl, SyntheticFederatedData(task),
                              pipeline=False).run(params)
    _records_equal(h_pipe, h_sync)
    _params_close(p_pipe, p_sync)


def test_experiment_pipeline_depth_knob(world):
    model, params, task = world
    exp = Experiment(model, SyntheticFederatedData(task), "ours",
                     rounds=3, cohort_size=4, local_steps=1, batch_size=4,
                     budget=2, lam=1.0, seed=3, pipeline_depth=3)
    assert exp.build().pipeline_depth == 3
    _, hist = exp.run(params)
    assert len(hist.records) == 3


def test_depth_validation(world):
    model, params, task = world
    fl = FLConfig(n_clients=12, cohort_size=4, rounds=1)
    with pytest.raises(ValueError, match="pipeline_depth"):
        FLServer(model, fl, SyntheticFederatedData(task), pipeline_depth=0)
    server = FLServer(model, fl, SyntheticFederatedData(task))
    with pytest.raises(ValueError, match="depth"):
        RoundScheduler(server, depth=0)


# ---------------------------------------------------------------------------
# Host-solver acceleration: warm start + unchanged-utilities early exit
# ---------------------------------------------------------------------------

def test_select_round_memo_and_warm_cache(world):
    """Byte-identical (cohort, budgets, stats) skips the (P1) solve; the
    warm-mask cache tracks every selected client id."""
    model, params, task = world
    fl = FLConfig(n_clients=12, cohort_size=4, rounds=1, local_steps=1,
                  batch_size=4, strategy="ours", budget=2, lam=1.0, seed=0)
    server = FLServer(model, fl, SyntheticFederatedData(task))
    cohort = np.array([1, 4, 7])
    plan = server._plan_for(cohort, t=0)
    rng = np.random.RandomState(0)
    stats = {"grad_sq_norms":
             np.abs(rng.randn(len(plan.probe_ids), server.L))
             .astype(np.float32)}
    m1 = server.select_round(plan, stats)
    assert server.select_stats == _stats(solves=1)
    assert set(server._warm_masks) == {1, 4, 7}
    # identical inputs, but the warm init changed (cold → m1): replaying
    # would be unsound for a solver that may not have converged, so this
    # re-solves; the converged m1 is a fixed point, so masks are unchanged
    m2 = server.select_round(plan, stats)
    assert server.select_stats == _stats(solves=2)
    np.testing.assert_array_equal(m1, m2)
    # now (inputs, init) are both byte-identical: the memo hits
    m3 = server.select_round(plan, stats)
    assert server.select_stats == _stats(solves=2, memo_hits=1)
    np.testing.assert_array_equal(m1, m3)
    # changed utilities invalidate the memo
    stats2 = {"grad_sq_norms": stats["grad_sq_norms"] + 1.0}
    server.select_round(plan, stats2)
    assert server.select_stats["solves"] == 3


def test_round_dependent_host_strategy_is_never_memoized(world):
    """A custom host strategy that does NOT declare memoizable_select must
    be re-run even on byte-identical inputs (it may depend on ctx.round)."""
    from repro.api import Strategy

    class _Annealed(Strategy):
        name = "test_annealed"
        host = True
        probe_requirements = frozenset({"grad_sq_norms"})

        def select(self, probe, budgets, ctx):
            masks = np.zeros((probe.n, probe.L), np.float32)
            masks[:, ctx.round % probe.L] = 1.0       # round-dependent
            return masks

    model, params, task = world
    fl = FLConfig(n_clients=12, cohort_size=3, rounds=1, local_steps=1,
                  batch_size=4, budget=1, lam=1.0, seed=0)
    server = FLServer(model, fl, SyntheticFederatedData(task),
                      strategy=_Annealed())
    cohort = np.array([2, 5, 8])
    stats = {"grad_sq_norms":
             np.ones((3, server.L), np.float32)}
    m0 = server.select_round(server._plan_for(cohort, t=0), stats)
    m1 = server.select_round(server._plan_for(cohort, t=1), stats)
    assert server.select_stats == _stats(solves=2)
    assert not np.array_equal(m0, m1)     # the schedule actually advanced


def test_warm_start_runs_stay_deterministic(world):
    """The warm start is per-run state: two identical runs (fresh servers)
    produce identical mask trajectories."""
    model, params, task = world
    fl = FLConfig(n_clients=12, cohort_size=4, rounds=4, local_steps=1,
                  lr=0.01, batch_size=4, strategy="ours", budget=2,
                  lam=1.0, seed=11)
    _, h1 = FLServer(model, fl, SyntheticFederatedData(task),
                     pipeline_depth=2).run(params)
    _, h2 = FLServer(model, fl, SyntheticFederatedData(task),
                     pipeline_depth=2).run(params)
    _records_equal(h1, h2)
    for rec in h1.records:      # warm-started solves stay budget-exact
        assert np.all(rec.mask_matrix.sum(1) <= 2)


# ---------------------------------------------------------------------------
# Verbose: printing decoupled from materialisation; wall_s semantics
# ---------------------------------------------------------------------------

def test_verbose_pipelined_matches_quiet_and_prints_all_rounds(world, capsys):
    model, params, task = world
    fl = FLConfig(n_clients=12, cohort_size=4, rounds=3, local_steps=1,
                  lr=0.01, batch_size=4, strategy="ours", budget=2,
                  lam=1.0, seed=29)
    _, h_quiet = FLServer(model, fl, SyntheticFederatedData(task),
                          pipeline_depth=2).run(params, verbose=False)
    capsys.readouterr()
    _, h_verb = FLServer(model, fl, SyntheticFederatedData(task),
                         pipeline_depth=2).run(params, verbose=True)
    out = capsys.readouterr().out
    # every round printed, in order, exactly once
    printed = [line for line in out.splitlines() if line.startswith("[round")]
    assert len(printed) == 3
    assert [int(line.split("]")[0].split()[-1]) for line in printed] == [0, 1, 2]
    _records_equal(h_verb, h_quiet)


def test_pipelined_wall_s_is_host_time(world):
    """Pipelined wall_s = per-round host time (dispatch + select), drain
    excluded: the per-round times are disjoint sub-intervals of the run, so
    their sum never exceeds the elapsed wall clock."""
    model, params, task = world
    fl = FLConfig(n_clients=12, cohort_size=4, rounds=4, local_steps=1,
                  lr=0.01, batch_size=4, strategy="ours", budget=2,
                  lam=1.0, seed=31)
    server = FLServer(model, fl, SyntheticFederatedData(task),
                      pipeline_depth=2)
    t0 = time.time()
    _, hist = server.run(params)
    elapsed = time.time() - t0
    walls = [r.wall_s for r in hist.records]
    assert all(np.isfinite(w) and w >= 0 for w in walls)
    assert sum(walls) <= elapsed + 1e-6


# ---------------------------------------------------------------------------
# Plan-stage guards: empty / undersized pools, straggler-shrunk cohorts
# ---------------------------------------------------------------------------

class _HookedData:
    """Wrap a task with scripted availability/straggler hooks."""

    def __init__(self, inner, pool_fn=None, keep_fn=None):
        self._inner = inner
        self.sizes = inner.sizes
        self._pool_fn = pool_fn
        self._keep_fn = keep_fn

    def cohort_batches(self, cohort, batch_size, n):
        return self._inner.cohort_batches(cohort, batch_size, n)

    def test_batch(self, batch_size=None):
        return self._inner.test_batch(batch_size)

    def available_clients(self, t, rng):
        return None if self._pool_fn is None else self._pool_fn(t)

    def drop_stragglers(self, t, cohort, rng):
        if self._keep_fn is None:
            return np.ones(len(cohort), bool)
        return self._keep_fn(t, cohort)


def test_empty_pool_fails_at_plan_stage_with_cause(world):
    model, params, task = world
    data = _HookedData(SyntheticFederatedData(task), pool_fn=lambda t: [])
    fl = FLConfig(n_clients=12, cohort_size=4, rounds=2, local_steps=1,
                  batch_size=4, strategy="ours", budget=2, lam=1.0)
    for pipeline in (False, True):
        server = FLServer(model, fl, data, pipeline=pipeline)
        with pytest.raises(ValueError, match="empty pool for round 0"):
            server.run(params)


@pytest.mark.parametrize("pipeline", [False, True])
def test_singleton_pool_reaches_every_stage(world, pipeline):
    """An undersized pool (1 client) must flow through probe / select /
    update / eval without shape errors, in both scheduling modes."""
    model, params, task = world
    data = _HookedData(SyntheticFederatedData(task),
                       pool_fn=lambda t: [t % 12])
    fl = FLConfig(n_clients=12, cohort_size=4, rounds=3, local_steps=1,
                  lr=0.01, batch_size=4, strategy="ours", budget=2, lam=1.0)
    _, hist = FLServer(model, fl, data, pipeline=pipeline,
                       pipeline_depth=2).run(params)
    assert len(hist.records) == 3
    for rec in hist.records:
        assert len(rec.cohort) == 1
        assert rec.mask_matrix.shape == (1, model.n_selectable)
        assert 1 <= rec.mask_matrix.sum() <= 2
        assert np.isfinite(rec.test_loss) and np.isfinite(rec.train_loss)
        assert rec.uploaded_params > 0


@pytest.mark.parametrize("engine", ["vectorized", "sequential"])
def test_straggler_shrunk_cohort_reaches_every_stage(world, engine):
    """Stragglers shrinking the drawn cohort to one member must reach every
    stage; dropping *everyone* keeps the full cohort (documented guard)."""
    model, params, task = world

    def keep(t, cohort):
        k = np.zeros(len(cohort), bool)
        if t % 2 == 0:
            k[0] = True          # shrink to a single member
        return k                 # odd rounds: nobody reports -> keep all

    data = _HookedData(SyntheticFederatedData(task), keep_fn=keep)
    fl = FLConfig(n_clients=12, cohort_size=4, rounds=2, local_steps=1,
                  lr=0.01, batch_size=4, strategy="ours", budget=2, lam=1.0)
    _, hist = FLServer(model, fl, data, engine=engine).run(params)
    assert [len(r.cohort) for r in hist.records] == [1, 4]
    for rec in hist.records:
        assert np.isfinite(rec.test_loss)
        assert np.all(rec.mask_matrix.sum(1) <= 2)
