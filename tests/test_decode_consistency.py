"""Decode-vs-prefill consistency: token-by-token decoding with the KV/state
cache must reproduce the full-sequence forward logits — for every family.

This is the strongest correctness test of the cache machinery (RoPE at
write time, rolling windows, SSD state recurrence, shared-attention caches,
MLA latent caches, cross-attention caches).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import RuntimeConfig, get_arch, reduced
from repro.models import blocks as B
from repro.models.model import Model

FAMS = ["tinyllama_1_1b", "gemma_7b", "grok_1_314b", "deepseek_v2_lite_16b",
        "mamba2_370m", "zamba2_7b"]


def _full_logits(model, params, tokens):
    """All-position logits from the sequence forward."""
    h, _, _ = model.forward_seq(params, {"tokens": tokens})
    cfg = model.cfg
    h = B.rms_norm(h, params["final_norm"], cfg.norm_eps)
    w = params["embed"]["tok"].T if cfg.tie_embeddings else params["head"]
    return B.softcap(h @ w, cfg.logit_softcap)


@pytest.mark.parametrize("arch", FAMS)
def test_decode_matches_prefill(arch):
    import dataclasses
    cfg = reduced(get_arch(arch))
    if cfg.n_experts:
        # Capacity-based routing drops tokens as a function of T=B·S, so
        # prefill and decode only agree exactly in the dropless regime.
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    model = Model(cfg, RuntimeConfig(remat=False, seq_chunk=8))
    params = model.init(jax.random.PRNGKey(0))
    Bsz, S = 2, 12
    tokens = jax.random.randint(jax.random.PRNGKey(1), (Bsz, S), 0,
                                cfg.vocab_size)
    want = np.asarray(_full_logits(model, params, tokens), np.float32)

    cache = model.init_cache(Bsz, S, dtype="float32")
    got = []
    for t in range(S):
        logits, cache = model.decode_step(params, tokens[:, t],
                                          jnp.int32(t), cache)
        got.append(np.asarray(logits, np.float32))
    got = np.stack(got, axis=1)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_sliding_window_decode_matches_windowed_prefill():
    """Windowed decode == windowed full attention (dense family)."""
    cfg = reduced(get_arch("tinyllama_1_1b")).with_sliding_window(4)
    model = Model(cfg, RuntimeConfig(remat=False, seq_chunk=8))
    params = model.init(jax.random.PRNGKey(0))
    Bsz, S, W = 2, 10, 4
    tokens = jax.random.randint(jax.random.PRNGKey(2), (Bsz, S), 0,
                                cfg.vocab_size)
    h, _, _ = model.forward_seq(params, {"tokens": tokens})
    hn = B.rms_norm(h, params["final_norm"], cfg.norm_eps)
    w = params["embed"]["tok"].T if cfg.tie_embeddings else params["head"]
    want = np.asarray(hn @ w, np.float32)

    cache = model.init_cache(Bsz, S, window=W, dtype="float32")
    got = []
    for t in range(S):
        logits, cache = model.decode_step(params, tokens[:, t], jnp.int32(t),
                                          cache, window=W)
        got.append(np.asarray(logits, np.float32))
    got = np.stack(got, axis=1)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_whisper_decode_matches_prefill():
    cfg = reduced(get_arch("whisper_medium"))
    model = Model(cfg, RuntimeConfig(remat=False, seq_chunk=8))
    params = model.init(jax.random.PRNGKey(0))
    Bsz, S = 2, 8
    frames = jax.random.normal(jax.random.PRNGKey(3),
                               (Bsz, cfg.enc_seq, cfg.d_model))
    tokens = jax.random.randint(jax.random.PRNGKey(4), (Bsz, S), 0,
                                cfg.vocab_size)
    h, _, _ = model.forward_seq(params, {"frames": frames, "tokens": tokens})
    hn = B.rms_norm(h, params["final_norm"], cfg.norm_eps)
    w = params["embed"]["tok"].T if cfg.tie_embeddings else params["head"]
    want = np.asarray(hn @ w, np.float32)

    # build cross-kv cache from the encoder (prefill half of serve)
    e = frames.astype(params["embed"]["frame_proj"].dtype) @ params["embed"]["frame_proj"]
    e = e + B.sinusoid_positions(jnp.arange(cfg.enc_seq), cfg.d_model).astype(e.dtype)
    from jax import lax
    from repro.models.model import _take, _dense_block_fwd
    def enc_step(carry, p):
        hh, _ = _dense_block_fwd(p, carry, cfg,
                                 positions=jnp.arange(cfg.enc_seq, dtype=jnp.int32),
                                 causal=False, window=0, prefix_len=0, seq_chunk=8)
        return hh, None
    e, _ = lax.scan(enc_step, e, params["enc_blocks"])
    enc_out = B.rms_norm(e, params["enc_norm"], cfg.norm_eps)

    cache = model.init_cache(Bsz, S, dtype="float32")
    def fill(p, _):
        return B.make_cross_kv(_take(p, "xattn_"), enc_out, cfg)
    ks, vs = [], []
    for l in range(cfg.n_layers):
        pl = jax.tree.map(lambda a: a[l], params["blocks"])
        k, v = B.make_cross_kv(_take(pl, "xattn_"), enc_out, cfg)
        ks.append(k); vs.append(v)
    cache["cross_kv"]["k"] = jnp.stack(ks).astype(cache["cross_kv"]["k"].dtype)
    cache["cross_kv"]["v"] = jnp.stack(vs).astype(cache["cross_kv"]["v"].dtype)

    got = []
    for t in range(S):
        logits, cache = model.decode_step(params, tokens[:, t], jnp.int32(t),
                                          cache)
        got.append(np.asarray(logits, np.float32))
    got = np.stack(got, axis=1)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_chunked_attention_matches_full():
    """The lax chunked-attention path equals unchunked full attention."""
    cfg = reduced(get_arch("tinyllama_1_1b"))
    tokens = jax.random.randint(jax.random.PRNGKey(5), (2, 64), 0,
                                cfg.vocab_size)
    m_small = Model(cfg, RuntimeConfig(remat=False, seq_chunk=16))   # chunked
    m_big = Model(cfg, RuntimeConfig(remat=False, seq_chunk=256))    # full
    params = m_small.init(jax.random.PRNGKey(0))
    l1 = m_small.loss(params, {"tokens": tokens})
    l2 = m_big.loss(params, {"tokens": tokens})
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
