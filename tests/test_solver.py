"""Tests for the (P1) solvers."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # tier-1 must run without optional deps
    from _hypothesis_compat import given, settings, st

from repro.core.solver import objective, solve_icm, solve_unified


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 6), st.integers(2, 10), st.integers(0, 2 ** 30),
       st.floats(0.0, 100.0))
def test_budgets_respected(n, L, seed, lam):
    rng = np.random.RandomState(seed % (2 ** 31 - 1))
    G = np.abs(rng.randn(n, L)).astype(np.float64)
    budgets = rng.randint(1, L + 1, n)
    masks, _, _ = solve_icm(G, budgets, lam)
    assert masks.shape == (n, L)
    assert np.all(masks.sum(1) <= budgets + 1e-9)
    assert np.all(masks.sum(1) >= 1)          # at least one layer each
    assert set(np.unique(masks)) <= {0.0, 1.0}


def test_lambda_zero_is_per_client_topk():
    G = np.array([[5., 1., 3.], [1., 9., 2.]])
    masks, _, _ = solve_icm(G, 1, lam=0.0)
    np.testing.assert_array_equal(masks, [[1, 0, 0], [0, 1, 0]])


def test_large_lambda_forces_agreement():
    """λ→∞ must produce identical masks (equal budgets)."""
    rng = np.random.RandomState(0)
    G = np.abs(rng.randn(5, 8))
    masks, _, _ = solve_icm(G, 2, lam=1e6)
    for i in range(1, 5):
        np.testing.assert_array_equal(masks[i], masks[0])
    # and matches the unified solver
    uni = solve_unified(G, 2)
    np.testing.assert_array_equal(masks, uni)


def test_icm_improves_over_init():
    rng = np.random.RandomState(3)
    G = np.abs(rng.randn(6, 10))
    lam = 0.5
    init = np.stack([np.eye(10, dtype=np.float32)[i % 10] for i in range(6)])
    masks, val, iters = solve_icm(G, 1, lam, init=init)
    assert val >= objective(G, init, lam) - 1e-9


def test_unified_heterogeneous_budgets_nested():
    """Unified selection with R_i ∈ {1,3}: the R=1 mask is a prefix subset."""
    rng = np.random.RandomState(1)
    G = np.abs(rng.randn(4, 6))
    budgets = np.array([1, 3, 1, 3])
    masks = solve_unified(G, budgets)
    assert masks[0].sum() == 1 and masks[1].sum() == 3
    assert np.all(masks[0] <= masks[1])       # nested prefixes
    np.testing.assert_array_equal(masks[0], masks[2])


def test_costs_knapsack():
    """Non-uniform layer costs: budget counts parameters, not layers."""
    G = np.array([[10.0, 10.0, 1.0]])
    costs = np.array([4.0, 1.0, 1.0])
    masks, _, _ = solve_icm(G, budgets=2.0, lam=0.0, costs=costs)
    # layer 0 too expensive (cost 4 > 2); pick layers 1 then 2
    np.testing.assert_array_equal(masks, [[0, 1, 1]])


def test_budget_admitting_no_layer_yields_empty_mask():
    """Regression: when R_i admits not even the cheapest layer the old
    fallback forced argmin(costs) anyway, silently violating R(m_i) <= R_i.
    The constraint is hard — the client sits the round out (empty mask)."""
    G = np.array([[10.0, 5.0, 1.0], [1.0, 2.0, 3.0]])
    costs = np.array([4.0, 3.0, 5.0])
    masks, _, _ = solve_icm(G, budgets=np.array([2.0, 3.0]), lam=1.0,
                            costs=costs)
    np.testing.assert_array_equal(masks[0], [0, 0, 0])   # nothing fits R=2
    np.testing.assert_array_equal(masks[1], [0, 1, 0])   # only cost-3 fits
    assert np.all(masks @ costs <= np.array([2.0, 3.0]) + 1e-9)


def test_unified_budget_admitting_no_layer_yields_empty_mask():
    """solve_unified has the same hard-constraint contract (audit)."""
    G = np.abs(np.random.RandomState(2).randn(3, 4))
    costs = np.array([3.0, 3.0, 4.0, 5.0])
    masks = solve_unified(G, budgets=np.array([2.0, 3.0, 6.0]), costs=costs)
    np.testing.assert_array_equal(masks[0], np.zeros(4))
    assert masks[1].sum() == 1.0                          # one cost-3 layer
    assert np.all(masks @ costs <= np.array([2.0, 3.0, 6.0]) + 1e-9)


def test_warm_start_converges_in_one_sweep_at_fixed_point():
    """init= at a converged solution: one sweep, identical masks — the
    round scheduler's warm start shrinks solver iterations as utilities
    stabilise without changing the solution."""
    rng = np.random.RandomState(7)
    G = np.abs(rng.randn(6, 10))
    cold, val, cold_iters = solve_icm(G, 2, lam=0.7)
    warm, wval, warm_iters = solve_icm(G, 2, lam=0.7, init=cold)
    np.testing.assert_array_equal(warm, cold)
    assert warm_iters == 1 <= cold_iters
    assert wval == pytest.approx(val)


def test_warm_start_shape_validated():
    G = np.abs(np.random.RandomState(0).randn(3, 5))
    with pytest.raises(ValueError, match="init shape"):
        solve_icm(G, 1, lam=0.0, init=np.zeros((2, 5), np.float32))
