"""Slot-based serving loop: all requests complete, generations consistent."""
import jax
import numpy as np

from repro.configs.base import RuntimeConfig, get_arch, reduced
from repro.launch.serve import Request, SlotServer
from repro.models.model import Model


def test_slot_server_completes_all_requests():
    cfg = reduced(get_arch("tinyllama_1_1b"))
    model = Model(cfg, RuntimeConfig(remat=False, seq_chunk=16))
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(1)
    reqs = [Request(i, rng.randint(0, cfg.vocab_size, 4).tolist(), 5)
            for i in range(7)]
    server = SlotServer(model, params, slots=3, max_seq=16)
    done, stats = server.run(reqs)
    assert len(done) == 7
    assert all(len(r.generated) == 5 for r in done)
    assert stats["steps"] > 0


def test_slot_server_stats_are_guarded():
    """steps / wall_s / gen_tokens reported separately; tok_per_s counts
    only generated tokens and never divides by ~0 wall time."""
    cfg = reduced(get_arch("tinyllama_1_1b"), n_layers=2, d_model=32)
    model = Model(cfg, RuntimeConfig(remat=False, seq_chunk=16))
    params = model.init(jax.random.PRNGKey(0))
    server = SlotServer(model, params, slots=2, max_seq=12)
    done, stats = server.run([Request(0, [1, 2], 3), Request(1, [3], 2)])
    assert stats["gen_tokens"] == sum(len(r.generated) for r in done) == 5
    assert stats["steps"] > 0 and stats["wall_s"] > 0
    assert stats["tok_per_s"] == stats["gen_tokens"] / stats["wall_s"]
    # the zero-work edge: no requests, no wall-clock blowup
    empty_done, empty = SlotServer(model, params, 2, 12).run([])
    assert empty_done == [] and empty["gen_tokens"] == 0
    assert empty["tok_per_s"] == 0.0


def test_slot_server_matches_single_decode():
    """A lone request through the server == direct decode_step loop."""
    import jax.numpy as jnp
    cfg = reduced(get_arch("tinyllama_1_1b"))
    model = Model(cfg, RuntimeConfig(remat=False, seq_chunk=16))
    params = model.init(jax.random.PRNGKey(0))
    prompt = [3, 7, 11]
    server = SlotServer(model, params, slots=1, max_seq=12)
    done, _ = server.run([Request(0, list(prompt), 4)])

    cache = model.init_cache(1, 12)
    toks = list(prompt)
    out = []
    for t in range(len(prompt) + 4 - 1):
        cur = toks[t] if t < len(prompt) else out[-1]
        logits, cache = model.decode_step(params, jnp.asarray([cur]),
                                          jnp.int32(t), cache)
        if t >= len(prompt) - 1:
            out.append(int(jnp.argmax(logits[0])))
    assert done[0].generated == out
