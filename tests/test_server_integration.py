"""Integration: full Algorithm 1 rounds on the simulator + invariants."""
import jax
import numpy as np
import pytest

from repro.configs.base import FLConfig, RuntimeConfig, get_arch, reduced
from repro.core.server import FLServer
from repro.data.synthetic import FederatedTaskConfig, SyntheticFederatedData
from repro.models.model import Model


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_arch("xlm_roberta_base"), n_layers=4, d_model=64)
    model = Model(cfg, RuntimeConfig(remat=False, seq_chunk=16))
    params = model.init(jax.random.PRNGKey(0))
    data = SyntheticFederatedData(FederatedTaskConfig(
        n_clients=12, n_classes=10, vocab_size=cfg.vocab_size, seq_len=16,
        samples_per_client=16, skew="label", objective="classification"))
    return model, params, data


@pytest.mark.parametrize("strategy", ["ours", "top", "rgn", "full"])
def test_rounds_run_and_masks_respect_budget(setup, strategy):
    model, params, data = setup
    fl = FLConfig(n_clients=12, cohort_size=4, rounds=2, local_steps=2,
                  lr=0.01, batch_size=8, strategy=strategy, budget=2, lam=1.0)
    server = FLServer(model, fl, data)
    new_params, hist = server.run(params)
    assert len(hist.records) == 2
    for rec in hist.records:
        assert np.isfinite(rec.test_loss)
        if strategy != "full":
            assert np.all(rec.mask_matrix.sum(1) <= 2)
        assert rec.uploaded_params > 0
    # params actually changed
    moved = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(abs(np.asarray(a - b)).max()), params, new_params)))
    assert moved > 0


def test_heterogeneous_budgets(setup):
    model, params, data = setup
    fl = FLConfig(n_clients=12, cohort_size=4, rounds=1, local_steps=1,
                  lr=0.01, batch_size=8, strategy="ours",
                  budgets=(1, 2, 3, 4), lam=1.0)
    server = FLServer(model, fl, data)
    _, hist = server.run(params)
    rec = hist.records[0]
    budgets = np.array([fl.budget_of(int(i)) for i in rec.cohort])
    assert np.all(rec.mask_matrix.sum(1) <= budgets)


def test_selection_period_caches_masks(setup):
    model, params, data = setup
    fl = FLConfig(n_clients=12, cohort_size=4, rounds=3, local_steps=1,
                  lr=0.01, batch_size=8, strategy="ours", budget=1,
                  selection_period=3, lam=1000.0)
    server = FLServer(model, fl, data)
    _, hist = server.run(params)
    # rounds 1,2 reuse round-0 masks (lam high => identical rows)
    m0 = hist.records[0].mask_matrix
    m1 = hist.records[1].mask_matrix
    np.testing.assert_array_equal(m0, m1)


def test_frozen_groups_never_move(setup):
    model, params, data = setup
    fl = FLConfig(n_clients=12, cohort_size=4, rounds=2, local_steps=2,
                  lr=0.1, batch_size=8, strategy="ours", budget=2, lam=1.0)
    server = FLServer(model, fl, data)
    new_params, _ = server.run(params)
    for grp in ("embed", "head", "final_norm"):
        if grp in params:
            d = jax.tree.map(lambda a, b: float(abs(np.asarray(a - b)).max()),
                             params[grp], new_params[grp])
            assert max(jax.tree.leaves(d) or [0.0]) == 0.0, grp
