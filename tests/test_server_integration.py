"""Integration: full Algorithm 1 rounds on the simulator + invariants."""
import json

import jax
import numpy as np
import pytest

from repro.configs.base import FLConfig, RuntimeConfig, get_arch, reduced
from repro.core.server import FLServer
from repro.data.synthetic import FederatedTaskConfig, SyntheticFederatedData
from repro.models.model import Model


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_arch("xlm_roberta_base"), n_layers=4, d_model=64)
    model = Model(cfg, RuntimeConfig(remat=False, seq_chunk=16))
    params = model.init(jax.random.PRNGKey(0))
    data = SyntheticFederatedData(FederatedTaskConfig(
        n_clients=12, n_classes=10, vocab_size=cfg.vocab_size, seq_len=16,
        samples_per_client=16, skew="label", objective="classification"))
    return model, params, data


@pytest.mark.parametrize("strategy", ["ours", "top", "rgn", "full"])
def test_rounds_run_and_masks_respect_budget(setup, strategy):
    model, params, data = setup
    fl = FLConfig(n_clients=12, cohort_size=4, rounds=2, local_steps=2,
                  lr=0.01, batch_size=8, strategy=strategy, budget=2, lam=1.0)
    server = FLServer(model, fl, data)
    new_params, hist = server.run(params)
    assert len(hist.records) == 2
    for rec in hist.records:
        assert np.isfinite(rec.test_loss)
        if strategy != "full":
            assert np.all(rec.mask_matrix.sum(1) <= 2)
        assert rec.uploaded_params > 0
    # params actually changed
    moved = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(abs(np.asarray(a - b)).max()), params, new_params)))
    assert moved > 0


def test_heterogeneous_budgets(setup):
    model, params, data = setup
    fl = FLConfig(n_clients=12, cohort_size=4, rounds=1, local_steps=1,
                  lr=0.01, batch_size=8, strategy="ours",
                  budgets=(1, 2, 3, 4), lam=1.0)
    server = FLServer(model, fl, data)
    _, hist = server.run(params)
    rec = hist.records[0]
    budgets = np.array([fl.budget_of(int(i)) for i in rec.cohort])
    assert np.all(rec.mask_matrix.sum(1) <= budgets)


def test_selection_period_caches_masks(setup):
    model, params, data = setup
    fl = FLConfig(n_clients=12, cohort_size=4, rounds=3, local_steps=1,
                  lr=0.01, batch_size=8, strategy="ours", budget=1,
                  selection_period=3, lam=1000.0)
    server = FLServer(model, fl, data)
    _, hist = server.run(params)
    # rounds 1,2 reuse round-0 masks (lam high => identical rows)
    m0 = hist.records[0].mask_matrix
    m1 = hist.records[1].mask_matrix
    np.testing.assert_array_equal(m0, m1)


def test_frozen_groups_never_move(setup):
    model, params, data = setup
    fl = FLConfig(n_clients=12, cohort_size=4, rounds=2, local_steps=2,
                  lr=0.1, batch_size=8, strategy="ours", budget=2, lam=1.0)
    server = FLServer(model, fl, data)
    new_params, _ = server.run(params)
    for grp in ("embed", "head", "final_norm"):
        if grp in params:
            d = jax.tree.map(lambda a, b: float(abs(np.asarray(a - b)).max()),
                             params[grp], new_params[grp])
            assert max(jax.tree.leaves(d) or [0.0]) == 0.0, grp


def test_selection_period_masks_track_cohort_budgets(setup):
    """Regression (stale-mask bug): with selection_period > 1 and
    heterogeneous budgets, cached selections must be re-derived for the
    *current* cohort's clients and budgets — the old code reused mask rows
    computed for a different cohort, so a budget-1 client could be handed
    a budget-4 row."""
    model, params, data = setup
    fl = FLConfig(n_clients=12, cohort_size=4, rounds=5, local_steps=1,
                  lr=0.01, batch_size=8, strategy="ours",
                  budgets=tuple(1 + (i % 4) for i in range(12)),
                  selection_period=3, lam=1.0)
    server = FLServer(model, fl, data)
    _, hist = server.run(params)
    assert len(hist.records) == 5
    for rec in hist.records:
        budgets = np.array([fl.budget_of(int(i)) for i in rec.cohort])
        assert np.all(rec.mask_matrix.sum(1) <= budgets), \
            f"round {rec.round}: rows {rec.mask_matrix.sum(1)} vs {budgets}"


def test_history_empty_summary_and_to_json(setup):
    from repro.core.server import History
    empty = History()
    s = empty.summary()
    assert s["rounds"] == 0 and s["final_acc"] is None
    json.dumps(empty.to_json())          # serialisable even when empty

    model, params, data = setup
    fl = FLConfig(n_clients=12, cohort_size=3, rounds=2, local_steps=1,
                  lr=0.01, batch_size=8, strategy="top", budget=2)
    _, hist = FLServer(model, fl, data).run(params)
    j = json.loads(json.dumps(hist.to_json()))
    assert j["summary"]["rounds"] == 2
    assert len(j["records"]) == 2
    rec = j["records"][0]
    assert len(rec["mask_matrix"]) == 3          # cohort rows
    assert rec["uploaded_params"] > 0
    assert isinstance(rec["cohort"][0], int)


def test_select_masks_compat_draws_probe_batches_only(setup):
    """The public select_masks path probes exactly the given cohort and
    leaves every client's update stream untouched (the caller owns it)."""
    model, params, data = setup
    fl = FLConfig(n_clients=12, cohort_size=3, rounds=1, local_steps=1,
                  lr=0.01, batch_size=4, strategy="ours", budget=2, lam=1.0)
    server = FLServer(model, fl, data)
    cohort = np.array([2, 5, 9])

    def state(i):    # (key, pos): pos catches draws within one MT block
        s = data._rngs[i].get_state()
        return s[1].copy(), s[2]

    before = [state(i) for i in range(12)]
    masks = server.select_masks(params, cohort, 0)
    assert masks.shape == (3, model.n_selectable)
    assert np.all(masks.sum(1) <= 2)
    moved = [not (np.array_equal(before[i][0], state(i)[0])
                  and before[i][1] == state(i)[1]) for i in range(12)]
    assert moved == [i in (2, 5, 9) for i in range(12)]
