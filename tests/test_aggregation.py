"""Eq.(5)-(7) aggregation semantics on real parameter pytrees."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FLConfig, RuntimeConfig, get_arch, reduced
from repro.core import aggregation as agg
from repro.core.masks import aggregation_weights, count_layer_params
from repro.models.model import Model, apply_layer_mask


@pytest.fixture(scope="module")
def model_and_params():
    cfg = reduced(get_arch("tinyllama-1.1b"), n_layers=3, d_model=64)
    model = Model(cfg, RuntimeConfig(remat=False, seq_chunk=16))
    return model, model.init(jax.random.PRNGKey(0))


def test_masked_grad_zeroes_unselected(model_and_params):
    model, params = model_and_params
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                          model.cfg.vocab_size)}
    g = jax.grad(model.loss)(params, batch)
    mask = jnp.array([1.0, 0.0, 1.0])
    gm = apply_layer_mask(g, mask, model.cfg)
    # layer 1 zeroed, layers 0/2 intact
    for name, leaf in gm["blocks"].items():
        assert float(jnp.abs(leaf[1]).max()) == 0.0, name
        orig = g["blocks"][name]
        np.testing.assert_array_equal(np.asarray(leaf[0]), np.asarray(orig[0]))
    # frozen groups zeroed
    assert all(float(jnp.abs(x).max()) == 0.0
               for x in jax.tree.leaves(gm["embed"]))


def test_aggregate_weighted_mean(model_and_params):
    """Eq.(5): layer selected by clients {0,1} with d = (1, 3) → w = ¼, ¾."""
    model, params = model_and_params
    cfg = model.cfg
    ones = jax.tree.map(jnp.ones_like, params)
    twos = jax.tree.map(lambda x: 2 * jnp.ones_like(x), params)
    masks = jnp.array([[1, 1, 0], [1, 0, 0]], jnp.float32)
    sizes = jnp.array([1.0, 3.0])
    out = agg.aggregate([ones, twos], masks, sizes, cfg)
    b = out["blocks"]["attn_wq"]
    np.testing.assert_allclose(np.asarray(b[0]), 0.25 * 1 + 0.75 * 2)  # both
    np.testing.assert_allclose(np.asarray(b[1]), 1.0)                  # only c0
    np.testing.assert_allclose(np.asarray(b[2]), 0.0)                  # nobody


def test_weights_layer_selected_by_none():
    """Eq.(7) invariant: an unselected layer yields a zero column, never NaN."""
    masks = np.array([[1, 0, 1], [1, 0, 0]], np.float32)
    sizes = np.array([7.0, 13.0])
    W = np.asarray(aggregation_weights(masks, sizes))
    assert np.all(np.isfinite(W))
    np.testing.assert_array_equal(W[:, 1], 0.0)


def test_weights_single_selector_gets_full_weight():
    """Eq.(7) invariant: a layer selected by exactly one client gets w=1
    for that client, regardless of its relative dataset size."""
    masks = np.array([[0, 1], [1, 1], [0, 1]], np.float32)
    sizes = np.array([1.0, 99.0, 5.0])
    W = np.asarray(aggregation_weights(masks, sizes))
    np.testing.assert_allclose(W[:, 0], [0.0, 1.0, 0.0])


def test_weights_renormalize_over_selectors():
    """Eq.(7) invariant: over the selectors of each layer, weights are
    size-proportional and sum to 1."""
    rng = np.random.RandomState(0)
    masks = (rng.rand(5, 6) > 0.4).astype(np.float32)
    masks[0] = 1.0                                   # every layer selected
    sizes = rng.randint(1, 100, 5).astype(np.float32)
    W = np.asarray(aggregation_weights(masks, sizes))
    np.testing.assert_allclose(W.sum(0), 1.0, atol=1e-6)
    for l in range(6):
        sel = masks[:, l] > 0
        expect = sizes * masks[:, l] / (sizes * masks[:, l]).sum()
        np.testing.assert_allclose(W[sel, l], expect[sel], atol=1e-6)


def test_aggregate_stacked_matches_sequential(model_and_params):
    """The vectorized einsum path (Eq. 5 over a stacked pytree) equals the
    per-client scale-and-add oracle."""
    model, params = model_and_params
    cfg = model.cfg
    rng = np.random.RandomState(0)
    n = 3
    deltas = [jax.tree.map(
        lambda x: jnp.asarray(rng.randn(*x.shape), jnp.float32), params)
        for _ in range(n)]
    masks = jnp.asarray(np.array([[1, 1, 0], [1, 0, 1], [0, 0, 1]], np.float32))
    sizes = jnp.asarray([4.0, 12.0, 9.0])
    seq = agg.aggregate(deltas, masks, sizes, cfg)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *deltas)
    W = aggregation_weights(masks, sizes)
    vec = agg.aggregate_stacked(stacked, W, cfg)
    err = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), seq, vec)))
    assert err < 1e-5


def test_apply_update_direction(model_and_params):
    model, params = model_and_params
    upd = jax.tree.map(jnp.ones_like, params)
    new = agg.apply_update(params, upd, lr=0.5)
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs((a - b) + 0.5))),
                     new, params)
    assert max(jax.tree.leaves(d)) < 1e-6


def test_count_layer_params(model_and_params):
    model, params = model_and_params
    counts = count_layer_params(params, model.cfg)
    assert counts.shape == (3,)
    assert np.all(counts == counts[0])     # identical stacked layers
    per_block = sum(int(np.prod(x.shape[1:]))
                    for x in jax.tree.leaves(params["blocks"]))
    assert counts[0] == per_block
