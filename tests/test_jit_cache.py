"""Shared jit suite cache: repeated FLServer/Client construction for the
same (ArchConfig, RuntimeConfig) must reuse compiled programs — zero
recompilation across benchmark sweeps and multi-server runs."""
import jax
import numpy as np
import pytest

from repro.configs.base import FLConfig, RuntimeConfig, get_arch, reduced
from repro.core import client as client_mod
from repro.core.client import Client
from repro.core.server import FLServer
from repro.data.synthetic import FederatedTaskConfig, SyntheticFederatedData
from repro.models.model import Model


def _world():
    cfg = reduced(get_arch("xlm_roberta_base"), n_layers=2, d_model=32)
    model = Model(cfg, RuntimeConfig(remat=False, seq_chunk=16))
    task = FederatedTaskConfig(n_clients=8, n_classes=10,
                               vocab_size=cfg.vocab_size, seq_len=8,
                               samples_per_client=16, skew="label",
                               objective="classification")
    fl = FLConfig(n_clients=8, cohort_size=3, rounds=2, local_steps=2,
                  lr=0.01, batch_size=4, strategy="ours", budget=1, lam=1.0,
                  seed=0)
    return model, model.init(jax.random.PRNGKey(0)), task, fl


def test_repeated_server_construction_zero_recompilation():
    model, params, task, fl = _world()
    client_mod.clear_jit_cache()

    s1 = FLServer(model, fl, SyntheticFederatedData(task))
    _, h1 = s1.run(params)
    stats = client_mod.jit_cache_stats()
    assert stats["misses"] == 1 and stats["entries"] == 1
    hot = {name: getattr(s1.client, f"_{name}")
           for name in ("cohort_update", "probe_cohort",
                        "probe_update_cohort", "eval")}
    sizes = {name: fn._cache_size() for name, fn in hot.items()}

    # same model object, and a *fresh* Model with an equal config: both hit
    s2 = FLServer(model, fl, SyntheticFederatedData(task))
    model2 = Model(model.cfg, model.runtime)
    s3 = FLServer(model2, fl, SyntheticFederatedData(task))
    stats = client_mod.jit_cache_stats()
    assert stats["hits"] >= 2 and stats["misses"] == 1

    for name, fn in hot.items():
        assert getattr(s2.client, f"_{name}") is fn
        assert getattr(s3.client, f"_{name}") is fn

    _, h2 = s2.run(params)
    _, h3 = s3.run(params)
    for name, fn in hot.items():
        assert fn._cache_size() == sizes[name], \
            f"{name} recompiled on repeated server construction"
    # identical configuration => identical runs through the shared programs
    assert h1.summary() == h2.summary() == h3.summary()


def test_masked_engine_compile_stability():
    """Mask-aware programs are keyed on the static prefix cut: with a fixed
    budget pattern across rounds, the engine compiles at most one variant
    per *distinct* cut seen, and repeated rounds / repeated servers with
    the same configuration add zero recompiles (jit_cache_stats()).
    """
    from repro.core.masks import first_trainable_layer

    model, params, task, fl = _world()
    # fixed heterogeneous budget pattern; 'top' selects the highest R_i
    # layers, so the round cut is L − max(cohort budgets) — at most two
    # distinct cuts ever occur with this pattern
    from dataclasses import replace
    fl = replace(fl, strategy="top", budgets=(1, 2), budget=1, rounds=6)
    client_mod.clear_jit_cache()

    server = FLServer(model, fl, SyntheticFederatedData(task))
    assert server.mask_aware
    _, hist = server.run(params)
    cuts = {first_trainable_layer(r.mask_matrix) for r in hist.records}
    stats = client_mod.jit_cache_stats()
    masked = {k: v for k, v in stats["programs"].items() if "masked" in k}
    assert sum(masked.values()) >= 1, "mask-aware engine never dispatched"
    for name, count in masked.items():
        assert count <= len(cuts), \
            f"{name}: {count} program variants for {len(cuts)} distinct cuts"

    # zero per-round recompiles: more rounds and a fresh server over the
    # same (ArchConfig, RuntimeConfig) reuse every compiled variant
    server.run(params)
    _, hist2 = FLServer(model, fl, SyntheticFederatedData(task)).run(params)
    cuts2 = cuts | {first_trainable_layer(r.mask_matrix)
                    for r in hist2.records}
    assert cuts2 == cuts
    after = client_mod.jit_cache_stats()["programs"]
    for name, count in masked.items():
        assert after[name] == count, f"{name} recompiled on repeated rounds"


def test_custom_shard_models_bypass_cache():
    model, _, _, _ = _world()
    client_mod.clear_jit_cache()
    Client(model)
    sharded = Model(model.cfg, model.runtime, shard=lambda x, kind=None: x)
    Client(sharded)
    stats = client_mod.jit_cache_stats()
    assert stats["misses"] == 1 and stats["uncached"] == 1
