"""Federation API: registry, Task protocol, Experiment builder, mixtures.

Covers the pluggable-API acceptance pins:

* the back-compat shims — ``FLServer(strategy="name")`` and
  ``strategies.select(name, ...)`` — produce bit-identical masks to the
  registry/Experiment path;
* requirements-trimmed probes carry only the stats the strategy declared;
* ``ProbeReport.from_rows`` handles trimmed rows (regression: np.stack
  over None);
* unknown strategies list registered names + a nearest-match suggestion on
  both the registry and the FLServer shim path;
* per-client heterogeneous mixtures match running each member strategy on
  its own rows, with heterogeneous budgets and selection_period > 1;
* the Dirichlet token-mixture Task and its availability/straggler hooks.
"""
import jax
import numpy as np
import pytest

from repro.api import (Experiment, MixtureStrategy, ProbeReport,
                       ScoreStrategy, SelectionContext, Strategy,
                       UnknownStrategyError, get_strategy, register_strategy,
                       strategy_names)
from repro.api.task import (DirichletTaskConfig, DirichletTokenMixtureTask,
                            Task)
from repro.configs.base import FLConfig, RuntimeConfig, get_arch, reduced
from repro.core.client import Client
from repro.core.server import FLServer
from repro.core.strategies import ALL_STRATEGIES, select
from repro.data.synthetic import FederatedTaskConfig, SyntheticFederatedData
from repro.models.model import Model


@pytest.fixture(scope="module")
def world():
    cfg = reduced(get_arch("xlm_roberta_base"), n_layers=4, d_model=32)
    model = Model(cfg, RuntimeConfig(remat=False, seq_chunk=16))
    params = model.init(jax.random.PRNGKey(0))
    task = FederatedTaskConfig(
        n_clients=12, n_classes=10, vocab_size=cfg.vocab_size, seq_len=8,
        samples_per_client=16, skew="label", objective="classification")
    return model, params, task


def _probe(n=4, L=6, seed=0):
    rng = np.random.RandomState(seed)
    return ProbeReport(
        grad_sq_norms=np.abs(rng.randn(n, L)).astype(np.float32),
        param_sq_norms=np.abs(rng.randn(n, L)).astype(np.float32) + 1.0,
        grad_means=rng.randn(n, L).astype(np.float32),
        grad_vars=np.abs(rng.randn(n, L)).astype(np.float32) + 0.1)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

def test_registry_covers_all_legacy_strategies():
    names = strategy_names()
    for s in ALL_STRATEGIES + ("ours_unified", "unified"):
        assert s in names, s


def test_unknown_strategy_lists_names_and_suggests():
    with pytest.raises(UnknownStrategyError) as ei:
        get_strategy("rng")
    msg = str(ei.value)
    assert "did you mean 'rgn'?" in msg
    for name in ("ours", "top", "snr"):
        assert name in msg
    # back-compat: callers catching either built-in type keep working
    assert isinstance(ei.value, KeyError) and isinstance(ei.value, ValueError)


def test_unknown_strategy_via_select_shim():
    with pytest.raises(UnknownStrategyError, match="did you mean"):
        select("borrom", _probe(), 2)


def test_unknown_strategy_via_flserver_shim(world):
    model, params, task = world
    fl = FLConfig(n_clients=12, cohort_size=4, rounds=1, strategy="oours")
    with pytest.raises(UnknownStrategyError, match="did you mean 'ours'?"):
        FLServer(model, fl, SyntheticFederatedData(task))


def test_unknown_probe_requirements_fail_fast(world):
    """A custom strategy with a misspelled requirement must error at server
    construction, not silently probe nothing and select on zeros."""
    model, params, task = world

    class _Typo(Strategy):
        name = "typo_reqs"
        probe_requirements = frozenset({"grad_norms"})    # not a probe key

        def select(self, probe, budgets, ctx):            # pragma: no cover
            return np.zeros((probe.n, probe.L), np.float32)

    fl = FLConfig(n_clients=12, cohort_size=4, rounds=1)
    with pytest.raises(ValueError, match="unknown probe_requirements"):
        FLServer(model, fl, SyntheticFederatedData(task), strategy=_Typo())


def test_register_and_resolve_custom_strategy():
    @register_strategy("test_only_last")
    class _Last(Strategy):
        def select(self, probe, budgets, ctx):
            masks = np.zeros((probe.n, probe.L), np.float32)
            masks[:, -1] = 1.0
            return masks

    strat = get_strategy("test_only_last")
    masks = strat.select(_probe(), 1, SelectionContext(np.arange(4)))
    np.testing.assert_array_equal(masks[:, -1], np.ones(4))
    assert masks.sum() == 4


# ---------------------------------------------------------------------------
# ProbeReport trimming (satellite: from_rows over absent stats)
# ---------------------------------------------------------------------------

def test_from_rows_with_absent_optional_stats():
    # regression: np.stack over None crashed when optional stats were
    # absent; trimmed rows carry only what the strategy requested
    rows = [{"grad_sq_norms": np.ones(5), "param_sq_norms": None}
            for _ in range(3)]
    p = ProbeReport.from_rows(rows)
    assert p.n == 3 and p.L == 5
    assert p.param_sq_norms is None and p.grad_means is None
    # rows missing the key entirely behave the same
    p2 = ProbeReport.from_rows([{"grad_means": np.zeros(4),
                                 "grad_vars": np.ones(4)}] * 2)
    assert p2.n == 2 and p2.L == 4 and p2.grad_sq_norms is None


def test_from_rows_mixed_rows_keep_common_keys_only():
    rows = [{"grad_sq_norms": np.ones(4), "grad_means": np.zeros(4)},
            {"grad_sq_norms": np.ones(4)}]
    p = ProbeReport.from_rows(rows)
    assert p.grad_sq_norms.shape == (2, 4)
    assert p.grad_means is None


def test_empty_probe_report_raises():
    with pytest.raises(ValueError, match="empty ProbeReport"):
        ProbeReport().n


def test_probe_requirements_trim_client_stats(world):
    model, params, task = world
    data = SyntheticFederatedData(task)
    client = Client(model)
    batches = data.cohort_batches(np.arange(3), 4, 2)
    out = client.probe_cohort(params, batches, ("grad_sq_norms",))
    assert set(out) == {"grad_sq_norms"}
    out = client.probe_cohort(params, batches, ("grad_means", "grad_vars"))
    assert set(out) == {"grad_means", "grad_vars"}
    # all-stats default unchanged
    out = client.probe_cohort(params, batches)
    assert set(out) == set(ProbeReport.KEYS)
    # fused device scoring adds the scores row
    snr = get_strategy("snr")
    out = client.probe_cohort(params, batches, ("grad_means", "grad_vars"),
                              snr.device_score_fn())
    assert set(out) == {"grad_means", "grad_vars", "scores"}
    assert out["scores"].shape == (3, model.n_selectable)


def test_trimmed_probe_matches_all_stats_probe(world):
    model, params, task = world
    data = SyntheticFederatedData(task)
    client = Client(model)
    batches = data.cohort_batches(np.arange(3), 4, 2)
    full = client.probe_cohort(params, batches)
    trimmed = client.probe_cohort(params, batches, ("grad_sq_norms",))
    np.testing.assert_allclose(trimmed["grad_sq_norms"],
                               full["grad_sq_norms"], rtol=1e-6)


# ---------------------------------------------------------------------------
# Experiment ≡ FLServer string shim (acceptance pin)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("strategy", ["ours", "rgn", "snr", "top"])
def test_experiment_matches_flserver_shim(world, strategy):
    """Old FLServer(strategy=str) and the Experiment/registry path produce
    bit-identical masks and cohorts per round."""
    model, params, task = world
    fl = FLConfig(n_clients=12, cohort_size=4, rounds=3, local_steps=1,
                  lr=0.01, batch_size=4, strategy=strategy, budget=2,
                  lam=1.0, seed=3)
    _, h_old = FLServer(model, fl, SyntheticFederatedData(task)).run(params)
    exp = Experiment(model, SyntheticFederatedData(task), strategy, fl=fl)
    _, h_new = exp.run(params)
    assert len(h_old.records) == len(h_new.records) == 3
    for ro, rn in zip(h_old.records, h_new.records):
        np.testing.assert_array_equal(ro.cohort, rn.cohort)
        np.testing.assert_array_equal(ro.mask_matrix, rn.mask_matrix)
        assert ro.test_loss == pytest.approx(rn.test_loss, abs=1e-6)


def test_experiment_sequential_engine_and_strategy_instance(world):
    model, params, task = world
    exp_v = Experiment(model, SyntheticFederatedData(task),
                       get_strategy("rgn"), rounds=2, cohort_size=4,
                       local_steps=1, batch_size=4, budget=2, seed=7)
    exp_s = Experiment(model, SyntheticFederatedData(task), "rgn",
                       engine="sequential", rounds=2, cohort_size=4,
                       local_steps=1, batch_size=4, budget=2, seed=7)
    _, h_v = exp_v.run(params)
    _, h_s = exp_s.run(params)
    for rv, rs in zip(h_v.records, h_s.records):
        np.testing.assert_array_equal(rv.cohort, rs.cohort)
        np.testing.assert_array_equal(rv.mask_matrix, rs.mask_matrix)


# ---------------------------------------------------------------------------
# Mixture strategies (satellite: per-client heterogeneous strategies)
# ---------------------------------------------------------------------------

def test_mixture_matches_member_strategies_on_own_rows():
    probe = _probe(n=6, L=8, seed=2)
    ids = np.array([3, 7, 11, 2, 9, 5])
    budgets = np.array([1, 2, 3, 1, 4, 2])       # heterogeneous budgets
    assign = {3: "rgn", 7: "snr", 2: "rgn", 9: "top", 5: "ours"}
    mix = MixtureStrategy(assign, default="ours")
    ctx = SelectionContext(client_ids=ids, lam=1.0, n_layers=8)
    masks = mix.select(probe, budgets, ctx)
    # each member strategy's rows must equal running that strategy on its
    # own client rows (joint solvers like 'ours' couple clients *within*
    # their group via λ, so the comparison is per group, not per row)
    owners = {int(i): assign.get(int(i), "ours") for i in ids}
    for name in set(owners.values()):
        rows = np.array([r for r, i in enumerate(ids)
                         if owners[int(i)] == name])
        sub_ctx = SelectionContext(client_ids=ids[rows], lam=1.0, n_layers=8)
        expect = get_strategy(name).select(probe.take(rows), budgets[rows],
                                           sub_ctx)
        np.testing.assert_array_equal(masks[rows], expect,
                                      err_msg=f"group {name}")


def test_mixture_requirements_are_union():
    mix = MixtureStrategy({0: "snr", 1: "rgn"}, default="top")
    assert mix.probe_requirements == frozenset(
        {"grad_means", "grad_vars", "grad_sq_norms", "param_sq_norms"})
    assert not mix.host
    mix2 = MixtureStrategy({0: "ours"}, default="ours")
    assert mix2.probe_requirements == frozenset({"grad_sq_norms"})
    assert mix2.host


def test_mixture_callable_assignment_requires_members():
    with pytest.raises(ValueError, match="members"):
        MixtureStrategy(lambda i: "rgn")
    mix = MixtureStrategy(lambda i: "rgn" if i % 2 else "snr",
                          members=["rgn", "snr"], default="snr")
    assert mix.strategy_of(1).name == "rgn"
    assert mix.strategy_of(2).name == "snr"


@pytest.mark.parametrize("period", [1, 2])
def test_mixture_end_to_end_matches_uniform_run(world, period):
    """A mixture assigning every client the same strategy must reproduce
    the plain run bit-for-bit — including heterogeneous budgets and
    selection_period > 1 (cache + on-demand probe path)."""
    model, params, task = world
    fl = FLConfig(n_clients=12, cohort_size=4, rounds=4, local_steps=1,
                  lr=0.01, batch_size=4, strategy="rgn",
                  budgets=(1, 2, 3, 4), selection_period=period, lam=1.0,
                  seed=5)
    _, h_plain = FLServer(model, fl, SyntheticFederatedData(task)).run(params)
    mix = MixtureStrategy({i: "rgn" for i in range(12)}, default="rgn")
    _, h_mix = FLServer(model, fl, SyntheticFederatedData(task),
                        strategy=mix).run(params)
    for rp, rm in zip(h_plain.records, h_mix.records):
        np.testing.assert_array_equal(rp.cohort, rm.cohort)
        np.testing.assert_array_equal(rp.mask_matrix, rm.mask_matrix)


def test_mixture_heterogeneous_end_to_end_budgets_respected(world):
    model, params, task = world
    mix = MixtureStrategy({i: ("rgn" if i < 6 else "top")
                           for i in range(12)}, default="ours")
    fl = FLConfig(n_clients=12, cohort_size=5, rounds=3, local_steps=1,
                  lr=0.01, batch_size=4, budgets=(1, 2, 3), lam=1.0,
                  selection_period=2, seed=9)
    exp = Experiment(model, SyntheticFederatedData(task), mix, fl=fl)
    _, hist = exp.run(params)
    assert len(hist.records) == 3
    for rec in hist.records:
        budgets = np.array([fl.budget_of(int(i)) for i in rec.cohort])
        assert np.all(rec.mask_matrix.sum(1) <= budgets)
        # positional members must have produced positional rows
        for r, i in enumerate(rec.cohort):
            if int(i) >= 6:      # "top" clients: suffix mask
                R = budgets[r]
                np.testing.assert_array_equal(
                    rec.mask_matrix[r, -R:], np.ones(R))


# ---------------------------------------------------------------------------
# Task protocol: Dirichlet token-mixture + plan-stage hooks
# ---------------------------------------------------------------------------

def test_dirichlet_task_implements_protocol():
    task = DirichletTokenMixtureTask(DirichletTaskConfig(n_clients=6))
    assert isinstance(task, Task)
    assert isinstance(SyntheticFederatedData(FederatedTaskConfig(
        n_clients=4)), Task)


def test_dirichlet_task_shapes_and_determinism():
    cfg = DirichletTaskConfig(n_clients=6, vocab_size=64, seq_len=8,
                              test_samples=32, seed=1)
    t1 = DirichletTokenMixtureTask(cfg)
    t2 = DirichletTokenMixtureTask(cfg)
    b1 = t1.cohort_batches(np.array([0, 3]), 4, 2)
    b2 = t2.cohort_batches(np.array([0, 3]), 4, 2)
    assert b1["tokens"].shape == (2, 2, 4, 8)
    assert b1["tokens"].max() < 64
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    np.testing.assert_array_equal(b1["label"], b2["label"])
    np.testing.assert_array_equal(t1.test_batch(16)["tokens"],
                                  t2.test_batch(16)["tokens"])


def test_experiment_on_dirichlet_task_with_hooks(world):
    model, params, _ = world
    cfg = DirichletTaskConfig(n_clients=12,
                              vocab_size=model.cfg.vocab_size, seq_len=8,
                              test_samples=32, availability=0.5,
                              straggler_rate=0.4, seed=2)
    task = DirichletTokenMixtureTask(cfg)
    exp = Experiment(model, task, "ours", rounds=4, cohort_size=4,
                     local_steps=1, batch_size=4, budget=1, lam=1.0, seed=0)
    _, hist = exp.run(params)
    assert len(hist.records) == 4
    for rec in hist.records:
        # availability: the cohort is drawn from the round's rotating pool
        pool = set(task.available_pool(rec.round).tolist())
        assert set(np.asarray(rec.cohort).tolist()) <= pool
        # stragglers may shrink the cohort but never empty it
        assert 1 <= len(rec.cohort) <= 4
        assert np.all(rec.mask_matrix.sum(1) <= 1)
    # with a 40% drop rate, 4 rounds of 4 draws should lose someone
    assert any(len(r.cohort) < 4 for r in hist.records)


def test_hookless_task_cohort_stream_unchanged(world):
    """Tasks without hooks must leave the server rng stream untouched —
    the same seed draws the same cohorts as a pre-API server."""
    model, params, task = world
    fl = FLConfig(n_clients=12, cohort_size=4, rounds=3, strategy="top",
                  budget=1, seed=42)
    _, hist = FLServer(model, fl, SyntheticFederatedData(task)).run(params)
    rng = np.random.RandomState(42)
    for rec in hist.records:
        np.testing.assert_array_equal(
            rec.cohort, rng.choice(12, size=4, replace=False))
