"""Vectorized sampler vs the scalar parity oracle — bit-identical draws.

The vectorized path (`_sample_vec` / `_sample_mixture_vec`) must consume the
per-client rng streams in exactly the same order as the scalar per-sample
oracle (`_sample` / `_sample_mixture`) and produce bit-identical batches —
the foundation of the engine-parity guarantee after the streaming-pipeline
refactor.
"""
import numpy as np
import pytest

from repro.data.synthetic import FederatedTaskConfig, SyntheticFederatedData


def _pair(seed=1234):
    return np.random.RandomState(seed), np.random.RandomState(seed)


CASES = {
    "tokens-label": dict(skew="label", modality="tokens"),
    "tokens-feature": dict(skew="feature", modality="tokens"),
    "tokens-lm": dict(skew="label", modality="tokens", objective="lm"),
    "patches-label": dict(skew="label", modality="patches"),
    "patches-feature": dict(skew="feature", modality="patches"),
}


@pytest.mark.parametrize("case", sorted(CASES))
def test_vectorized_matches_scalar_oracle(case):
    data = SyntheticFederatedData(FederatedTaskConfig(
        n_clients=6, n_classes=5, vocab_size=97, seq_len=16, seed=3,
        **CASES[case]))
    for i in (0, 4):
        r_vec, r_ora = _pair(100 + i)
        label_p = data.client_label_p[i]
        dom = int(data.client_domain[i])
        a = data._sample_vec(r_vec, label_p, dom, 33)
        b = data._sample(r_ora, label_p, dom, 33)
        assert a.keys() == b.keys()
        for k in a:
            np.testing.assert_array_equal(a[k], b[k], err_msg=f"{case}:{k}")
        # the streams advanced identically too
        assert r_vec.randint(1 << 30) == r_ora.randint(1 << 30)


@pytest.mark.parametrize("case", ["tokens-feature", "patches-feature"])
def test_mixture_vectorized_matches_scalar_oracle(case):
    data = SyntheticFederatedData(FederatedTaskConfig(
        n_clients=8, n_classes=5, vocab_size=97, seq_len=16, seed=5,
        **CASES[case]))
    r_vec, r_ora = _pair(7)
    owners = r_vec.choice(8, size=40, p=data.alpha)
    owners2 = r_ora.choice(8, size=40, p=data.alpha)
    np.testing.assert_array_equal(owners, owners2)
    a = data._sample_mixture_vec(r_vec, owners)
    b = data._sample_mixture(r_ora, owners)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k], err_msg=f"{case}:{k}")
    assert r_vec.randint(1 << 30) == r_ora.randint(1 << 30)


def test_client_batches_is_one_vectorized_draw():
    """client_batches == one (n·B)-sample draw of the same client stream."""
    task = FederatedTaskConfig(n_clients=4, seq_len=8, seed=9)
    d1 = SyntheticFederatedData(task)
    d2 = SyntheticFederatedData(task)
    stacked = d1.client_batches(2, 4, 3)
    flat = d2._sample_vec(d2._rngs[2], d2.client_label_p[2],
                          int(d2.client_domain[2]), 12)
    for k in stacked:
        np.testing.assert_array_equal(
            stacked[k].reshape(flat[k].shape), flat[k])


def test_test_set_fixed_and_stream_pure():
    """test_batch() is deterministic and never mutates the pretrain/legacy
    test rng stream (the held-out set has its own dedicated stream)."""
    data = SyntheticFederatedData(FederatedTaskConfig(n_clients=5, seed=11))
    s0 = data._test_rng.get_state()
    a = data.test_batch()
    b = data.test_batch()
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])
    s1 = data._test_rng.get_state()
    np.testing.assert_array_equal(s0[1], s1[1])
    assert s0[2] == s1[2]        # pos: catches draws within one MT block
    small = data.test_batch(10)
    assert small["tokens"].shape[0] == 10
    np.testing.assert_array_equal(small["tokens"], a["tokens"][:10])
    with pytest.raises(ValueError):
        data.test_batch(data.cfg.test_samples + 1)


def test_same_seed_same_test_set():
    task = FederatedTaskConfig(n_clients=5, seed=13)
    a = SyntheticFederatedData(task).test_batch()
    b = SyntheticFederatedData(task).test_batch()
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])


def test_legacy_sampling_path_shapes_and_rng_mutation():
    """The pre-pipeline baseline still works (full_round benchmark)."""
    data = SyntheticFederatedData(FederatedTaskConfig(
        n_clients=4, seq_len=8, seed=17, test_samples=12))
    data.legacy_sampling = True
    b = data.client_batch(1, 6)
    assert b["tokens"].shape == (6, 8)
    stacked = data.client_batches(0, 4, 2)
    assert stacked["tokens"].shape == (2, 4, 8)
    state0 = data._test_rng.get_state()[1].copy()
    t = data.test_batch(12)
    assert t["tokens"].shape == (12, 8)
    assert not np.array_equal(state0, data._test_rng.get_state()[1])
