"""Fault-injection harness + graceful degradation (DESIGN.md §12).

The degradation parity matrix the fault PR promises:

(a) injector wired but disabled ⇒ bit-identical masks/params/History to
    the injector-free path, both engines, pipeline depths 1 and 4;
(b) same fault seed ⇒ identical History and select_stats across runs
    (fault schedules are replayable);
(c) mid-round client death ⇒ the survivor-reweighted vectorized program
    matches the sequential oracle run over the survivors only;
(d) corrupted latest checkpoint ⇒ auto-resume from the previous intact
    step completes the run.

Plus the degradation policies themselves: all-quarantined rounds leave
params bit-exact and surface as ``nonfinite_rounds``, solver stalls fall
back to warm/greedy masks, dispatch failures retry boundedly, checkpoint
damage of every kind is detected, plan-stage chaos (empty pools,
all-straggler rounds) degrades per contract, and the serve loop drops
instead of livelocking.
"""
import math
import os
import warnings

import jax
import numpy as np
import pytest

from repro.api import ChaosTask, Experiment
from repro.api.task import DirichletTaskConfig, DirichletTokenMixtureTask
from repro.configs.base import FLConfig, RuntimeConfig, get_arch, reduced
from repro.core import client as client_mod
from repro.core.server import FLServer, History, RoundRecord
from repro.data.synthetic import FederatedTaskConfig, SyntheticFederatedData
from repro.faults import CORRUPT_CODES, FaultInjector, FaultPlan, TransientFault
from repro.faults.injector import CKPT_CORRUPT_KINDS
from repro.models.model import Model


@pytest.fixture(scope="module")
def world():
    cfg = reduced(get_arch("xlm_roberta_base"), n_layers=2, d_model=32)
    model = Model(cfg, RuntimeConfig(remat=False, seq_chunk=16))
    params = model.init(jax.random.PRNGKey(0))
    task = FederatedTaskConfig(n_clients=8, n_classes=10,
                               vocab_size=cfg.vocab_size, seq_len=8,
                               samples_per_client=16, skew="label",
                               objective="classification")
    return model, params, task


def _fl(**kw):
    base = dict(n_clients=8, cohort_size=3, rounds=4, local_steps=2,
                lr=0.01, batch_size=4, strategy="ours", budget=1, lam=1.0,
                seed=0)
    base.update(kw)
    return FLConfig(**base)


CHAOS = dict(seed=5, death_rate=0.4, corrupt_rate=0.4,
             corrupt_kinds=("nan", "inf"))


def _records_equal(h_a, h_b, atol=1e-5, bitwise=False):
    """NaN-aware record comparison (wall_s excluded — host telemetry)."""
    assert len(h_a.records) == len(h_b.records)
    for ra, rb in zip(h_a.records, h_b.records):
        np.testing.assert_array_equal(ra.cohort, rb.cohort)
        np.testing.assert_array_equal(ra.mask_matrix, rb.mask_matrix)
        assert ra.uploaded_params == rb.uploaded_params
        for fld in ("train_loss", "test_loss", "test_acc"):
            va, vb = getattr(ra, fld), getattr(rb, fld)
            if math.isnan(va) or math.isnan(vb):
                assert math.isnan(va) and math.isnan(vb), (fld, va, vb)
            elif bitwise:
                assert va == vb, (fld, va, vb)
            else:
                assert va == pytest.approx(vb, abs=atol), (fld, va, vb)


def _params_equal(p_a, p_b):
    for a, b in zip(jax.tree.leaves(p_a), jax.tree.leaves(p_b)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _params_close(p_a, p_b, atol=1e-5):
    err = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(np.abs(np.asarray(a, np.float32)
                                  - np.asarray(b, np.float32)).max()),
        p_a, p_b)))
    assert err < atol, f"param divergence {err}"


# ---------------------------------------------------------------------------
# (a) wired-but-disabled is contractually free
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine,depth", [("vectorized", 1),
                                          ("vectorized", 4),
                                          ("sequential", 1)])
def test_disabled_injector_bit_identical(world, engine, depth):
    model, params, task = world
    disabled = FaultPlan(enabled=False, **CHAOS)
    p_none, h_none = FLServer(
        model, _fl(), SyntheticFederatedData(task), engine=engine,
        pipeline_depth=depth).run(params)
    p_off, h_off = FLServer(
        model, _fl(), SyntheticFederatedData(task), engine=engine,
        pipeline_depth=depth, faults=disabled).run(params)
    _records_equal(h_none, h_off, bitwise=True)
    _params_equal(p_none, p_off)


def test_disabled_injector_draws_nothing():
    inj = FaultInjector(FaultPlan(enabled=False, death_rate=1.0,
                                  corrupt_rate=1.0, stall_rate=1.0,
                                  dispatch_fail_rate=1.0))
    survivors, codes = inj.round_faults(0, 5)
    np.testing.assert_array_equal(survivors, np.ones(5, np.float32))
    np.testing.assert_array_equal(codes, np.zeros(5, np.int32))
    assert not inj.solver_stalls(0)
    assert inj.dispatch_failures(0) == 0
    inj.maybe_fail_dispatch(0, 0)        # must not raise
    assert all(v == 0 for v in inj.stats.values())


# ---------------------------------------------------------------------------
# (b) same fault seed ⇒ identical replay
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine,depth", [("vectorized", 1),
                                          ("vectorized", 4),
                                          ("sequential", 1)])
def test_fault_schedule_replays_deterministically(world, engine, depth):
    model, params, task = world
    runs = []
    for _ in range(2):
        srv = FLServer(model, _fl(), SyntheticFederatedData(task),
                       engine=engine, pipeline_depth=depth,
                       faults=FaultPlan(**CHAOS))
        p, h = srv.run(params)
        runs.append((p, h, dict(srv.select_stats),
                     dict(srv._injector.stats)))
    _records_equal(runs[0][1], runs[1][1], bitwise=True)
    _params_equal(runs[0][0], runs[1][0])
    assert runs[0][2] == runs[1][2]
    assert runs[0][3] == runs[1][3]
    assert runs[0][2]["dead_clients"] > 0        # chaos actually happened


def test_fault_draws_independent_of_call_order():
    """Per-(site, round) rng lanes: drawing round 3 before round 0, or
    skipping sites entirely, never changes what a round sees."""
    a, b = (FaultInjector(FaultPlan(seed=9, death_rate=0.5,
                                    corrupt_rate=0.5)) for _ in range(2))
    fwd = [a.round_faults(t, 6) for t in range(4)]
    a_stalls = [a.solver_stalls(t) for t in range(4)]
    rev = [b.round_faults(t, 6) for t in reversed(range(4))][::-1]
    for (s1, c1), (s2, c2) in zip(fwd, rev):
        np.testing.assert_array_equal(s1, s2)
        np.testing.assert_array_equal(c1, c2)
    assert a_stalls == [b.solver_stalls(t) for t in range(4)]


# ---------------------------------------------------------------------------
# (c) survivor-reweighted aggregation matches the survivors-only oracle
# ---------------------------------------------------------------------------

def test_guarded_engines_agree_under_faults(world):
    model, params, task = world
    outs = []
    for engine in ("vectorized", "sequential"):
        srv = FLServer(model, _fl(), SyntheticFederatedData(task),
                       engine=engine, faults=FaultPlan(**CHAOS))
        outs.append(srv.run(params))
    _records_equal(outs[0][1], outs[1][1], atol=2e-4)
    _params_close(outs[0][0], outs[1][0], atol=2e-4)


def test_client_death_matches_survivor_subset_oracle(world):
    """Death only (no corruption): the guarded program's params must equal
    the plain dense round run over exactly the surviving rows."""
    model, params, task = world
    srv = FLServer(model, _fl(), SyntheticFederatedData(task))
    plan = srv.plan_round(0)
    sampled = srv.sample_round(plan)
    stats = srv.probe_round(params, sampled)
    masks = srv.select_round(plan, stats)
    n = len(plan.cohort)
    survivors = np.ones(n, np.float32)
    survivors[0] = 0.0                       # kill the first cohort member
    codes = np.zeros(n, np.int32)

    p_guard, _, ok = srv.client.cohort_update_guarded(
        params, sampled.update_batches, masks, plan.sizes, srv.fl.lr,
        survivors, codes, 1e30, math.inf)
    np.testing.assert_array_equal(ok, survivors)

    idx = np.flatnonzero(survivors > 0)
    sub_batches = jax.tree.map(lambda x: np.asarray(x)[idx],
                               sampled.update_batches)
    p_ref, _ = srv.client.cohort_update(
        params, sub_batches, masks[idx], plan.sizes[idx], srv.fl.lr)
    _params_close(p_guard, p_ref, atol=1e-5)


def test_all_quarantined_round_leaves_params_bitexact(world):
    """Everyone reports NaN: zero rows aggregate, θ − η·0 = θ exactly, and
    the round's losses surface as NaN instead of a fake finite value."""
    model, params, task = world
    srv = FLServer(model, _fl(), SyntheticFederatedData(task),
                   faults=FaultPlan(seed=1, corrupt_rate=1.0,
                                    corrupt_kinds=("nan",)))
    plan = srv.plan_round(0)
    sampled = srv.sample_round(plan)
    masks = srv.select_round(plan, srv.probe_round(params, sampled))
    new_params, losses = srv.update_round(params, sampled, masks)
    _params_equal(new_params, params)
    assert np.isnan(losses).all()
    assert srv.select_stats["quarantined_rows"] == len(plan.cohort)


def test_norm_threshold_quarantines_exploding_rows(world):
    model, params, task = world
    srv = FLServer(model, _fl(), SyntheticFederatedData(task),
                   faults=FaultPlan(seed=2, corrupt_rate=1.0,
                                    corrupt_kinds=("explode",),
                                    explode_scale=1e6, max_delta_sq=1.0))
    plan = srv.plan_round(0)
    sampled = srv.sample_round(plan)
    masks = srv.select_round(plan, srv.probe_round(params, sampled))
    new_params, _ = srv.update_round(params, sampled, masks)
    _params_equal(new_params, params)    # every row over threshold


# ---------------------------------------------------------------------------
# solver stalls + dispatch failures
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("depth", [1, 4])
def test_solver_stall_falls_back_and_completes(world, depth):
    model, params, task = world
    srv = FLServer(model, _fl(), SyntheticFederatedData(task),
                   pipeline_depth=depth,
                   faults=FaultPlan(seed=3, stall_rate=1.0))
    _, hist = srv.run(params)
    assert len(hist.records) == srv.fl.rounds
    assert srv.select_stats["solver_timeouts"] == srv.fl.rounds
    assert srv._injector.stats["stalls"] == srv.fl.rounds


def test_dispatch_retry_recovers(world):
    model, params, task = world
    srv = FLServer(model, _fl(), SyntheticFederatedData(task),
                   faults=FaultPlan(seed=4, dispatch_fail_rate=1.0,
                                    dispatch_fail_count=2,
                                    max_dispatch_retries=3))
    _, hist = srv.run(params)
    assert len(hist.records) == srv.fl.rounds
    # 2 failed attempts per round, then success
    assert srv.select_stats["dispatch_retries"] == 2 * srv.fl.rounds


def test_dispatch_retry_exhaustion_raises(world):
    model, params, task = world
    srv = FLServer(model, _fl(), SyntheticFederatedData(task),
                   faults=FaultPlan(seed=4, dispatch_fail_rate=1.0,
                                    dispatch_fail_count=5,
                                    max_dispatch_retries=2))
    with pytest.raises(TransientFault):
        srv.run(params)


def test_real_solver_deadline_degrades(world):
    """A wall-clock deadline the solve cannot meet: the round proceeds on
    the warm-start fallback and the run still completes every round."""
    model, params, task = world
    srv = FLServer(model, _fl(), SyntheticFederatedData(task),
                   pipeline_depth=2, solver_deadline_s=1e-9)
    _, hist = srv.run(params)
    assert len(hist.records) == srv.fl.rounds
    assert srv.select_stats["solver_timeouts"] > 0


# ---------------------------------------------------------------------------
# (d) self-healing checkpoints
# ---------------------------------------------------------------------------

def _experiment(model_cfg, task, ckpt_dir, rounds, **kw):
    return Experiment(
        Model(model_cfg, RuntimeConfig(remat=False, seq_chunk=16)), task,
        strategy="ours", cohort_size=3, rounds=rounds, local_steps=2,
        lr=0.01, batch_size=4, budget=1, lam=1.0, seed=0,
        checkpoint_dir=ckpt_dir, checkpoint_every=2, **kw)


def _dirichlet_task():
    return DirichletTokenMixtureTask(DirichletTaskConfig(
        n_clients=8, n_topics=4, vocab_size=128, seq_len=8,
        samples_per_client=16, test_samples=32, seed=0))


@pytest.fixture(scope="module")
def small_cfg():
    return reduced(get_arch("xlm_roberta_base"), n_layers=2, d_model=32)


@pytest.mark.parametrize("kind", CKPT_CORRUPT_KINDS)
def test_corrupt_latest_checkpoint_auto_resumes(small_cfg, tmp_path, kind):
    ckpt = str(tmp_path / "ckpt")
    exp = _experiment(small_cfg, _dirichlet_task(), ckpt, rounds=6)
    params0 = exp.init_params()
    _, h_first = exp.run(params0, rounds=6)
    assert len(h_first.records) == 6

    # damage the newest checkpoint (step 6), leaving step 4 intact
    FaultInjector.corrupt_checkpoint_dir(
        os.path.join(ckpt, "step_00000006"), kind)

    exp2 = _experiment(small_cfg, _dirichlet_task(), ckpt, rounds=8)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        params, hist = exp2.run(params0, rounds=8)
    assert any("corrupt checkpoint" in str(w.message) for w in caught)
    assert exp2.server.select_stats["ckpt_fallbacks"] == 1
    assert len(hist.records) == 8
    # the resumed prefix is the restored step-4 history: rounds 0..3
    assert [r.round for r in hist.records] == list(range(8))

    # and it matches an uninterrupted 8-round run on masks/cohorts
    ref = _experiment(small_cfg, _dirichlet_task(),
                      str(tmp_path / "ref"), rounds=8)
    _, h_ref = ref.run(params0, rounds=8)
    _records_equal(hist, h_ref)


def test_all_checkpoints_corrupt_resumes_from_scratch(small_cfg, tmp_path):
    ckpt = str(tmp_path / "ckpt")
    exp = _experiment(small_cfg, _dirichlet_task(), ckpt, rounds=4)
    params0 = exp.init_params()
    exp.run(params0, rounds=4)
    for d in os.listdir(ckpt):
        if d.startswith("step_"):
            FaultInjector.corrupt_checkpoint_dir(
                os.path.join(ckpt, d), "manifest")
    exp2 = _experiment(small_cfg, _dirichlet_task(), ckpt, rounds=4)
    with warnings.catch_warnings(record=True):
        warnings.simplefilter("always")
        _, hist = exp2.run(params0, rounds=4)
    assert len(hist.records) == 4       # full re-run from round 0


def test_verify_checkpoint_detects_every_kind(small_cfg, tmp_path):
    from repro.ckpt import (latest_intact_step, save_checkpoint,
                            verify_checkpoint)
    # big enough that the bitflip's mid-archive byte lands in array data,
    # not zip framing/padding (where it would be a silent no-op)
    tree = {"a": np.arange(4096, dtype=np.float32).reshape(64, 64),
            "b": {"c": np.ones(2048, np.int32)}}
    for step, kind in enumerate(CKPT_CORRUPT_KINDS):
        d = str(tmp_path / kind)
        path = save_checkpoint(d, 1, tree)
        ok, why = verify_checkpoint(d, 1)
        assert ok, why
        FaultInjector.corrupt_checkpoint_dir(path, kind)
        ok, why = verify_checkpoint(d, 1)
        assert not ok and why
        step_ok, skipped = latest_intact_step(d)
        assert step_ok is None
        assert skipped and skipped[0][0] == 1


def test_injected_checkpoint_corruption_counted(small_cfg, tmp_path):
    from repro.ckpt import verify_checkpoint
    ckpt = str(tmp_path / "ckpt")
    exp = _experiment(small_cfg, _dirichlet_task(), ckpt, rounds=4,
                      faults=FaultPlan(seed=11, ckpt_corrupt_rate=1.0,
                                       ckpt_corrupt_kind="bitflip"))
    params0 = exp.init_params()
    _, hist = exp.run(params0, rounds=4)
    assert len(hist.records) == 4
    assert exp.server._injector.stats["ckpt_corruptions"] > 0
    for step in (2, 4):
        ok, _ = verify_checkpoint(ckpt, step)
        assert not ok


# ---------------------------------------------------------------------------
# plan-stage chaos: empty pools × all-straggler rounds × deep pipelines
# ---------------------------------------------------------------------------

def test_all_straggler_rounds_degrade_and_count(small_cfg, world):
    model, params, task = world
    chaos = ChaosTask(SyntheticFederatedData(task),
                      all_straggler_rounds=(1, 2))
    srv = FLServer(model, _fl(), chaos, pipeline_depth=4)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        _, hist = srv.run(params)
    assert len(hist.records) == srv.fl.rounds
    assert srv.select_stats["all_straggler_rounds"] == 2
    assert any("drop_stragglers" in str(w.message) for w in caught)


def test_chaos_task_outside_listed_rounds_is_transparent(world):
    model, params, task = world
    p_plain, h_plain = FLServer(model, _fl(), SyntheticFederatedData(task),
                                pipeline_depth=2).run(params)
    p_chaos, h_chaos = FLServer(model, _fl(),
                                ChaosTask(SyntheticFederatedData(task)),
                                pipeline_depth=2).run(params)
    _records_equal(h_plain, h_chaos, bitwise=True)
    _params_equal(p_plain, p_chaos)


def test_empty_pool_mid_pipeline_fails_clean_checkpoint_survives(
        small_cfg, tmp_path):
    """Round 3's pool is empty under a depth-4 pipeline with a checkpoint
    barrier at round 2: the run fails with the plan-stage ValueError (not
    an opaque downstream crash), the barrier checkpoint is intact, and a
    fresh run resumes from it."""
    from repro.ckpt import verify_checkpoint
    ckpt = str(tmp_path / "ckpt")
    chaos = ChaosTask(_dirichlet_task(), empty_pool_rounds=(3,))
    exp = _experiment(small_cfg, chaos, ckpt, rounds=6, pipeline_depth=4)
    params0 = exp.init_params()
    with pytest.raises(ValueError, match="empty pool"):
        exp.run(params0, rounds=6)
    ok, why = verify_checkpoint(ckpt, 2)
    assert ok, why

    exp2 = _experiment(small_cfg, _dirichlet_task(), ckpt, rounds=6,
                       pipeline_depth=4)
    _, hist = exp2.run(params0, rounds=6)
    assert len(hist.records) == 6
    assert [r.round for r in hist.records] == list(range(6))


# ---------------------------------------------------------------------------
# History.summary NaN containment
# ---------------------------------------------------------------------------

def _rec(t, loss, acc):
    return RoundRecord(round=t, test_loss=loss, test_acc=acc,
                       train_loss=loss, mask_matrix=np.ones((2, 2)),
                       cohort=np.arange(2), union_frac=1.0,
                       uploaded_params=10, wall_s=0.0)


def test_summary_excludes_nonfinite_rounds():
    h = History(records=[_rec(0, 1.0, 0.5), _rec(1, float("nan"), 0.9),
                         _rec(2, 0.8, 0.6), _rec(3, float("inf"), 0.1)])
    s = h.summary()
    assert s["rounds"] == 4
    assert s["nonfinite_rounds"] == 2
    assert s["final_loss"] == 0.8           # last *clean* round
    assert s["best_acc"] == 0.6             # NaN round's 0.9 excluded
    assert s["uploaded_params_total"] == 40  # uploads happened regardless


def test_summary_all_poisoned():
    h = History(records=[_rec(0, float("nan"), float("nan"))])
    s = h.summary()
    assert s["nonfinite_rounds"] == 1
    assert s["final_loss"] is None and s["best_acc"] is None
    h_empty = History()
    assert h_empty.summary()["nonfinite_rounds"] == 0


# ---------------------------------------------------------------------------
# no per-fault recompiles: ONE guarded program
# ---------------------------------------------------------------------------

def test_guarded_program_compiles_once(world):
    model, params, task = world
    client_mod.clear_jit_cache()
    srv = FLServer(model, _fl(rounds=3), SyntheticFederatedData(task),
                   faults=FaultPlan(seed=7, death_rate=0.5,
                                    corrupt_rate=0.5))
    srv.run(params)
    programs = client_mod.jit_cache_stats()["programs"]
    assert programs["cohort_update_guarded"] == 1
    # and varying every fault knob still replays the same trace
    plan = srv.plan_round(98)
    sampled = srv.sample_round(plan)
    masks = srv.select_round(plan, srv.probe_round(params, sampled))
    n = len(plan.cohort)
    for pattern in (np.zeros(n), np.ones(n), np.arange(n) % 2):
        srv.client.cohort_update_guarded(
            params, sampled.update_batches, masks, plan.sizes, srv.fl.lr,
            pattern.astype(np.float32),
            (pattern * CORRUPT_CODES["explode"]).astype(np.int32),
            123.0, 456.0)
    assert client_mod.jit_cache_stats()["programs"][
        "cohort_update_guarded"] == 1


def test_fault_round_strict_mode(strict_mode, world):
    """The warmed fault path runs under the transfer guard + retrace
    sentinel: its host syncs are the sanctioned round-boundary ones and
    fault patterns never retrace."""
    model, params, task = world
    plan = FaultPlan(seed=13, death_rate=0.4, corrupt_rate=0.4,
                     stall_rate=0.3)
    client_mod.clear_jit_cache()
    warm = FLServer(model, _fl(), SyntheticFederatedData(task),
                    faults=plan)
    _, h_warm = warm.run(params)
    srv = FLServer(model, _fl(), SyntheticFederatedData(task), faults=plan)
    with strict_mode("fault round loop", force=True):
        _, h_strict = srv.run(params)
    assert h_warm.summary() == h_strict.summary()


# ---------------------------------------------------------------------------
# serve-side degradation: admit drops, slot failures, upload retries
# ---------------------------------------------------------------------------

def _serve_world(n_layers=3, d_model=64):
    cfg = reduced(get_arch("tinyllama_1_1b"), n_layers=n_layers,
                  d_model=d_model)
    model = Model(cfg, RuntimeConfig(remat=False, seq_chunk=16))
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _two_layer_record(model, params):
    from repro.serve import delta_from_params
    tuned = dict(params)
    tuned["blocks"] = {k: np.asarray(v, np.float32) + 0.01
                       for k, v in params["blocks"].items()}
    return delta_from_params(params, tuned, model.cfg, layers=[0, 1])


@pytest.mark.parametrize("admit_retries,n_done,n_dropped",
                         [(2, 1, 2),      # bounded retry: heads dropped
                          (30, 3, 0)])    # patient: all served serially
def test_slot_server_capacity_exhaustion_bounded(admit_retries, n_done,
                                                 n_dropped):
    """One user whose delta fills the whole capacity-1 overlay, three
    requests for it: the second admit can never succeed while the first
    decodes.  The old loop requeued unconditionally — an idle server with
    an unadmittable head raised RuntimeError / livelocked.  Now the head
    is retried ``admit_retries`` times then dropped (small budget) or
    admitted after the running request releases (large budget)."""
    from repro.launch.serve import Request, SlotServer
    from repro.serve import DeltaStore
    model, params = _serve_world()
    store = DeltaStore(model.cfg)
    store.put(0, _two_layer_record(model, params))
    reqs = [Request(i, [1, 2, 3], 4, user_id=0) for i in range(3)]
    srv = SlotServer(model, params, slots=2, max_seq=16, mode="delta",
                     store=store, capacity=1, admit_retries=admit_retries)
    done, stats = srv.run(reqs)
    assert len(done) == n_done
    assert stats["dropped_requests"] == n_dropped == len(srv.dropped)
    for r in done:
        assert len(r.generated) == r.max_new     # survivors fully served


def test_slot_faults_requeue_then_drop():
    from repro.launch.serve import Request, SlotServer
    model, params = _serve_world()
    inj = FaultInjector(FaultPlan(seed=21, slot_fault_rate=1.0))
    srv = SlotServer(model, params, slots=2, max_seq=16, mode="shared",
                     injector=inj, max_slot_retries=1)
    done, stats = srv.run([Request(i, [1, 2, 3], 4) for i in range(3)])
    # every step strikes every slot: nothing ever finishes, everything is
    # retried max_slot_retries times then dropped — and the loop terminates
    assert not done
    assert stats["dropped_requests"] == 3
    assert stats["slot_failures"] == 3 * (1 + 1)  # initial + one retry each
    assert inj.stats["slot_faults"] > 0


def test_slot_faults_recoverable_at_low_rate():
    from repro.launch.serve import Request, SlotServer
    model, params = _serve_world()
    inj = FaultInjector(FaultPlan(seed=3, slot_fault_rate=0.1))
    srv = SlotServer(model, params, slots=2, max_seq=32, mode="shared",
                     injector=inj, max_slot_retries=50)
    done, stats = srv.run([Request(i, [1, 2, 3], 4) for i in range(4)])
    assert len(done) == 4                    # retries absorb the strikes
    assert stats["dropped_requests"] == 0
    for r in done:
        assert len(r.generated) == r.max_new


def test_overlay_upload_retries_and_rollback():
    from repro.serve import DeltaOverlay
    model, params = _serve_world()
    rec = _two_layer_record(model, params)

    # permanent failure: all-or-nothing rollback, no half-admitted user
    inj = FaultInjector(FaultPlan(seed=0, upload_fail_rate=1.0))
    ov = DeltaOverlay(model, capacity=2, injector=inj,
                      max_upload_retries=2)
    assert not ov.try_admit(0, rec)
    assert ov.stats["failed_admits"] == 1
    assert ov.n_entries == 0
    assert ov.entries[0] == []
    assert inj.stats["upload_faults"] == 3       # attempts 0..max_retries
    assert ov.stats["upload_retries"] == 2

    # transient failure: bounded retries absorb it
    inj2 = FaultInjector(FaultPlan(seed=2, upload_fail_rate=0.4))
    ov2 = DeltaOverlay(model, capacity=2, injector=inj2,
                       max_upload_retries=10)
    assert ov2.try_admit(0, rec)
    assert ov2.n_entries == rec.n_layers == 2
    assert inj2.stats["upload_faults"] == ov2.stats["upload_retries"]
