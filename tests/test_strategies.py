"""Tests for the selection strategies (§5.1 baselines + ours)."""
import numpy as np
import pytest

from repro.core.strategies import ALL_STRATEGIES, ProbeReport, select


def _probe(n=4, L=6, seed=0):
    rng = np.random.RandomState(seed)
    return ProbeReport(
        grad_sq_norms=np.abs(rng.randn(n, L)).astype(np.float32),
        param_sq_norms=np.abs(rng.randn(n, L)).astype(np.float32) + 1.0,
        grad_means=rng.randn(n, L).astype(np.float32),
        grad_vars=np.abs(rng.randn(n, L)).astype(np.float32) + 0.1)


def test_top_bottom_positions():
    p = _probe()
    top = select("top", p, 2)
    bot = select("bottom", p, 2)
    assert np.all(top[:, -2:] == 1) and np.all(top[:, :-2] == 0)
    assert np.all(bot[:, :2] == 1) and np.all(bot[:, 2:] == 0)


def test_both_splits():
    p = _probe()
    both = select("both", p, 2)
    assert np.all(both[:, 0] == 1) and np.all(both[:, -1] == 1)
    assert both.sum() == 2 * p.n


def test_full():
    p = _probe()
    assert select("full", p, 1).sum() == p.n * p.L


def test_budget_respected_all_strategies():
    p = _probe()
    budgets = np.array([1, 2, 3, 1])
    for s in ALL_STRATEGIES:
        if s == "full":
            continue
        m = select(s, p, budgets)
        assert np.all(m.sum(1) <= budgets), s


def test_rgn_picks_relative_norm():
    g = np.array([[4.0, 1.0]])      # |g| = 2, 1
    th = np.array([[16.0, 0.25]])   # |θ| = 4, 0.5 → rgn = 0.5, 2.0
    p = ProbeReport(grad_sq_norms=g, param_sq_norms=th)
    m = select("rgn", p, 1)
    np.testing.assert_array_equal(m, [[0, 1]])


def test_snr_picks_high_signal():
    mean = np.array([[1.0, 1.0]])
    var = np.array([[0.1, 10.0]])
    p = ProbeReport(grad_sq_norms=np.ones((1, 2)), grad_means=mean,
                    grad_vars=var)
    m = select("snr", p, 1)
    np.testing.assert_array_equal(m, [[1, 0]])


def test_ours_prefers_high_gradient_layers():
    G = np.zeros((3, 5), np.float32)
    G[:, 2] = 100.0                  # layer 2 dominates for everyone
    p = ProbeReport(grad_sq_norms=G)
    m = select("ours", p, 1, lam=1.0)
    assert np.all(m[:, 2] == 1)
