"""End-to-end behaviour tests of the paper's system.

The headline claims, validated on synthetic federated tasks:
1. Selective fine-tuning with the proposed strategy reaches the full
   fine-tuning neighbourhood at R≪L (Table 1 claim).
2. The communication cost of a selective round is R/L of full (Table 3).
3. Property (hypothesis): one FL round is *invariant* to client order and
   scales correctly with duplicated clients.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # tier-1 must run without optional deps
    from _hypothesis_compat import given, settings, st

from repro.configs.base import FLConfig, RuntimeConfig, get_arch, reduced
from repro.core import aggregation as agg
from repro.core.server import FLServer
from repro.data.pretrain import pretrain
from repro.data.synthetic import FederatedTaskConfig, SyntheticFederatedData
from repro.models.model import Model, apply_layer_mask


@pytest.fixture(scope="module")
def world():
    cfg = reduced(get_arch("xlm_roberta_base"), n_layers=4, d_model=64)
    model = Model(cfg, RuntimeConfig(remat=False, seq_chunk=16))
    data = SyntheticFederatedData(FederatedTaskConfig(
        n_clients=16, n_classes=10, vocab_size=cfg.vocab_size, seq_len=16,
        samples_per_client=24, skew="feature", objective="classification",
        signal=0.8, domain_strength=0.4))
    params = pretrain(model, model.init(jax.random.PRNGKey(0)), data,
                      steps=120, lr=3e-3)
    return model, params, data


def _run(model, params, data, strategy, rounds=10, budget=2, lr=0.01):
    fl = FLConfig(n_clients=16, cohort_size=4, rounds=rounds, local_steps=2,
                  lr=lr, batch_size=8, strategy=strategy, budget=budget,
                  lam=1.0, seed=5)
    server = FLServer(model, fl, data)
    return server.run(params)


def test_selective_tracks_full(world):
    """'Ours' at R=2 of 4 layers stays within reach of full fine-tuning."""
    model, params, data = world
    _, h_ours = _run(model, params, data, "ours")
    _, h_full = _run(model, params, data, "full")
    assert h_ours.summary()["best_acc"] >= h_full.summary()["best_acc"] - 0.08


def test_selective_beats_bottom(world):
    """Gradient-informed selection beats the weakest positional baseline."""
    model, params, data = world
    _, h_ours = _run(model, params, data, "ours")
    _, h_bot = _run(model, params, data, "bottom")
    assert h_ours.summary()["best_acc"] >= h_bot.summary()["best_acc"] - 0.05


def test_upload_is_r_over_l(world):
    """Table 3 claim: uploaded parameters per round = (R/L)·full."""
    model, params, data = world
    _, h_sel = _run(model, params, data, "top", rounds=2, budget=1)
    _, h_full = _run(model, params, data, "full", rounds=2)
    L = model.n_selectable
    ratio = (h_sel.summary()["uploaded_params_total"]
             / h_full.summary()["uploaded_params_total"])
    assert ratio == pytest.approx(1.0 / L, rel=1e-6)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2 ** 20))
def test_round_invariant_to_client_order(seed):
    """Aggregation (Eq. 5) is permutation-invariant in the cohort."""
    cfg = reduced(get_arch("tinyllama_1_1b"), n_layers=3, d_model=32)
    model = Model(cfg, RuntimeConfig(remat=False, seq_chunk=16))
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(seed % (2 ** 31 - 1))
    n = 3
    batches = [{"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (2, 16)))}
               for _ in range(n)]
    masks = jnp.asarray((rng.rand(n, 3) > 0.3).astype(np.float32))
    sizes = jnp.asarray(rng.randint(1, 50, n).astype(np.float32))
    deltas = [apply_layer_mask(jax.grad(model.loss)(params, b), masks[i], cfg)
              for i, b in enumerate(batches)]
    upd = agg.aggregate(deltas, masks, sizes, cfg)
    perm = rng.permutation(n)
    upd_p = agg.aggregate([deltas[i] for i in perm], masks[perm], sizes[perm],
                          cfg)
    err = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), upd, upd_p)))
    assert err < 1e-5


def test_duplicated_client_equals_double_weight():
    """Eq.(7): a client listed twice == the same client with 2·d_i."""
    cfg = reduced(get_arch("tinyllama_1_1b"), n_layers=3, d_model=32)
    model = Model(cfg, RuntimeConfig(remat=False, seq_chunk=16))
    params = model.init(jax.random.PRNGKey(0))
    b1 = {"tokens": jnp.asarray(np.random.RandomState(0).randint(0, 64, (2, 16)))}
    b2 = {"tokens": jnp.asarray(np.random.RandomState(1).randint(0, 64, (2, 16)))}
    m = jnp.ones((1, 3), jnp.float32)
    g1 = apply_layer_mask(jax.grad(model.loss)(params, b1), m[0], cfg)
    g2 = apply_layer_mask(jax.grad(model.loss)(params, b2), m[0], cfg)
    dup = agg.aggregate([g1, g1, g2], jnp.ones((3, 3)), jnp.array([5., 5., 10.]), cfg)
    wt = agg.aggregate([g1, g2], jnp.ones((2, 3)), jnp.array([10., 10.]), cfg)
    err = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), dup, wt)))
    assert err < 1e-5
