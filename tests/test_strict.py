"""Strict-mode tripwires over the streaming round loop (REPRO_STRICT).

The static linter (repro.analysis) proves the *code* has no unsanctioned
sync/jit sites; these tests prove the *execution*: with the jit suite
warmed, steady-state rounds run under ``jax.transfer_guard("disallow")``
(zero implicit host↔device transfers — every batch, mask and scalar is
explicitly device_put) and under the jit-suite retrace sentinel (zero new
compiled programs — the pins in test_jit_cache.py backed by a trace-count
assertion, per ISSUE 8).

The strict region is always forced here; the conftest ``strict_mode``
fixture arms only under REPRO_STRICT=1 so ordinary tests can opt in
cheaply (the CI smoke job sets it).
"""
import jax
import pytest

from repro.configs.base import FLConfig, RuntimeConfig, get_arch, reduced
from repro.core import client as client_mod
from repro.core.server import FLServer
from repro.data.synthetic import FederatedTaskConfig, SyntheticFederatedData
from repro.models.model import Model


def _world():
    cfg = reduced(get_arch("xlm_roberta_base"), n_layers=2, d_model=32)
    model = Model(cfg, RuntimeConfig(remat=False, seq_chunk=16))
    task = FederatedTaskConfig(n_clients=8, n_classes=10,
                               vocab_size=cfg.vocab_size, seq_len=8,
                               samples_per_client=16, skew="label",
                               objective="classification")
    fl = FLConfig(n_clients=8, cohort_size=3, rounds=4, local_steps=2,
                  lr=0.01, batch_size=4, strategy="ours", budget=1, lam=1.0,
                  seed=0)
    return model, model.init(jax.random.PRNGKey(0)), task, fl


@pytest.mark.parametrize("depth", [1, 4])
def test_round_loop_strict_no_transfers_no_retraces(strict_mode, depth):
    """An identically-configured warmup run compiles every program variant
    (incl. per-cut masked programs — same seeds ⇒ same cut sequence);
    the second run must then replay cached traces end to end with only
    explicit transfers, at pipeline depth 1 and 4."""
    model, params, task, fl = _world()
    client_mod.clear_jit_cache()

    warm = FLServer(model, fl, SyntheticFederatedData(task),
                    pipeline_depth=depth)
    _, h_warm = warm.run(params)

    srv = FLServer(model, fl, SyntheticFederatedData(task),
                   pipeline_depth=depth)
    with strict_mode(f"round loop depth={depth}", force=True):
        _, h_strict = srv.run(params)

    # strictness must not have changed the math
    assert h_warm.summary() == h_strict.summary()


def test_strict_region_trips_on_implicit_transfer(strict_mode):
    """The guard actually guards: an np array smuggled into a jitted
    program raises inside the region and passes outside it."""
    import numpy as np

    f = jax.jit(lambda x: x + 1)
    f(np.ones(4))                        # warm + legal outside the region
    with pytest.raises(Exception, match="[Tt]ransfer"):
        with strict_mode("tripwire", force=True):
            f(np.ones(4)).block_until_ready()


def test_retrace_sentinel_trips_on_new_program():
    """A fresh suite entry compiled inside the region is reported as a
    retrace, with the grown entry point named."""
    from repro.analysis.strict import RetraceSentinel

    model, params, task, fl = _world()
    client_mod.clear_jit_cache()
    with pytest.raises(AssertionError, match="retrace inside cold run"):
        with RetraceSentinel("cold run"):
            FLServer(model, fl, SyntheticFederatedData(task)).run(params)
