"""Per-architecture smoke tests (assignment requirement).

For each of the 10 assigned architectures (+ the paper's own 3): instantiate
the REDUCED variant (≤2 layers core, d_model ≤ 512, ≤4 experts), run one
forward/train step on CPU, assert output shapes and no NaNs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_batch
from repro.configs.base import (ASSIGNED_ARCHS, PAPER_ARCHS, RuntimeConfig,
                                get_arch, reduced)
from repro.models.model import Model, count_params

ALL = ASSIGNED_ARCHS + PAPER_ARCHS


@pytest.mark.parametrize("arch", ALL)
def test_forward_and_train_step(arch):
    cfg = reduced(get_arch(arch))
    model = Model(cfg, RuntimeConfig(remat=False, seq_chunk=16))
    params = model.init(jax.random.PRNGKey(0))
    assert count_params(params) > 0
    B, S = 2, 32
    batch = make_batch(cfg, B, S)

    # forward: loss is a finite scalar
    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), arch

    # one SGD step: params stay finite, loss decreases on same batch
    new_params = jax.tree.map(lambda p, g: p - 0.05 * g, params, grads)
    assert all(bool(jnp.all(jnp.isfinite(x)))
               for x in jax.tree.leaves(new_params)), arch
    loss2 = model.loss(new_params, batch)
    assert bool(jnp.isfinite(loss2))
    assert float(loss2) < float(loss) + 1e-3, f"{arch}: no descent"


@pytest.mark.parametrize("arch", [a for a in ALL])
def test_decode_step_shapes(arch):
    cfg = reduced(get_arch(arch))
    if cfg.task == "classification":
        pytest.skip("classification archs have no decode path")
    model = Model(cfg, RuntimeConfig(remat=False, seq_chunk=16))
    params = model.init(jax.random.PRNGKey(0))
    B = 2
    cache = model.init_cache(B, 16)
    tok = jnp.zeros((B,), jnp.int32)
    logits, new_cache = model.decode_step(params, tok, jnp.int32(0), cache)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), arch
    assert jax.tree.structure(cache) == jax.tree.structure(new_cache)


@pytest.mark.parametrize("arch", ["tinyllama_1_1b", "zamba2_7b",
                                  "mamba2_370m", "deepseek_v2_lite_16b"])
def test_sliding_window_variant(arch):
    """long_500k policy: windowed decode must also work."""
    cfg = reduced(get_arch(arch))
    if cfg.family == "ssm":
        pytest.skip("attention-free")
    model = Model(cfg, RuntimeConfig(remat=False, seq_chunk=16))
    params = model.init(jax.random.PRNGKey(0))
    cache = model.init_cache(2, 64, window=8)
    tok = jnp.zeros((2,), jnp.int32)
    logits, _ = model.decode_step(params, tok, jnp.int32(0), cache, window=8)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_exact_assigned_configs():
    """The full configs carry the exact assigned hyper-parameters."""
    expect = {
        "tinyllama-1.1b": dict(n_layers=22, d_model=2048, n_heads=32,
                               n_kv_heads=4, d_ff=5632, vocab_size=32000),
        "grok-1-314b": dict(n_layers=64, d_model=6144, n_heads=48,
                            n_kv_heads=8, d_ff=32768, vocab_size=131072,
                            n_experts=8, top_k=2),
        "smollm-360m": dict(n_layers=32, d_model=960, n_heads=15,
                            n_kv_heads=5, d_ff=2560, vocab_size=49152),
        "zamba2-7b": dict(n_layers=81, d_model=3584, n_heads=32,
                          n_kv_heads=32, d_ff=14336, vocab_size=32000,
                          ssm_state=64),
        "codeqwen1.5-7b": dict(n_layers=32, d_model=4096, n_heads=32,
                               n_kv_heads=32, d_ff=13440, vocab_size=92416),
        "paligemma-3b": dict(n_layers=18, d_model=2048, n_heads=8,
                             n_kv_heads=1, d_ff=16384, vocab_size=257216),
        "deepseek-v2-lite-16b": dict(n_layers=27, d_model=2048, n_heads=16,
                                     n_kv_heads=16, d_ff=1408,
                                     vocab_size=102400, n_experts=64,
                                     top_k=6, kv_lora_rank=512),
        "mamba2-370m": dict(n_layers=48, d_model=1024, d_ff=0,
                            vocab_size=50280, ssm_state=128),
        "gemma-7b": dict(n_layers=28, d_model=3072, n_heads=16,
                         n_kv_heads=16, d_ff=24576, vocab_size=256000,
                         head_dim=256),
        "whisper-medium": dict(n_layers=24, d_model=1024, n_heads=16,
                               n_kv_heads=16, d_ff=4096, vocab_size=51865,
                               n_enc_layers=24),
    }
    for name, fields in expect.items():
        cfg = get_arch(name)
        for k, v in fields.items():
            assert getattr(cfg, k) == v, (name, k, getattr(cfg, k), v)


def test_param_count_magnitudes():
    """Full configs land in the advertised parameter range."""
    import numpy as np
    targets = {"tinyllama-1.1b": (1.0e9, 1.25e9),
               "smollm-360m": (3.2e8, 4.1e8),
               "mamba2-370m": (3.2e8, 4.2e8),
               "grok-1-314b": (2.9e11, 3.4e11)}
    for name, (lo, hi) in targets.items():
        cfg = get_arch(name)
        from repro.models.model import init_params
        shapes = jax.eval_shape(lambda k: init_params(cfg, k),
                                jax.ShapeDtypeStruct((2,), jnp.uint32))
        n = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shapes))
        assert lo <= n <= hi, (name, n)
