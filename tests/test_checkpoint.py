"""Checkpoint/resume: partial restore, robust step discovery, tmp sweeps,
roundtrips across model families, and bit-exact resume parity.

The resume-parity contract is the spine of the population-state feature:
running T rounds straight must equal running t, killing the process, and
resuming from the round-t checkpoint with a *fresh* server and task —
bit-identically on cohorts/masks/stream draws, within fp tolerance on
params — in both engines and at pipeline_depth > 1.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import Experiment
from repro.ckpt import (latest_step, load_checkpoint_arrays,
                        restore_checkpoint, save_checkpoint, sweep_tmp_dirs)
from repro.configs.base import FLConfig, RuntimeConfig, get_arch, reduced
from repro.core.server import FLServer, History
from repro.data.synthetic import FederatedTaskConfig, SyntheticFederatedData
from repro.models.model import Model


# --- ckpt module: partial restore, latest_step, tmp sweep ------------------

def test_restore_reports_restored_keys(tmp_path):
    d = str(tmp_path / "c")
    save_checkpoint(d, 0, {"w": jnp.ones((3,)), "b": jnp.zeros((2,))})
    out, manifest = restore_checkpoint(d, {"w": jnp.zeros((3,)),
                                           "b": jnp.ones((2,))})
    assert sorted(manifest["restored"]) == ["b", "w"]
    assert manifest["skipped"] == []
    np.testing.assert_array_equal(np.asarray(out["w"]), np.ones(3))


def test_partial_restore_keeps_template_leaf(tmp_path):
    d = str(tmp_path / "c")
    save_checkpoint(d, 0, {"w": jnp.ones((3,))})
    template = {"w": jnp.zeros((3,)), "opt_state": jnp.full((2,), 7.0)}
    out, manifest = restore_checkpoint(d, template, partial=True)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.ones(3))
    np.testing.assert_array_equal(np.asarray(out["opt_state"]),
                                  np.full(2, 7.0))
    assert manifest["restored"] == ["w"]
    assert manifest["skipped"] == ["opt_state"]


def test_strict_restore_raises_on_missing_key(tmp_path):
    d = str(tmp_path / "c")
    save_checkpoint(d, 0, {"w": jnp.ones((3,))})
    with pytest.raises(KeyError, match="partial=True"):
        restore_checkpoint(d, {"w": jnp.zeros((3,)), "extra": jnp.zeros(1)})


def test_latest_step_skips_non_numeric_entries(tmp_path):
    d = str(tmp_path / "c")
    save_checkpoint(d, 3, {"w": jnp.ones(2)})
    os.makedirs(os.path.join(d, "step_final"))       # stray non-checkpoint
    os.makedirs(os.path.join(d, "step_"))
    assert latest_step(d) == 3


def test_save_sweeps_orphaned_tmp_dirs(tmp_path):
    d = str(tmp_path / "c")
    os.makedirs(os.path.join(d, "tmporphan"))        # interrupted save
    with open(os.path.join(d, "tmporphan", "arrays.npz"), "w") as f:
        f.write("junk")
    save_checkpoint(d, 1, {"w": jnp.ones(2)})
    assert not os.path.exists(os.path.join(d, "tmporphan"))
    assert latest_step(d) == 1
    # sweep is also callable standalone
    os.makedirs(os.path.join(d, "tmpagain"))
    assert sweep_tmp_dirs(d) == [os.path.join(d, "tmpagain")]


@pytest.mark.parametrize("arch", ["tinyllama_1_1b",        # dense
                                  "deepseek_v2_lite_16b",  # moe
                                  "mamba2_370m"])          # ssm
def test_checkpoint_roundtrip_families(arch, tmp_path):
    cfg = reduced(get_arch(arch), n_layers=2, d_model=64)
    model = Model(cfg, RuntimeConfig(remat=False, seq_chunk=16))
    params = model.init(jax.random.PRNGKey(0))
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 2, params, extra={"round": 2})
    template = jax.tree.map(jnp.zeros_like, params)
    restored, manifest = restore_checkpoint(d, template)
    assert manifest["extra"]["round"] == 2
    assert manifest["skipped"] == []
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --- resume parity ---------------------------------------------------------

@pytest.fixture(scope="module")
def world():
    cfg = reduced(get_arch("xlm_roberta_base"), n_layers=4, d_model=32)
    model = Model(cfg, RuntimeConfig(remat=False, seq_chunk=16))
    params = model.init(jax.random.PRNGKey(0))
    task = FederatedTaskConfig(
        n_clients=12, n_classes=10, vocab_size=cfg.vocab_size, seq_len=8,
        samples_per_client=16, skew="label", objective="classification")
    return model, params, task


def _records_equal(h_a, h_b, atol=1e-5):
    assert len(h_a.records) == len(h_b.records)
    for ra, rb in zip(h_a.records, h_b.records):
        np.testing.assert_array_equal(ra.cohort, rb.cohort)
        np.testing.assert_array_equal(ra.mask_matrix, rb.mask_matrix)
        assert ra.train_loss == pytest.approx(rb.train_loss, abs=atol)
        assert ra.test_loss == pytest.approx(rb.test_loss, abs=atol)


def _params_close(p_a, p_b, atol=1e-5):
    err = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(np.abs(np.asarray(a, np.float32)
                                  - np.asarray(b, np.float32)).max()),
        p_a, p_b)))
    assert err < atol, f"param divergence {err}"


def _fl(period=1, rounds=6):
    return FLConfig(n_clients=12, cohort_size=4, rounds=rounds,
                    local_steps=2, lr=0.01, batch_size=4, strategy="ours",
                    budget=2, selection_period=period, lam=1.0, seed=29)


@pytest.mark.parametrize("engine,depth,period", [
    ("sequential", 1, 1),      # paper-literal oracle loop
    ("vectorized", 1, 1),      # streaming scheduler, double buffer
    ("vectorized", 3, 1),      # deep lookahead crosses the ckpt barrier
    ("vectorized", 2, 2),      # stats-cache survives the save/restore
])
def test_resume_parity(world, tmp_path, engine, depth, period):
    """6 rounds straight == 3 + save + fresh server/task + restore + 3:
    cohorts/masks/stream draws bit-identical, params within fp."""
    model, params, task = world
    fl = _fl(period)
    d = str(tmp_path / "ckpt")

    data_s = SyntheticFederatedData(task)
    p_straight, h_straight = FLServer(
        model, fl, data_s, engine=engine,
        pipeline_depth=depth).run(params, rounds=6)

    # interrupted run: checkpoint lands exactly at round 3, then "crash"
    data_k = SyntheticFederatedData(task)
    srv_k = FLServer(model, fl, data_k, engine=engine, pipeline_depth=depth,
                     checkpoint_dir=d, checkpoint_every=3)
    srv_k.run(params, rounds=3)
    assert latest_step(d) == 3

    # resume on a FRESH server + task (nothing carried over in-process)
    data_r = SyntheticFederatedData(task)
    srv_r = FLServer(model, fl, data_r, engine=engine, pipeline_depth=depth,
                     checkpoint_dir=d, checkpoint_every=3)
    restored = srv_r.restore_state(params)
    assert restored is not None
    p_mid, start, hist = restored
    assert start == 3 and len(hist.records) == 3
    p_resumed, h_resumed = srv_r.run(p_mid, rounds=6, start=start,
                                     history=hist)

    _records_equal(h_resumed, h_straight)
    _params_close(p_resumed, p_straight)
    np.testing.assert_array_equal(data_r.stream_positions(),
                                  data_s.stream_positions())


def test_mid_run_checkpoints_match_synchronous_state(world, tmp_path):
    """Pipelined run with a mid-run boundary (checkpoint_every < rounds):
    the barrier must stop prefetch from consuming post-boundary rng/stream
    draws, so the round-2 checkpoint resumes bit-identically too."""
    model, params, task = world
    fl = _fl()
    d = str(tmp_path / "ckpt")
    data_s = SyntheticFederatedData(task)
    p_straight, h_straight = FLServer(model, fl, data_s,
                                      pipeline_depth=3).run(params, rounds=5)
    data_k = SyntheticFederatedData(task)
    srv_k = FLServer(model, fl, data_k, pipeline_depth=3,
                     checkpoint_dir=d, checkpoint_every=2)
    srv_k.run(params, rounds=5)
    assert latest_step(d) == 5                 # boundaries at 2, 4, 5

    data_r = SyntheticFederatedData(task)
    srv_r = FLServer(model, fl, data_r, pipeline_depth=3,
                     checkpoint_dir=d, checkpoint_every=2)
    p_mid, start, hist = srv_r.restore_state(params, step=2)
    assert start == 2
    p_resumed, h_resumed = srv_r.run(p_mid, rounds=5, start=start,
                                     history=hist)
    _records_equal(h_resumed, h_straight)
    _params_close(p_resumed, p_straight)
    np.testing.assert_array_equal(data_r.stream_positions(),
                                  data_s.stream_positions())


def test_checkpoint_contents_and_select_stats(world, tmp_path):
    """What rides the checkpoint: params, store arrays, rng states, task
    streams, History + select_stats in the manifest."""
    model, params, task = world
    d = str(tmp_path / "ckpt")
    srv = FLServer(model, _fl(), SyntheticFederatedData(task),
                   checkpoint_dir=d, checkpoint_every=2)
    srv.run(params, rounds=2)
    flat, manifest = load_checkpoint_arrays(d)
    assert any(k.startswith("params/") for k in flat)
    assert "client/warm" in flat and "client/gen" in flat
    assert "server_rng/keys" in flat and flat["server_rng/keys"].shape == (624,)
    assert "task/streams/positions" in flat
    extra = manifest["extra"]
    assert extra["round"] == 2
    assert len(extra["history"]["records"]) == 2
    assert extra["select_stats"]["solves"] >= 1
    hist = History.from_json(extra["history"])
    assert hist.records[1].round == 1


def test_experiment_auto_resume(world, tmp_path):
    """Experiment(checkpoint_dir=...) resumes transparently: run 2 rounds,
    rebuild from scratch, run(rounds=4) continues — equal to 4 straight."""
    model, params, task = world
    d = str(tmp_path / "ckpt")

    def exp(ckpt):
        return Experiment(model, SyntheticFederatedData(task), "ours",
                          rounds=4, cohort_size=4, local_steps=2,
                          batch_size=4, budget=2, lam=1.0, seed=29,
                          checkpoint_dir=ckpt, checkpoint_every=2)

    p_straight, h_straight = exp(None).run(params)
    exp(d).run(params, rounds=2)
    p_resumed, h_resumed = exp(d).run(params)        # picks up at round 2
    _records_equal(h_resumed, h_straight)
    _params_close(p_resumed, p_straight)
    # a checkpoint at/past the requested horizon returns the restored state
    p_again, h_again = exp(d).run(params)
    assert len(h_again.records) == 4
    _params_close(p_again, p_resumed, atol=1e-7)
