"""ClientStateStore / ClientStreamState: flat-array population state.

O(cohort) gather/scatter semantics, O(1) generation invalidation, lazy
stream materialisation, byte-exact state_dict roundtrips, the dict-like
back-compat views, and population-scale construction (10⁵–10⁶ ids).
"""
import numpy as np
import pytest

from repro.core.state import (ClientStateStore, ClientStreamState,
                              rng_state_from_arrays, rng_state_to_arrays,
                              sub_state)


# --- rng pack/unpack -------------------------------------------------------

def test_rng_state_arrays_roundtrip():
    rng = np.random.RandomState(42)
    rng.randn(100)
    rng.standard_normal()                      # leave a cached gaussian
    packed = rng_state_to_arrays(rng)
    twin = rng_state_from_arrays(packed)
    np.testing.assert_array_equal(rng.randn(50), twin.randn(50))
    np.testing.assert_array_equal(rng.randint(0, 1000, 20),
                                  twin.randint(0, 1000, 20))


def test_rng_state_restore_in_place():
    rng = np.random.RandomState(7)
    rng.randn(10)
    packed = rng_state_to_arrays(rng)
    ahead = rng.randn(5)                       # advance past the snapshot
    rng_state_from_arrays(packed, rng)         # rewind
    np.testing.assert_array_equal(rng.randn(5), ahead)


def test_sub_state_strips_prefix():
    d = {"a/x": np.zeros(1), "a/y": np.ones(1), "b/x": np.full(1, 2.0)}
    sub = sub_state(d, "a/")
    assert set(sub) == {"x", "y"}


# --- ClientStateStore: warm-mask rows --------------------------------------

def test_warm_rows_gather_scatter():
    store = ClientStateStore(100, 4)
    assert not store.has_warm
    cohort = np.array([3, 17, 42])
    masks = np.eye(3, 4, dtype=np.float32)
    store.set_warm_rows(cohort, masks, t=5)
    rows, valid = store.warm_rows([17, 99, 3])
    np.testing.assert_array_equal(valid, [True, False, True])
    np.testing.assert_array_equal(rows[0], masks[1])
    np.testing.assert_array_equal(rows[2], masks[0])
    np.testing.assert_array_equal(rows[1], np.zeros(4))
    np.testing.assert_array_equal(store.warm_ids(), [3, 17, 42])
    assert store.last_seen[17] == 5 and store.last_seen[99] == -1


def test_warm_rows_are_copies():
    store = ClientStateStore(10, 4)
    store.set_warm_rows([1], np.ones((1, 4), np.float32))
    rows, _ = store.warm_rows([1])
    rows[0, 0] = 99.0
    assert store.warm_rows([1])[0][0, 0] == 1.0


def test_set_warm_rows_shape_validated():
    store = ClientStateStore(10, 4)
    with pytest.raises(ValueError, match="mask rows"):
        store.set_warm_rows([1, 2], np.ones((2, 5), np.float32))


def test_warm_mask_view_compat():
    """The dict-like view the old ``FLServer._warm_masks`` pokes expect."""
    store = ClientStateStore(50, 3)
    view = store.warm_masks
    assert len(view) == 0 and not view
    store.set_warm_rows([4, 9], np.ones((2, 3), np.float32))
    assert set(view) == {4, 9}
    assert len(view) == 2 and 4 in view and 5 not in view
    np.testing.assert_array_equal(view[9], np.ones(3))
    assert view.get(5) is None
    with pytest.raises(KeyError):
        view[5]


# --- ClientStateStore: probe-stat cache ------------------------------------

def test_stats_scatter_gather_and_generation_clear():
    store = ClientStateStore(100, 4)
    cohort = np.array([5, 6, 7])
    assert not store.stats_valid(cohort).any()
    np.testing.assert_array_equal(store.missing_stats(cohort), cohort)

    stats = {"grad_sq_norms": np.arange(12, dtype=np.float32).reshape(3, 4)}
    store.set_stat_rows(cohort, stats)
    assert store.stats_valid(cohort).all()
    assert len(store.missing_stats(cohort)) == 0
    got = store.stat_rows([7, 5])
    np.testing.assert_array_equal(got["grad_sq_norms"],
                                  stats["grad_sq_norms"][[2, 0]])

    store.clear_stats()                        # O(1) generation bump
    assert not store.stats_valid(cohort).any()
    with pytest.raises(KeyError, match="no cached stats"):
        store.stat_rows(cohort)

    # re-scatter a subset in the new generation; the rest stay invalid
    store.set_stat_rows([6], {"grad_sq_norms": np.ones((1, 4), np.float32)})
    np.testing.assert_array_equal(store.stats_valid(cohort),
                                  [False, True, False])
    np.testing.assert_array_equal(store.missing_stats(cohort), [5, 7])


def test_stats_key_intersection_within_generation():
    """Mirrors ProbeReport.from_rows: only keys every scatter carried."""
    store = ClientStateStore(10, 2)
    store.set_stat_rows([0], {"grad_sq_norms": np.ones((1, 2), np.float32),
                              "scores": np.ones((1, 2), np.float32)})
    store.set_stat_rows([1], {"grad_sq_norms": np.zeros((1, 2), np.float32)})
    assert set(store.stat_rows([0, 1])) == {"grad_sq_norms"}


def test_missing_stats_preserves_cohort_dtype():
    store = ClientStateStore(10, 2)
    cohort = np.array([1, 2], np.int32)
    assert store.missing_stats(cohort).dtype == np.int32


# --- ClientStateStore: checkpoint roundtrip --------------------------------

def test_store_state_dict_roundtrip():
    store = ClientStateStore(64, 3)
    store.set_warm_rows([2, 8], np.ones((2, 3), np.float32), t=4)
    store.set_stat_rows([2, 8, 9],
                        {"grad_sq_norms":
                         np.arange(9, dtype=np.float32).reshape(3, 3)})
    store.clear_stats()
    store.set_stat_rows([9], {"grad_sq_norms": np.ones((1, 3), np.float32)})

    twin = ClientStateStore(64, 3)
    twin.load_state_dict(store.state_dict())
    np.testing.assert_array_equal(twin.warm_rows([2, 8, 9])[0],
                                  store.warm_rows([2, 8, 9])[0])
    np.testing.assert_array_equal(twin.stats_valid(np.arange(64)),
                                  store.stats_valid(np.arange(64)))
    np.testing.assert_array_equal(twin.stat_rows([9])["grad_sq_norms"],
                                  store.stat_rows([9])["grad_sq_norms"])
    np.testing.assert_array_equal(twin.last_seen, store.last_seen)
    assert twin.has_warm and len(twin.warm_masks) == 2


def test_store_load_rejects_population_mismatch():
    store = ClientStateStore(10, 3)
    with pytest.raises(ValueError, match="population or layer count"):
        ClientStateStore(20, 3).load_state_dict(store.state_dict())


# --- ClientStreamState -----------------------------------------------------

def test_streams_lazy_and_bit_identical_to_eager():
    seed_fn = lambda i: 1000 + 7 * i
    streams = ClientStreamState(1000, seed_fn)
    assert len(streams.touched()) == 0
    draws = streams.rng(42).randn(16)          # ...until first touch
    np.testing.assert_array_equal(streams.touched(), [42])
    np.testing.assert_array_equal(
        draws, np.random.RandomState(seed_fn(42)).randn(16))
    # indexing back-compat (data._rngs[i] pokes in older tests)
    assert streams[42] is streams.rng(42)


def test_streams_positions_advance():
    streams = ClientStreamState(10, lambda i: i)
    streams.advance(3, 8)
    streams.advance(3, 8)
    assert streams.positions[3] == 16 and streams.positions.sum() == 16


def test_streams_state_roundtrip_mid_stream():
    seed_fn = lambda i: 31 * i + 5
    a = ClientStreamState(100, seed_fn)
    for i in (4, 7):
        a.rng(i).randn(10)
        a.advance(i, 10)
    snap = a.state_dict()
    ahead = {i: a.rng(i).randn(6) for i in (4, 7, 11)}   # 11: fresh stream

    b = ClientStreamState(100, seed_fn)
    b.load_state_dict(snap)
    np.testing.assert_array_equal(b.positions, snap["positions"])
    for i in (4, 7, 11):                       # touched restored, lazy fresh
        np.testing.assert_array_equal(b.rng(i).randn(6), ahead[i])


def test_streams_state_dict_is_o_touched():
    streams = ClientStreamState(10**6, lambda i: i)   # eager would be ~2.5GB
    streams.rng(123456).randn(1)
    d = streams.state_dict()
    assert d["keys"].shape == (1, 624)
    assert d["positions"].shape == (10**6,)


def test_streams_load_rejects_population_mismatch():
    a = ClientStreamState(10, lambda i: i)
    with pytest.raises(ValueError, match="population size changed"):
        ClientStreamState(11, lambda i: i).load_state_dict(a.state_dict())


# --- population scale ------------------------------------------------------

def test_population_scale_ops_touch_only_cohort():
    """10⁵-client store: per-round ops are pure O(cohort) gather/scatter
    (the micro-benchmark gates the wall-clock half of this claim)."""
    n = 100_000
    store = ClientStateStore(n, 8)
    cohort = np.array([17, 4_242, 73_291, 99_999])
    store.set_stat_rows(cohort, {"grad_sq_norms":
                                 np.ones((4, 8), np.float32)})
    store.set_warm_rows(cohort, np.ones((4, 8), np.float32), t=0)
    assert store.stats_valid(cohort).all()
    assert int(store._stats_stamp.sum()) == 4          # only cohort stamped
    store.clear_stats()                                # no O(n) sweep
    assert not store.stats_valid(cohort).any()
    rows, valid = store.warm_rows(cohort)
    assert valid.all() and rows.shape == (4, 8)
