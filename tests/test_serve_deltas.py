"""Personalized-delta serving (DESIGN.md §9): parity of the batched
delta/dense paths against private-params-alone decoding, overlay capacity
bookkeeping, checkpoint delta extraction, and the one-program pin."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RuntimeConfig, get_arch, reduced
from repro.core.client import clear_jit_cache, jit_cache_stats
from repro.launch.serve import Request, SlotServer, demo_store
from repro.models.model import Model
from repro.serve import DeltaOverlay, DeltaStore, delta_from_params


def _world(n_layers=3, d_model=64):
    cfg = reduced(get_arch("tinyllama_1_1b"), n_layers=n_layers,
                  d_model=d_model)
    model = Model(cfg, RuntimeConfig(remat=False, seq_chunk=16))
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _decode_alone(model, params, prompt, max_new, max_seq):
    """The oracle: one request, scalar-position cache, no batching."""
    cache = model.init_cache(1, max_seq)
    out = []
    for t in range(len(prompt) + max_new - 1):
        cur = prompt[t] if t < len(prompt) else out[-1]
        logits, cache = model.decode_step(params, jnp.asarray([cur]),
                                          jnp.int32(t), cache)
        if t >= len(prompt) - 1:
            out.append(int(jnp.argmax(logits[0])))
    return out


def _requests(cfg, n, plen=4, max_new=5, users=0, seed=1):
    rng = np.random.RandomState(seed)
    return [Request(i, rng.randint(0, cfg.vocab_size, plen).tolist(), max_new,
                    user_id=(i % users if users else -1)) for i in range(n)]


def test_shared_staggered_matches_alone():
    """7 requests through 3 slots admit at staggered positions; every
    generation equals decoding that request alone (per-slot positions)."""
    model, params = _world()
    reqs = _requests(model.cfg, 7)
    prompts = {r.rid: list(r.prompt) for r in reqs}
    server = SlotServer(model, params, slots=3, max_seq=16)
    done, stats = server.run(reqs)
    assert len(done) == 7 and stats["gen_tokens"] == 35
    for r in done:
        assert r.generated == _decode_alone(model, params, prompts[r.rid],
                                            r.max_new, 16), r.rid


def test_delta_staggered_matches_private_alone():
    """The batched delta path == decoding each request alone against the
    user's materialised private params — with different users' deltas
    resident in the same batch."""
    model, params = _world()
    store = demo_store(model, params, users=3, layers_per_user=2, seed=0)
    reqs = _requests(model.cfg, 7, users=3)
    prompts = {r.rid: (list(r.prompt), r.user_id) for r in reqs}
    server = SlotServer(model, params, slots=3, max_seq=16, mode="delta",
                        store=store)
    done, _ = server.run(reqs)
    assert len(done) == 7
    for r in done:
        prompt, uid = prompts[r.rid]
        private = store.materialize(params, uid)
        assert r.generated == _decode_alone(model, private, prompt,
                                            r.max_new, 16), r.rid


def test_dense_staggered_matches_private_alone():
    """The vmapped per-slot-params baseline hits the same oracle."""
    model, params = _world()
    store = demo_store(model, params, users=3, layers_per_user=1, seed=2)
    reqs = _requests(model.cfg, 5, users=3)
    prompts = {r.rid: (list(r.prompt), r.user_id) for r in reqs}
    server = SlotServer(model, params, slots=2, max_seq=16, mode="dense",
                        store=store)
    done, _ = server.run(reqs)
    assert len(done) == 5
    for r in done:
        prompt, uid = prompts[r.rid]
        private = store.materialize(params, uid)
        assert r.generated == _decode_alone(model, private, prompt,
                                            r.max_new, 16), r.rid


def test_one_program_serves_mixed_deltas():
    """The whole mixed-user run compiles exactly one delta-decode program:
    the overlay is data, not program structure (acceptance pin)."""
    clear_jit_cache()
    model, params = _world()
    store = demo_store(model, params, users=4, layers_per_user=2, seed=0)
    server = SlotServer(model, params, slots=3, max_seq=16, mode="delta",
                        store=store)
    done, _ = server.run(_requests(model.cfg, 8, users=4))
    assert len(done) == 8
    programs = jit_cache_stats()["programs"]
    assert programs["serve_decode_delta"] == 1
    assert programs["serve_reset_slot"] == 1
    clear_jit_cache()


def test_overlay_capacity_admit_release():
    model, params = _world()
    tuned = dict(params)
    tuned["blocks"] = {k: np.asarray(v, np.float32) + 0.01
                       for k, v in params["blocks"].items()}
    rec = delta_from_params(params, tuned, model.cfg, layers=[0, 1])
    ov = DeltaOverlay(model, capacity=1)
    assert ov.try_admit(0, rec)
    assert ov.n_entries == 2
    assert not ov.try_admit(1, rec)          # layer capacity exhausted
    assert ov.n_entries == 2                 # failed admit wrote nothing
    ov.release(0)
    assert ov.try_admit(1, rec)
    dev = ov.device()
    assert np.asarray(dev["slots"]).max() == 1


def test_delta_record_autodetect_and_materialize():
    """layers=None detects exactly the perturbed rows; store.materialize
    reproduces the tuned tree on those rows and leaves the rest alone."""
    model, params = _world()
    cfg = model.cfg
    tuned = dict(params)
    tuned["blocks"] = {
        k: np.asarray(v, np.float32)
        + 0.05 * (np.arange(v.shape[0]) == 1).reshape(
            (-1,) + (1,) * (np.ndim(v) - 1))
        for k, v in params["blocks"].items()}
    rec = delta_from_params(params, tuned, cfg)
    assert rec.layers.tolist() == [1]
    store = DeltaStore(cfg)
    store.put(7, rec)
    mat = store.materialize(params, 7)
    for k in params["blocks"]:
        np.testing.assert_allclose(np.asarray(mat["blocks"][k], np.float32),
                                   tuned["blocks"][k], atol=1e-6)
    # unknown user falls back to base params untouched
    assert store.materialize(params, 99) is params


def test_extract_delta_from_round_checkpoint(tmp_path):
    """FL round checkpoint (wrapped ``params/`` tree) → DeltaRecord."""
    from repro.ckpt import extract_delta, save_checkpoint
    model, params = _world()
    tuned = jax.tree.map(lambda x: x, params)
    tuned["blocks"] = {
        k: jnp.asarray(np.asarray(v, np.float32)
                       + 0.05 * (np.arange(v.shape[0]) == 2).reshape(
                           (-1,) + (1,) * (np.ndim(v) - 1))).astype(v.dtype)
        for k, v in params["blocks"].items()}
    save_checkpoint(str(tmp_path), 3, {"params": tuned, "round": 3})
    rec = extract_delta(str(tmp_path), params, model.cfg)
    assert rec.layers.tolist() == [2]
    rows, leaves = rec.segments["blocks"]
    assert rows.tolist() == [2]
    got = np.asarray(params["blocks"]["attn_wq"], np.float32)[2] \
        + leaves["attn_wq"][0]
    np.testing.assert_allclose(
        got, np.asarray(tuned["blocks"]["attn_wq"], np.float32)[2],
        atol=1e-6)
