"""Unit + property tests for masking vectors and Eq.(7) aggregation weights."""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # tier-1 must run without optional deps
    from _hypothesis_compat import given, settings, st

from repro.core.masks import (aggregation_weights, chi_divergence,
                              mask_from_indices, indices_from_mask, union_mask)


def test_mask_roundtrip():
    m = mask_from_indices([0, 3], 5)
    assert m.tolist() == [1, 0, 0, 1, 0]
    assert indices_from_mask(m) == (0, 3)


def test_union():
    mm = np.array([[1, 0, 0], [0, 0, 1]], np.float32)
    assert union_mask(mm).tolist() == [1, 0, 1]


def test_eq7_weights_exact():
    """Hand-computed Eq. (7) example."""
    masks = np.array([[1, 1, 0], [1, 0, 0]], np.float32)
    sizes = np.array([10.0, 30.0])
    W = np.asarray(aggregation_weights(masks, sizes))
    np.testing.assert_allclose(W[:, 0], [0.25, 0.75])   # both selected l=0
    np.testing.assert_allclose(W[:, 1], [1.0, 0.0])     # only client 0
    np.testing.assert_allclose(W[:, 2], [0.0, 0.0])     # nobody


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 8), st.integers(1, 6), st.integers(0, 2 ** 30))
def test_weights_columns_normalised(n, L, seed):
    """Property: for every selected layer, weights over cohort sum to 1;
    unselected layers sum to 0; weights are zero where mask is zero."""
    rng = np.random.RandomState(seed % (2 ** 31 - 1))
    masks = (rng.rand(n, L) > 0.4).astype(np.float32)
    sizes = rng.randint(1, 100, n).astype(np.float32)
    W = np.asarray(aggregation_weights(masks, sizes))
    col = W.sum(0)
    sel = union_mask(masks)
    np.testing.assert_allclose(col, sel, atol=1e-5)
    assert np.all(W[masks == 0] == 0)
    assert np.all(W >= 0)


def test_chi_divergence_zero_when_weights_match_alpha():
    alpha = np.array([0.2, 0.3, 0.5], np.float32)
    W = np.tile(alpha[:, None], (1, 4))
    chi = np.asarray(chi_divergence(jnp.asarray(W), jnp.asarray(alpha)))
    np.testing.assert_allclose(chi, 0.0, atol=1e-6)


def test_chi_divergence_grows_with_partial_cohort():
    """Leaving clients out increases χ (the paper's E_t2 driver)."""
    alpha = np.full(4, 0.25, np.float32)
    # full participation, equal sizes
    W_full = np.full((4, 1), 0.25, np.float32)
    # only two clients selected the layer
    W_half = np.array([[0.5], [0.5], [0.0], [0.0]], np.float32)
    chi_f = float(chi_divergence(jnp.asarray(W_full), jnp.asarray(alpha))[0])
    chi_h = float(chi_divergence(jnp.asarray(W_half), jnp.asarray(alpha))[0])
    assert chi_f < 1e-6 < chi_h
