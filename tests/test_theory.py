"""Validation of the §4.1 theory quantities (Lemma 4.6 / Theorem 4.7)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import RuntimeConfig, get_arch, reduced
from repro.core import theory
from repro.core.masks import union_mask
from repro.models.model import Model


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_arch("xlm_roberta_base"), n_layers=4, d_model=64)
    model = Model(cfg, RuntimeConfig(remat=False, seq_chunk=16))
    params = model.init(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    batches = []
    for i in range(4):
        ks = jax.random.split(jax.random.fold_in(key, i), 2)
        batches.append({
            "tokens": jax.random.randint(ks[0], (8, 16), 0, cfg.vocab_size),
            "label": jax.random.randint(ks[1], (8,), 0, cfg.n_classes)})
    alpha = np.array([0.1, 0.2, 0.3, 0.4])
    gg = theory.global_gradient(model, params, batches, alpha)
    cg = theory.per_client_gradients(model, params, batches)
    return model, params, batches, alpha, gg, cg


def test_e_t1_zero_when_all_selected(setup):
    model, *_, gg, _ = setup[0], *setup[1:5], setup[5]
    model, params, batches, alpha, gg, cg = setup
    assert theory.e_t1(model, gg, np.ones(4, np.float32)) == 0.0


def test_e_t1_monotone_in_selection(setup):
    model, params, batches, alpha, gg, cg = setup
    full = theory.e_t1(model, gg, np.zeros(4, np.float32))
    partial = theory.e_t1(model, gg, np.array([1, 0, 0, 0], np.float32))
    assert full >= partial >= 0.0


def test_e_t2_zero_for_full_cohort_uniform(setup):
    """All clients, all layers, weights == alpha ⇒ χ = 0 ⇒ E_t2 = 0."""
    model, params, batches, alpha, gg, cg = setup
    kappa = theory.kappa_per_layer(model, gg, cg)
    masks = np.ones((4, 4), np.float32)
    sizes = alpha * 100
    val = theory.e_t2(masks, sizes, kappa)
    assert val < 1e-6


def test_e_t2_positive_for_partial_cohort(setup):
    model, params, batches, alpha, gg, cg = setup
    kappa = theory.kappa_per_layer(model, gg, cg)
    masks = np.array([[1, 1, 0, 0], [1, 0, 1, 0]], np.float32)
    sizes = np.array([10.0, 20.0])
    val = theory.e_t2(masks, sizes, kappa,
                      population_alpha=alpha, cohort_idx=np.array([0, 1]))
    assert val > 0.0


def test_kappa_nonnegative_and_bounding(setup):
    """κ_l upper-bounds each client's layer-gradient deviation."""
    model, params, batches, alpha, gg, cg = setup
    from repro.core.masks import per_layer_sq_norms
    kappa = theory.kappa_per_layer(model, gg, cg)
    assert np.all(kappa >= 0)
    for g_i in cg:
        diff = jax.tree.map(lambda a, b: a - b.astype(jnp.float32), gg, g_i)
        sq = np.asarray(per_layer_sq_norms(diff, model.cfg))
        assert np.all(np.sqrt(sq) <= kappa + 1e-5)


def test_theorem_rhs_structure():
    """Error floor: grows with E-terms, decays with T in the other terms."""
    base = dict(f0=2.0, f_star=0.5, eta=0.01, gamma=1.0, sigma_sq=0.1)
    r_small = theory.theorem_4_7_rhs(**base, T=100, e1_sum=0.0, e2_sum=0.0)
    r_big_e = theory.theorem_4_7_rhs(**base, T=100, e1_sum=50.0, e2_sum=50.0)
    assert r_big_e > r_small
    r_long = theory.theorem_4_7_rhs(**base, T=10000, e1_sum=0.0, e2_sum=0.0)
    assert r_long < r_small


def test_error_floor_tracks_selection_quality(setup):
    """The paper's core claim: selecting high-gradient layers (ours) gives a
    smaller E_t1+E_t2 than selecting low-gradient layers."""
    model, params, batches, alpha, gg, cg = setup
    from repro.core.masks import per_layer_sq_norms
    sq = np.asarray(per_layer_sq_norms(gg, model.cfg))
    best, worst = np.argmax(sq), np.argmin(sq)
    kappa = theory.kappa_per_layer(model, gg, cg)
    sizes = alpha * 100

    def floor(layer):
        masks = np.zeros((4, 4), np.float32)
        masks[:, layer] = 1
        return (theory.e_t1(model, gg, union_mask(masks))
                + theory.e_t2(masks, sizes, kappa))

    assert floor(best) < floor(worst)
